"""Docs gate: markdown link check + README quickstart smoke test.

Stdlib-only (CI runs it before any heavyweight install). Three checks:

1. every relative link target in the repo's ``*.md`` files (root and
   ``docs/``) must exist on disk, and in-page ``#anchor`` fragments
   must match a heading in the target file (GitHub slug rules);
2. the first ``python`` code fence in README.md — the quickstart — is
   executed; it must run to completion without raising;
3. the audit rule-ID tables in DESIGN.md (S14) and docs/analysis.md
   must stay in sync with the registry in ``repro.analysis.rules``
   (every registered ID documented in both; no stale IDs documented).

External ``http(s)://`` links are not fetched (no network flakiness in
CI); they are only checked for obvious malformation (empty target).

Usage::

    PYTHONPATH=src python tools/docs_check.py [--no-quickstart]
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# [text](target) — ignores images' leading "!" (same target rules)
_LINK = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug for a heading line."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _md_files() -> list[Path]:
    return sorted(ROOT.glob("*.md")) + sorted((ROOT / "docs").glob("*.md"))


def _anchors(path: Path) -> set[str]:
    return {_slug(h) for h in _HEADING.findall(path.read_text())}


def check_links() -> list[str]:
    """Return a list of broken-link descriptions (empty = clean)."""
    errors = []
    for md in _md_files():
        for target in _LINK.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, frag = target.partition("#")
            dest = (md.parent / path_part).resolve() if path_part else md
            if not dest.exists():
                errors.append(f"{md.relative_to(ROOT)}: missing {target}")
            elif frag and dest.suffix == ".md" and _slug(frag) not in _anchors(dest):
                errors.append(f"{md.relative_to(ROOT)}: no anchor #{frag} in {dest.name}")
    return errors


def check_rule_tables() -> list[str]:
    """DESIGN.md S14 + docs/analysis.md vs `repro.analysis.rules`.

    The rules module is stdlib-only (no jax), so this import is safe in
    the docs job's bare environment.
    """
    sys.path.insert(0, str(ROOT / "src"))
    from repro.analysis.rules import RULES

    rule_like = re.compile(r"\b(?:JAX|LINT|VMEM)-[A-Z][A-Z-]+\b")
    errors = []
    for rel in ("DESIGN.md", "docs/analysis.md"):
        text = (ROOT / rel).read_text()
        mentioned = set(rule_like.findall(text))
        for rid in RULES:
            if rid not in mentioned:
                errors.append(f"{rel}: rule {rid} missing from the "
                              f"rule-ID table")
        for rid in sorted(mentioned - set(RULES)):
            errors.append(f"{rel}: documents unknown rule {rid} "
                          f"(stale? registry is repro.analysis.rules)")
    return errors


def run_quickstart() -> None:
    """Extract README's first python fence and exec it (raises on failure)."""
    readme = (ROOT / "README.md").read_text()
    fences = _FENCE.findall(readme)
    if not fences:
        raise SystemExit("README.md has no ```python fence to smoke-test")
    code = fences[0]
    print("-- README quickstart --")
    print(code)
    exec(compile(code, "README.md:quickstart", "exec"), {"__name__": "__main__"})


def main(argv: list[str] | None = None) -> int:
    """CLI entry point; returns a process exit status."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--no-quickstart", action="store_true",
                    help="only check links (skip executing the README snippet)")
    args = ap.parse_args(argv)

    errors = check_links()
    for e in errors:
        print(f"BROKEN LINK: {e}", file=sys.stderr)
    print(f"link check: {len(_md_files())} files, "
          f"{len(errors)} broken link(s)")
    rule_errors = check_rule_tables()
    for e in rule_errors:
        print(f"RULE TABLE: {e}", file=sys.stderr)
    print(f"rule-table check: {len(rule_errors)} mismatch(es)")
    if errors or rule_errors:
        return 1
    if not args.no_quickstart:
        run_quickstart()
        print("quickstart: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
