"""Determinism & VMEM invariant auditor CLI (DESIGN.md S14).

Runs the three static-analysis layers over the live tree and exits
nonzero on any finding:

* ``jaxpr``  — abstract-trace the registry workload x solver route
  matrix through the real epoch builders and walk the jaxprs for
  determinism-contract bugs (psum exchanges, shard_map loop-closure
  hazards, unordered reductions);
* ``lint``   — stdlib AST rules (kernel contract registry, collective
  allowlist markers, unseeded RNG, CSR entry altitudes);
* ``budget`` — sweep planner candidate geometries against the kernels'
  own VMEM estimators.

``--selftest`` additionally runs the mutation self-tests (one injected
bug per rule ID; each must be detected).  Usage::

    PYTHONPATH=src python tools/audit.py [--report AUDIT.json]
        [--selftest] [--layers jaxpr,lint,budget] [--workloads a,b]

No accelerator needed: traces run on forced host devices with Pallas
interpret mode (XLA_FLAGS is set below, BEFORE jax loads).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

# must precede any (transitive) jax import: the audit matrix needs 8
# host devices for its 1x2x2 meshes
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def main(argv: list[str] | None = None) -> int:
    """CLI entry point; returns a process exit status."""
    from repro.analysis import runner

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--report", metavar="PATH",
                    help="write the JSON report here")
    ap.add_argument("--selftest", action="store_true",
                    help="also run the mutation self-tests")
    ap.add_argument("--layers", default=",".join(runner.LAYERS),
                    help="comma-separated subset of "
                         f"{','.join(runner.LAYERS)}")
    ap.add_argument("--workloads", default="",
                    help="comma-separated registry names (default: all)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-case progress lines")
    args = ap.parse_args(argv)

    log = (lambda s: None) if args.quiet else print
    layers = [s for s in args.layers.split(",") if s]
    workloads = [s for s in args.workloads.split(",") if s] or None

    report = runner.run_audit(layers=layers, workloads=workloads,
                              log=log)
    for f in report.findings:
        print(f"FINDING: {f}", file=sys.stderr)

    failures: list[str] = []
    if args.selftest:
        from repro.analysis import selftest
        log("[selftest] mutation checks, one per rule ID")
        failures = selftest.run_selftests(log=log)
        for msg in failures:
            print(f"SELFTEST FAILURE: {msg}", file=sys.stderr)

    if args.report:
        doc = report.to_json()
        if args.selftest:
            doc["selftest_failures"] = failures
        Path(args.report).write_text(json.dumps(doc, indent=2) + "\n")
        log(f"report written to {args.report}")

    print(f"audit: {len(report.cases)} traced case(s), "
          f"{report.plans_swept} plan(s) swept, "
          f"{len(report.findings)} finding(s)"
          + (f", {len(failures)} selftest failure(s)"
             if args.selftest else ""))
    return 1 if (report.findings or failures) else 0


if __name__ == "__main__":
    raise SystemExit(main())
