"""Launch-layer units: HLO collective parsing, roofline math, serve
driver, sharding context, GLM analytic model."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (Roofline, collective_bytes,
                                       _shape_bytes)
from repro.launch import glm as glm_launch
from repro.launch.mesh import abstract_mesh


def test_shape_bytes_parsing():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[4,4], s8[16])") == 64 + 16
    assert _shape_bytes("pred[]") == 1          # scalar => empty dims


def test_collective_bytes_parsing():
    hlo = """
  %ag = f32[64,128]{1,0} all-gather(%x), replica_groups={}
  %ar = (bf16[32]{0}, bf16[32]{0}) all-reduce-start(%a, %b)
  %done = (bf16[32]{0}, bf16[32]{0}) all-reduce-done(%ar)
  %cp = s8[1024]{0} collective-permute(%y), source_target_pairs={{0,1}}
  %a2a = f32[16,16]{1,0} all-to-all(%z), dimensions={0}
  %not = f32[9]{0} add(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 64 * 128 * 4
    assert out["all-reduce"] == 2 * 32 * 2      # -start counted, -done not
    assert out["collective-permute"] == 1024
    assert out["all-to-all"] == 16 * 16 * 4
    assert out["count"] == 4


def test_roofline_terms_and_bottleneck():
    rl = Roofline(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=50e9,
                  peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(2.0)
    assert rl.t_collective == pytest.approx(1.0)
    assert rl.bottleneck == "memory"
    assert rl.step_time == pytest.approx(2.0)


def test_glm_analytic_reflects_knobs():
    mesh = abstract_mesh((16, 16), ("data", "model"))
    base = glm_launch.GLM_CONFIGS["glm-criteo"]
    opt = glm_launch.GLM_CONFIGS["glm-criteo-opt"]
    a_base = glm_launch.glm_analytic(base, mesh)
    a_opt = glm_launch.glm_analytic(opt, mesh)
    assert a_opt["coll"] < 0.5 * a_base["coll"]
    assert a_opt["flops"] == a_base["flops"]


def test_glm_worker_counts():
    mesh3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    # sparse / narrow-dense use every chip; feature-sharded uses pod*data
    assert glm_launch._worker_count(
        mesh3, glm_launch.GLM_CONFIGS["glm-criteo"]) == 512
    assert glm_launch._worker_count(
        mesh3, glm_launch.GLM_CONFIGS["glm-epsilon"]) == 32


def test_sharding_context_noop_without_mesh():
    from repro import sharding
    sharding.set_mesh(None)
    x = jnp.ones((4, 4))
    out = sharding.constrain(x, "data", None)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_serve_driver_end_to_end():
    from repro.configs import get_smoke
    from repro.launch.serve import serve
    toks = serve(get_smoke("smollm-360m"), batch=2, prompt_len=8, gen=4,
                 verbose=False)
    assert toks.shape == (2, 4)
    assert bool((toks >= 0).all())


def test_flash_analytic_causal_half():
    from repro.launch.variants import flash_analytic
    from repro.configs import get_config
    from repro.launch.specs import SHAPES
    cfg = get_config("granite-20b")
    fa = flash_analytic(cfg, SHAPES["train_4k"], chips=256)
    # causal: ~half of full S^2 score+pv work, x3.5 train passes
    S, B, H, hd = 4096, 256, cfg.n_heads, cfg.head_dim
    full = 2 * B * S * S * H * (hd + hd) * cfg.n_layers * 3.5 / 256
    assert 0.4 * full < fa["flops"] < 0.6 * full
