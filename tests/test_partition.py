"""Partition schedules: coverage, (in)variance across epochs, jit-safety."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bucketing import choose_bucket_size, make_plan
from repro.core.partition import PartitionPlan


def _sched(plan, e):
    return np.asarray(plan.schedule(jnp.int32(e)))


@pytest.mark.parametrize("mode", ["static", "dynamic", "hierarchical",
                                  "rotation"])
def test_every_epoch_covers_all_buckets_once(mode):
    plan = PartitionPlan(n_buckets=96, pods=2, lanes=4, mode=mode)
    for e in range(4):
        s = _sched(plan, e)
        assert s.shape == (2, 4, 12)
        assert sorted(s.reshape(-1).tolist()) == list(range(96))


def test_static_is_epoch_invariant():
    plan = PartitionPlan(n_buckets=64, pods=2, lanes=4, mode="static")
    assert np.array_equal(_sched(plan, 0), _sched(plan, 5))


@pytest.mark.parametrize("mode", ["dynamic", "hierarchical", "rotation"])
def test_nonstatic_changes_across_epochs(mode):
    plan = PartitionPlan(n_buckets=64, pods=2, lanes=4, mode=mode)
    assert not np.array_equal(_sched(plan, 0), _sched(plan, 1))


@pytest.mark.parametrize("mode", ["hierarchical", "rotation"])
def test_pod_assignment_is_static(mode):
    """Buckets never cross pods (paper's NUMA rule): pod p owns the
    contiguous range [p*per_pod, (p+1)*per_pod)."""
    plan = PartitionPlan(n_buckets=64, pods=4, lanes=2, mode=mode)
    per_pod = 64 // 4
    for e in range(3):
        s = _sched(plan, e)
        for p in range(4):
            ids = s[p].reshape(-1)
            assert ids.min() >= p * per_pod
            assert ids.max() < (p + 1) * per_pod


def test_rotation_rotates_lane_blocks():
    """At epoch e, lane k holds (a shuffle of) lane (k+e)%K's static
    block."""
    plan = PartitionPlan(n_buckets=64, pods=1, lanes=4, mode="rotation")
    per_lane = 16
    for e in range(5):
        s = _sched(plan, e)[0]
        for k in range(4):
            src = (k + e) % 4
            expect = set(range(src * per_lane, (src + 1) * per_lane))
            assert set(s[k].tolist()) == expect


def test_schedule_is_jittable():
    plan = PartitionPlan(n_buckets=32, pods=2, lanes=2, mode="dynamic")
    f = jax.jit(lambda e: plan.schedule(e))
    s = np.asarray(f(jnp.int32(3)))
    assert sorted(s.reshape(-1).tolist()) == list(range(32))


def test_seed_determinism():
    p1 = PartitionPlan(n_buckets=32, pods=1, lanes=4, mode="dynamic",
                       seed=7)
    p2 = PartitionPlan(n_buckets=32, pods=1, lanes=4, mode="dynamic",
                       seed=7)
    assert np.array_equal(_sched(p1, 2), _sched(p2, 2))


def test_divisibility_error():
    with pytest.raises(ValueError):
        PartitionPlan(n_buckets=10, pods=3, lanes=2)


# -- bucketing heuristic -----------------------------------------------------

def test_bucket_heuristic_llc_cutoff():
    assert choose_bucket_size(100_000, 100) == 1          # fits 'LLC'
    assert choose_bucket_size(1_000_000, 100) == 64       # big n, small d
    assert choose_bucket_size(1_000_000, 100, force=16) == 16
    assert choose_bucket_size(1_000_000, 100, force=1) == 1


def test_bucket_vmem_budget_shrinks_bucket():
    # huge d: only small buckets fit the VMEM tile budget
    assert choose_bucket_size(1_000_000, 100_000) == 8


def test_make_plan_divisibility():
    with pytest.raises(ValueError):
        make_plan(1_000_001, 100)   # not divisible by chosen bucket
    plan = make_plan(1_048_576, 100)
    assert plan.n_buckets * plan.bucket == plan.n
