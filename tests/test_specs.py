"""Shape/sharding metadata: input_specs, applicability, spec divisibility.

Uses AbstractMesh so the production 256/512-chip shardings are checked
without device allocation (smoke processes only have 1 CPU device).
"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.launch import steps as steps_lib
from repro.launch.mesh import abstract_mesh
from repro.launch.specs import SHAPES, applicable, cache_pspec, input_specs
from repro.models.layers import ParamSpec

POD = abstract_mesh((16, 16), ("data", "model"))
MULTIPOD = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _check_divisible(sds, mesh):
    """Every sharded dim must divide by the product of its mesh axes."""
    spec = sds.sharding.spec
    for dim, entry in zip(sds.shape, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        div = int(np.prod([mesh.shape[a] for a in axes]))
        assert dim % div == 0, (sds.shape, spec)


@pytest.mark.parametrize("mesh", [POD, MULTIPOD], ids=["pod", "multipod"])
@pytest.mark.parametrize("shape_name", list(SHAPES))
@pytest.mark.parametrize("arch", list_archs())
def test_input_specs_shardings_divide(arch, shape_name, mesh):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        assert "sub-quadratic" in why or "full-attention" in why
        return
    specs = input_specs(cfg, shape, mesh)
    for sds in jax.tree.leaves(specs):
        if hasattr(sds, "sharding") and sds.sharding is not None:
            _check_divisible(sds, mesh)


@pytest.mark.parametrize("mesh", [POD, MULTIPOD], ids=["pod", "multipod"])
@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_shardings_divide(arch, mesh):
    cfg = get_config(arch)
    specs = steps_lib.model_param_specs(cfg, mesh)

    def check(s: ParamSpec):
        entries = list(s.pspec) + [None] * (len(s.shape) - len(s.pspec))
        for dim, entry in zip(s.shape, entries):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            div = int(np.prod([mesh.shape[a] for a in axes
                               if a in mesh.shape]))
            assert dim % div == 0, (s.shape, s.pspec)

    jax.tree.map(check, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def test_long_500k_skips_full_attention():
    skipped = [a for a in list_archs()
               if not applicable(get_config(a), SHAPES["long_500k"])[0]]
    ran = [a for a in list_archs()
           if applicable(get_config(a), SHAPES["long_500k"])[0]]
    assert sorted(ran) == ["recurrentgemma-2b", "xlstm-1.3b"]
    assert len(skipped) == 8


def test_decode_cells_have_cache_and_pos():
    cfg = get_config("smollm-360m")
    s = input_specs(cfg, SHAPES["decode_32k"], POD)
    assert s["tokens"].shape == (128, 1)
    assert s["pos"].shape == ()
    kv = jax.tree.leaves(s["cache"])
    # every KV leaf carries the 32k context dim (stacked leaves have a
    # leading layer dim, so just require membership)
    assert kv and all(32_768 in x.shape for x in kv)


def test_cache_pspec_rules():
    # (B, S, Hkv, hd): shard heads when divisible, else head_dim
    assert cache_pspec((128, 32768, 16, 128), 16, 32) == \
        P(("pod", "data"), None, "model", None)
    assert cache_pspec((128, 32768, 8, 64), 16, 32) == \
        P(("pod", "data"), None, None, "model")
    # never shard the sequence dim of (B, S, feat) when feat divides
    assert cache_pspec((128, 32768, 512), 16, 32) == \
        P(("pod", "data"), None, "model")
    # (B, feat) 2-d caches shard feat
    assert cache_pspec((1, 2560), 16, 32) == P(None, "model")
    # batch=1 never sharded
    assert cache_pspec((1, 2048, 4, 512), 16, 32)[0] is None


def test_vision_train_spec_reserves_patch_positions():
    cfg = get_config("phi-3-vision-4.2b")
    s = input_specs(cfg, SHAPES["train_4k"], POD)
    assert s["tokens"].shape[1] + cfg.n_patches == 4096
    assert s["patches"].shape == (256, cfg.n_patches, cfg.d_model)


def test_audio_train_spec_has_frames():
    cfg = get_config("whisper-base")
    s = input_specs(cfg, SHAPES["train_4k"], POD)
    assert s["frames"].shape == (256, cfg.enc_seq, cfg.d_model)


def test_fsdp_transform_only_big_params():
    cfg = get_config("granite-20b")
    specs = steps_lib.model_param_specs(cfg, MULTIPOD)
    flat = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    big = [s for s in flat if int(np.prod(s.shape)) >= (1 << 22)]
    small = [s for s in flat if int(np.prod(s.shape)) < (1 << 22)]
    assert any("data" in str(s.pspec) for s in big)
    assert all("data" not in str(s.pspec) for s in small)
