"""Mesh input pipeline (DESIGN.md S16): streamed-from-host training on
a shard_map mesh is bitwise-identical to resident mesh training — and
to the sim streamed loop driven by the same `MeshSchedule` — under
`deterministic=True`, for dense and sparse, replicated and
feature-sharded (slice-compacted) routes.

The multi-device tests shell out with 8 forced host devices (repo
convention: only launch entrypoints force device counts); the
compaction unit tests run in-process.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import engine
from repro.data.cache import compact_slice_rows

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(code: str, timeout=600):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(REPO / "src"))
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


# -- bitwise pins: streamed-mesh == resident-mesh == sim-streamed -----------

def test_mesh_streamed_trio_bitwise_dense():
    """Dense replicated on a (data=8) mesh: the mesh-streamed epochs,
    the resident mesh epochs, and the SIM streamed loop driven by the
    same `MeshSchedule` all produce bitwise-identical (alpha, v)."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import engine
        from repro.core.objectives import LOGISTIC
        from repro.launch.mesh import make_host_mesh
        from repro.launch.glm import (GLMScale, make_dense_epoch,
                                      make_streamed_epoch_mesh)
        from repro.data.cache import ArrayFeed

        K = 8; n, d, B = 1024, 64, 8
        scale = GLMScale("t", "dense", n=n, d=d, bucket=B, chunks=2,
                         deterministic=True, compress_pod=False,
                         local_solver="xla", lam=1e-3)
        mesh = make_host_mesh(pod=1, data=K, model=1)
        rng = np.random.default_rng(0)
        X = rng.normal(size=(d, n)).astype(np.float32)
        y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)

        ep = jax.jit(make_dense_epoch(scale, mesh))
        Xr, yr = jnp.asarray(X), jnp.asarray(y)
        ar, vr = jnp.zeros(n), jnp.zeros(d)
        for e in range(2):
            Xr, yr, ar, vr = ep(Xr, yr, ar, vr, e)

        stats = {}
        epoch_m = make_streamed_epoch_mesh(
            scale, mesh, ArrayFeed(y, X=X, bucket=B), stats=stats)
        am, vm = jnp.zeros(n), jnp.zeros(d)
        for e in range(2):
            am, vm = epoch_m(am, vm, e)

        sched = engine.MeshSchedule(n // B, pods=1, data=K, model=1,
                                    seed=scale.seed)
        epoch_s = engine.make_streamed_epoch(
            LOGISTIC, scale.engine_config(mesh), sched,
            ArrayFeed(y, X=X, bucket=B), lam=scale.lam)
        als, vs = jnp.zeros(n), jnp.zeros(d)
        for e in range(2):
            als, vs = epoch_s(als, vs, e)

        assert np.array_equal(np.asarray(vm), np.asarray(vs))
        assert np.array_equal(np.asarray(am), np.asarray(als))
        lay = epoch_m.schedule.layout(1)   # resident layout, last epoch
        cols = (lay[..., None] * B
                + np.arange(B, dtype=np.int64)).reshape(-1)
        assert np.array_equal(np.asarray(vm), np.asarray(vr))
        assert np.array_equal(np.asarray(am)[cols],
                              np.asarray(ar).reshape(-1))
        assert np.abs(np.asarray(vm)).max() > 0       # actually trained
        assert stats["chunks"] == 2
        assert 0.0 <= stats["transfer_hidden_frac"] <= 1.0
        assert epoch_m.feed.bytes_h2d == 2 * (n * d * 4 + n * 4)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_mesh_streamed_bitwise_sparse_replicated():
    """Sparse replicated rows (full idx/val per worker) stream bitwise
    against the resident sparse mesh epochs."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_host_mesh
        from repro.launch.glm import (GLMScale, make_sparse_epoch,
                                      make_streamed_epoch_mesh)
        from repro.data.cache import ArrayFeed

        n, d, nnz, B = 1024, 256, 8, 8
        rng = np.random.default_rng(2)
        idx = np.stack([rng.choice(d, size=nnz, replace=False)
                        for _ in range(n)]).astype(np.int32)
        val = rng.normal(size=(n, nnz)).astype(np.float32)
        y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
        scale = GLMScale("t", "sparse", n=n, d=d, nnz=nnz, bucket=B,
                         chunks=2, deterministic=True,
                         compress_pod=False, local_solver="xla",
                         lam=1e-3, seed=2)
        mesh = make_host_mesh(pod=1, data=8, model=1)
        ep = jax.jit(make_sparse_epoch(scale, mesh))
        st = (jnp.asarray(idx), jnp.asarray(val), jnp.asarray(y),
              jnp.zeros(n), jnp.zeros(d))
        for e in range(2):
            st = ep(*st, e)
        ar, vr = st[3], st[4]

        epoch_m = make_streamed_epoch_mesh(
            scale, mesh, ArrayFeed(y, idx=idx, val=val, d=d, bucket=B))
        am, vm = jnp.zeros(n), jnp.zeros(d)
        for e in range(2):
            am, vm = epoch_m(am, vm, e)

        assert np.array_equal(np.asarray(vm), np.asarray(vr))
        lay = epoch_m.schedule.layout(1)
        cols = (lay[..., None] * B
                + np.arange(B, dtype=np.int64)).reshape(-1)
        assert np.array_equal(np.asarray(am)[cols],
                              np.asarray(ar).reshape(-1))
        assert np.abs(np.asarray(vm)).max() > 0
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_mesh_streamed_bitwise_sparse_sharded_slice_compacted():
    """Feature-sharded sparse on a (data=4, model=2) mesh: the feed
    routes through `TileCache.slice_gather` (per-lane slice-compacted
    idx/val/pos), the step reassembles exact rows on device, and the
    result is bitwise the resident sharded run.  Per-lane transfer
    bytes follow the rows*w*12 model exactly."""
    r = _run("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.data import registry
        from repro.launch.mesh import make_host_mesh
        from repro.launch.glm import (GLMScale, make_sparse_epoch,
                                      make_streamed_epoch_mesh)

        root = tempfile.mkdtemp()
        cache = registry.materialize("synthetic-sparse", root, bucket=8,
                                     pods=1, n=512, d=64,
                                     pad_multiple=256)
        m = cache.meta
        (idx, val), y = cache.load_arrays()
        scale = GLMScale("t", "sparse", n=m.n, d=m.d, nnz=m.nnz,
                         bucket=m.bucket, chunks=4, feature_shard=True,
                         deterministic=True, compress_pod=False,
                         local_solver="xla", lam=1e-3, seed=3)
        mesh = make_host_mesh(pod=1, data=4, model=2)
        ep = jax.jit(make_sparse_epoch(scale, mesh))
        st = (jnp.asarray(idx), jnp.asarray(val), jnp.asarray(y),
              jnp.zeros(m.n), jnp.zeros(m.d))
        for e in range(2):
            st = ep(*st, e)
        ar, vr = st[3], st[4]

        epoch_m = make_streamed_epoch_mesh(scale, mesh, cache)
        feed = epoch_m.feed
        assert feed.sliced and feed.cache is cache
        am, vm = jnp.zeros(m.n), jnp.zeros(m.d)
        for e in range(2):
            am, vm = epoch_m(am, vm, e)

        assert np.array_equal(np.asarray(vm), np.asarray(vr))
        B = m.bucket
        lay = epoch_m.schedule.layout(1)
        cols = (lay[..., None] * B
                + np.arange(B, dtype=np.int64)).reshape(-1)
        assert np.array_equal(np.asarray(am)[cols],
                              np.asarray(ar).reshape(-1))
        assert np.abs(np.asarray(vm)).max() > 0
        # per-lane slice-compacted bytes: each of the M model lanes
        # ships rows*w*12 (idx/val/pos) + the shared labels
        M, w = 2, feed.width
        assert feed.bytes_h2d == 2 * (M * m.n * w * 12 + m.n * 4)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_mesh_streamed_bitwise_dense_tp_and_pods():
    """Dense TP (feature-sharded, model=2) and a 2-pod mesh with the
    int8 cross-pod reduce both stream bitwise vs resident."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_host_mesh
        from repro.launch.glm import (GLMScale, make_dense_epoch,
                                      make_streamed_epoch_mesh)
        from repro.data.cache import ArrayFeed

        n, d, B = 1024, 64, 8
        rng = np.random.default_rng(4)
        X = rng.normal(size=(d, n)).astype(np.float32)
        y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)

        for name, kw, mk in [
            ("tp", dict(feature_shard=True, compress_pod=False, seed=4),
             dict(pod=1, data=4, model=2)),
            ("pods", dict(compress_pod=True, seed=6),
             dict(pod=2, data=4, model=1)),
        ]:
            scale = GLMScale(name, "dense", n=n, d=d, bucket=B,
                             chunks=2, deterministic=True,
                             local_solver="xla", lam=1e-3, **kw)
            mesh = make_host_mesh(**mk)
            ep = jax.jit(make_dense_epoch(scale, mesh))
            st = (jnp.asarray(X), jnp.asarray(y), jnp.zeros(n),
                  jnp.zeros(d))
            for e in range(2):
                st = ep(*st, e)
            ar, vr = st[2], st[3]
            epoch_m = make_streamed_epoch_mesh(
                scale, mesh, ArrayFeed(y, X=X, bucket=B))
            am, vm = jnp.zeros(n), jnp.zeros(d)
            for e in range(2):
                am, vm = epoch_m(am, vm, e)
            assert np.array_equal(np.asarray(vm),
                                  np.asarray(vr).reshape(-1)), name
            lay = epoch_m.schedule.layout(1)
            cols = (lay[..., None] * B
                    + np.arange(B, dtype=np.int64)).reshape(-1)
            assert np.array_equal(np.asarray(am)[cols],
                                  np.asarray(ar).reshape(-1)), name
            assert np.abs(np.asarray(vm)).max() > 0, name
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_session_mesh_streamed():
    """`Session(..., streamed=True, mesh=...)` drives the mesh
    pipeline: reproducible bitwise across constructions, ingest stats
    + h2d counters populated, and a clear error without a streamed
    source."""
    r = _run("""
        import jax, numpy as np
        from repro.api.session import Session
        from repro.core.config import EngineConfig
        from repro.launch.mesh import make_host_mesh

        rng = np.random.default_rng(7)
        n, d, B = 512, 32, 8
        X = rng.normal(size=(d, n)).astype(np.float32)
        y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
        cfg = EngineConfig.make(pods=1, lanes=4, bucket=B, chunks=2,
                                partition="alltoall",
                                deterministic=True,
                                local_solver="xla", compress_pod=False)
        mesh = make_host_mesh(pod=1, data=4, model=1)
        runs = []
        for _ in range(2):
            s = Session((X, y), objective="logistic", lam=1e-3,
                        cfg=cfg, streamed=True, mesh=mesh)
            s.fit(max_epochs=3, tol=0)
            runs.append(s)
        a, b = runs
        assert np.array_equal(np.asarray(a.v), np.asarray(b.v))
        assert np.array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
        assert a.stream_stats["chunks"] == 2
        assert a.mesh_feed.bytes_h2d > 0
        assert np.isfinite(a.gap())
        try:
            Session((X, y), cfg=cfg, mesh=mesh)
        except ValueError:
            pass
        else:
            raise AssertionError("mesh= without streamed must raise")
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


# -- slice compaction unit tests (no devices needed) ------------------------

def _reassemble(idx, pieces, nnz):
    """Scatter per-lane (idx, val, pos) compactions back into full
    rows — the numpy mirror of the step's on-device all_gather +
    positional scatter."""
    n = idx.shape[0]
    fi = np.zeros((n, nnz), np.int32)
    fv = np.zeros((n, nnz), np.float32)
    for ic, vc, pos in pieces:
        rows = np.broadcast_to(np.arange(n)[:, None], pos.shape)
        keep = pos < nnz                  # pos == nnz is the pad slot
        fi[rows[keep], pos[keep]] = ic[keep]
        fv[rows[keep], pos[keep]] = vc[keep]
    return fi, fv


def test_slice_compaction_positions_roundtrip():
    """compact_slice_rows(positions=True) pieces reassemble the exact
    original rows: global ids, explicit-zero values preserved, padding
    slots (idx=0, val=0) reproduced by the zeros base."""
    rng = np.random.default_rng(11)
    n, d, nnz, M = 64, 96, 12, 3
    idx = np.stack([rng.choice(d, size=nnz, replace=False)
                    for _ in range(n)]).astype(np.int32)
    val = rng.normal(size=(n, nnz)).astype(np.float32)
    val[rng.random((n, nnz)) < 0.2] = 0.0     # explicit zeros
    idx[:, -2:] = 0                           # padding tail
    val[:, -2:] = 0.0
    dl = d // M
    pieces = [compact_slice_rows(idx, val, m * dl, (m + 1) * dl,
                                 positions=True)
              for m in range(M)]
    fi, fv = _reassemble(idx, pieces, nnz)
    assert np.array_equal(fi, idx)
    assert np.array_equal(fv, val)


def test_slice_compaction_per_lane_bytes_and_width():
    """The per-lane compaction is the ~M-fold transfer saving: each
    lane's (idx, val, pos) triple is rows*w*12 bytes with w ~= nnz/M,
    vs rows*nnz*8 for full replicated rows; an undersized forced width
    raises instead of silently dropping nonzeros."""
    rng = np.random.default_rng(13)
    n, d, nnz, M = 128, 4096, 256, 8
    idx = np.stack([rng.choice(d, size=nnz, replace=False)
                    for _ in range(n)]).astype(np.int32)
    val = rng.normal(size=(n, nnz)).astype(np.float32)
    dl = d // M
    per_lane = []
    for m in range(M):
        ic, vc, pos = compact_slice_rows(idx, val, m * dl, (m + 1) * dl,
                                         positions=True)
        per_lane.append(ic.nbytes + vc.nbytes + pos.nbytes)
        assert ic.shape[1] <= compact_slice_rows(
            idx, val, m * dl, (m + 1) * dl, positions=True,
            width=ic.shape[1])[0].shape[1]
    full = n * nnz * 8
    # uniform ids: each slice holds ~nnz/M of the row, so per-lane
    # bytes land well under the replicated-row transfer
    assert max(per_lane) < full / 2
    with pytest.raises(ValueError):
        compact_slice_rows(idx, val, 0, dl, positions=True, width=1)


def test_mesh_schedule_pure_and_composed():
    """`MeshSchedule` is a pure function of (seed, epoch): independent
    instances agree, layouts compose re-deals epoch over epoch, and
    every epoch's schedule is a permutation of all buckets."""
    a = engine.MeshSchedule(64, pods=2, data=2, model=2, seed=9)
    b = engine.MeshSchedule(64, pods=2, data=2, model=2, seed=9)
    s3 = a.schedule(3)                  # builds layouts 0..3 in order
    assert np.array_equal(s3, b.schedule(3))
    assert np.array_equal(a.layout(2), b.layout(2))
    for e in range(4):
        assert np.array_equal(np.sort(a.schedule(e), axis=None),
                              np.arange(64))
    # static mode: layout never moves, visit order still shuffles
    st = engine.MeshSchedule(64, pods=2, data=2, model=2, seed=9,
                             redeal=False)
    assert np.array_equal(st.layout(3), st.layout(0))
    assert not np.array_equal(st.schedule(1), st.schedule(2))
