"""Unit + property tests for the GLM objectives and SDCA scalar update."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test dep
from hypothesis import given, settings, strategies as st

from repro.core.objectives import (HINGE, LOGISTIC, RIDGE, duality_gap,
                                   get_objective)

jax.config.update("jax_enable_x64", False)

OBJS = [RIDGE, HINGE, LOGISTIC]


def _label(obj, rng):
    return (rng.choice([-1.0, 1.0]) if obj.classification
            else float(rng.standard_normal()))


@pytest.mark.parametrize("obj", OBJS, ids=lambda o: o.name)
def test_delta_minimizes_scalar_subproblem(obj):
    """delta = argmin_d phi*(-(a+d)) + m d + q d^2/2 — check vs grid."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        m = float(rng.standard_normal())
        y = _label(obj, rng)
        q = float(rng.uniform(0.05, 5.0))
        if obj.classification:
            b0 = rng.uniform(0.02, 0.98)
            a = float(y * b0)
        else:
            a = float(rng.standard_normal() * 0.3)
        d_star = float(obj.delta(jnp.float32(m), jnp.float32(a),
                                 jnp.float32(y), jnp.float32(q)))

        def g(d):
            return float(obj.conj_neg(jnp.float32(a + d), jnp.float32(y))
                         + m * d + 0.5 * q * d * d)

        g_star = g(d_star)
        # compare against a fine grid around the feasible region
        if obj.classification:
            grid = (np.linspace(1e-4, 1 - 1e-4, 2001) * y - a)
        else:
            grid = np.linspace(d_star - 2.0, d_star + 2.0, 2001)
        g_grid = min(g(d) for d in grid)
        assert g_star <= g_grid + 5e-4, (obj.name, g_star, g_grid)


@pytest.mark.parametrize("obj", OBJS, ids=lambda o: o.name)
def test_conjugate_fenchel_young(obj):
    """phi(z) + phi*(-a) = -z*a at a = -phi'(z) (Fenchel-Young)."""
    rng = np.random.default_rng(1)
    for _ in range(100):
        z = float(rng.standard_normal() * 2)
        y = _label(obj, rng)
        if obj.name == "ridge":
            a_opt = -(z - y)
        elif obj.name == "logistic":
            a_opt = y / (1 + np.exp(y * z))
        else:           # hinge: subgradient; test only at z*y < 1 (a=y)
            if y * z >= 1:
                continue
            a_opt = y
        lhs = float(obj.loss(jnp.float32(z), jnp.float32(y))
                    + obj.conj_neg(jnp.float32(a_opt), jnp.float32(y)))
        assert abs(lhs + z * a_opt) < 1e-3, (obj.name, lhs, -z * a_opt)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([o.name for o in OBJS]))
@settings(max_examples=30, deadline=None)
def test_weak_duality_property(seed, obj_name):
    """gap = P(v) - D(alpha) >= 0 whenever v = A @ alpha / (lam n)."""
    obj = get_objective(obj_name)
    rng = np.random.default_rng(seed)
    d, n = 5, 32
    lam = 0.1
    X = jnp.asarray(rng.standard_normal((d, n)), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], n) if obj.classification
                    else rng.standard_normal(n), jnp.float32)
    if obj.classification:
        alpha = jnp.asarray(rng.uniform(0.01, 0.99, n), jnp.float32) * y
    else:
        alpha = jnp.asarray(rng.standard_normal(n), jnp.float32)
    v = X @ alpha / (lam * n)
    gap = float(duality_gap(obj, alpha, v, X, y, lam))
    assert gap >= -1e-3, gap


@given(st.floats(-3, 3), st.floats(0.05, 5), st.floats(0.02, 0.98),
       st.sampled_from([-1.0, 1.0]))
@settings(max_examples=100, deadline=None)
def test_delta_keeps_dual_feasible(m, q, b0, y):
    """classification duals must stay in the conjugate domain."""
    for obj in (HINGE, LOGISTIC):
        a = y * b0
        d = float(obj.delta(jnp.float32(m), jnp.float32(a),
                            jnp.float32(y), jnp.float32(q)))
        b_new = (a + d) * y
        assert -1e-5 <= b_new <= 1 + 1e-5, (obj.name, b_new)


def test_get_objective_errors():
    with pytest.raises(ValueError):
        get_objective("nope")
