"""Shared fixtures: deterministic fault injection via $REPRO_FAULTS."""
import pytest


@pytest.fixture
def fault_env(monkeypatch, tmp_path):
    """Arm a deterministic fault schedule through the environment, the
    way an operator (or the CI chaos job) would: sets $REPRO_FAULTS,
    $REPRO_SEED and $REPRO_FAULT_LOG, and returns the event-log path.

        log = fault_env("kill@e1c2", seed=3)
        ... run training; read log.read_text() for the event stream
    """
    def arm(schedule: str, seed: int = 0):
        log = tmp_path / "fault-events.jsonl"
        monkeypatch.setenv("REPRO_FAULTS", schedule)
        monkeypatch.setenv("REPRO_SEED", str(seed))
        monkeypatch.setenv("REPRO_FAULT_LOG", str(log))
        return log
    return arm
