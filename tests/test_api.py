"""Public API surface: Session epoch control, sklearn-compatible
estimators (+ real-sklearn parity), callbacks, whole-estimator
checkpoint resume, and the legacy-shim deprecation contract."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (BenchmarkRecorder, EarlyStopping, GapLogger,
                       LinearSVC, LogisticRegression, NotFittedError,
                       ReproDeprecationWarning, Ridge, Session)
from repro.api import load as load_estimator
from repro.api.deprecation import reset_deprecation_registry
from repro.core import EngineConfig, SolverConfig
from repro.data import (make_dense_classification,
                        make_sparse_classification, registry)

DET = EngineConfig.make(pods=1, lanes=2, bucket=8, chunks=2,
                        partition="hierarchical", deterministic=True)


def _dense(n=512, d=32, seed=0):
    X, y = make_dense_classification(n=n, d=d, seed=seed)
    return np.asarray(X), np.asarray(y)


# -- Session ----------------------------------------------------------------

def test_session_epoch_and_fit_until_are_reentrant():
    X, y = _dense()
    kw = dict(objective="logistic", lam=1e-2, cfg=DET)
    a = Session((X, y), **kw)
    rec = a.epoch()
    assert rec["epoch"] == 1 and rec["rel_change"] > 0
    a.fit(until=6, tol=0.0)
    assert a.epochs_done == 6

    b = Session((X, y), **kw)
    b.fit(until=3, tol=0.0)
    b.fit(until=6, tol=0.0)
    np.testing.assert_array_equal(np.asarray(a.v), np.asarray(b.v))
    np.testing.assert_array_equal(np.asarray(a.alpha),
                                  np.asarray(b.alpha))
    with pytest.raises(TypeError, match="either"):
        a.fit(until=9, max_epochs=1)


def test_session_matches_legacy_trainer_bitwise():
    from repro.core import GLMTrainer
    X, y = _dense()
    ses = Session((X, y), objective="logistic", lam=1e-2, cfg=DET)
    ses.fit(max_epochs=3, tol=0.0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", ReproDeprecationWarning)
        tr = GLMTrainer(X, y, objective="logistic", lam=1e-2, cfg=DET)
    tr.fit(max_epochs=3, tol=0.0)
    np.testing.assert_array_equal(np.asarray(ses.v), np.asarray(tr.v))
    np.testing.assert_array_equal(np.asarray(ses.alpha),
                                  np.asarray(tr.alpha))


def test_session_pads_arbitrary_n():
    X, y = _dense(n=500)          # 500 does not divide the topology
    ses = Session((X, y), lam=1e-2, cfg=DET)
    assert ses.n_examples == 500 and ses.n % (2 * 2 * 2 * 8) == 0
    res = ses.fit(max_epochs=5, tol=1e-4)
    assert np.isfinite(res.final_gap)


def test_session_from_feed_matches_resident():
    from repro.data.cache import ArrayFeed
    X, y = _dense(n=256, d=16)
    resident = Session((X, y), lam=1e-2, cfg=DET)
    resident.fit(max_epochs=2, tol=0.0)
    feed = ArrayFeed(y, X=X, bucket=8)
    streamed = Session(feed, objective="logistic", lam=1e-2, cfg=DET)
    assert streamed.streamed
    streamed.fit(max_epochs=2, tol=0.0)
    np.testing.assert_array_equal(np.asarray(resident.v),
                                  np.asarray(streamed.v))
    np.testing.assert_array_equal(np.asarray(resident.alpha),
                                  np.asarray(streamed.alpha))
    # diagnostics flow through the feed's streaming pass
    assert streamed.gap() == pytest.approx(resident.gap(),
                                           rel=1e-4, abs=1e-6)


def test_session_streamed_arrays_match_resident():
    """streamed=True over plain arrays wraps an ArrayFeed: chunked
    device residency, bitwise-identical training, working gap()."""
    X, y = _dense(n=256, d=16)
    resident = Session((X, y), lam=1e-2, cfg=DET)
    resident.fit(max_epochs=2, tol=0.0)
    streamed = Session((X, y), lam=1e-2, cfg=DET, streamed=True)
    assert streamed.streamed and streamed.feed is not None
    streamed.fit(max_epochs=2, tol=0.0)
    np.testing.assert_array_equal(np.asarray(resident.v),
                                  np.asarray(streamed.v))
    assert streamed.gap() == pytest.approx(resident.gap(),
                                           rel=1e-4, abs=1e-6)


def test_session_registry_and_cache_sources(tmp_path):
    res = Session("synthetic-dense", n=256, d=32, cfg=DET).fit(
        max_epochs=3, tol=0.0)
    cache = registry.materialize("synthetic-dense", tmp_path, bucket=8,
                                 n=256, d=32, pad_multiple=64)
    ses = Session(cache, cfg=DET, streamed=True)
    res2 = ses.fit(max_epochs=3, tol=0.0)
    assert res2.epochs == 3
    assert np.abs(res2.v).max() > 0
    assert np.isfinite(res.final_gap) and np.isfinite(res2.final_gap)


# -- callbacks --------------------------------------------------------------

def test_callbacks_early_stop_logger_recorder():
    X, y = _dense()
    logger = GapLogger(every=1, printer=None)
    rec = BenchmarkRecorder()
    stop = EarlyStopping(monitor="gap", threshold=1e-3)
    ses = Session((X, y), lam=1e-2, cfg=DET)
    res = ses.fit(until=50, tol=0.0, callbacks=[logger, stop, rec])
    assert res.epochs < 50                      # certificate stop fired
    assert logger.trace and logger.trace[-1][1] < 1e-3
    assert len(rec.records) == res.epochs
    assert rec.wall_time > 0


def test_bare_callable_callback_stops():
    X, y = _dense()
    ses = Session((X, y), lam=1e-2, cfg=DET)
    res = ses.fit(until=50, tol=0.0,
                  callbacks=[lambda m: m["epoch"] >= 2])
    assert res.epochs == 2


def test_checkpoint_hook_saves_steps(tmp_path):
    from repro.api import CheckpointHook
    X, y = _dense()
    hook = CheckpointHook(tmp_path / "ck", every=2, keep_n=2)
    ses = Session((X, y), lam=1e-2, cfg=DET)
    ses.fit(until=5, tol=0.0, callbacks=[hook])
    hook.mgr.wait()
    assert hook.mgr.all_steps() == [2, 4]


# -- estimators -------------------------------------------------------------

def test_estimator_sklearn_protocol():
    est = LogisticRegression(lam=1e-2, lanes=4, max_epochs=7)
    params = est.get_params()
    assert params["lanes"] == 4 and params["max_epochs"] == 7
    clone = LogisticRegression(**params)
    assert clone.get_params() == params
    est.set_params(lanes=2, tol=1e-5)
    assert est.lanes == 2 and est.tol == 1e-5
    with pytest.raises(ValueError, match="invalid parameter"):
        est.set_params(nope=1)
    with pytest.raises(NotFittedError):
        est.predict(np.zeros((3, 4)))


def test_logreg_fit_predict_score_proba():
    X, y = _dense(n=1024, d=32)
    Xsk = X.T                                    # sklearn layout
    y01 = (y > 0).astype(int)                    # arbitrary binary labels
    est = LogisticRegression(lam=1e-3, bucket=8, lanes=2, max_epochs=40,
                             tol=1e-4)
    assert est.fit(Xsk, y01) is est
    assert list(est.classes_) == [0, 1]
    preds = est.predict(Xsk)
    assert set(np.unique(preds)) <= {0, 1}
    assert est.score(Xsk, y01) > 0.6
    proba = est.predict_proba(Xsk)
    np.testing.assert_allclose(proba.sum(axis=1), 1.0, atol=1e-6)
    assert np.array_equal(preds, est.classes_[
        (est.decision_function(Xsk) > 0).astype(int)])
    assert est.coef_.shape == (32,) and est.n_iter_ > 0


def test_linear_svc_and_ridge():
    X, y = _dense(n=512, d=16)
    svc = LinearSVC(lam=1e-3, bucket=8, max_epochs=30)
    svc.fit(X.T, y)
    assert svc.score(X.T, y) > 0.6

    rng = np.random.default_rng(0)
    Xr = rng.standard_normal((400, 12)).astype(np.float32)
    w = rng.standard_normal(12).astype(np.float32)
    yr = Xr @ w + 0.01 * rng.standard_normal(400).astype(np.float32)
    ridge = Ridge(lam=1e-4, bucket=8, max_epochs=60, tol=1e-6)
    ridge.fit(Xr, yr)
    assert ridge.score(Xr, yr) > 0.98


def test_estimator_sparse_pair_input():
    (idx, val), y, d = make_sparse_classification(n=512, d=128, nnz=8,
                                                  seed=3)
    est = LogisticRegression(lam=1e-3, bucket=8, max_epochs=30,
                             n_features=d)
    est.fit((idx, val), y)
    acc = est.score((idx, val), y)
    assert acc > 0.6
    assert est.coef_.shape == (d,)


def test_estimator_streamed_from_cache(tmp_path):
    cache = registry.materialize("synthetic-dense", tmp_path, bucket=8,
                                 n=256, d=32, pad_multiple=64)
    est = LogisticRegression(bucket=8, max_epochs=5, streamed=True)
    est.fit(cache)
    assert est.session_.streamed
    assert est.n_iter_ > 0 and np.abs(est.coef_).max() > 0


# -- whole-estimator checkpointing ------------------------------------------

@pytest.mark.parametrize("kind", ["dense", "sparse"])
def test_estimator_checkpoint_resume_bitwise(tmp_path, kind):
    """fit(3) -> save -> load -> fit(remaining) == one straight fit,
    bitwise, dense and sparse, under deterministic=True."""
    common = dict(lam=1e-2, bucket=8, pods=1, lanes=2, chunks=2,
                  deterministic=True, tol=0.0)
    if kind == "dense":
        X, y = _dense(n=256, d=16)
        fit_args = (X.T, y)
        common["partition"] = "hierarchical"
    else:
        (idx, val), y, d = make_sparse_classification(n=256, d=64,
                                                      nnz=8, seed=1)
        fit_args = ((idx, val), y)
        common["n_features"] = d

    straight = LogisticRegression(max_epochs=8, **common)
    straight.fit(*fit_args)

    half = LogisticRegression(max_epochs=3, **common)
    half.fit(*fit_args)
    half.save(tmp_path / "est")

    resumed = load_estimator(tmp_path / "est")
    assert type(resumed) is LogisticRegression
    assert resumed.n_iter_ == 3
    # predicts immediately, without refitting
    np.testing.assert_array_equal(resumed.predict(fit_args[0]),
                                  half.predict(fit_args[0]))
    resumed.set_params(max_epochs=8)
    resumed.fit(*fit_args)
    assert resumed.n_iter_ == 8
    np.testing.assert_array_equal(resumed.coef_, straight.coef_)
    np.testing.assert_array_equal(np.asarray(resumed.session_.alpha),
                                  np.asarray(straight.session_.alpha))


def test_loaded_estimator_fit_without_budget_reports_state(tmp_path):
    """fit() on a loaded estimator whose budget is already spent runs 0
    epochs but still reports a REAL gap, not nan."""
    X, y = _dense(n=256, d=16)
    est = LogisticRegression(bucket=8, max_epochs=3, tol=0.0)
    est.fit(X.T, y)
    est.save(tmp_path / "est")
    again = load_estimator(tmp_path / "est")
    again.fit(X.T, y)
    assert again.n_iter_ == 3
    assert np.isfinite(again.fit_result_.final_gap)
    np.testing.assert_array_equal(again.coef_, est.coef_)


def test_resume_rejects_different_n(tmp_path):
    X, y = _dense(n=256, d=16)
    est = LogisticRegression(bucket=8, max_epochs=2, tol=0.0)
    est.fit(X.T, y)
    est.save(tmp_path / "est")
    X2, y2 = _dense(n=512, d=16, seed=1)
    resumed = load_estimator(tmp_path / "est")
    with pytest.raises(ValueError, match="checkpoint n="):
        resumed.fit(X2.T, y2)


def test_save_warns_on_unserializable_params(tmp_path):
    X, y = _dense(n=256, d=16)
    est = LogisticRegression(bucket=8, max_epochs=2, tol=0.0,
                             callbacks=[lambda m: None])
    est.fit(X.T, y)
    with pytest.warns(UserWarning, match="callbacks"):
        est.save(tmp_path / "est")
    assert load_estimator(tmp_path / "est").callbacks is None


def test_estimator_load_rejects_wrong_class(tmp_path):
    X, y = _dense(n=256, d=16)
    est = LogisticRegression(bucket=8, max_epochs=2, tol=0.0)
    est.fit(X.T, y)
    est.save(tmp_path / "est")
    with pytest.raises(ValueError, match="LogisticRegression"):
        Ridge.load(tmp_path / "est")


# -- sklearn parity (the acceptance criterion) ------------------------------

def test_sklearn_parity_on_registry_dataset():
    sklearn = pytest.importorskip("sklearn")  # noqa: F841
    from sklearn.linear_model import LogisticRegression as SkLR

    ds = registry.get_dataset("synthetic-dense")   # 2048 x 64
    Xsk, y = np.asarray(ds.X).T, np.asarray(ds.y)
    lam = 1e-3
    ours = LogisticRegression(lam=lam, bucket=8, lanes=4,
                              partition="dynamic", max_epochs=100,
                              tol=1e-5)
    ours.fit(Xsk, y)
    theirs = SkLR(C=1.0 / (lam * y.shape[0]), fit_intercept=False,
                  solver="lbfgs", max_iter=1000, tol=1e-8)
    theirs.fit(Xsk, y)

    assert abs(ours.score(Xsk, y) - theirs.score(Xsk, y)) <= 1e-2
    agree = np.mean(ours.predict(Xsk) == theirs.predict(Xsk))
    assert agree >= 0.99


def test_scipy_csr_input_matches_pair():
    sp = pytest.importorskip("scipy.sparse")
    (idx, val), y, d = make_sparse_classification(n=256, d=64, nnz=8,
                                                  seed=2)
    n, nnz = idx.shape
    rows = np.repeat(np.arange(n), nnz)
    mat = sp.csr_matrix((val.ravel(), (rows, idx.ravel())), shape=(n, d))
    kw = dict(lam=1e-2, bucket=8, max_epochs=5, tol=0.0,
              deterministic=True, n_features=d)
    a = LogisticRegression(**kw).fit(mat, y)
    b = LogisticRegression(**kw).fit((idx, val), y)
    # scipy sums duplicate (row, col) entries and reorders columns, so
    # the padded rows agree only up to f32 summation order
    np.testing.assert_allclose(a.coef_, b.coef_, rtol=1e-2, atol=1e-4)
    np.testing.assert_array_equal(a.predict(mat), b.predict((idx, val)))


# -- serving ----------------------------------------------------------------

def test_serve_glm_batch_and_streamed(tmp_path):
    from repro.launch.serve import glm_predict_batch, glm_predict_streamed

    cache = registry.materialize("synthetic-dense", tmp_path, bucket=8,
                                 n=256, d=32, pad_multiple=64)
    est = LogisticRegression(bucket=8, max_epochs=10)
    est.fit(cache)
    X, _y = cache.load_arrays()
    Xsk = np.asarray(X).T[:cache.meta.n_examples]

    direct = est.predict(Xsk)
    batched = glm_predict_batch(est, Xsk, batch=50)
    np.testing.assert_array_equal(direct, batched)
    proba = glm_predict_batch(est, Xsk, batch=50, proba=True)
    assert proba.shape == (Xsk.shape[0], 2)

    streamed = glm_predict_streamed(est, cache, gbuckets=4)
    np.testing.assert_array_equal(direct, streamed)


def test_estimator_epoch_lowers_to_mesh():
    from repro.launch.glm import estimator_epoch, glm_input_specs
    from repro.launch.mesh import make_host_mesh
    import jax

    X, y = _dense(n=256, d=16)
    est = LogisticRegression(lam=1e-2, bucket=8, max_epochs=2, tol=0.0)
    est.fit(X.T, y)
    mesh = make_host_mesh(pod=1, data=1, model=1)
    epoch_fn, scale = estimator_epoch(est, mesh)
    assert scale.kind == "dense" and scale.n == est.session_.n
    assert scale.bucket == 8 and scale.lam == pytest.approx(1e-2)
    specs = glm_input_specs(scale, mesh)
    assert specs[0].shape == (scale.d, scale.n)
    ses = est.session_
    with mesh:
        Xm, ym, am, vm = jax.jit(epoch_fn)(
            ses.X, ses.y, jnp.zeros(ses.n), jnp.zeros(ses.d),
            jnp.int32(0))
    assert vm.shape == (scale.d,)
    assert np.isfinite(np.asarray(vm)).all()
    assert np.abs(np.asarray(vm)).max() > 0


def test_estimator_epoch_requires_fitted():
    from repro.launch.glm import scale_for_estimator
    with pytest.raises(ValueError, match="fitted"):
        scale_for_estimator(LogisticRegression())


# -- deprecation shims ------------------------------------------------------

def test_legacy_entry_points_warn_once():
    from repro.core import (GLMTrainer, StreamedGLMTrainer, cocoa,
                            fit_dataset)
    from repro.core.bucketing import make_plan
    from repro.core.objectives import LOGISTIC
    from repro.core.partition import PartitionPlan

    X, y = _dense(n=128, d=8)
    reset_deprecation_registry()

    with pytest.warns(ReproDeprecationWarning, match="GLMTrainer"):
        tr = GLMTrainer(X, y, cfg=SolverConfig(bucket=8))
    # once per process: a second construction is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", ReproDeprecationWarning)
        GLMTrainer(X, y, cfg=SolverConfig(bucket=8))

    with pytest.warns(ReproDeprecationWarning, match="fit_dataset"):
        fit_dataset("synthetic-dense", n=128, d=16, max_epochs=1,
                    tol=0.0)

    plan = PartitionPlan(n_buckets=16, pods=1, lanes=2)
    bplan = make_plan(128, 8, force=8)
    with pytest.warns(ReproDeprecationWarning, match="epoch_sim"):
        cocoa.epoch_sim(LOGISTIC, jnp.asarray(X), jnp.asarray(y),
                        tr.alpha * 0, tr.v * 0, 1e-3, plan, bplan,
                        SolverConfig(lanes=2, bucket=8), jnp.int32(0))

    (idx, val), ys, d = make_sparse_classification(n=128, d=32, nnz=4,
                                                   seed=0)
    with pytest.warns(ReproDeprecationWarning, match="epoch_sim_sparse"):
        cocoa.epoch_sim_sparse(
            LOGISTIC, jnp.asarray(idx), jnp.asarray(val),
            jnp.asarray(ys), jnp.zeros(128), jnp.zeros(d), 1e-3,
            PartitionPlan(n_buckets=16, pods=1, lanes=2),
            make_plan(128, d, force=8),
            SolverConfig(lanes=2, bucket=8), jnp.int32(0))


def test_streamed_trainer_shim_warns(tmp_path):
    from repro.core import StreamedGLMTrainer
    cache = registry.materialize("synthetic-dense", tmp_path, bucket=8,
                                 n=256, d=32, pad_multiple=64)
    reset_deprecation_registry()
    with pytest.warns(ReproDeprecationWarning, match="StreamedGLMTrainer"):
        tr = StreamedGLMTrainer(cache, cfg=SolverConfig(bucket=8))
    assert tr.plan.n_buckets == tr.n // 8


# -- local-solver dispatch (satellite) --------------------------------------

def test_sparse_local_solver_auto_resolves_to_xla(monkeypatch):
    # off-TPU (every CI host), "auto" still means the XLA scan; the
    # TPU->pallas resolution + env hatch are pinned in test_engine.py
    monkeypatch.delenv("REPRO_LOCAL_SOLVER", raising=False)
    from repro.core import make_local_solver
    from repro.core.objectives import LOGISTIC

    solver = make_local_solver("auto", LOGISTIC, 1.0, 1.0, sparse=True)
    assert callable(solver)
    # behaves identically to an explicit "xla"
    (idx, val), y, d = make_sparse_classification(n=8, d=16, nnz=4,
                                                  seed=0)
    xla = make_local_solver("xla", LOGISTIC, 1.0, 1.0, sparse=True)
    args = ((jnp.asarray(idx), jnp.asarray(val)), jnp.asarray(y),
            jnp.zeros(8), jnp.zeros(d))
    a1, dv1 = solver(*args)
    a2, dv2 = xla(*args)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(dv1), np.asarray(dv2))
    with pytest.raises(ValueError, match="unknown local_solver"):
        make_local_solver("nope", LOGISTIC, 1.0, 1.0, sparse=True)


def test_session_rejects_duplicate_nonzeros_for_pallas(monkeypatch):
    """Ad-hoc sparse rows that repeat a feature id with NONZERO values
    are rejected at Session entry when the resolved solver is the
    Pallas kernel (arrays are still concrete there; inside the jitted
    epoch they're tracers) — and stay accepted on the XLA scan, which
    accumulates duplicates fine."""
    from repro.api import Session
    from repro.core.config import EngineConfig

    monkeypatch.delenv("REPRO_LOCAL_SOLVER", raising=False)
    (idx, val), y, d = make_sparse_classification(n=64, d=32, nnz=8,
                                                  seed=5)
    bad_idx = np.asarray(idx).copy()
    bad_val = np.asarray(val).copy()
    bad_idx[2, 1] = bad_idx[2, 0]
    bad_val[2, :2] = [0.5, 0.25]
    cfg = EngineConfig.make(pods=1, lanes=2, bucket=8,
                            local_solver="pallas")
    with pytest.raises(ValueError, match="zero_duplicates"):
        Session(((bad_idx, bad_val), y), objective="logistic", lam=1e-2,
                d=d, cfg=cfg)
    # CPU "auto" resolves to xla -> duplicates remain acceptable
    cfg_auto = EngineConfig.make(pods=1, lanes=2, bucket=8,
                                 local_solver="auto")
    Session(((bad_idx, bad_val), y), objective="logistic", lam=1e-2,
            d=d, cfg=cfg_auto).fit(max_epochs=1)
    # TPU "auto": enforced when the kernel would run the rows...
    import jax
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    with pytest.raises(ValueError, match="zero_duplicates"):
        Session(((bad_idx, bad_val), y), objective="logistic",
                lam=1e-2, d=d, cfg=cfg_auto)
    # ...but NOT when the engine's misfit fallback routes the workload
    # to the XLA scan anyway (nnz=7 breaks the sublane alignment)
    Session(((bad_idx[:, :7], bad_val[:, :7]), y), objective="logistic",
            lam=1e-2, d=d, cfg=cfg_auto)
    # the misfit pre-check must see the RESOLVED bucket: cfg leaves
    # bucket at the default 1 (which could never fit the kernel) and
    # the Session kwarg supplies the real, kernel-fitting bucket
    cfg_nobucket = EngineConfig.make(pods=1, lanes=2,
                                     local_solver="auto")
    with pytest.raises(ValueError, match="zero_duplicates"):
        Session(((bad_idx, bad_val), y), objective="logistic",
                lam=1e-2, d=d, bucket=8, cfg=cfg_nobucket)
    # a user-supplied ArrayFeed is checked at Session entry too (the
    # jitted streamed step only ever sees tracers)
    from repro.data.cache import ArrayFeed
    feed = ArrayFeed(y, idx=bad_idx, val=bad_val, d=d, bucket=8)
    with pytest.raises(ValueError, match="zero_duplicates"):
        Session(feed, objective="logistic", lam=1e-2, cfg=cfg_auto)


# -- bench compare (CI perf-trajectory satellite) ---------------------------

def test_bench_compare_flags_regressions():
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.compare import compare

    prev = {"schema": "bench-summary/v1", "quick": True,
            "figures": {"fig1": {"failed": False, "runtime_s": 10.0,
                                 "final_gap": 1e-4},
                        "fig2": {"failed": False, "runtime_s": 5.0,
                                 "final_gap": None}}}
    ok = {"schema": "bench-summary/v1", "quick": True,
          "figures": {"fig1": {"failed": False, "runtime_s": 11.0,
                               "final_gap": 1.1e-4},
                      "fig2": {"failed": False, "runtime_s": 5.5,
                               "final_gap": None}}}
    assert compare(prev, ok) == []

    slow = {"schema": "bench-summary/v1", "quick": True,
            "figures": {"fig1": {"failed": False, "runtime_s": 14.0,
                                 "final_gap": 1e-4},
                        "fig2": {"failed": True, "runtime_s": 1.0}}}
    problems = compare(prev, slow)
    assert any("runtime" in p for p in problems)
    assert any("FAILING" in p for p in problems)

    worse_gap = {"schema": "bench-summary/v1", "quick": True,
                 "figures": {"fig1": {"failed": False, "runtime_s": 10.0,
                                      "final_gap": 2e-4},
                             "fig2": {"failed": False, "runtime_s": 5.0,
                                      "final_gap": None}}}
    assert any("gap" in p for p in compare(prev, worse_gap))
    # quick vs full runs are never compared
    assert compare(prev, dict(worse_gap, quick=False)) == []
    # a workload-version bump resets the baseline on purpose
    assert compare(prev, dict(worse_gap, workload=3)) == []
    # a vanished figure is a regression
    assert any("disappeared" in p
               for p in compare(prev, {"schema": "bench-summary/v1",
                                       "quick": True, "figures": {}}))


def test_bench_compare_parity_trajectory():
    """The sklearn-parity gate (PR-4 satellite): an absolute
    predict_agree floor on every run + vanished parity records count
    as regressions."""
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.compare import compare, parity_floor_problems

    rec = {"dataset": "higgs", "solver": "estimator",
           "score": 0.9, "score_sklearn": 0.9, "predict_agree": 0.999}
    good = {"schema": "bench-summary/v1", "quick": True,
            "figures": {"fig6": {"failed": False, "runtime_s": 5.0,
                                 "parity": [rec]}}}
    assert parity_floor_problems(good) == []

    bad = {"schema": "bench-summary/v1", "quick": True,
           "figures": {"fig6": {"failed": False, "runtime_s": 5.0,
                                "parity": [dict(rec,
                                                predict_agree=0.97)]}}}
    probs = parity_floor_problems(bad)
    assert probs and "0.99" in probs[0] and "fig6" in probs[0]
    # a custom floor is honoured
    assert parity_floor_problems(bad, floor=0.9) == []
    # an already-failed figure doesn't double-report
    failed = {"figures": {"fig6": {"failed": True,
                                   "parity": [dict(rec,
                                                   predict_agree=0.5)]}}}
    assert parity_floor_problems(failed) == []

    # cross-run: losing a parity record is a regression, keeping it is
    # fine even if the value moved (the absolute floor owns the value)
    lost = {"schema": "bench-summary/v1", "quick": True,
            "figures": {"fig6": {"failed": False, "runtime_s": 5.0}}}
    assert any("parity" in p and "disappeared" in p
               for p in compare(good, lost))
    moved = {"schema": "bench-summary/v1", "quick": True,
             "figures": {"fig6": {"failed": False, "runtime_s": 5.0,
                                  "parity": [dict(rec,
                                                  predict_agree=0.992)]}}}
    assert compare(good, moved) == []
