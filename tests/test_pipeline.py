"""Real-dataset pipeline: ingestion -> tile cache -> streamed epochs.

Pins the PR-2 acceptance contract: svmlight/CSV round-trips are exact,
the bucket-tile cache is byte-stable across processes, and streamed-
from-cache training is bitwise-identical to in-memory training under
`deterministic=True` for a dense and a sparse registry dataset.
"""
import hashlib
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import EngineConfig, StreamedGLMTrainer, fit_dataset
from repro.data import (cache as tile_cache, formats, registry)

REPO = pathlib.Path(__file__).resolve().parents[1]

DET_CFG = EngineConfig.make(pods=2, lanes=2, bucket=8, chunks=2,
                            partition="hierarchical", deterministic=True)


# -- formats: svmlight / CSV ------------------------------------------------

def test_svmlight_parses_reference_text():
    text = ("# comment line\n"
            "+1 qid:3 1:0.5 4:-2 7:1e-3\n"
            "-1 2:1.25\n"
            "0.5   # empty row with float label\n")
    (idx, val), y, d = formats.parse_svmlight(text)
    np.testing.assert_array_equal(y, [1.0, -1.0, 0.5])
    assert d == 7                      # 1-based ids shifted down
    assert idx.shape == val.shape == (3, 3)
    np.testing.assert_array_equal(idx[0], [0, 3, 6])
    np.testing.assert_allclose(val[0], [0.5, -2.0, 1e-3])
    assert val[2].tolist() == [0.0, 0.0, 0.0]


def test_svmlight_errors():
    with pytest.raises(ValueError, match="bad label"):
        formats.parse_svmlight("notanumber 1:2\n")
    with pytest.raises(ValueError, match="feature id"):
        formats.parse_svmlight("1 0:2\n")       # 0 is invalid 1-based
    with pytest.raises(ValueError, match="exceeds nnz"):
        formats.parse_svmlight("1 1:1 2:2\n", nnz=1)


def test_csv_parses_header_and_shapes():
    text = "label,f1,f2\n1,0.5,-1\n-1,2,3\n"
    X, y = formats.parse_csv(text)
    assert X.shape == (2, 2)
    np.testing.assert_array_equal(y, [1.0, -1.0])
    np.testing.assert_array_equal(X[:, 1], [2.0, 3.0])


def test_svmlight_roundtrip_exact_seeded():
    rng = np.random.default_rng(0)
    n, nnz, d = 64, 5, 100
    idx = rng.integers(0, d, size=(n, nnz)).astype(np.int32)
    val = rng.standard_normal((n, nnz)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    text = formats.dump_svmlight(idx, val, y)
    (idx2, val2), y2, _ = formats.parse_svmlight(text, d=d, nnz=nnz)
    np.testing.assert_array_equal(y, y2)
    np.testing.assert_array_equal(idx, idx2)
    np.testing.assert_array_equal(val, val2)


def test_svmlight_roundtrip_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    f32 = st.floats(width=32, allow_nan=False, allow_infinity=False,
                    min_value=1e-6, max_value=1e6)

    @given(st.lists(st.lists(st.tuples(st.integers(0, 999), f32),
                             min_size=0, max_size=8,
                             unique_by=lambda t: t[0]),
                    min_size=1, max_size=16),
           st.lists(f32, min_size=16, max_size=16))
    @settings(max_examples=25, deadline=None)
    def roundtrip(rows, labels):
        n = len(rows)
        nnz = max(max((len(r) for r in rows), default=1), 1)
        idx = np.zeros((n, nnz), np.int32)
        val = np.zeros((n, nnz), np.float32)
        for i, r in enumerate(rows):
            for k, (j, x) in enumerate(r):
                idx[i, k], val[i, k] = j, x
        y = np.asarray(labels[:n], np.float32)
        text = formats.dump_svmlight(idx, val, y)
        (idx2, val2), y2, _ = formats.parse_svmlight(text, d=1000,
                                                     nnz=nnz)
        np.testing.assert_array_equal(val, val2)
        np.testing.assert_array_equal(np.where(val != 0, idx, 0), idx2)
        np.testing.assert_array_equal(y, y2)

    roundtrip()


def test_csv_roundtrip_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    f32 = st.floats(width=32, allow_nan=False, allow_infinity=False)

    @given(st.integers(1, 6), st.integers(1, 12), st.data())
    @settings(max_examples=25, deadline=None)
    def roundtrip(d, n, data):
        X = np.asarray(data.draw(st.lists(f32, min_size=d * n,
                                          max_size=d * n)),
                       np.float32).reshape(d, n)
        y = np.asarray(data.draw(st.lists(f32, min_size=n, max_size=n)),
                       np.float32)
        X2, y2 = formats.parse_csv(formats.dump_csv(X, y))
        np.testing.assert_array_equal(X, X2)
        np.testing.assert_array_equal(y, y2)

    roundtrip()


def test_to_dense_accumulates_duplicates():
    idx = np.asarray([[0, 0], [1, 2]], np.int32)
    val = np.asarray([[1.0, 2.0], [3.0, 4.0]], np.float32)
    X = formats.to_dense(idx, val, d=3)
    np.testing.assert_array_equal(X[:, 0], [3.0, 0.0, 0.0])
    np.testing.assert_array_equal(X[:, 1], [0.0, 3.0, 4.0])


# -- tile cache -------------------------------------------------------------

def test_cache_roundtrip_dense(tmp_path):
    rng = np.random.default_rng(1)
    d, n, B = 13, 96, 8                       # d deliberately un-padded
    X = rng.standard_normal((d, n)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], n).astype(np.float32)
    tc = tile_cache.build_cache(tmp_path / "c", "t", X=X, y=y,
                                bucket=B, pods=2)
    assert tc.meta.d_pad == 16 and tc.meta.n == 96
    X2, y2 = tc.load_arrays()
    np.testing.assert_array_equal(X, X2)
    np.testing.assert_array_equal(y, y2)
    # tile gather == direct column slices for arbitrary bucket ids
    bids = np.asarray([[5, 0], [11, 3]])
    data, yg = tc.gather_buckets(bids)
    cols = (bids[..., None] * B + np.arange(B)).reshape(2, -1)
    np.testing.assert_array_equal(data, np.moveaxis(X[:, cols], 0, -2))
    np.testing.assert_array_equal(yg, y[cols])


def test_cache_roundtrip_sparse_with_padding(tmp_path):
    rng = np.random.default_rng(2)
    n, nnz, d, B = 50, 4, 32, 8               # n pads up to 64
    idx = rng.integers(0, d, (n, nnz)).astype(np.int32)
    val = rng.standard_normal((n, nnz)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], n).astype(np.float32)
    tc = tile_cache.build_cache(tmp_path / "c", "t", idx=idx, val=val,
                                y=y, d=d, bucket=B, pods=2,
                                pad_multiple=64)
    assert tc.meta.n == 64 and tc.meta.n_examples == 50
    (idx2, val2), y2 = tc.load_arrays()
    np.testing.assert_array_equal(idx, idx2[:n])
    np.testing.assert_array_equal(val, val2[:n])
    np.testing.assert_array_equal(y, y2[:n])
    assert (val2[n:] == 0).all() and (y2[n:] == 1.0).all()


def test_cache_nnz_multiple_pads_rows_lane_aligned(tmp_path):
    """build_cache(..., nnz_multiple=8) pads odd row widths with inert
    idx=0/val=0 columns so tiles satisfy the sparse kernel's alignment
    (PR-4 satellite)."""
    rng = np.random.default_rng(7)
    n, nnz, d, B = 32, 5, 16, 8               # nnz 5 -> padded to 8
    idx = rng.integers(0, d, (n, nnz)).astype(np.int32)
    val = rng.standard_normal((n, nnz)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], n).astype(np.float32)
    tc = tile_cache.build_cache(tmp_path / "c", "t", idx=idx, val=val,
                                y=y, d=d, bucket=B, nnz_multiple=8)
    assert tc.meta.nnz == 8
    (idx2, val2), y2 = tc.load_arrays()
    np.testing.assert_array_equal(idx2[:, :nnz], idx)
    np.testing.assert_array_equal(val2[:, :nnz], val)
    assert (idx2[:, nnz:] == 0).all() and (val2[:, nnz:] == 0).all()
    np.testing.assert_array_equal(y2, y)
    # already-aligned widths are untouched, and the knob keys the
    # materialize cache so aligned/unaligned builds coexist
    tc2 = tile_cache.build_cache(tmp_path / "c2", "t", idx=idx2,
                                 val=val2, y=y, d=d, bucket=B,
                                 nnz_multiple=8)
    assert tc2.meta.nnz == 8
    a = registry.materialize("synthetic-sparse", tmp_path, n=64, d=32)
    b = registry.materialize("synthetic-sparse", tmp_path, n=64, d=32,
                             nnz_multiple=16)
    assert a.path != b.path and b.meta.nnz == 16


def test_cache_slice_gather_compacts_feature_slice(tmp_path):
    """TileCache.slice_gather keeps only a [lo, hi) feature slice's
    nonzeros, in row order, rebased to slice-local ids and padded to
    the kernel lane multiple (DESIGN.md S12 streamed-shard building
    block)."""
    rng = np.random.default_rng(11)
    n, nnz, d, B = 32, 8, 40, 8
    idx = rng.integers(0, d, (n, nnz)).astype(np.int32)
    val = rng.standard_normal((n, nnz)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], n).astype(np.float32)
    tc = tile_cache.build_cache(tmp_path / "c", "t", idx=idx, val=val,
                                y=y, d=d, bucket=B)
    lo, hi = 16, 32
    bids = np.asarray([2, 0, 3])
    (idx_s, val_s), y_s = tc.slice_gather(bids, lo, hi)
    (idx_g, val_g), y_g = tc.gather_buckets(bids)
    np.testing.assert_array_equal(y_s, y_g)
    assert idx_s.shape[-1] % 8 == 0
    for r in range(idx_g.shape[0]):
        own = [(int(i) - lo, float(v)) for i, v in
               zip(idx_g[r], val_g[r]) if lo <= i < hi and v != 0]
        got = [(int(i), float(v)) for i, v in
               zip(idx_s[r], val_s[r]) if v != 0]
        assert got == own                         # order-preserving
        assert (val_s[r, len(own):] == 0).all()   # inert right padding
    # the slice's dense reconstruction equals slicing the full rows
    Xf = formats.to_dense(idx_g, val_g, d)[lo:hi]
    Xs = formats.to_dense(idx_s, val_s, hi - lo)
    np.testing.assert_array_equal(Xf, Xs)
    # guards: sparse-only, sane bounds
    rngd = np.random.default_rng(12)
    Xd = rngd.standard_normal((8, 16)).astype(np.float32)
    yd = np.ones(16, np.float32)
    tcd = tile_cache.build_cache(tmp_path / "cd", "t", X=Xd, y=yd,
                                 bucket=8)
    with pytest.raises(ValueError, match="sparse-only"):
        tcd.slice_gather(bids, lo, hi)
    with pytest.raises(ValueError, match="feature slice"):
        tc.slice_gather(bids, 8, 8)


def test_raw_ingest_nnz_multiple_reaches_pallas(tmp_path):
    """The alignment error's suggested fix is reachable from the top:
    a raw svmlight ingest with an odd row width trains with
    local_solver='pallas' once fit_dataset passes nnz_multiple=8."""
    import warnings
    from repro.core import EngineConfig, fit_dataset

    rng = np.random.default_rng(9)
    n, nnz, d = 96, 5, 64                     # nnz=5: misaligned raw rows
    idx = rng.integers(0, d, (n, nnz)).astype(np.int32)
    val = rng.standard_normal((n, nnz)).astype(np.float32)
    val = formats.zero_duplicates(idx, val)
    y = rng.choice([-1.0, 1.0], n).astype(np.float32)
    raw_dir = tmp_path / "raw"
    raw_dir.mkdir()
    (raw_dir / "criteo-kaggle-sub.svm").write_text(
        formats.dump_svmlight(idx, val, y))
    kw = dict(cache_dir=tmp_path / "cache", data_dir=raw_dir,
              streamed=True, max_epochs=2, tol=0.0, nnz_multiple=8)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        outs = {}
        for solver in ("xla", "pallas"):
            cfg = EngineConfig.make(lanes=2, bucket=8, chunks=2,
                                    deterministic=True,
                                    local_solver=solver)
            res = fit_dataset("criteo-kaggle-sub", cfg=cfg, **kw)
            outs[solver] = (res.alpha, res.v)
    assert np.array_equal(outs["xla"][0], outs["pallas"][0])
    assert np.array_equal(outs["xla"][1], outs["pallas"][1])
    assert np.abs(outs["pallas"][1]).max() > 0


def test_cache_version_and_magic_guard(tmp_path):
    rng = np.random.default_rng(3)
    X = rng.standard_normal((4, 16)).astype(np.float32)
    y = np.ones(16, np.float32)
    tile_cache.build_cache(tmp_path / "c", "t", X=X, y=y, bucket=8)
    doc = json.loads((tmp_path / "c" / "meta.json").read_text())
    doc["version"] = 999
    (tmp_path / "c" / "meta.json").write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="version"):
        tile_cache.open_cache(tmp_path / "c")
    doc["magic"] = "nope"
    (tmp_path / "c" / "meta.json").write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="not a"):
        tile_cache.open_cache(tmp_path / "c")
    # crc verification catches bit flips
    tc = tile_cache.build_cache(tmp_path / "c2", "t", X=X, y=y, bucket=8)
    assert tile_cache.open_cache(tc.path, verify=True)
    data = bytearray((tc.path / "X.bin").read_bytes())
    data[3] ^= 0xFF
    (tc.path / "X.bin").write_bytes(bytes(data))
    with pytest.raises(ValueError, match="crc32"):
        tile_cache.open_cache(tc.path, verify=True)


def _cache_digest(path: pathlib.Path) -> dict:
    return {f.name: hashlib.sha256(f.read_bytes()).hexdigest()
            for f in sorted(path.iterdir())}


def test_cache_bit_stable_across_processes(tmp_path):
    """Two builds of the same registry dataset — one in a fresh
    process — produce byte-identical cache directories."""
    here = registry.materialize("synthetic-sparse", tmp_path / "a",
                                bucket=8, pods=2, n=256, d=64)
    code = (
        "import sys; sys.path.insert(0, %r)\n"
        "from repro.data import registry\n"
        "registry.materialize('synthetic-sparse', %r, bucket=8, pods=2, "
        "n=256, d=64)\n"
        % (str(REPO / "src"), str(tmp_path / "b")))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=dict(os.environ), timeout=300)
    assert r.returncode == 0, r.stderr
    da = _cache_digest(here.path)
    db = _cache_digest(next((tmp_path / "b").iterdir()))
    assert da == db


# -- registry ---------------------------------------------------------------

def test_registry_specs_and_fallbacks():
    with pytest.raises(ValueError, match="unknown dataset"):
        registry.get_spec("nope")
    ds = registry.get_dataset("higgs", n=512)
    assert not ds.sparse and ds.X.shape == (28, 512)
    assert 0 < ds.scale < 1e-3
    # row width is the kernel-aligned 40 (criteo's real ~39 padded to a
    # multiple of 8 so local_solver="pallas" works out of the box)
    ds = registry.get_dataset("criteo-kaggle-sub", n=256, d=128)
    assert ds.sparse and ds.idx.shape == (256, 40)
    assert ds.provenance == "synthetic"


def test_registry_ingests_raw_svmlight_file(tmp_path):
    rng = np.random.default_rng(4)
    idx = rng.integers(0, 64, (32, 4)).astype(np.int32)
    val = rng.standard_normal((32, 4)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], 32).astype(np.float32)
    (tmp_path / "criteo-kaggle-sub.svm").write_text(
        formats.dump_svmlight(idx, val, y))
    ds = registry.get_dataset("criteo-kaggle-sub", data_dir=tmp_path)
    assert ds.provenance.startswith("file:")
    np.testing.assert_array_equal(ds.val, val)
    np.testing.assert_array_equal(ds.y, y)


# -- streamed == in-memory (the acceptance pin) -----------------------------

@pytest.mark.parametrize("name", ["synthetic-dense", "synthetic-sparse"])
def test_streamed_matches_inmemory_bitwise(tmp_path, name):
    """Streamed-from-cache training is bitwise-identical to in-memory
    training under deterministic=True (dense + sparse registry data)."""
    kw = dict(cfg=DET_CFG, cache_dir=tmp_path, n=512, d=64,
              max_epochs=3, tol=0.0)
    mem = fit_dataset(name, streamed=False, **kw)
    st = fit_dataset(name, streamed=True, **kw)
    assert np.array_equal(mem.alpha, st.alpha)
    assert np.array_equal(mem.v, st.v)
    assert np.abs(st.v).max() > 0              # actually trained
    assert st.final_gap < 1.0


def test_streamed_feed_sources_agree(tmp_path):
    """TileFeed (mmap cache) and ArrayFeed (resident arrays) drive the
    streamed loop to identical results — cache exactness isolated from
    the chunk-loop contract."""
    from repro.core import engine
    from repro.core.objectives import LOGISTIC

    cache = registry.materialize("synthetic-dense", tmp_path, bucket=8,
                                 pods=2, n=256, d=32, pad_multiple=64)
    X, y = cache.load_arrays()
    feeds = [cache.feed(),
             tile_cache.ArrayFeed(y, X=X, bucket=8)]
    outs = []
    for feed in feeds:
        tr = StreamedGLMTrainer(cache, cfg=DET_CFG, lam=1e-2)
        ep = engine.make_streamed_epoch(LOGISTIC, DET_CFG, tr.plan,
                                        feed, lam=1e-2)
        a, v = tr.alpha, tr.v
        for e in range(2):
            a, v = ep(a, v, e)
        outs.append((np.asarray(a), np.asarray(v)))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    np.testing.assert_array_equal(outs[0][1], outs[1][1])


def test_streamed_trainer_guards_bucket_mismatch(tmp_path):
    cache = registry.materialize("synthetic-dense", tmp_path, bucket=8,
                                 n=256, d=32)
    bad = EngineConfig.make(bucket=16)
    with pytest.raises(ValueError, match="bucket"):
        StreamedGLMTrainer(cache, cfg=bad)


def test_streamed_gap_matches_inmemory_diagnostics(tmp_path):
    res, tr = fit_dataset("synthetic-dense", cfg=DET_CFG,
                          cache_dir=tmp_path, n=512, d=64, streamed=True,
                          max_epochs=3, tol=0.0, return_trainer=True)
    mem_res, mem_tr = fit_dataset("synthetic-dense", cfg=DET_CFG,
                                  cache_dir=tmp_path, n=512, d=64,
                                  streamed=False, max_epochs=3, tol=0.0,
                                  return_trainer=True)
    assert tr.gap() == pytest.approx(mem_tr.gap(), rel=1e-3, abs=1e-6)
    assert tr.primal() == pytest.approx(mem_tr.primal(), rel=1e-3)


# -- benchmark harness ------------------------------------------------------

def test_bench_run_writes_json_and_fails_loudly(tmp_path, monkeypatch,
                                                capsys):
    sys.path.insert(0, str(REPO))
    from benchmarks import run as bench_run

    class Boom:
        @staticmethod
        def run(quick=True):
            raise RuntimeError("figure exploded")

    class Fine:
        @staticmethod
        def run(quick=True):
            return [{"bench": "ok", "gap": 1e-4}]

    out = tmp_path / "BENCH_2.json"
    monkeypatch.setattr(bench_run, "BENCHES",
                        [("fine", Fine), ("boom", Boom)])
    rc = bench_run.main(["--json", str(out)])
    assert rc == 1                              # a raising figure fails CI
    doc = json.loads(out.read_text())
    assert doc["failed"] == ["boom"]
    assert doc["figures"]["fine"]["final_gap"] == pytest.approx(1e-4)
    assert doc["figures"]["boom"]["failed"] is True
    assert str(out) in capsys.readouterr().out  # path is printed

    monkeypatch.setattr(bench_run, "BENCHES", [("fine", Fine)])
    assert bench_run.main(["--json", str(out)]) == 0
