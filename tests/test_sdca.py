"""SDCA core: sequential convergence, bucket/Gram exactness, sparse path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import objectives as O
from repro.core import sdca
from repro.data import (make_dense_classification, make_dense_regression,
                        make_sparse_classification)


def _run_sequential(obj, X, y, lam, epochs, bucket=1, seed=0):
    d, n = X.shape
    alpha = jnp.zeros(n)
    v = jnp.zeros(d)
    for e in range(epochs):
        perm = jax.random.permutation(
            jax.random.fold_in(jax.random.PRNGKey(seed), e), n)
        alpha, v = sdca.sequential_epoch(obj, X, y, alpha, v, lam,
                                         perm.astype(jnp.int32),
                                         bucket=bucket)
    return alpha, v


@pytest.mark.parametrize("objname,maker", [
    ("logistic", make_dense_classification),
    ("hinge", make_dense_classification),
    ("ridge", make_dense_regression),
])
def test_sequential_converges(objname, maker):
    obj = O.get_objective(objname)
    X, y = maker(n=512, d=20, seed=0)
    X, y = jnp.asarray(X), jnp.asarray(y)
    lam = 1e-2
    alpha, v = _run_sequential(obj, X, y, lam, epochs=30, bucket=8)
    gap = float(O.duality_gap(obj, alpha, v, X, y, lam))
    p = float(O.primal_value(obj, v, X, y, lam))
    assert gap < 1e-3 * max(abs(p), 1.0), (objname, gap, p)


def test_bucket_gram_recursion_is_exact():
    """bucket>1 must produce EXACTLY the per-coordinate sequence."""
    obj = O.LOGISTIC
    X, y = make_dense_classification(n=128, d=16, seed=1)
    X, y = jnp.asarray(X), jnp.asarray(y)
    lam = 1e-2
    a1, v1 = _run_sequential(obj, X, y, lam, epochs=3, bucket=1, seed=3)
    a8, v8 = _run_sequential(obj, X, y, lam, epochs=3, bucket=8, seed=3)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v8),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a8),
                               rtol=2e-4, atol=2e-5)


def test_v_consistency_invariant():
    """v must always equal X @ alpha / (lam n) after any epoch."""
    obj = O.LOGISTIC
    X, y = make_dense_classification(n=256, d=12, seed=2)
    X, y = jnp.asarray(X), jnp.asarray(y)
    lam = 5e-2
    alpha, v = _run_sequential(obj, X, y, lam, epochs=5, bucket=16)
    v_re = X @ alpha / (lam * y.shape[0])
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_re),
                               rtol=1e-3, atol=1e-5)


def test_sparse_matches_dense_on_same_data():
    """A dense matrix expressed in padded-CSR must give the same result."""
    obj = O.LOGISTIC
    rng = np.random.default_rng(3)
    d, n = 10, 64
    Xd = rng.standard_normal((d, n)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], n).astype(np.float32)
    lam = 1e-2
    idx = np.tile(np.arange(d, dtype=np.int32), (n, 1))
    val = Xd.T.copy()

    lam_n = jnp.float32(lam * n)
    a0 = jnp.zeros(n)
    v0 = jnp.zeros(d)
    a_s, dv_s = sdca.sparse_local_subepoch(
        obj, jnp.asarray(idx), jnp.asarray(val), jnp.asarray(y), a0, v0,
        lam_n, jnp.float32(1.0))
    a_d, dv_d = sdca.dense_local_subepoch(
        obj, jnp.asarray(Xd), jnp.asarray(y), a0, v0, lam_n,
        jnp.float32(1.0), bucket=8)
    np.testing.assert_allclose(np.asarray(a_s), np.asarray(a_d),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(dv_s), np.asarray(dv_d),
                               rtol=2e-4, atol=2e-5)


def test_sparse_sequential_converges():
    (idx, val), y, d = make_sparse_classification(n=512, d=64, nnz=6,
                                                  seed=4)
    obj = O.LOGISTIC
    lam = 1e-2
    n = y.shape[0]
    alpha = jnp.zeros(n)
    v = jnp.zeros(d)
    for e in range(30):
        alpha, v = sdca.sparse_local_subepoch(
            obj, jnp.asarray(idx), jnp.asarray(val), jnp.asarray(y),
            alpha, v, jnp.float32(lam * n), jnp.float32(1.0))
        v = jnp.zeros(d).at[jnp.asarray(idx).reshape(-1)].add(
            (jnp.asarray(val) * alpha[:, None]).reshape(-1)) / (lam * n)
    m = jnp.sum(v[jnp.asarray(idx)] * jnp.asarray(val), axis=1)
    p = float(jnp.sum(obj.loss(m, jnp.asarray(y))) / n
              + 0.5 * lam * jnp.sum(v * v))
    dual = float(O.dual_value(obj, alpha, v, jnp.asarray(y), lam))
    assert p - dual < 1e-3 * max(abs(p), 1.0), (p, dual)
