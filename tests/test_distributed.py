"""Multi-device SPMD tests (subprocess: needs 8 forced host devices).

Each test shells out with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps its single CPU device (per the repo
convention: only launch entrypoints force device counts).
"""
import os
import pathlib
import subprocess
import sys
import textwrap


REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(code: str, timeout=600):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(REPO / "src"))
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_distributed_glm_epochs_converge():
    r = _run("""
        import jax, jax.numpy as jnp
        from repro.launch.glm import GLMScale, make_dense_epoch, \\
            make_sparse_epoch
        from repro.launch.mesh import make_host_mesh
        from repro.core.objectives import LOGISTIC, duality_gap
        from repro.data import make_dense_classification, \\
            make_sparse_classification
        import repro.core.objectives as O

        mesh = make_host_mesh(pod=2, data=2, model=2)

        # dense, feature-sharded (TP) path
        sc = GLMScale("t", "dense", n=1024, d=64, bucket=8, chunks=2,
                      feature_shard=True, lam=1e-2, compress_pod=False)
        X, y = make_dense_classification(n=1024, d=64, seed=0)
        X, y = jnp.asarray(X), jnp.asarray(y)
        a, v = jnp.zeros(1024), jnp.zeros(64)
        with mesh:
            ep = jax.jit(make_dense_epoch(sc, mesh))
            for e in range(15):
                X, y, a, v = ep(X, y, a, v, jnp.int32(e))
            gap = float(duality_gap(LOGISTIC, a, v, X, y, 1e-2))
        assert abs(gap) < 1e-3, gap

        # sparse path with int8 cross-pod reduce
        (idx, val), ys, d = make_sparse_classification(
            n=1024, d=256, nnz=8, seed=2)
        sc3 = GLMScale("t3", "sparse", n=1024, d=256, nnz=8, bucket=8,
                       chunks=2, lam=1e-2, compress_pod=True)
        with mesh:
            ep3 = jax.jit(make_sparse_epoch(sc3, mesh))
            ii, vv, yy = (jnp.asarray(t) for t in (idx, val, ys))
            aa, vvec = jnp.zeros(1024), jnp.zeros(256)
            for e in range(15):
                ii, vv, yy, aa, vvec = ep3(ii, vv, yy, aa, vvec,
                                           jnp.int32(e))
            m = jnp.sum(vvec[ii] * vv, axis=1)
            p = (jnp.sum(O.LOGISTIC.loss(m, yy)) / 1024
                 + 0.5 * 1e-2 * jnp.sum(vvec ** 2))
            dv = O.dual_value(O.LOGISTIC, aa, vvec, yy, 1e-2)
        assert abs(float(p - dv)) < 1e-2, float(p - dv)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_lm_train_step_sharded_matches_single_device():
    r = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.launch import steps as steps_lib
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import batch_at
        from repro.optim import adamw

        cfg = dataclasses.replace(get_smoke("smollm-360m"),
                                  n_heads=4, n_kv_heads=2, d_model=128,
                                  d_ff=256)
        opt_cfg = steps_lib.make_opt_cfg(cfg)
        b = batch_at(cfg, 4, 32, 0)

        def run(mesh):
            params = steps_lib.init_params(cfg, jax.random.PRNGKey(0),
                                           mesh)
            opt = adamw.init(params, opt_cfg)
            ctx = mesh if mesh is not None else jax.sharding.Mesh(
                np.array(jax.devices()[:1]), ("x",))
            step = jax.jit(steps_lib.make_train_step(cfg, opt_cfg))
            losses = []
            for s in range(3):
                params, opt, m = step(params, opt, b)
                losses.append(float(m["loss"]))
            return losses

        l1 = run(None)
        mesh = make_host_mesh(pod=2, data=2, model=2)
        from repro import sharding as shctx
        shctx.set_mesh(mesh)
        with mesh:
            l8 = run(mesh)
        shctx.set_mesh(None)
        np.testing.assert_allclose(l1, l8, rtol=2e-2, atol=2e-2)
        print("OK", l1, l8)
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_elastic_checkpoint_across_meshes():
    r = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.configs import get_smoke
        from repro.checkpoint import save_tree, restore_tree
        from repro.launch import steps as steps_lib
        from repro.launch.mesh import make_host_mesh
        from repro.launch.specs import clean_pspec
        from jax.sharding import NamedSharding
        from repro.models.layers import ParamSpec

        cfg = dataclasses.replace(get_smoke("smollm-360m"),
                                  d_model=128, n_heads=4, n_kv_heads=2)
        mesh_a = make_host_mesh(pod=1, data=2, model=4)
        mesh_b = make_host_mesh(pod=2, data=2, model=2)

        params = steps_lib.init_params(cfg, jax.random.PRNGKey(0), mesh_a)
        with tempfile.TemporaryDirectory() as td:
            save_tree(td + "/ck", params)
            specs = steps_lib.model_param_specs(cfg, mesh_b)
            sh = jax.tree.map(
                lambda s: NamedSharding(mesh_b,
                                        clean_pspec(mesh_b, s.pspec)),
                specs, is_leaf=lambda x: isinstance(x, ParamSpec))
            out, _ = restore_tree(td + "/ck", params, shardings=sh)
        for l1, l2 in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
            np.testing.assert_array_equal(
                np.asarray(l1, np.float32), np.asarray(l2, np.float32))
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr
