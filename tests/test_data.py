"""Data pipeline: determinism, hierarchy, learnable token stream."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test dep
from hypothesis import given, settings, strategies as st

from repro.data import (criteo_like, epsilon_like, higgs_like,
                        make_dense_classification,
                        make_sparse_classification)
from repro.data.loader import ShardedBatcher, markov_batch


def test_batcher_is_deterministic_and_restartable():
    b1 = ShardedBatcher(n=256, global_batch=32, pods=2, lanes=4, seed=3)
    b2 = ShardedBatcher(n=256, global_batch=32, pods=2, lanes=4, seed=3)
    for e in range(3):
        for x, y in zip(b1.batches(e), b2.batches(e)):
            np.testing.assert_array_equal(x, y)


def test_batcher_epoch_covers_all_and_respects_pods():
    b = ShardedBatcher(n=128, global_batch=16, pods=2, lanes=2, seed=0)
    seen = []
    for batch in b.batches(0):
        assert batch.shape == (16,)
        half = 16 // 2
        assert (batch[:half] < 64).all()      # pod 0's static range
        assert (batch[half:] >= 64).all()     # pod 1's static range
        seen.extend(batch.tolist())
    assert sorted(seen) == list(range(128))


def test_batcher_reshuffles_within_pod_across_epochs():
    b = ShardedBatcher(n=128, global_batch=16, pods=2, lanes=2, seed=0)
    e0 = np.concatenate(list(b.batches(0)))
    e1 = np.concatenate(list(b.batches(1)))
    assert not np.array_equal(e0, e1)


def test_markov_batch_restartable_and_learnable():
    b1 = markov_batch(64, 8, 32, table_seed=1, step=5)
    b2 = markov_batch(64, 8, 32, table_seed=1, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # structure: successors should concentrate on <= 4 values per token
    big = markov_batch(16, 64, 128, table_seed=1, step=0)
    toks, labs = big["tokens"].reshape(-1), big["labels"].reshape(-1)
    t0 = toks[toks == 3]
    succ = labs[toks == 3]
    if len(succ) > 30:
        top4 = np.sort(np.bincount(succ, minlength=16))[-4:].sum()
        assert top4 / len(succ) > 0.6


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_sparse_dataset_properties(seed):
    (idx, val), y, d = make_sparse_classification(n=64, d=128, nnz=5,
                                                  seed=seed)
    assert idx.shape == (64, 5) and val.shape == (64, 5)
    assert idx.min() >= 0 and idx.max() < d
    assert set(np.unique(y)) <= {-1.0, 1.0}


def test_dense_dataset_normalized():
    X, y = make_dense_classification(n=128, d=16, seed=0)
    norms = np.linalg.norm(X, axis=0)
    np.testing.assert_allclose(norms, 1.0, rtol=1e-5)
    assert set(np.unique(y)) <= {-1.0, 1.0}


def test_standin_dataset_shapes():
    (idx, val), y, d = criteo_like(n=1024, d=512)
    assert idx.shape[1] == 39 and d == 512
    Xh, yh = higgs_like(n=1024)
    assert Xh.shape == (28, 1024)
    Xe, ye = epsilon_like(n=512)
    assert Xe.shape == (2000, 512)


def test_criteo_like_is_skewed():
    (idx, _), _, d = criteo_like(n=4096, d=256)
    counts = np.bincount(idx.reshape(-1), minlength=256)
    top = np.sort(counts)[-26:].sum() / counts.sum()
    assert top > 0.3    # top-10% of features get >30% of mass (Zipf-ish)
