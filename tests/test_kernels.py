"""Pallas kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.objectives import HINGE, LOGISTIC, RIDGE
from repro.kernels import ops, ref

OBJS = [LOGISTIC, RIDGE, HINGE]


def _data(obj, d, n, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((d, n)), dtype)
    y = jnp.asarray(rng.choice([-1.0, 1.0], n) if obj.classification
                    else rng.standard_normal(n), dtype)
    a = jnp.zeros(n, dtype)
    v0 = jnp.asarray(rng.standard_normal(d) * 0.1, jnp.float32)
    return X, y, a, v0


@pytest.mark.parametrize("obj", OBJS, ids=lambda o: o.name)
@pytest.mark.parametrize("d,n,B", [
    (8, 32, 8),          # minimal tile
    (37, 64, 16),        # d needs padding
    (100, 96, 16),       # padding + several buckets
    (128, 64, 32),       # aligned, wide bucket
    (13, 40, 8),         # both d and n awkward; B | n
])
def test_sdca_bucket_kernel_matches_oracle(obj, d, n, B):
    X, y, a, v0 = _data(obj, d, n, seed=d * 1000 + n)
    lam_n, sig = 0.1 * n, 2.0
    a_k, dv_k = ops.sdca_bucket_subepoch(obj, X, y, a, v0, lam_n, sig,
                                         bucket=B, interpret=True)
    a_r, v_r = ref.sdca_subepoch_ref(obj, X, y, a, v0, lam_n, sig)
    dv_r = (v_r - v0) / sig
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r),
                               rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(np.asarray(dv_k), np.asarray(dv_r),
                               rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("obj", OBJS, ids=lambda o: o.name)
def test_sdca_kernel_sequential_semantics(obj):
    """Kernel must process buckets IN ORDER: running it over [b0, b1] must
    equal running b0 then b1 with the carried v."""
    d, n, B = 16, 32, 16
    X, y, a, v0 = _data(obj, d, n, seed=9)
    lam_n, sig = 3.2, 1.0
    a_all, dv_all = ops.sdca_bucket_subepoch(obj, X, y, a, v0, lam_n, sig,
                                             bucket=B, interpret=True)
    a1, dv1 = ops.sdca_bucket_subepoch(obj, X[:, :B], y[:B], a[:B], v0,
                                       lam_n, sig, bucket=B,
                                       interpret=True)
    v_mid = v0 + sig * dv1
    a2, dv2 = ops.sdca_bucket_subepoch(obj, X[:, B:], y[B:], a[B:], v_mid,
                                       lam_n, sig, bucket=B,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(a_all),
                               np.concatenate([a1, a2]),
                               rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(np.asarray(dv_all),
                               np.asarray(dv1 + dv2), rtol=3e-4,
                               atol=3e-5)


@pytest.mark.parametrize("T,D,bt", [
    (64, 128, 16), (128, 128, 128), (256, 256, 64), (32, 8, 8),
])
def test_rglru_kernel_matches_oracle(T, D, bt):
    rng = np.random.default_rng(T + D)
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    ga = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    gx = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    a_log = -jnp.abs(jnp.asarray(rng.standard_normal(D), jnp.float32)) * .1
    h0 = jnp.asarray(rng.standard_normal(D) * 0.1, jnp.float32)
    hk = ops.rglru_scan(x, a_log, ga, gx, h0, block_t=bt, interpret=True)
    hr = ref.rglru_ref(x, a_log, ga, gx, h0)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_kernel_dtypes(dtype):
    T, D = 64, 128
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((T, D)), dtype)
    ga = jnp.asarray(rng.standard_normal((T, D)), dtype)
    gx = jnp.asarray(rng.standard_normal((T, D)), dtype)
    a_log = -jnp.abs(jnp.asarray(rng.standard_normal(D), jnp.float32)) * .1
    h0 = jnp.zeros(D, jnp.float32)
    hk = ops.rglru_scan(x, a_log, ga, gx, h0, block_t=32, interpret=True)
    hr = ref.rglru_ref(x.astype(jnp.float32), a_log,
                       ga.astype(jnp.float32), gx.astype(jnp.float32), h0)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(hk, np.float32),
                               np.asarray(hr), rtol=tol, atol=tol)


def test_kernel_rejects_bad_tile():
    from repro.kernels import sdca_bucket
    with pytest.raises(ValueError, match="multiples of 8"):
        sdca_bucket.sdca_bucket_kernel(
            LOGISTIC, jnp.zeros((2, 9, 8)), jnp.zeros((2, 8)),
            jnp.zeros((2, 8)), jnp.zeros((9, 1)), jnp.zeros(2), True)
    # the error names the offending data source
    with pytest.raises(ValueError, match="tile cache"):
        sdca_bucket.sdca_bucket_kernel(
            LOGISTIC, jnp.zeros((2, 9, 8)), jnp.zeros((2, 8)),
            jnp.zeros((2, 8)), jnp.zeros((9, 1)), jnp.zeros(2), True,
            "tile cache")


# ---------------------------------------------------------------------------
# Sparse SDCA bucket kernel (kernels/sdca_sparse_bucket.py): the contract
# is BITWISE equality with the XLA gather/scatter scan, not allclose.
# ---------------------------------------------------------------------------

from repro.core import sdca as core_sdca
from repro.data.formats import zero_duplicates


def _sparse_data(obj, n, d, nnz, seed, v_scale=0.1):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, d, (n, nnz)).astype(np.int32)
    val = (rng.standard_normal((n, nnz)) / np.sqrt(max(nnz, 1))
           ).astype(np.float32)
    val = zero_duplicates(idx, val)          # CSR invariant (S11)
    y = np.asarray(rng.choice([-1.0, 1.0], n) if obj.classification
                   else rng.standard_normal(n), np.float32)
    a = np.zeros(n, np.float32)
    v0 = (rng.standard_normal(d) * v_scale).astype(np.float32)
    return (jnp.asarray(idx), jnp.asarray(val), jnp.asarray(y),
            jnp.asarray(a), jnp.asarray(v0))


def _run_both(obj, idx, val, y, a, v0, lam_n, sig, B):
    a_ref, dv_ref = core_sdca.sparse_local_subepoch(
        obj, idx, val, y, a, v0, jnp.float32(lam_n), jnp.float32(sig))
    a_k, dv_k = ops.sdca_sparse_bucket_subepoch(
        obj, idx, val, y, a, v0, jnp.float32(lam_n), jnp.float32(sig),
        bucket=B, interpret=True)
    return (np.asarray(a_ref), np.asarray(dv_ref),
            np.asarray(a_k), np.asarray(dv_k))


@pytest.mark.parametrize("obj", OBJS, ids=lambda o: o.name)
@pytest.mark.parametrize("n,d,nnz,B", [
    (32, 64, 8, 8),       # minimal tile
    (64, 128, 16, 8),     # wider rows, several buckets
    (64, 32, 8, 16),      # tiny d: heavy feature sharing inside buckets
    (48, 1000, 8, 8),     # nearly collision-free rows
])
def test_sdca_sparse_kernel_bitwise_vs_scan(obj, n, d, nnz, B):
    idx, val, y, a, v0 = _sparse_data(obj, n, d, nnz, seed=n * 7 + d)
    a_ref, dv_ref, a_k, dv_k = _run_both(
        obj, idx, val, y, a, v0, 0.1 * n, 2.0, B)
    np.testing.assert_array_equal(a_k, a_ref)
    np.testing.assert_array_equal(dv_k, dv_ref)
    assert np.abs(dv_k).max() > 0          # actually moved


@pytest.mark.parametrize("obj", OBJS, ids=lambda o: o.name)
def test_sdca_sparse_kernel_sequential_semantics(obj):
    """Buckets must be processed IN ORDER: one call over [b0, b1] must
    equal b0 then b1 with the carried v — bitwise."""
    n, d, nnz, B = 32, 64, 8, 16
    idx, val, y, a, v0 = _sparse_data(obj, n, d, nnz, seed=5)
    lam_n, sig = jnp.float32(3.2), jnp.float32(1.0)
    a_all, dv_all = ops.sdca_sparse_bucket_subepoch(
        obj, idx, val, y, a, v0, lam_n, sig, bucket=B, interpret=True)
    a1, dv1 = ops.sdca_sparse_bucket_subepoch(
        obj, idx[:B], val[:B], y[:B], a[:B], v0, lam_n, sig,
        bucket=B, interpret=True)
    v_mid = v0 + sig * dv1
    a2, _ = ops.sdca_sparse_bucket_subepoch(
        obj, idx[B:], val[B:], y[B:], a[B:], v_mid, lam_n, sig,
        bucket=B, interpret=True)
    np.testing.assert_array_equal(np.asarray(a_all),
                                  np.concatenate([a1, a2]))
    assert np.abs(np.asarray(dv_all)).max() > 0


def test_sdca_sparse_kernel_padding_rows_inert():
    """Cache-style padding rows (idx=0, val=0, y=+1) leave v untouched
    and the real rows' results bitwise-unchanged."""
    n, d, nnz, B = 24, 64, 8, 8
    idx, val, y, a, v0 = _sparse_data(LOGISTIC, n, d, nnz, seed=11)
    pad = 8
    idx_p = jnp.concatenate([idx, jnp.zeros((pad, nnz), jnp.int32)])
    val_p = jnp.concatenate([val, jnp.zeros((pad, nnz), jnp.float32)])
    y_p = jnp.concatenate([y, jnp.ones(pad, jnp.float32)])
    a_p = jnp.concatenate([a, jnp.zeros(pad, jnp.float32)])
    lam_n, sig = jnp.float32(0.1 * n), jnp.float32(2.0)
    a1, dv1 = ops.sdca_sparse_bucket_subepoch(
        LOGISTIC, idx, val, y, a, v0, lam_n, sig, bucket=B,
        interpret=True)
    a2, dv2 = ops.sdca_sparse_bucket_subepoch(
        LOGISTIC, idx_p, val_p, y_p, a_p, v0, lam_n, sig, bucket=B,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(a2)[:n], np.asarray(a1))
    np.testing.assert_array_equal(np.asarray(dv2), np.asarray(dv1))


def test_sdca_sparse_kernel_rejects_misalignment_actionably():
    ok = dict(bucket=8, interpret=True)
    idx, val, y, a, v0 = _sparse_data(LOGISTIC, 16, 32, 8, seed=0)
    lam_n = sig = jnp.float32(1.0)
    # nnz not a multiple of 8: names the alignment AND both fixes
    with pytest.raises(ValueError, match="multiples of 8"):
        ops.sdca_sparse_bucket_subepoch(
            LOGISTIC, idx[:, :7], val[:, :7], y, a, v0, lam_n, sig, **ok)
    with pytest.raises(ValueError, match="nnz_multiple"):
        ops.sdca_sparse_bucket_subepoch(
            LOGISTIC, idx[:, :7], val[:, :7], y, a, v0, lam_n, sig, **ok)
    # the offending source is reported (cache vs ad-hoc arrays)
    with pytest.raises(ValueError, match="ad-hoc arrays"):
        ops.sdca_sparse_bucket_subepoch(
            LOGISTIC, idx[:, :7], val[:, :7], y, a, v0, lam_n, sig, **ok)
    with pytest.raises(ValueError, match="tile cache"):
        ops.sdca_sparse_bucket_subepoch(
            LOGISTIC, idx[:, :7], val[:, :7], y, a, v0, lam_n, sig,
            bucket=8, interpret=True, source="tile cache")
    # bucket not a multiple of 8
    with pytest.raises(ValueError, match="multiples of 8"):
        ops.sdca_sparse_bucket_subepoch(
            LOGISTIC, idx, val, y, a, v0, lam_n, sig, bucket=4,
            interpret=True)
    # bucket must divide the chunk
    with pytest.raises(ValueError, match="divide"):
        ops.sdca_sparse_bucket_subepoch(
            LOGISTIC, idx[:12], val[:12], y[:12], a[:12], v0, lam_n,
            sig, **ok)


def test_sdca_sparse_kernel_vmem_budget_guard():
    from repro.kernels.sdca_sparse_bucket import V_VMEM_BUDGET_BYTES
    d_big = V_VMEM_BUDGET_BYTES // 4 + 8
    idx, val, y, a, _ = _sparse_data(LOGISTIC, 8, 32, 8, seed=1)
    with pytest.raises(ValueError, match="xla"):
        ops.sdca_sparse_bucket_subepoch(
            LOGISTIC, idx, val, y, a, jnp.zeros(d_big, jnp.float32),
            jnp.float32(1.0), jnp.float32(1.0), bucket=8, interpret=True)


def test_sdca_sparse_kernel_total_vmem_budget_guard():
    """Wide tiles whose (B, nnz, nnz) match tensor blows the TOTAL VMEM
    budget get the same actionable ValueError narrow workloads do, not
    an opaque Mosaic OOM (v alone is tiny here: B=16, nnz=512 puts the
    match tensor at 16 MiB)."""
    from repro.kernels.sdca_sparse_bucket import (
        TOTAL_VMEM_BUDGET_BYTES, vmem_bytes_estimate)
    B, nnz, d = 16, 512, 64
    assert vmem_bytes_estimate(B, nnz, 64) > TOTAL_VMEM_BUDGET_BYTES
    idx = jnp.zeros((B, nnz), jnp.int32)
    val = jnp.zeros((B, nnz), jnp.float32)
    y = jnp.ones(B, jnp.float32)
    a = jnp.zeros(B, jnp.float32)
    with pytest.raises(ValueError, match="match tensor"):
        ops.sdca_sparse_bucket_subepoch(
            LOGISTIC, idx, val, y, a, jnp.zeros(d, jnp.float32),
            jnp.float32(1.0), jnp.float32(1.0), bucket=B, interpret=True)
    with pytest.raises(ValueError, match="xla"):
        ops.sdca_sparse_bucket_subepoch(
            LOGISTIC, idx, val, y, a, jnp.zeros(d, jnp.float32),
            jnp.float32(1.0), jnp.float32(1.0), bucket=B, interpret=True)


def test_sdca_dense_kernel_bucket_cap_and_vmem_guard():
    """The dense kernel enforces its documented B <= 512 cap and a
    total-VMEM budget (tile + resident v + Gram) with actionable
    errors instead of an opaque Mosaic OOM."""
    from repro.kernels.sdca_bucket import (MAX_BUCKET,
                                           TOTAL_VMEM_BUDGET_BYTES,
                                           vmem_bytes_estimate)
    one = jnp.float32(1.0)
    B = MAX_BUCKET + 8
    with pytest.raises(ValueError, match=str(MAX_BUCKET)):
        ops.sdca_bucket_subepoch(
            LOGISTIC, jnp.zeros((8, B)), jnp.ones(B), jnp.zeros(B),
            jnp.zeros(8), one, one, bucket=B, interpret=True)
    # tall tiles: d_pad * B over the total budget even at B = 512
    d = 4096
    assert vmem_bytes_estimate(MAX_BUCKET, d) > TOTAL_VMEM_BUDGET_BYTES
    with pytest.raises(ValueError, match="xla"):
        ops.sdca_bucket_subepoch(
            LOGISTIC, jnp.zeros((d, MAX_BUCKET)), jnp.ones(MAX_BUCKET),
            jnp.zeros(MAX_BUCKET), jnp.zeros(d), one, one,
            bucket=MAX_BUCKET, interpret=True)


def test_sdca_sparse_kernel_rejects_duplicate_nonzeros():
    """Concrete ad-hoc rows repeating a feature id with NONZERO values
    break the bitwise-vs-XLA contract silently — they must be rejected
    with a pointer at formats.zero_duplicates.  Zero-valued duplicates
    (padding, sanitized rows) stay accepted."""
    idx, val, y, a, v0 = _sparse_data(LOGISTIC, 8, 32, 8, seed=2)
    bad_idx = np.asarray(idx).copy()
    bad_val = np.asarray(val).copy()
    bad_idx[3, 1] = bad_idx[3, 0]            # duplicate feature id...
    bad_val[3, 0] = 0.5
    bad_val[3, 1] = 0.25                     # ...both values nonzero
    with pytest.raises(ValueError, match="zero_duplicates"):
        ops.sdca_sparse_bucket_subepoch(
            LOGISTIC, jnp.asarray(bad_idx), jnp.asarray(bad_val), y, a,
            v0, jnp.float32(1.0), jnp.float32(1.0), bucket=8,
            interpret=True)
    # a zero-valued duplicate BETWEEN two nonzero duplicates of the
    # same id must not mask the violation (value order A, 0, A after
    # the stable sort defeats a naive adjacent-pair check)
    tri_idx = np.asarray(idx).copy()
    tri_val = np.asarray(val).copy()
    tri_idx[5, :3] = 7
    tri_val[5, :3] = [1.0, 0.0, 2.0]
    with pytest.raises(ValueError, match="zero_duplicates"):
        ops.sdca_sparse_bucket_subepoch(
            LOGISTIC, jnp.asarray(tri_idx), jnp.asarray(tri_val), y, a,
            v0, jnp.float32(1.0), jnp.float32(1.0), bucket=8,
            interpret=True)
    # sanitizing the same rows makes them acceptable again
    ok_val = zero_duplicates(bad_idx, bad_val)
    ops.sdca_sparse_bucket_subepoch(
        LOGISTIC, jnp.asarray(bad_idx), jnp.asarray(ok_val), y, a, v0,
        jnp.float32(1.0), jnp.float32(1.0), bucket=8, interpret=True)


def test_sdca_sparse_kernel_bitwise_property():
    """Hypothesis sweep: bitwise equality with the scan across random
    shapes, objectives, scalings, and warm dual starts."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.sampled_from(OBJS),
           st.sampled_from([8, 16]),            # bucket
           st.integers(1, 3),                   # buckets per sub-epoch
           st.sampled_from([8, 16]),            # nnz
           st.integers(10, 200),                # d
           st.integers(0, 2 ** 16),             # data seed
           st.floats(0.05, 50.0),               # lam*n
           st.sampled_from([1.0, 2.0, 8.0]))    # sigma'
    @settings(max_examples=40, deadline=None)
    def bitwise(obj, B, nb, nnz, d, seed, lam_n, sig):
        n = B * nb
        idx, val, y, a, v0 = _sparse_data(obj, n, d, nnz, seed=seed)
        if obj.classification:    # feasible warm start: a*y in [0, 1)
            rng = np.random.default_rng(seed + 1)
            a = jnp.asarray(
                rng.uniform(0, 0.5, n).astype(np.float32) * np.asarray(y))
        a_ref, dv_ref, a_k, dv_k = _run_both(
            obj, idx, val, y, a, v0, lam_n, sig, B)
        np.testing.assert_array_equal(a_k, a_ref)
        np.testing.assert_array_equal(dv_k, dv_ref)

    bitwise()


# ---------------------------------------------------------------------------
# Feature-sharded sparse kernel (DESIGN.md S12): the same bitwise contract,
# lane by lane, with the engine's exchange emulated in-process.
# ---------------------------------------------------------------------------

from repro.kernels import sdca_sparse_bucket


@pytest.mark.parametrize("obj", OBJS, ids=lambda o: o.name)
@pytest.mark.parametrize("n,d,nnz,B", [
    (32, 64, 8, 8),       # aligned d
    (32, 250, 8, 16),     # d needs sublane padding inside the slice
])
def test_sdca_sparse_sharded_single_lane_bitwise(obj, n, d, nnz, B):
    """model_lanes=1: the one slice IS the whole v, so the sharded
    driver must reproduce the scan (and replicated kernel) bitwise."""
    idx, val, y, a, v0 = _sparse_data(obj, n, d, nnz, seed=n + d)
    lam_n, sig = jnp.float32(0.1 * n), jnp.float32(2.0)
    a_ref, dv_ref = core_sdca.sparse_local_subepoch(
        obj, idx, val, y, a, v0, lam_n, sig)
    a_s, dv_s = ops.sdca_sparse_sharded_subepoch(
        obj, idx, val, y, a, v0, lam_n, sig, bucket=B, interpret=True)
    np.testing.assert_array_equal(np.asarray(a_s), np.asarray(a_ref))
    np.testing.assert_array_equal(np.asarray(dv_s), np.asarray(dv_ref))
    assert np.abs(np.asarray(dv_s)).max() > 0


@pytest.mark.parametrize("obj", OBJS, ids=lambda o: o.name)
@pytest.mark.parametrize("M", [2, 4])
def test_sdca_sparse_sharded_multilane_emulated_exchange(obj, M):
    """Drive the per-bucket kernel pair lane by lane with the engine's
    all-gather/owner-select exchange emulated in jnp: the M lanes'
    disjoint dv slices, concatenated, must equal the serial scan's dv
    bitwise, and every lane must agree on the duals."""
    n, d, nnz, B = 32, 50, 8, 16       # d=50: uneven slices + padding
    idx, val, y, a, v0 = _sparse_data(obj, n, d, nnz, seed=3 + M)
    lam_n, sig = jnp.float32(0.1 * n), jnp.float32(2.0)
    a_ref, dv_ref = core_sdca.sparse_local_subepoch(
        obj, idx, val, y, a, v0, lam_n, sig)

    d_loc = ops.sparse_slice_width(d, M)
    d_pad = d_loc * M
    v_pad = jnp.zeros((d_pad, 1), jnp.float32).at[:d, 0].set(v0)
    v_locs = [v_pad[k * d_loc:(k + 1) * d_loc] for k in range(M)]
    v0_locs = list(v_locs)
    scal = jnp.stack([lam_n, sig])
    valf = val.astype(jnp.float32)
    q = jnp.sum(valf * valf, axis=1)
    a_rows = []
    for b in range(n // B):
        sl = slice(b * B, (b + 1) * B)
        idx_t, val_t = idx[sl], val[sl]
        y_t, a_t, q_t = y[sl], a[sl], q[sl]
        parts = jnp.stack([
            sdca_sparse_bucket.sdca_sparse_gather_bucket(
                idx_t, v_locs[k], jnp.int32(k * d_loc), True)
            for k in range(M)])                       # (M, B, nnz)
        owner = (idx_t // jnp.int32(d_loc)).astype(jnp.int32)
        W = jnp.take_along_axis(parts, owner[None], axis=0)[0]
        a_lanes = []
        for k in range(M):
            a_new, v_locs[k] = (
                sdca_sparse_bucket.sdca_sparse_sharded_bucket(
                    obj, idx_t, val_t, y_t, a_t, q_t, W, v_locs[k],
                    scal, jnp.int32(k * d_loc), True))
            a_lanes.append(np.asarray(a_new))
        for other in a_lanes[1:]:       # redundant recursion agrees
            np.testing.assert_array_equal(other, a_lanes[0])
        a_rows.append(a_lanes[0])
    dv = jnp.concatenate(
        [(v_locs[k] - v0_locs[k])[:, 0] for k in range(M)])[:d] / sig
    np.testing.assert_array_equal(np.concatenate(a_rows),
                                  np.asarray(a_ref))
    np.testing.assert_array_equal(np.asarray(dv), np.asarray(dv_ref))
    assert np.abs(np.asarray(dv)).max() > 0


def test_sdca_sparse_sharded_kernel_guards():
    """The sharded kernel pair enforces alignment and both VMEM budgets
    with actionable errors, mirroring the replicated kernel's guards."""
    from repro.kernels.sdca_sparse_bucket import (
        TOTAL_VMEM_BUDGET_BYTES, V_VMEM_BUDGET_BYTES,
        vmem_bytes_estimate_sharded)
    B, nnz = 8, 8
    idx_t = jnp.zeros((B, nnz), jnp.int32)
    lo = jnp.int32(0)
    # slice rows over the resident budget even after sharding
    d_big = V_VMEM_BUDGET_BYTES // 4 + 8
    with pytest.raises(ValueError, match="even feature-sharded"):
        sdca_sparse_bucket.sdca_sparse_gather_bucket(
            idx_t, jnp.zeros((d_big, 1), jnp.float32), lo, True)
    # slice not sublane-aligned (the driver always aligns; direct
    # callers get told who is responsible)
    with pytest.raises(ValueError, match="multiple of 8"):
        sdca_sparse_bucket.sdca_sparse_gather_bucket(
            idx_t, jnp.zeros((12, 1), jnp.float32), lo, True)
    # (B, nnz, nnz) match tensor blows the total budget
    Bw, nnzw = 16, 512
    assert (vmem_bytes_estimate_sharded(Bw, nnzw, 64)
            > TOTAL_VMEM_BUDGET_BYTES)
    with pytest.raises(ValueError, match="match tensor"):
        sdca_sparse_bucket.sdca_sparse_sharded_bucket(
            LOGISTIC, jnp.zeros((Bw, nnzw), jnp.int32),
            jnp.zeros((Bw, nnzw), jnp.float32), jnp.ones(Bw),
            jnp.zeros(Bw), jnp.zeros(Bw),
            jnp.zeros((Bw, nnzw), jnp.float32),
            jnp.zeros((64, 1), jnp.float32),
            jnp.stack([jnp.float32(1.0), jnp.float32(1.0)]), lo, True)


# ---------------------------------------------------------------------------
# Flash attention kernel (kernels/flash_attention.py)
# ---------------------------------------------------------------------------

from repro.models.attention import blocked_attention


@pytest.mark.parametrize("kind", ["causal", "full", "local"])
@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,hd,hd_v", [
    (2, 64, 64, 4, 2, 32, 32),      # GQA
    (1, 64, 64, 4, 1, 32, 16),      # MQA, hd_v != hd (MLA-like)
    (1, 32, 64, 2, 2, 32, 32),      # Sq != Sk
    (2, 48, 48, 2, 2, 16, 16),      # non-multiple of block (pads)
])
def test_flash_attention_matches_blocked(kind, B, Sq, Sk, H, Hkv, hd,
                                         hd_v):
    if kind == "causal" and Sq != Sk:
        pytest.skip("causal needs aligned positions")
    rng = np.random.default_rng(Sq + Sk + H)
    window = 24
    q = jnp.asarray(rng.standard_normal((B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sk, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sk, Hkv, hd_v)), jnp.float32)
    ref_out = blocked_attention(q, k, v, q_positions=jnp.arange(Sq),
                                kind=kind, window=window, chunk=16)
    out = ops.flash_attention(q, k, v, kind=kind, window=window,
                              bq=16, bk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(7)
    B, S, H, hd = 1, 64, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    ref_out = blocked_attention(q, k, v, q_positions=jnp.arange(S),
                                kind="causal", chunk=16)
    out = ops.flash_attention(q, k, v, kind="causal", bq=16, bk=16,
                              interpret=True)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_causal_tile_skip_correct():
    """The skipped tiles must not change results vs a full sweep: compare
    block sizes that do / don't align with the diagonal."""
    rng = np.random.default_rng(9)
    B, S, H, hd = 1, 96, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    o1 = ops.flash_attention(q, k, v, kind="causal", bq=16, bk=16,
                             interpret=True)
    o2 = ops.flash_attention(q, k, v, kind="causal", bq=32, bk=48,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
