"""Pallas kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.objectives import HINGE, LOGISTIC, RIDGE
from repro.kernels import ops, ref

OBJS = [LOGISTIC, RIDGE, HINGE]


def _data(obj, d, n, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((d, n)), dtype)
    y = jnp.asarray(rng.choice([-1.0, 1.0], n) if obj.classification
                    else rng.standard_normal(n), dtype)
    a = jnp.zeros(n, dtype)
    v0 = jnp.asarray(rng.standard_normal(d) * 0.1, jnp.float32)
    return X, y, a, v0


@pytest.mark.parametrize("obj", OBJS, ids=lambda o: o.name)
@pytest.mark.parametrize("d,n,B", [
    (8, 32, 8),          # minimal tile
    (37, 64, 16),        # d needs padding
    (100, 96, 16),       # padding + several buckets
    (128, 64, 32),       # aligned, wide bucket
    (13, 40, 8),         # both d and n awkward; B | n
])
def test_sdca_bucket_kernel_matches_oracle(obj, d, n, B):
    X, y, a, v0 = _data(obj, d, n, seed=d * 1000 + n)
    lam_n, sig = 0.1 * n, 2.0
    a_k, dv_k = ops.sdca_bucket_subepoch(obj, X, y, a, v0, lam_n, sig,
                                         bucket=B, interpret=True)
    a_r, v_r = ref.sdca_subepoch_ref(obj, X, y, a, v0, lam_n, sig)
    dv_r = (v_r - v0) / sig
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r),
                               rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(np.asarray(dv_k), np.asarray(dv_r),
                               rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("obj", OBJS, ids=lambda o: o.name)
def test_sdca_kernel_sequential_semantics(obj):
    """Kernel must process buckets IN ORDER: running it over [b0, b1] must
    equal running b0 then b1 with the carried v."""
    d, n, B = 16, 32, 16
    X, y, a, v0 = _data(obj, d, n, seed=9)
    lam_n, sig = 3.2, 1.0
    a_all, dv_all = ops.sdca_bucket_subepoch(obj, X, y, a, v0, lam_n, sig,
                                             bucket=B, interpret=True)
    a1, dv1 = ops.sdca_bucket_subepoch(obj, X[:, :B], y[:B], a[:B], v0,
                                       lam_n, sig, bucket=B,
                                       interpret=True)
    v_mid = v0 + sig * dv1
    a2, dv2 = ops.sdca_bucket_subepoch(obj, X[:, B:], y[B:], a[B:], v_mid,
                                       lam_n, sig, bucket=B,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(a_all),
                               np.concatenate([a1, a2]),
                               rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(np.asarray(dv_all),
                               np.asarray(dv1 + dv2), rtol=3e-4,
                               atol=3e-5)


@pytest.mark.parametrize("T,D,bt", [
    (64, 128, 16), (128, 128, 128), (256, 256, 64), (32, 8, 8),
])
def test_rglru_kernel_matches_oracle(T, D, bt):
    rng = np.random.default_rng(T + D)
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    ga = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    gx = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    a_log = -jnp.abs(jnp.asarray(rng.standard_normal(D), jnp.float32)) * .1
    h0 = jnp.asarray(rng.standard_normal(D) * 0.1, jnp.float32)
    hk = ops.rglru_scan(x, a_log, ga, gx, h0, block_t=bt, interpret=True)
    hr = ref.rglru_ref(x, a_log, ga, gx, h0)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_kernel_dtypes(dtype):
    T, D = 64, 128
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((T, D)), dtype)
    ga = jnp.asarray(rng.standard_normal((T, D)), dtype)
    gx = jnp.asarray(rng.standard_normal((T, D)), dtype)
    a_log = -jnp.abs(jnp.asarray(rng.standard_normal(D), jnp.float32)) * .1
    h0 = jnp.zeros(D, jnp.float32)
    hk = ops.rglru_scan(x, a_log, ga, gx, h0, block_t=32, interpret=True)
    hr = ref.rglru_ref(x.astype(jnp.float32), a_log,
                       ga.astype(jnp.float32), gx.astype(jnp.float32), h0)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(hk, np.float32),
                               np.asarray(hr), rtol=tol, atol=tol)


def test_kernel_rejects_bad_tile():
    with pytest.raises(ValueError):
        from repro.kernels import sdca_bucket
        sdca_bucket.sdca_bucket_kernel(
            LOGISTIC, jnp.zeros((2, 9, 8)), jnp.zeros((2, 8)),
            jnp.zeros((2, 8)), jnp.zeros((9, 1)), jnp.zeros(2), True)


# ---------------------------------------------------------------------------
# Flash attention kernel (kernels/flash_attention.py)
# ---------------------------------------------------------------------------

from repro.models.attention import blocked_attention


@pytest.mark.parametrize("kind", ["causal", "full", "local"])
@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,hd,hd_v", [
    (2, 64, 64, 4, 2, 32, 32),      # GQA
    (1, 64, 64, 4, 1, 32, 16),      # MQA, hd_v != hd (MLA-like)
    (1, 32, 64, 2, 2, 32, 32),      # Sq != Sk
    (2, 48, 48, 2, 2, 16, 16),      # non-multiple of block (pads)
])
def test_flash_attention_matches_blocked(kind, B, Sq, Sk, H, Hkv, hd,
                                         hd_v):
    if kind == "causal" and Sq != Sk:
        pytest.skip("causal needs aligned positions")
    rng = np.random.default_rng(Sq + Sk + H)
    window = 24
    q = jnp.asarray(rng.standard_normal((B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sk, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sk, Hkv, hd_v)), jnp.float32)
    ref_out = blocked_attention(q, k, v, q_positions=jnp.arange(Sq),
                                kind=kind, window=window, chunk=16)
    out = ops.flash_attention(q, k, v, kind=kind, window=window,
                              bq=16, bk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(7)
    B, S, H, hd = 1, 64, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    ref_out = blocked_attention(q, k, v, q_positions=jnp.arange(S),
                                kind="causal", chunk=16)
    out = ops.flash_attention(q, k, v, kind="causal", bq=16, bk=16,
                              interpret=True)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_causal_tile_skip_correct():
    """The skipped tiles must not change results vs a full sweep: compare
    block sizes that do / don't align with the diagonal."""
    rng = np.random.default_rng(9)
    B, S, H, hd = 1, 96, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    o1 = ops.flash_attention(q, k, v, kind="causal", bq=16, bk=16,
                             interpret=True)
    o2 = ops.flash_attention(q, k, v, kind="causal", bq=32, bk=48,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
