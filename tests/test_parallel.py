"""Parallel epoch simulator: wild pathology, domesticated convergence,
stragglers, sync-interval chunks — the paper's Fig 1/3 behaviors."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GLMTrainer, SolverConfig
from repro.data import make_dense_classification, make_sparse_classification

LAM = 1e-3


@pytest.fixture(scope="module")
def dense_data():
    return make_dense_classification(n=2048, d=64, seed=0)


def _fit(X, y, cfg, max_epochs=60, **kw):
    tr = GLMTrainer(X, y, objective="logistic", lam=LAM, cfg=cfg, **kw)
    return tr.fit(max_epochs=max_epochs, tol=1e-4), tr


def test_domesticated_matches_sequential_solution(dense_data):
    X, y = dense_data
    res_seq, _ = _fit(X, y, SolverConfig(bucket=8))
    res_par, _ = _fit(X, y, SolverConfig(pods=2, lanes=4, bucket=8,
                                         partition="hierarchical"))
    assert res_par.converged
    # same optimum: v vectors close in relative L2
    rel = (np.linalg.norm(res_par.v - res_seq.v)
           / np.linalg.norm(res_seq.v))
    assert rel < 0.05, rel


def test_wild_struggles_on_dense_many_workers(dense_data):
    """Paper Fig 1a: wild updates break down as workers grow (dense)."""
    X, y = dense_data
    res_wild, tr = _fit(X, y, SolverConfig(pods=1, lanes=32, bucket=8,
                                           partition="dynamic",
                                           aggregation="wild"),
                        max_epochs=40)
    res_dom, _ = _fit(X, y, SolverConfig(pods=1, lanes=32, bucket=8,
                                         partition="dynamic",
                                         aggregation="adding"),
                      max_epochs=40)
    assert res_dom.converged
    # wild either diverges, fails to converge, or lands at a worse gap
    wild_bad = (res_wild.diverged or not res_wild.converged
                or res_wild.final_gap > 10 * max(res_dom.final_gap, 1e-9))
    assert wild_bad


def test_wild_is_fine_on_sparse_few_workers():
    """Paper Fig 1b: sparse data tolerates wild updates at low K."""
    (idx, val), y, d = make_sparse_classification(n=2048, d=512, nnz=5,
                                                  seed=1)
    res, _ = _fit((idx, val), y,
                  SolverConfig(pods=1, lanes=4, bucket=8,
                               partition="dynamic", aggregation="wild"),
                  sparse=True, d=d)
    assert res.converged and res.final_gap < 1e-2


def test_static_needs_more_epochs_than_dynamic(dense_data):
    """Paper Fig 2b / 5a: static partitioning slows convergence."""
    X, y = dense_data
    res_sta, _ = _fit(X, y, SolverConfig(pods=1, lanes=16, bucket=8,
                                         partition="static"),
                      max_epochs=100)
    res_dyn, _ = _fit(X, y, SolverConfig(pods=1, lanes=16, bucket=8,
                                         partition="dynamic"),
                      max_epochs=100)
    assert res_dyn.converged
    assert res_dyn.epochs <= res_sta.epochs


def test_alltoall_close_to_dynamic(dense_data):
    """Our TPU-native all-to-all re-deal must track full re-shuffling."""
    X, y = dense_data
    res_dyn, _ = _fit(X, y, SolverConfig(pods=2, lanes=8, bucket=8,
                                         partition="hierarchical"),
                      max_epochs=100)
    res_a2a, _ = _fit(X, y, SolverConfig(pods=2, lanes=8, bucket=8,
                                         partition="alltoall"),
                      max_epochs=100)
    assert res_a2a.converged
    assert res_a2a.epochs <= int(res_dyn.epochs * 1.5) + 2


def test_rotation_is_equivalent_to_static(dense_data):
    """Documented refuted hypothesis: ring rotation of FIXED blocks does
    not change the subproblem sets, so it converges like static, not
    dynamic (see core/partition.py)."""
    X, y = dense_data
    res_rot, _ = _fit(X, y, SolverConfig(pods=1, lanes=8, bucket=8,
                                         partition="rotation"),
                      max_epochs=100)
    assert res_rot.converged   # still converges — just no dynamic benefit


def test_chunked_sync_converges(dense_data):
    X, y = dense_data
    res, _ = _fit(X, y, SolverConfig(pods=1, lanes=8, bucket=8,
                                     partition="dynamic", chunks=4))
    assert res.converged


def test_straggler_mask_still_converges(dense_data):
    """A dead lane per epoch only slows convergence (over-decomposition
    story): updates of masked lanes are dropped, model remains valid."""
    from repro.core import cocoa
    from repro.core.bucketing import make_plan
    from repro.core.partition import PartitionPlan
    from repro.core.objectives import LOGISTIC, duality_gap

    X, y = dense_data
    X, y = jnp.asarray(X), jnp.asarray(y)
    d, n = X.shape
    cfg = SolverConfig(pods=1, lanes=8, bucket=8, partition="dynamic")
    bplan = make_plan(n, d, force=8)
    plan = PartitionPlan(n_buckets=bplan.n_buckets, pods=1, lanes=8,
                         mode="dynamic")
    alpha, v = jnp.zeros(n), jnp.zeros(d)
    rng = np.random.default_rng(0)
    for e in range(50):
        mask = np.ones((1, 8), bool)
        mask[0, rng.integers(0, 8)] = False      # one straggler per epoch
        alpha, v = cocoa.epoch_sim(
            LOGISTIC, X, y, alpha, v, LAM, plan, bplan, cfg,
            jnp.int32(e), straggler_mask=jnp.asarray(mask))
    gap = float(duality_gap(LOGISTIC, alpha, v, X, y, LAM))
    assert gap < 1e-2, gap


def test_straggler_masked_worker_alpha_slice_unchanged(dense_data):
    """The examples a masked-out worker was dealt keep their alpha
    exactly (its local updates are dropped), while every live worker's
    slice moves — the over-decomposition contract (partition.py)."""
    import jax.numpy as jnp
    from repro.core import cocoa
    from repro.core.bucketing import make_plan
    from repro.core.partition import PartitionPlan
    from repro.core.objectives import LOGISTIC

    X, y = dense_data
    X, y = jnp.asarray(X), jnp.asarray(y)
    d, n = X.shape
    P_, K = 1, 8
    cfg = SolverConfig(pods=P_, lanes=K, bucket=8, partition="dynamic")
    bplan = make_plan(n, d, force=8)
    plan = PartitionPlan(n_buckets=bplan.n_buckets, pods=P_, lanes=K,
                         mode="dynamic")
    alpha0, v0 = jnp.zeros(n), jnp.zeros(d)
    dead = 3
    mask = np.ones((P_, K), bool)
    mask[0, dead] = False
    alpha, v = cocoa.epoch_sim(LOGISTIC, X, y, alpha0, v0, LAM, plan,
                               bplan, cfg, jnp.int32(0),
                               straggler_mask=jnp.asarray(mask))
    sched = plan.schedule(jnp.int32(0))          # (P, K, per_lane)
    ex = (np.asarray(sched)[..., None] * 8
          + np.arange(8)).reshape(P_, K, -1)
    a = np.asarray(alpha)
    # dead worker's slice untouched (alpha started at zero)
    np.testing.assert_array_equal(a[ex[0, dead]],
                                  np.asarray(alpha0)[ex[0, dead]])
    # every live worker's slice changed
    for k in range(K):
        if k != dead:
            assert np.abs(a[ex[0, k]]).max() > 0, k
    # and v still moved (the epoch is valid, not a no-op)
    assert float(jnp.max(jnp.abs(v))) > 0


def test_kernel_path_matches_jnp_path(dense_data):
    """cfg.use_kernel routes through the Pallas kernel (interpret on CPU)
    and must give the same epoch results."""
    X, y = dense_data
    X_ = X[:, :256]
    y_ = y[:256]
    cfg_j = SolverConfig(pods=1, lanes=2, bucket=8, partition="dynamic")
    cfg_k = SolverConfig(pods=1, lanes=2, bucket=8, partition="dynamic",
                         use_kernel=True)
    tr_j = GLMTrainer(X_, y_, objective="logistic", lam=LAM, cfg=cfg_j)
    tr_k = GLMTrainer(X_, y_, objective="logistic", lam=LAM, cfg=cfg_k)
    a_j, v_j = tr_j._epoch_fn(tr_j.alpha, tr_j.v, jnp.int32(0))
    a_k, v_k = tr_k._epoch_fn(tr_k.alpha, tr_k.v, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(v_j), np.asarray(v_k),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(a_j), np.asarray(a_k),
                               rtol=2e-4, atol=2e-5)
