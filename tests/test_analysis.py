"""Static-analysis auditor tests (DESIGN.md S14, docs/analysis.md).

The jaxpr-layer tests shell out with forced host devices (repo
convention: only launch entrypoints force device counts); the lint,
budget, and registry tests run in-process — they are stdlib-side.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(code: str, timeout=600):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(REPO / "src"))
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


# ---------------------------------------------------------------------------
# jaxpr layer: the loop-closure regression pair (the PR 1 / PR 6 bug
# class, reconstructed minimally) + a clean slice of the real matrix
# ---------------------------------------------------------------------------


def test_loop_closure_regression_pair():
    """The shard_map loop-invariant-replicated closure bug: a fori_loop
    body closing over an axis_index-derived offset MUST be flagged, and
    the carry-threaded form of the same program MUST pass.  This is the
    auditor-level pin of the bug `engine.run_epoch` unrolls its chunk
    loop to avoid and `ops.sdca_sparse_sharded_subepoch` threads `lo`
    through its scan carry to avoid."""
    r = _run("""
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.analysis import jaxpr_audit, rules
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(pod=1, data=2, model=1)

        def trace(inner):
            f = shard_map(inner, mesh=mesh, in_specs=P("data"),
                          out_specs=P("data"))
            return jax.make_jaxpr(f)(jnp.zeros(8))

        def buggy(x):
            lo = jax.lax.axis_index("data") * 4
            def body(i, acc):
                return acc + x[lo + i]      # closed over -> replicated
            return jax.lax.fori_loop(0, 4, body, 0.0)[None]

        def threaded(x):
            lo = jax.lax.axis_index("data") * 4
            def body(i, carry):
                acc, lo = carry
                return acc + x[lo + i], lo  # threaded through the carry
            return jax.lax.fori_loop(0, 4, body, (0.0, lo))[0][None]

        got = jaxpr_audit.audit_jaxpr(trace(buggy), deterministic=True)
        assert [f.rule for f in got] == [rules.JAX_LOOP_CLOSURE], got
        assert "carry" in got[0].message
        clean = jaxpr_audit.audit_jaxpr(trace(threaded),
                                        deterministic=True)
        assert clean == [], [str(f) for f in clean]
        print("OK")
        """)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


def test_matrix_clean_on_one_workload():
    """One registry workload through every route: the real epoch
    programs trace and audit clean (the full matrix is the CI audit
    job; this pins the plumbing inside tier-1)."""
    r = _run("""
        from repro.analysis import matrix
        found = matrix.run_matrix(["synthetic-sparse"])
        assert found == [], [str(f) for f in found]
        cases = [c.name for c in matrix.build_cases(["synthetic-sparse"])]
        assert "synthetic-sparse/pallas-sharded/det" in cases, cases
        print("OK", len(cases))
        """)
    assert r.returncode == 0, r.stderr
    assert "OK 6" in r.stdout


def test_psum_and_nondet_detectors_fire():
    """Injected psum / pmax inside shard_map are flagged under the
    deterministic contract and ignored outside it."""
    r = _run("""
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.analysis import jaxpr_audit, rules
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(pod=1, data=2, model=1)
        for fn, rule in [(jax.lax.psum, rules.JAX_PSUM_EXCHANGE),
                         (jax.lax.pmax, rules.JAX_NONDET_PRIM)]:
            f = shard_map(lambda x, fn=fn: fn(x, "data"), mesh=mesh,
                          in_specs=P("data"), out_specs=P(None))
            j = jax.make_jaxpr(f)(jnp.zeros(8))
            det = jaxpr_audit.audit_jaxpr(j, deterministic=True)
            assert [x.rule for x in det] == [rule], (rule, det)
            assert det[0].where, "findings must carry file:line anchors"
            nondet = jaxpr_audit.audit_jaxpr(j, deterministic=False)
            assert nondet == []
        print("OK")
        """)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


def test_selftests_all_pass():
    """Every mutation self-test proves its detector fires (the same
    gate the CI static-analysis job runs via --selftest)."""
    r = _run("""
        from repro.analysis import selftest
        failures = selftest.run_selftests()
        assert failures == [], failures
        assert len(selftest.SELFTESTS) == 9
        print("OK")
        """, timeout=900)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# lint layer (in-process: stdlib AST, no jax)
# ---------------------------------------------------------------------------


def _analysis():
    sys.path.insert(0, str(REPO / "src"))
    from repro.analysis import config, lint, rules
    return config, lint, rules


def test_lint_clean_on_live_tree():
    config, lint, rules = _analysis()
    found = lint.run_lint()
    assert found == [], [str(f) for f in found]


def test_lint_flags_unmarked_collective_outside_scoped_files():
    """A collective appearing in a scoped file without a marker is
    flagged; the same source under a non-scoped path is not linted by
    the marker rule (the scope IS the rule)."""
    config, lint, rules = _analysis()
    src = "import jax\n\ndef f(x, ax):\n    return jax.lax.psum(x, ax)\n"
    scoped = config.COLLECTIVE_SCOPED_FILES[0]
    got = lint.run_lint({scoped: src},
                        only=[rules.LINT_RAW_COLLECTIVE])
    assert [f.rule for f in got] == [rules.LINT_RAW_COLLECTIVE]
    assert got[0].where == f"{scoped}:4"
    not_scoped = lint.run_lint({"src/repro/core/elsewhere.py": src},
                               only=[rules.LINT_RAW_COLLECTIVE])
    assert not_scoped == []


def test_lint_kernel_contract_and_rng_rules():
    config, lint, rules = _analysis()
    rogue = ("from jax.experimental import pallas as pl\n"
             "def rogue(x):\n"
             "    return pl.pallas_call(None, out_shape=x)(x)\n")
    got = lint.check_kernel_contracts(
        "src/repro/kernels/sdca_bucket.py", rogue, {})
    assert [f.rule for f in got] == [rules.LINT_KERNEL_CONTRACT]

    rng = "import numpy as np\nx = np.random.rand(3)\n"
    got = lint.check_unseeded_rng("src/repro/core/x.py", rng)
    assert [f.rule for f in got] == [rules.LINT_UNSEEDED_RNG]
    seeded = "import numpy as np\nr = np.random.default_rng(0)\n"
    assert lint.check_unseeded_rng("src/repro/core/x.py", seeded) == []


def test_quarantine_matches_ruff_exclude():
    """repro.analysis.config.QUARANTINE and pyproject.toml's ruff
    extend-exclude are the same list (README documents them as one
    policy; this is the pin)."""
    config, _, _ = _analysis()
    text = (REPO / "pyproject.toml").read_text()
    block = text.split("extend-exclude = [", 1)[1].split("]", 1)[0]
    excluded = {s.strip().strip('",') for s in block.splitlines()
                if s.strip().startswith('"')}
    assert excluded == set(config.QUARANTINE)


def test_rules_registry_complete():
    """Every rule ID has registry metadata (invariant + history) and
    every detector layer's IDs are registered."""
    _, _, rules = _analysis()
    assert set(rules.RULES) == {
        "JAX-PSUM-EXCHANGE", "JAX-LOOP-CLOSURE", "JAX-NONDET-PRIM",
        "LINT-KERNEL-CONTRACT", "LINT-RAW-COLLECTIVE",
        "LINT-UNSEEDED-RNG", "LINT-CSR-ENTRY", "LINT-BARE-EXCEPT",
        "VMEM-PLAN-BUDGET"}
    for rule in rules.RULES.values():
        assert rule.invariant and rule.history
        assert rule.layer in ("jaxpr", "lint", "budget")


# ---------------------------------------------------------------------------
# budget layer + misfit reason codes
# ---------------------------------------------------------------------------


def test_budget_audit_clean_and_catches_forged_plan():
    sys.path.insert(0, str(REPO / "src"))
    from repro.analysis import budget, rules
    from repro.core.planner import (SolverPlan, Topology,
                                    WorkloadSignature)
    found, n_plans = budget.run_budget_audit()
    assert found == [], [str(f) for f in found]
    assert n_plans > 500          # the sweep actually swept

    sig = WorkloadSignature(n=4096, d=64, nnz=512, sparse=True)
    forged = SolverPlan(solver="pallas", route="pallas-replicated",
                        bucket=16, chunks=1, nnz_multiple=0,
                        feature_shard=False)
    got = budget.audit_plan(sig, Topology(backend="tpu"), forged)
    assert got and all(f.rule == rules.VMEM_PLAN_BUDGET for f in got)


def test_misfit_reasons_carry_stable_codes():
    """`ops` misfit reasons are str-compatible AND carry MisfitCode;
    the planner surfaces the code on SolverPlan.reason_code."""
    sys.path.insert(0, str(REPO / "src"))
    from repro.kernels import ops
    from repro.core.planner import (Topology, WorkloadSignature,
                                    static_plan)

    route, reason = ops.sparse_solver_plan(100, 8, 64, 16)
    assert route == "xla"
    assert isinstance(reason, str) and "does not divide" in reason
    assert reason.code == ops.MisfitCode.BUCKET_INDIVISIBLE

    _, reason = ops.sparse_solver_plan(16, 12, 64, 16)
    assert reason.code == ops.MisfitCode.ALIGNMENT
    _, reason = ops.sparse_solver_plan(16, 8, 3_000_000, 16)
    assert reason.code == ops.MisfitCode.VMEM_V
    _, reason = ops.sparse_solver_plan(16, 512, 64, 16)
    assert reason.code == ops.MisfitCode.VMEM_TOTAL

    why = ops.dense_kernel_misfit(64, 1024, 1024)
    assert why.code == ops.MisfitCode.BUCKET_CAP
    assert ops.dense_kernel_misfit(64, 64, 16) is None

    # planner surface: infeasible geometry -> code on the plan;
    # feasible -> empty code, reason "fits"
    sig = WorkloadSignature(n=4096, d=64, nnz=512, sparse=True)
    plan = static_plan(sig, Topology(backend="tpu"), bucket=16)
    assert plan.route == "xla"
    assert plan.reason_code == ops.MisfitCode.VMEM_TOTAL
    assert type(plan.reason) is str       # JSON-plain on the record
    fits = static_plan(WorkloadSignature(n=4096, d=64, nnz=8,
                                         sparse=True),
                       Topology(backend="tpu"), bucket=16)
    assert fits.reason == "fits" and fits.reason_code == ""
    doc = fits.to_json()
    assert doc["reason_code"] == ""


# ---------------------------------------------------------------------------
# CLI + report schema
# ---------------------------------------------------------------------------


def test_audit_cli_lint_layer_and_report(tmp_path):
    """The CLI's jax-free layer end-to-end: exit 0 on the clean tree,
    JSON report with the self-describing schema."""
    report = tmp_path / "AUDIT.json"
    r = subprocess.run(
        [sys.executable, str(REPO / "tools" / "audit.py"),
         "--layers", "lint,budget", "--report", str(report), "--quiet"],
        capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    import json
    doc = json.loads(report.read_text())
    assert doc["ok"] is True and doc["findings"] == []
    assert doc["version"] == 1 and doc["plans_swept"] > 500
    assert set(doc["rules"]) == {
        "JAX-PSUM-EXCHANGE", "JAX-LOOP-CLOSURE", "JAX-NONDET-PRIM",
        "LINT-KERNEL-CONTRACT", "LINT-RAW-COLLECTIVE",
        "LINT-UNSEEDED-RNG", "LINT-CSR-ENTRY", "LINT-BARE-EXCEPT",
        "VMEM-PLAN-BUDGET"}


def test_audit_cli_rejects_unknown_layer():
    r = subprocess.run(
        [sys.executable, str(REPO / "tools" / "audit.py"),
         "--layers", "nope"],
        capture_output=True, text=True, timeout=300)
    assert r.returncode != 0
    assert "unknown audit layers" in (r.stderr + r.stdout)


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
