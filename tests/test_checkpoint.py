"""Checkpoint manager: atomicity, keep-N, bit-exact restart (GLM + LM)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.configs import get_smoke
from repro.core import GLMTrainer, SolverConfig
from repro.data import make_dense_classification
from repro.launch import steps as steps_lib, train as train_mod



def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": [jnp.ones(4, jnp.bfloat16), jnp.float32(3.5)],
            "c": {"d": jnp.zeros((), jnp.int32)}}
    save_tree(tmp_path / "ck", tree, meta={"step": 7})
    out, meta = restore_tree(tmp_path / "ck", tree)
    assert meta["step"] == 7
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        assert l1.dtype == l2.dtype


def test_restore_rejects_shape_mismatch(tmp_path):
    save_tree(tmp_path / "ck", {"a": jnp.ones((2, 3))})
    with pytest.raises(ValueError):
        restore_tree(tmp_path / "ck", {"a": jnp.ones((3, 2))})


def test_manager_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full((2,), s)})
    assert mgr.all_steps() == [3, 4]
    out, meta = mgr.restore({"x": jnp.zeros(2)})
    assert meta["step"] == 4
    np.testing.assert_array_equal(np.asarray(out["x"]), [4.0, 4.0])


def test_async_write_snapshot_is_consistent(tmp_path):
    """The snapshot must capture values at save() time even if the caller
    mutates/donates the arrays right after."""
    mgr = CheckpointManager(tmp_path, async_write=True)
    x = jnp.arange(4.0)
    mgr.save(1, {"x": x})
    x = x * 0  # caller moves on immediately
    mgr.wait()
    out, _ = mgr.restore({"x": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(out["x"]), [0, 1, 2, 3])


def test_glm_restart_is_bit_exact(tmp_path):
    """Stop after 5 epochs, restore, continue 5 — must equal 10 straight.
    Works because partition schedules are pure functions of (seed,epoch)."""
    X, y = make_dense_classification(n=512, d=32, seed=0)
    cfg = SolverConfig(pods=2, lanes=2, bucket=8, partition="hierarchical")

    tr_full = GLMTrainer(X, y, objective="logistic", lam=1e-3, cfg=cfg)
    tr_full.fit(max_epochs=10, tol=0.0)

    tr_a = GLMTrainer(X, y, objective="logistic", lam=1e-3, cfg=cfg)
    tr_a.fit(max_epochs=5, tol=0.0)
    save_tree(tmp_path / "glm", tr_a.state_dict())

    tr_b = GLMTrainer(X, y, objective="logistic", lam=1e-3, cfg=cfg)
    st, _ = restore_tree(tmp_path / "glm", tr_b.state_dict())
    tr_b.load_state_dict(st)
    tr_b.fit(max_epochs=5, tol=0.0)

    np.testing.assert_allclose(tr_b.v, tr_full.v, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(tr_b.alpha, tr_full.alpha, rtol=1e-6,
                               atol=1e-7)


def test_lm_restart_matches_uninterrupted(tmp_path):
    """train 6 steps straight == train 3, checkpoint, restore, train 3."""
    cfg = get_smoke("smollm-360m")
    kw = dict(steps=6, batch=2, seq=16, lr=1e-3, verbose=False)

    p_full, _, losses_full = train_mod.train(cfg, **kw)

    kw_a = dict(kw, steps=3, ckpt_dir=str(tmp_path / "lm"), ckpt_every=3)
    train_mod.train(cfg, **kw_a)
    kw_b = dict(kw, ckpt_dir=str(tmp_path / "lm"))
    p_resumed, _, losses_b = train_mod.train(cfg, **kw_b)

    for l1, l2 in zip(jax.tree.leaves(p_full),
                      jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(losses_full[3:], losses_b, rtol=1e-5)


def test_save_tree_cleans_stale_tmp_from_killed_save(tmp_path):
    """A process killed mid-save leaves a stage dir; the next save must
    replace it, and it must never shadow the live checkpoint."""
    stale = tmp_path / ".tmp.ck"
    stale.mkdir()
    (stale / "junk.bin").write_bytes(b"half a tensor")
    save_tree(tmp_path / "ck", {"a": jnp.arange(3.0)}, meta={"step": 1})
    out, meta = restore_tree(tmp_path / "ck", {"a": jnp.zeros(3)})
    assert meta["step"] == 1
    np.testing.assert_array_equal(np.asarray(out["a"]), [0.0, 1.0, 2.0])
    assert not stale.exists()


def test_restore_tree_falls_back_to_old_after_torn_swap(tmp_path):
    """Crash between the swap's two renames: the live dir was moved to
    .old.<name> but the replacement never arrived.  restore_tree must
    serve the .old generation instead of failing on the torn target."""
    ck = tmp_path / "ck"
    save_tree(ck, {"a": jnp.arange(3.0)}, meta={"step": 1})
    ck.rename(tmp_path / ".old.ck")
    ck.mkdir()                            # half-written replacement,
    (ck / "partial.bin").write_bytes(b"")  # no keys.json manifest
    out, meta = restore_tree(ck, {"a": jnp.zeros(3)})
    assert meta["step"] == 1
    np.testing.assert_array_equal(np.asarray(out["a"]), [0.0, 1.0, 2.0])


def test_save_tree_overwrite_is_atomic_swap(tmp_path):
    """Re-saving over an existing checkpoint goes through the staged
    swap: the new generation lands, no .tmp/.old debris survives."""
    ck = tmp_path / "ck"
    save_tree(ck, {"a": jnp.zeros(4)}, meta={"step": 1})
    save_tree(ck, {"a": jnp.full((4,), 7.0)}, meta={"step": 2})
    out, meta = restore_tree(ck, {"a": jnp.zeros(4)})
    assert meta["step"] == 2
    np.testing.assert_array_equal(np.asarray(out["a"]), np.full(4, 7.0))
    leftover = [p.name for p in tmp_path.iterdir() if p.name != "ck"]
    assert leftover == []


def test_estimator_save_over_existing_checkpoint(tmp_path):
    """est.save onto an existing checkpoint dir swaps atomically and
    serves the newest fit (the CheckpointHook path uses the same
    save_tree protocol)."""
    from repro.api import LogisticRegression
    from repro.api import load as load_estimator
    X, y = make_dense_classification(n=256, d=16, seed=0)
    est = LogisticRegression(max_epochs=2, bucket=8, lanes=2,
                             deterministic=True)
    est.fit(np.asarray(X).T, np.asarray(y))
    est.save(tmp_path / "est")
    first = np.asarray(load_estimator(tmp_path / "est").coef_)

    est2 = LogisticRegression(max_epochs=6, bucket=8, lanes=2,
                              deterministic=True)
    est2.fit(np.asarray(X).T, np.asarray(y))
    est2.save(tmp_path / "est")
    again = load_estimator(tmp_path / "est")
    np.testing.assert_array_equal(np.asarray(again.coef_),
                                  np.asarray(est2.coef_))
    assert again.n_iter_ == 6 and not np.array_equal(
        np.asarray(again.coef_), first)
    assert not any(p.name.startswith((".tmp.", ".old."))
                   for p in tmp_path.iterdir())


def test_elastic_restore_into_resharded_target(tmp_path):
    """A checkpoint restores into a target with different shardings —
    the mesh is a property of the run, not the data (elastic restart)."""
    cfg = get_smoke("smollm-360m")
    params = steps_lib.init_params(cfg, jax.random.PRNGKey(0))
    save_tree(tmp_path / "el", params)
    # restore with explicit (single-device) shardings: exercises the
    # device_put path used for cross-mesh restores
    sh = jax.tree.map(
        lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]),
        params)
    out, _ = restore_tree(tmp_path / "el", params, shardings=sh)
    for l1, l2 in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                      np.asarray(l2, np.float32))
