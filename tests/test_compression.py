"""int8 error-feedback compression for cross-pod reductions."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test dep
from hypothesis import given, settings, strategies as st

from repro.optim.compression import compress, dequantize


@given(st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_quantization_error_bounded(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256) * rng.uniform(0.01, 100),
                    jnp.float32)
    qz, err = compress(x)
    scale = float(qz.scale)
    assert np.abs(np.asarray(err)).max() <= scale * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(dequantize(qz) + err),
                               np.asarray(x), rtol=1e-5, atol=1e-6)


def test_error_feedback_unbiases_over_time():
    """Repeatedly transmitting the same x with EF must converge: the
    accumulated transmitted mass approaches k*x (bias vanishes)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(128) * 0.01, jnp.float32)
    err = jnp.zeros_like(x)
    sent = jnp.zeros_like(x)
    k = 50
    for _ in range(k):
        qz, err = compress(x + err)
        sent = sent + dequantize(qz)
    np.testing.assert_allclose(np.asarray(sent / k), np.asarray(x),
                               rtol=0.02, atol=1e-5)


def test_per_row_scales():
    x = jnp.stack([jnp.ones(16) * 100.0, jnp.ones(16) * 0.001])
    qz, err = compress(x, axis=1)
    assert qz.scale.shape == (2, 1)
    # small row must not be crushed by the big row's scale
    np.testing.assert_allclose(np.asarray(dequantize(qz)[1]), 0.001,
                               rtol=0.02)
