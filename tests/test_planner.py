"""core.planner: decision table, plan cache, never-regress pins.

Three layers, mirroring DESIGN.md S13's contract:

  * decision-table tests drive (d, B, nnz, M, backend) corners —
    including EXACT VMEM boundaries computed from the kernels' own
    budget constants — through `resolve_plan` and assert the route;
  * plan-cache tests pin the round-trip, the version-bump
    invalidation, and that $REPRO_PLAN=off never touches disk;
  * never-regress pins: planner-resolved auto must equal
    static-resolved auto BITWISE on every previously-working config —
    at the plan level, at the Session level (same epoch output), and
    at the scale_for_dataset level (same GLMScale).
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import planner
from repro.core.planner import (PLAN_VERSION, SolverPlan, Topology,
                                WorkloadSignature)
from repro.kernels import ops as kops
from repro.kernels.sdca_sparse_bucket import (TOTAL_VMEM_BUDGET_BYTES,
                                              V_VMEM_BUDGET_BYTES)

TPU1 = Topology(backend="tpu")
TPU_M2 = Topology(backend="tpu", device_count=2, model_lanes=2)

# exact resident-v boundary: largest d whose padded f32 shared vector
# fits the sparse kernel's VMEM budget, and the first d past it
D_V_FIT = V_VMEM_BUDGET_BYTES // 4
D_V_OVER = D_V_FIT + 8
assert D_V_FIT % 8 == 0


def _plan(sig, topo, **kw):
    kw.setdefault("use_cache", False)
    return planner.resolve_plan(sig, topo, **kw)


def sparse_sig(d, nnz, n=4096, name=""):
    return WorkloadSignature(n=n, d=d, nnz=nnz, sparse=True, name=name)


# -- decision table ---------------------------------------------------------


@pytest.mark.parametrize(
    "d,bucket,nnz,topo,route",
    [
        # aligned, small: replicated kernel
        (1024, 8, 40, TPU1, "pallas-replicated"),
        (1024, 16, 40, TPU_M2, "pallas-replicated"),
        # alignment misfits -> xla (B and nnz must be sublane multiples)
        (1024, 12, 40, TPU1, "xla"),
        (1024, 8, 39, TPU1, "xla"),
        # exact resident-v boundary: d_pad*4 == budget still fits;
        # one sublane past it needs the sharded kernel (M > 1) or xla
        (D_V_FIT, 8, 8, TPU1, "pallas-replicated"),
        (D_V_OVER, 8, 8, TPU1, "xla"),
        (D_V_OVER, 8, 8, TPU_M2, "pallas-sharded"),
        # webspam's REAL row width blows the total-footprint budget
        # (the B*nnz*nnz match tensor) for every kernel variant
        (16_609_280, 16, 3728, TPU_M2, "xla"),
    ])
def test_sparse_decision_table(d, bucket, nnz, topo, route, monkeypatch):
    monkeypatch.delenv("REPRO_PLAN", raising=False)
    plan = _plan(sparse_sig(d, nnz), topo, bucket=bucket, chunks=1)
    assert plan.route == route
    # the planner's verdict is byte-identical to the kernels' own
    # dispatcher — it can never loosen feasibility
    want, why = kops.sparse_solver_plan(bucket, nnz, d, bucket,
                                        model_lanes=topo.model_lanes)
    assert plan.route == want
    if route == "xla":
        assert plan.reason == why


def test_total_budget_boundary():
    """Walk nnz across the total-footprint budget at fixed (B, d): the
    planner flips replicated -> xla exactly where the kernel's own
    estimate crosses TOTAL_VMEM_BUDGET_BYTES."""
    from repro.kernels.sdca_sparse_bucket import vmem_bytes_estimate
    d, B = 1024, 8
    d_pad = 1024
    flipped = None
    for nnz in range(8, 4096, 8):
        fits = (vmem_bytes_estimate(B, nnz, d_pad)
                <= TOTAL_VMEM_BUDGET_BYTES)
        plan = _plan(sparse_sig(d, nnz), TPU1, bucket=B, chunks=1)
        assert (plan.route == "pallas-replicated") == fits
        if not fits:
            flipped = nnz
            break
    assert flipped is not None, "never crossed the budget — widen range"


@pytest.mark.parametrize("bucket,route", [
    (8, "pallas-replicated"),
    (512, "pallas-replicated"),       # the dense kernel's bucket cap
    (520, "xla"),                     # one sublane past the cap
])
def test_dense_decision_table(bucket, route, monkeypatch):
    monkeypatch.delenv("REPRO_PLAN", raising=False)
    sig = WorkloadSignature(n=8 * bucket, d=64)
    plan = _plan(sig, TPU1, bucket=bucket, chunks=1)
    assert plan.route == route


def test_backend_picks_solver(monkeypatch):
    """Off-TPU the solver is xla even when the route says the kernel
    would fit (mirrors engine.resolve_auto_solver)."""
    monkeypatch.delenv("REPRO_PLAN", raising=False)
    sig = sparse_sig(1024, 40)
    assert _plan(sig, TPU1, bucket=8, chunks=1).solver == "pallas"
    cpu = Topology(backend="cpu")
    plan = _plan(sig, cpu, bucket=8, chunks=1)
    assert plan.solver == "xla" and plan.route == "pallas-replicated"


def test_feature_shard_default_matches_static_rule():
    # sparse: the replicated resident-v budget boundary
    assert not planner.feature_shard_default(sparse_sig(D_V_FIT, 8))
    assert planner.feature_shard_default(sparse_sig(D_V_OVER, 8))
    # dense: the TP width boundary
    assert not planner.feature_shard_default(WorkloadSignature(n=1, d=511))
    assert planner.feature_shard_default(WorkloadSignature(n=1, d=512))


def test_plan_mode_parsing(monkeypatch):
    monkeypatch.delenv("REPRO_PLAN", raising=False)
    assert planner.plan_mode() == "on"
    for m in ("off", "on", "search", "probe"):
        monkeypatch.setenv("REPRO_PLAN", m)
        assert planner.plan_mode() == m
    monkeypatch.setenv("REPRO_PLAN", "bogus")
    with pytest.raises(ValueError, match="REPRO_PLAN"):
        planner.plan_mode()


def test_search_respects_fixed_knobs(monkeypatch):
    monkeypatch.setenv("REPRO_PLAN", "search")
    sig = sparse_sig(1024, 40, n=4096)
    plan = _plan(sig, TPU1, bucket=16, chunks=4)
    assert (plan.bucket, plan.chunks) == (16, 4)
    free = _plan(sig, TPU1)
    assert free.bucket in planner.BUCKET_CANDIDATES
    assert free.route != "xla"        # search found a kernel geometry


def test_search_never_loosens_feasibility(monkeypatch):
    """Every candidate the search can emit passes the kernels' misfit
    predicates (or routes xla) — spot-check the whole candidate set."""
    monkeypatch.setenv("REPRO_PLAN", "search")
    sig = sparse_sig(D_V_OVER, 3728, n=8192)       # no kernel fits
    for cand in planner.candidate_plans(sig, TPU_M2):
        if cand.solver == "pallas":
            assert kops.sparse_kernel_misfit(
                cand.bucket, sig.nnz, sig.d, cand.bucket,
                model_lanes=TPU_M2.model_lanes if cand.feature_shard
                else 1) is None
    plan = _plan(sig, TPU_M2)
    assert plan.route == "xla"
    # and the layout never drifts from the static rule on a tie
    assert plan.feature_shard == planner.feature_shard_default(sig,
                                                               TPU_M2)


def test_probe_refinement(monkeypatch):
    """Probe mode times the ranked candidates and returns the fastest;
    a raising probe disqualifies its candidate only."""
    monkeypatch.setenv("REPRO_PLAN", "probe")
    sig = sparse_sig(1024, 40, n=4096)
    seen = []

    def probe(plan):
        seen.append((plan.bucket, plan.chunks))
        if len(seen) == 1:
            raise RuntimeError("first candidate crashes")
        return 0.5 / plan.bucket        # bigger bucket "measures" faster

    with pytest.warns(UserWarning, match="probe failed"):
        plan = _plan(sig, TPU1, probe_fn=probe)
    assert plan.origin == "probe" and plan.probe_s > 0
    assert (plan.bucket, plan.chunks) == max(seen[1:])[:2] or \
        plan.bucket == max(b for b, _ in seen[1:])


# -- plan cache -------------------------------------------------------------


def test_plan_cache_roundtrip(tmp_path):
    sig = sparse_sig(1024, 40, name="unit")
    plan = planner.static_plan(sig, TPU1, bucket=8, chunks=2)
    path = planner.store_plan(sig, TPU1, plan, cache_dir=tmp_path)
    assert path.parent == tmp_path / "plans"
    got = planner.load_cached_plan(sig, TPU1, cache_dir=tmp_path)
    assert got is not None and got.origin == "cache"
    assert dataclasses.replace(got, origin=plan.origin) == plan
    # a different topology or workload misses
    assert planner.load_cached_plan(sig, TPU_M2,
                                    cache_dir=tmp_path) is None
    assert planner.load_cached_plan(sparse_sig(2048, 40, name="unit"),
                                    TPU1, cache_dir=tmp_path) is None


def test_plan_cache_version_bump_invalidates(tmp_path, monkeypatch):
    sig = sparse_sig(1024, 40, name="unit")
    plan = planner.static_plan(sig, TPU1, bucket=8, chunks=2)
    path = planner.store_plan(sig, TPU1, plan, cache_dir=tmp_path)
    monkeypatch.setattr(planner, "PLAN_VERSION", PLAN_VERSION + 1)
    assert planner.load_cached_plan(sig, TPU1, cache_dir=tmp_path) is None
    # even a hand-renamed file is rejected by the stored version field
    monkeypatch.undo()
    doc = json.loads(path.read_text())
    doc["version"] = PLAN_VERSION + 1
    path.write_text(json.dumps(doc))
    assert planner.load_cached_plan(sig, TPU1, cache_dir=tmp_path) is None
    # corruption degrades to a miss, never an exception
    path.write_text("{not json")
    assert planner.load_cached_plan(sig, TPU1, cache_dir=tmp_path) is None


def test_search_caches_and_rehits(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN", "search")
    sig = sparse_sig(1024, 40, n=4096, name="unit")
    first = planner.resolve_plan(sig, TPU1, cache_dir=tmp_path)
    assert first.origin == "search"
    again = planner.resolve_plan(sig, TPU1, cache_dir=tmp_path)
    assert again.origin == "cache"
    assert dataclasses.replace(again, origin="x") == \
        dataclasses.replace(first, origin="x")


def test_plan_off_never_touches_disk(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN", "off")
    sig = sparse_sig(1024, 40, name="unit")
    planner.resolve_plan(sig, TPU1, cache_dir=tmp_path / "nope")
    assert not (tmp_path / "nope").exists()


def test_cached_plan_rechecks_feasibility(tmp_path):
    """A cached pallas plan that no longer passes the misfit predicates
    (e.g. budgets tightened between releases) is ignored."""
    sig = sparse_sig(1024, 40, name="unit")
    good = planner.static_plan(sig, TPU1, bucket=8, chunks=1)
    assert good.route == "pallas-replicated"
    bad = dataclasses.replace(good, bucket=12)     # now misaligned
    planner.store_plan(sig, TPU1, bad, cache_dir=tmp_path)
    assert planner.load_cached_plan(sig, TPU1, cache_dir=tmp_path) is None


# -- never-regress pins -----------------------------------------------------

WORKING_CONFIGS = [
    # (sig, topo, bucket, chunks) — every previously-working shape class
    (sparse_sig(1024, 40, n=4096), TPU1, 8, 2),          # criteo-ish
    (sparse_sig(1024, 40, n=4096), Topology(backend="cpu"), 8, 2),
    (sparse_sig(D_V_OVER, 64, n=128), TPU_M2, 8, 2),     # webspam-ish
    (sparse_sig(1024, 39, n=4096), TPU1, 8, 2),          # unaligned nnz
    (WorkloadSignature(n=4096, d=28), TPU1, 8, 4),       # higgs-ish
    (WorkloadSignature(n=4096, d=2000), TPU_M2, 16, 8),  # epsilon-ish
    (WorkloadSignature(n=4096, d=64), TPU1, 1, 1),       # bucketing off
]


@pytest.mark.parametrize("sig,topo,bucket,chunks", WORKING_CONFIGS)
def test_planner_auto_equals_static_auto(sig, topo, bucket, chunks,
                                         monkeypatch):
    """THE PR-4 contract: under the default $REPRO_PLAN the planner's
    resolution is bitwise the static resolution on every
    previously-working config."""
    monkeypatch.setenv("REPRO_PLAN", "off")
    off = _plan(sig, topo, bucket=bucket, chunks=chunks)
    monkeypatch.delenv("REPRO_PLAN")
    on = _plan(sig, topo, bucket=bucket, chunks=chunks)
    assert (on.solver, on.route, on.bucket, on.chunks, on.nnz_multiple,
            on.feature_shard) == \
           (off.solver, off.route, off.bucket, off.chunks,
            off.nnz_multiple, off.feature_shard)


def test_route_functions_equal_kernel_predicates():
    """The engine's misfit closures route through planner.route_* —
    pin them to the kernels' own predicates verbatim."""
    for (sig, topo, bucket, _) in WORKING_CONFIGS:
        if sig.sparse:
            assert planner.route_sparse(
                bucket, sig.nnz, sig.d, bucket,
                model_lanes=topo.model_lanes) == kops.sparse_solver_plan(
                bucket, sig.nnz, sig.d, bucket,
                model_lanes=topo.model_lanes)
        else:
            assert planner.route_dense(sig.d, bucket, bucket) == \
                kops.dense_kernel_misfit(sig.d, bucket, bucket)


def test_session_bitwise_pin(monkeypatch, tmp_path):
    """Session(auto) trains bitwise-identically with the planner on vs
    off, and records the resolved plan when on."""
    from repro.api.session import Session
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    rng = np.random.default_rng(0)
    X = rng.normal(size=(16, 256)).astype(np.float32)
    y = np.sign(rng.normal(size=256)).astype(np.float32)

    def fit(mode):
        if mode is None:
            monkeypatch.delenv("REPRO_PLAN", raising=False)
        else:
            monkeypatch.setenv("REPRO_PLAN", mode)
        ses = Session(X, y, objective="logistic", lam=1e-3)
        ses.fit(max_epochs=3, tol=0.0)
        return ses

    on, off = fit(None), fit("off")
    assert on.solver_plan is not None and off.solver_plan is None
    assert on.bplan.bucket == off.bplan.bucket
    assert on.spec.algo.chunks == off.spec.algo.chunks
    np.testing.assert_array_equal(np.asarray(on.v), np.asarray(off.v))
    np.testing.assert_array_equal(np.asarray(on.alpha),
                                  np.asarray(off.alpha))


def test_session_search_mode_sets_geometry(monkeypatch, tmp_path):
    from repro.api.session import Session
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_PLAN", "search")
    rng = np.random.default_rng(1)
    X = rng.normal(size=(16, 4096)).astype(np.float32)
    y = np.sign(rng.normal(size=4096)).astype(np.float32)
    ses = Session(X, y, objective="logistic", lam=1e-3)
    assert ses.solver_plan is not None
    assert ses.bplan.bucket == ses.solver_plan.bucket > 1
    assert ses.spec.algo.chunks == ses.solver_plan.chunks
    ses.epoch()                                  # the geometry trains
    # an explicit bucket kwarg still wins over the search
    pinned = Session(X, y, objective="logistic", lam=1e-3, bucket=8)
    assert pinned.bplan.bucket == 8


def test_scale_for_dataset_pin(monkeypatch, tmp_path):
    """scale_for_dataset resolves its layout through the planner and is
    byte-identical to the retired hardcoded rule on every registry
    dataset."""
    from repro.launch.glm import scale_for_dataset

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    names = ["criteo-kaggle-sub", "higgs", "epsilon", "webspam",
             "synthetic-dense", "synthetic-sparse"]
    monkeypatch.setenv("REPRO_PLAN", "off")
    off = [scale_for_dataset(n) for n in names]
    monkeypatch.delenv("REPRO_PLAN")
    on = [scale_for_dataset(n) for n in names]
    assert on == off
    # webspam keeps its sharded layout even under a full search
    monkeypatch.setenv("REPRO_PLAN", "search")
    assert scale_for_dataset("webspam").feature_shard
    # overrides always win
    assert scale_for_dataset("webspam", bucket=32, chunks=2,
                             feature_shard=False).bucket == 32


def test_resolve_plan_degrades_warn_and_safe(monkeypatch):
    """Any planner internals failure falls back to the static plan with
    a warning — never an exception out of resolve_plan."""
    monkeypatch.delenv("REPRO_PLAN", raising=False)
    monkeypatch.setattr(planner, "load_cached_plan",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("cache exploded")))
    sig = sparse_sig(1024, 40)
    with pytest.warns(UserWarning, match="falling"):
        plan = planner.resolve_plan(sig, TPU1, bucket=8, chunks=2)
    assert plan.origin == "static"
    assert (plan.bucket, plan.chunks) == (8, 2)


def test_ops_plan_solver_entry(monkeypatch, tmp_path):
    """kernels.ops.plan_solver is the kernels-side door: detects the
    live topology and returns a plan honoring $REPRO_PLAN."""
    monkeypatch.delenv("REPRO_PLAN", raising=False)
    plan = kops.plan_solver(4096, 1024, nnz=40, sparse=True,
                            bucket=8, chunks=2, cache_dir=tmp_path)
    assert isinstance(plan, SolverPlan)
    assert (plan.bucket, plan.chunks) == (8, 2)
    import jax
    assert plan.solver == ("pallas" if jax.default_backend() == "tpu"
                           else "xla")
