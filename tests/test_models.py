"""Per-architecture smoke tests + decode-vs-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke, list_archs
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.models import recurrent as rec
from repro.models.layers import materialize
from repro.optim import adamw

ARCHS = list_archs()


def _setup(name, seed=0):
    cfg = get_smoke(name)
    params = materialize(lm.param_specs(cfg), jax.random.PRNGKey(seed))
    return cfg, params


def _enc_kw(cfg, params, B, seed=0):
    if not cfg.is_encoder_decoder:
        return {}
    rng = np.random.default_rng(seed)
    frames = jnp.asarray(rng.standard_normal(
        (B, cfg.enc_seq, cfg.d_model), np.float32))
    return {"enc_out": lm.encoder_fwd(params, frames, cfg)}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg, params = _setup(arch)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits, _ = lm.forward(params, toks, cfg, mode="train",
                           **_enc_kw(cfg, params, B))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_reduces_loss(arch):
    """A few AdamW steps on one repeated batch must reduce the loss."""
    cfg = get_smoke(arch)
    opt_cfg = dataclasses.replace(steps_lib.make_opt_cfg(cfg), lr=3e-3)
    params = steps_lib.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw.init(params, opt_cfg)
    step = jax.jit(steps_lib.make_train_step(cfg, opt_cfg))
    B, S = 2, 16
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (B, cfg.enc_seq, cfg.d_model), np.float32))
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(rng.standard_normal(
            (B, cfg.n_patches, cfg.d_model), np.float32))
    losses = []
    for _ in range(5):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Greedy decode through caches must reproduce the full forward's
    logits position by position (teacher forcing).

    MoE archs compare with a DROPLESS capacity factor: with the training
    default, the full-sequence pass drops over-capacity tokens while
    single-token decode never does — an inherent capacity-MoE semantic,
    not a cache bug (configs/base.py moe_capacity)."""
    cfg, params = _setup(arch, seed=2)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity=float(cfg.n_experts))
        params = materialize(lm.param_specs(cfg), jax.random.PRNGKey(2))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    kw = _enc_kw(cfg, params, B, seed=2)

    full_logits, _ = lm.forward(params, toks, cfg, mode="train", **kw)

    S0 = S // 2
    logits_pre, cache = lm.forward(params, toks[:, :S0], cfg,
                                   mode="prefill", **kw)
    # widen caches to S so decode can append
    shapes = lm.cache_shapes(cfg, B, S)

    def widen(c, s):
        if c.shape == s.shape:
            return c.astype(s.dtype)
        pad = [(0, ds - dc) for dc, ds in zip(c.shape, s.shape)]
        return jnp.pad(c, pad).astype(s.dtype)

    cache = {
        "head": [jax.tree.map(widen, c, s)
                 for c, s in zip(cache["head"], shapes["head"])],
        "blocks": (jax.tree.map(widen, cache["blocks"], shapes["blocks"])
                   if shapes["blocks"] else {}),
        "tail": [jax.tree.map(widen, c, s)
                 for c, s in zip(cache["tail"], shapes["tail"])],
    }
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, S0 - 1], np.float32),
        np.asarray(full_logits[:, S0 - 1], np.float32),
        rtol=2e-2, atol=2e-2)

    # MoE archs accumulate bf16-latent-cache drift through router
    # near-ties; tolerate it but require near-perfect correlation
    # (catches real cache bugs, which decorrelate logits entirely)
    atol = 2e-1 if cfg.n_experts else 5e-2
    for t in range(S0, S):
        logits_t, cache = lm.forward(params, toks[:, t:t + 1], cfg,
                                     mode="decode", cache=cache,
                                     pos=jnp.int32(t), **kw)
        a = np.asarray(logits_t[:, 0], np.float32)
        b = np.asarray(full_logits[:, t], np.float32)
        np.testing.assert_allclose(a, b, rtol=5e-2, atol=atol)
        if cfg.n_experts:
            corr = np.corrcoef(a.reshape(-1), b.reshape(-1))[0, 1]
            assert corr > 0.99, (t, corr)


def test_local_attention_ring_cache_equals_full():
    """Ring decode (cache == window) must equal full-cache local attn."""
    cfg = get_smoke("recurrentgemma-2b")
    cfg_ring = dataclasses.replace(cfg, window=8)
    params = materialize(lm.param_specs(cfg_ring), jax.random.PRNGKey(4))
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab)
    full_logits, _ = lm.forward(params, toks, cfg_ring, mode="train")

    shapes = lm.cache_shapes(cfg_ring, B, S)   # attn caches -> window=8
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), shapes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    cache = {"head": [], "tail": [],
             "blocks": jax.tree.map(
                 lambda s: jnp.zeros(s.shape, s.dtype), shapes["blocks"])}
    for t in range(S):
        logits_t, cache = lm.forward(params, toks[:, t:t + 1], cfg_ring,
                                     mode="decode", cache=cache,
                                     pos=jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits_t[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        rtol=5e-2, atol=5e-2)


def test_mlstm_chunkwise_matches_decode_recurrence():
    cfg = dataclasses.replace(get_smoke("xlstm-1.3b"), attn_chunk=8)
    p = materialize(rec.mlstm_specs(cfg), jax.random.PRNGKey(0))
    B, S, d = 2, 32, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32
                          ).astype(jnp.bfloat16)
    h_chunk = rec.mlstm_fwd(p, x, cfg)
    st = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                      rec.mlstm_cache_shape(cfg, B))
    st["m"] = jnp.full_like(st["m"], -1e30)
    outs = []
    for t in range(S):
        o, st = rec.mlstm_decode(p, x[:, t:t + 1], st, cfg)
        outs.append(o)
    h_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_chunk, np.float32),
                               np.asarray(h_dec, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_unrolled_forward_matches_scanned():
    """The HLO-counting unrolled path must be numerically identical."""
    cfg, params = _setup("smollm-360m", seed=6)
    cfg_u = dataclasses.replace(cfg, unroll_layers=True)
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 16), 0,
                              cfg.vocab)
    l_s, _ = lm.forward(params, toks, cfg, mode="train")
    l_u, _ = lm.forward(params, toks, cfg_u, mode="train")
    # bf16 activations: reduction-order differences between lax.scan and
    # the python loop show up at bf16 resolution (~1e-2 at logit scale)
    np.testing.assert_allclose(np.asarray(l_s, np.float32),
                               np.asarray(l_u, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_param_counts_match_assignment_scale():
    """Full configs must land in the advertised parameter ballpark."""
    from repro.configs import get_config
    expect = {"smollm-360m": (0.3e9, 0.6e9),
              "xlstm-1.3b": (1.0e9, 1.7e9),
              "recurrentgemma-2b": (2.0e9, 4.0e9),
              "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
              "deepseek-v2-lite-16b": (14e9, 18e9)}
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, (name, n)
