"""Solver-engine seams: layered config, solver registry, and the
sim<->mesh backend equivalence the engine refactor exists to pin.

The multi-device tests shell out with 8 forced host devices (repo
convention: only launch entrypoints force device counts)."""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.core import (AlgoConfig, EngineConfig,
                        SolverConfig, as_engine_config, make_local_solver)
from repro.core.objectives import LOGISTIC

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(code: str, timeout=600):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(REPO / "src"))
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


# -- config layering --------------------------------------------------------

def test_engine_config_layering_and_make():
    spec = EngineConfig.make(pods=2, lanes=4, bucket=8, chunks=2,
                             compress_pod=True)
    assert spec.deployment.pods == 2 and spec.deployment.lanes == 4
    assert spec.algo.bucket == 8 and spec.algo.chunks == 2
    assert spec.deployment.compress_pod
    assert spec.workers == 8
    assert spec.sigma_prime() == 8.0
    assert spec.sigma_prime(workers=3) == 3.0
    with pytest.raises(TypeError):
        EngineConfig.make(not_a_knob=1)


def test_solver_config_converts_to_engine():
    flat = SolverConfig(pods=2, lanes=8, bucket=16, partition="alltoall",
                        aggregation="wild", use_kernel=True,
                        compress_sync=True, redeal_frac=0.25)
    spec = as_engine_config(flat)
    assert spec.deployment.pods == 2 and spec.deployment.lanes == 8
    assert spec.algo.partition == "alltoall"
    assert spec.algo.local_solver == "pallas"
    assert spec.algo.compress_sync and spec.algo.redeal_frac == 0.25
    # wild: sigma' stays 1 regardless of worker count
    assert spec.sigma_prime() == 1.0
    assert as_engine_config(spec) is spec


def test_engine_config_passthrough_everywhere():
    # EngineConfig is accepted by the legacy epoch_sim signature
    from repro.core import GLMTrainer
    from repro.data import make_dense_classification
    X, y = make_dense_classification(n=512, d=16, seed=0)
    spec = EngineConfig.make(pods=1, lanes=4, bucket=8,
                             partition="dynamic")
    tr = GLMTrainer(X, y, lam=1e-2, cfg=spec)
    res = tr.fit(max_epochs=30, tol=1e-3)
    assert res.converged


# -- local solver registry --------------------------------------------------

def test_local_solver_registry_guards():
    # sparse + pallas is a real solver now (PR 4), and WITH model_lanes
    # the feature-sharded sparse kernel is too (PR 6); a model_axis
    # without model_lanes still means the legacy TP layout, which no
    # pallas path supports, and unknown kinds are rejected
    assert callable(make_local_solver("pallas", LOGISTIC, 1.0, 1.0,
                                      bucket=8, sparse=True))
    assert callable(make_local_solver("pallas", LOGISTIC, 1.0, 1.0,
                                      bucket=8, sparse=True,
                                      model_axis="model", model_lanes=2))
    with pytest.raises(ValueError):
        make_local_solver("pallas", LOGISTIC, 1.0, 1.0, bucket=8,
                          model_axis="model")
    with pytest.raises(ValueError):
        make_local_solver("pallas", LOGISTIC, 1.0, 1.0, bucket=8,
                          sparse=True, model_axis="model")
    with pytest.raises(ValueError):
        make_local_solver("nope", LOGISTIC, 1.0, 1.0, bucket=8)
    with pytest.raises(ValueError):
        make_local_solver("nope", LOGISTIC, 1.0, 1.0, bucket=8,
                          sparse=True)


def test_local_solver_auto_model_axis_falls_back(monkeypatch):
    """On TPU hosts a backend-picked "auto" must keep LEGACY
    feature-sharded (model-axis without model_lanes) launches on the
    previously-working xla route; only an EXPLICIT pallas request
    (config or env var) raises.  With model_lanes the sparse path has a
    real sharded kernel now (PR 6) and routes there instead."""
    monkeypatch.delenv("REPRO_LOCAL_SOLVER", raising=False)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    # backend-auto + model_axis: silently xla, not a ValueError.  Pin
    # the actual route via the closure's qualname (the solver can't be
    # CALLED here — the model-axis psum needs a shard_map context):
    for sp, xla_route in ((False, "dense_xla_solver"),
                          (True, "sparse_solver")):
        solver = make_local_solver("auto", LOGISTIC, 1.0, 1.0, bucket=8,
                                   sparse=sp, model_axis="model")
        assert solver.__qualname__.startswith(xla_route)
    # sparse + model_lanes: the sharded-v solver exists, so auto keeps
    # the pallas choice (wrapped in the trace-time misfit fallback)
    assert callable(make_local_solver("auto", LOGISTIC, 1.0, 1.0,
                                      bucket=8, sparse=True,
                                      model_axis="model", model_lanes=2))
    # the explicit xla twin on the sharded layout masks dv to its slice
    solver = make_local_solver("xla", LOGISTIC, 1.0, 1.0, bucket=8,
                               sparse=True, model_axis="model",
                               model_lanes=2)
    assert solver.__qualname__.startswith("sparse_sharded_xla_solver")
    # env-forced pallas is an explicit request: still loud on the
    # legacy (no-model_lanes) layouts
    monkeypatch.setenv("REPRO_LOCAL_SOLVER", "pallas")
    with pytest.raises(ValueError, match="feature sharding"):
        make_local_solver("auto", LOGISTIC, 1.0, 1.0, bucket=8,
                          model_axis="model")
    with pytest.raises(ValueError, match="feature sharding"):
        make_local_solver("auto", LOGISTIC, 1.0, 1.0, bucket=8,
                          sparse=True, model_axis="model")


def test_local_solver_auto_sparse_workload_fallback(monkeypatch):
    """Backend-picked sparse "auto" routes kernel-unfit workloads
    (misaligned tiles, blown VMEM budgets) to the XLA scan at trace
    time with a warning, instead of raising at epoch build."""
    import numpy as np
    from repro.data import make_sparse_classification

    monkeypatch.delenv("REPRO_LOCAL_SOLVER", raising=False)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    auto = make_local_solver("auto", LOGISTIC, 1.6, 1.0, bucket=8,
                             sparse=True, interpret=True)
    xla = make_local_solver("xla", LOGISTIC, 1.6, 1.0, sparse=True)
    (idx, val), y, d = make_sparse_classification(n=16, d=32, nnz=8,
                                                  seed=0)
    # nnz=7 violates the sublane alignment -> falls back, bitwise-xla
    bad = ((jnp.asarray(idx[:, :7]), jnp.asarray(val[:, :7])),
           jnp.asarray(y), jnp.zeros(16), jnp.zeros(d))
    with pytest.warns(UserWarning, match="sparse Pallas"):
        a1, dv1 = auto(*bad)
    a2, dv2 = xla(*bad)
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    assert np.array_equal(np.asarray(dv1), np.asarray(dv2))
    # aligned tiles keep using the kernel (bitwise contract holds)
    good = ((jnp.asarray(idx), jnp.asarray(val)), jnp.asarray(y),
            jnp.zeros(16), jnp.zeros(d))
    a1, dv1 = auto(*good)
    a2, dv2 = xla(*good)
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    assert np.array_equal(np.asarray(dv1), np.asarray(dv2))


def test_local_solver_auto_dense_workload_fallback(monkeypatch):
    """Backend-picked dense "auto" routes kernel-unfit workloads (here:
    tiles over the VMEM budget) to the XLA Gram scan at trace time with
    a warning, and keeps the kernel for fitting ones."""
    import numpy as np

    monkeypatch.delenv("REPRO_LOCAL_SOLVER", raising=False)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.choice([-1.0, 1.0], 16).astype(np.float32))
    a = jnp.zeros(16)
    auto = make_local_solver("auto", LOGISTIC, 1.6, 1.0, bucket=8,
                             interpret=True)
    # d large enough that the double-buffered (d_pad, B) tile blows the
    # total VMEM budget -> falls back, bitwise-xla
    d_big = 250_000
    Xb = jnp.asarray(rng.standard_normal((d_big, 16)).astype(np.float32))
    xla = make_local_solver("xla", LOGISTIC, 1.6, 1.0, bucket=8)
    with pytest.warns(UserWarning, match="dense Pallas"):
        a1, dv1 = auto(Xb, y, a, jnp.zeros(d_big))
    a2, dv2 = xla(Xb, y, a, jnp.zeros(d_big))
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    assert np.array_equal(np.asarray(dv1), np.asarray(dv2))
    # a small workload keeps using the kernel (bitwise vs explicit)
    Xs = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    pallas = make_local_solver("pallas", LOGISTIC, 1.6, 1.0, bucket=8,
                               interpret=True)
    a1, dv1 = auto(Xs, y, a, jnp.zeros(32))
    a2, dv2 = pallas(Xs, y, a, jnp.zeros(32))
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    assert np.array_equal(np.asarray(dv1), np.asarray(dv2))


def test_local_solver_auto_resolution(monkeypatch):
    """"auto" = backend-dependent (xla off-TPU) with the
    $REPRO_LOCAL_SOLVER escape hatch in both directions."""
    import numpy as np
    from repro.core.engine import resolve_auto_solver
    from repro.data import make_sparse_classification

    monkeypatch.delenv("REPRO_LOCAL_SOLVER", raising=False)
    assert resolve_auto_solver() == "xla"        # CPU/GPU test hosts
    monkeypatch.setenv("REPRO_LOCAL_SOLVER", "pallas")
    assert resolve_auto_solver() == "pallas"
    monkeypatch.setenv("REPRO_LOCAL_SOLVER", "bogus")
    with pytest.raises(ValueError, match="REPRO_LOCAL_SOLVER"):
        resolve_auto_solver()

    # env-forced pallas flows through make_local_solver("auto") and is
    # bitwise-identical to the explicit kernel solver
    monkeypatch.setenv("REPRO_LOCAL_SOLVER", "pallas")
    (idx, val), y, d = make_sparse_classification(n=16, d=32, nnz=8,
                                                  seed=0)
    args = ((jnp.asarray(idx), jnp.asarray(val)), jnp.asarray(y),
            jnp.zeros(16), jnp.zeros(d))
    auto = make_local_solver("auto", LOGISTIC, 1.6, 1.0, bucket=8,
                             sparse=True)
    explicit = make_local_solver("pallas", LOGISTIC, 1.6, 1.0, bucket=8,
                                 sparse=True)
    a1, dv1 = auto(*args)
    a2, dv2 = explicit(*args)
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    assert np.array_equal(np.asarray(dv1), np.asarray(dv2))


def test_chunks_must_divide_buckets():
    from repro.core import DenseBlock, SimCollectives, run_epoch
    coll = SimCollectives(pods=1, lanes=2)
    solver = make_local_solver("xla", LOGISTIC, 1.0, 2.0, bucket=8)
    algo = AlgoConfig(bucket=8, chunks=3)
    X = jnp.zeros((2, 2, 4, 64))
    y = jnp.ones((2, 2, 64))
    with pytest.raises(ValueError, match="chunks"):
        run_epoch(coll, solver, algo, DenseBlock(X), y,
                  jnp.zeros((2, 2, 64)), jnp.zeros(4), 0)


# -- sim <-> mesh equivalence (the refactor's contract) ---------------------

def test_sim_mesh_bitwise_equivalence_dense():
    """engine + SimCollectives and engine + MeshCollectives (1 pod x 8
    data lanes, CPU) produce bitwise-identical (alpha, v) after 2
    epochs on a dense workload (deterministic collectives)."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import engine
        from repro.core.objectives import LOGISTIC
        from repro.launch.glm import GLMScale, make_dense_epoch
        from repro.launch.mesh import make_host_mesh
        from repro.data import make_dense_classification

        K = 8; n, d = 1024, 64
        scale = GLMScale("t", "dense", n=n, d=d, bucket=8, chunks=2,
                         lam=1e-2, compress_pod=False,
                         deterministic=True)
        X, y = make_dense_classification(n=n, d=d, seed=0)
        X, y = jnp.asarray(X), jnp.asarray(y)
        a0, v0 = jnp.zeros(n), jnp.zeros(d)

        mesh = make_host_mesh(pod=1, data=K, model=1)
        with mesh:
            ep = jax.jit(make_dense_epoch(scale, mesh))
            Xm, ym, am, vm = X, y, a0, v0
            for e in range(2):
                Xm, ym, am, vm = ep(Xm, ym, am, vm, jnp.int32(e))

        spec = scale.engine_config(mesh)
        Xs = jnp.transpose(X.reshape(d, 1, K, n // K), (1, 2, 0, 3))
        ys, as_ = y.reshape(1, K, -1), a0.reshape(1, K, -1)
        sim = jax.jit(lambda X_, y_, a_, v_, e:
                      engine.sim_sharded_dense_epoch(
                          LOGISTIC, spec, X_, y_, a_, v_, e,
                          lam=scale.lam, n_total=n))
        vs = v0
        for e in range(2):
            Xs, ys, as_, vs = sim(Xs, ys, as_, vs, jnp.int32(e))

        assert np.array_equal(np.asarray(vs), np.asarray(vm))
        assert np.array_equal(np.asarray(as_).reshape(-1),
                              np.asarray(am))
        assert np.array_equal(
            np.transpose(np.asarray(Xs)[0], (1, 0, 2)).reshape(d, n),
            np.asarray(Xm))
        assert float(jnp.max(jnp.abs(vs))) > 0   # actually trained
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_sim_mesh_bitwise_equivalence_sparse():
    """Same contract on a sparse (padded-CSR) workload."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import engine
        from repro.core.objectives import LOGISTIC
        from repro.launch.glm import GLMScale, make_sparse_epoch
        from repro.launch.mesh import make_host_mesh
        from repro.data import make_sparse_classification

        K = 8; n, d, nnz = 1024, 256, 8
        scale = GLMScale("s", "sparse", n=n, d=d, nnz=nnz, bucket=8,
                         chunks=2, lam=1e-2, compress_pod=False,
                         deterministic=True)
        (idx, val), y, _ = make_sparse_classification(n=n, d=d, nnz=nnz,
                                                      seed=2)
        idx, val, y = (jnp.asarray(t) for t in (idx, val, y))
        a0, v0 = jnp.zeros(n), jnp.zeros(d)

        mesh = make_host_mesh(pod=1, data=K, model=1)
        with mesh:
            ep = jax.jit(make_sparse_epoch(scale, mesh))
            st = (idx, val, y, a0, v0)
            for e in range(2):
                st = ep(*st, jnp.int32(e))
        im, vm_, ym, am, vvm = st

        spec = scale.engine_config(mesh)
        nl = n // K
        st2 = (idx.reshape(1, K, nl, nnz), val.reshape(1, K, nl, nnz),
               y.reshape(1, K, nl), a0.reshape(1, K, nl), v0)
        sim = jax.jit(lambda i, v_, y_, a_, vv, e:
                      engine.sim_sharded_sparse_epoch(
                          LOGISTIC, spec, i, v_, y_, a_, vv, e,
                          lam=scale.lam, n_total=n))
        for e in range(2):
            st2 = sim(*st2, jnp.int32(e))
        iS, vS, yS, aS, vv = st2

        assert np.array_equal(np.asarray(vv), np.asarray(vvm))
        assert np.array_equal(np.asarray(aS).reshape(-1), np.asarray(am))
        assert np.array_equal(np.asarray(iS).reshape(-1, nnz),
                              np.asarray(im))
        assert float(jnp.max(jnp.abs(vv))) > 0
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_sparse_pallas_solver_resident_and_streamed_bitwise(tmp_path):
    """`local_solver="pallas"` on the SPARSE path is bitwise-identical
    to the XLA gather/scatter scan through the full training loop, on
    both the resident and streamed-from-cache harnesses (the PR-4
    acceptance pin; the kernel-level contract lives in
    tests/test_kernels.py)."""
    import numpy as np
    import warnings
    from repro.core import fit_dataset

    outs: dict[tuple, tuple] = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for streamed in (False, True):
            for solver in ("xla", "pallas"):
                cfg = EngineConfig.make(
                    pods=2, lanes=2, bucket=8, chunks=2,
                    partition="hierarchical", deterministic=True,
                    local_solver=solver)
                res = fit_dataset(
                    "synthetic-sparse", cfg=cfg, cache_dir=tmp_path,
                    n=256, d=64, streamed=streamed, max_epochs=2,
                    tol=0.0)
                outs[(streamed, solver)] = (res.alpha, res.v)
    for streamed in (False, True):
        xa, xv = outs[(streamed, "xla")]
        pa, pv = outs[(streamed, "pallas")]
        assert np.array_equal(xa, pa), f"alpha differs (streamed={streamed})"
        assert np.array_equal(xv, pv), f"v differs (streamed={streamed})"
    assert np.abs(outs[(True, "pallas")][1]).max() > 0


def test_sparse_pallas_solver_vmap_path_bitwise():
    """The stacked-sim vmap path (deterministic=False) batches the
    sparse Pallas kernel across virtual workers and still matches XLA
    bitwise (pallas_call's vmap rule extends the grid)."""
    import numpy as np
    import warnings
    from repro.core import fit_dataset

    outs = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for solver in ("xla", "pallas"):
            cfg = EngineConfig.make(lanes=4, bucket=8, chunks=2,
                                    partition="dynamic",
                                    local_solver=solver)
            res = fit_dataset("synthetic-sparse", cfg=cfg, n=256, d=64,
                              max_epochs=2, tol=0.0)
            outs[solver] = (res.alpha, res.v)
    assert np.array_equal(outs["xla"][0], outs["pallas"][0])
    assert np.array_equal(outs["xla"][1], outs["pallas"][1])


def test_sparse_pallas_local_solver_on_mesh_path():
    """Sparse `local_solver='pallas'` through launch/glm.py's shard_map
    program is BITWISE-identical to the XLA local solver (deterministic
    collectives; interpret-mode kernel on CPU)."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.glm import GLMScale, make_sparse_epoch
        from repro.launch.mesh import make_host_mesh
        from repro.data import make_sparse_classification

        K = 8; n, d, nnz = 1024, 256, 8
        (idx, val), y, _ = make_sparse_classification(n=n, d=d, nnz=nnz,
                                                      seed=2)
        idx, val, y = (jnp.asarray(t) for t in (idx, val, y))
        a0, v0 = jnp.zeros(n), jnp.zeros(d)
        mesh = make_host_mesh(pod=1, data=K, model=1)
        outs = {}
        for solver in ("xla", "pallas"):
            sc = GLMScale("s", "sparse", n=n, d=d, nnz=nnz, bucket=8,
                          chunks=2, lam=1e-2, compress_pod=False,
                          deterministic=True, local_solver=solver)
            with mesh:
                ep = jax.jit(make_sparse_epoch(sc, mesh))
                st = (idx, val, y, a0, v0)
                for e in range(2):
                    st = ep(*st, jnp.int32(e))
            outs[solver] = [np.asarray(t) for t in st]
        for xa, pa in zip(outs["xla"], outs["pallas"]):
            assert np.array_equal(xa, pa)
        assert np.abs(outs["pallas"][4]).max() > 0
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


# -- feature-sharded sparse dispatch + mesh path (PR 6, DESIGN.md S12) ------

@pytest.mark.parametrize("n_local,nnz,d,B,M,route,reason_part", [
    # small d: whole v fits in VMEM — data-parallel replicated kernel,
    # regardless of how many model lanes the mesh has
    (64, 8, 4_096, 8, 1, "pallas-replicated", None),
    (64, 8, 4_096, 8, 4, "pallas-replicated", None),
    # exact VMEM boundary row: d_pad * 4 == V_VMEM_BUDGET_BYTES still
    # fits the replicated kernel (budget is inclusive)
    (64, 8, 2_097_152, 8, 1, "pallas-replicated", None),
    (64, 8, 2_097_152, 8, 4, "pallas-replicated", None),
    # one sublane past the boundary: replicated is out; a 2-lane mesh
    # puts it on the sharded kernel, a 1-lane mesh falls back to xla
    (64, 8, 2_097_160, 8, 1, "xla", "resident-v"),
    (64, 8, 2_097_160, 8, 2, "pallas-sharded", None),
    # 4x the boundary: even the d/2 slice is too wide, but d/8 fits
    (64, 8, 8_388_608, 8, 2, "xla", "slice does not fit"),
    (64, 8, 8_388_608, 8, 8, "pallas-sharded", None),
    # alignment and divisibility misfits beat everything
    (64, 7, 4_096, 8, 4, "xla", "multiples of 8"),
    (12, 8, 4_096, 8, 4, "xla", "divide"),
    # wide rows: the (B, nnz, nnz) match tensor blows the TOTAL budget
    # for replicated AND sharded alike — sharding v doesn't shrink it
    (64, 512, 4_096, 16, 2, "xla", "total budget"),
])
def test_sparse_solver_plan_decision_table(n_local, nnz, d, B, M, route,
                                           reason_part):
    """The data-parallel vs feature-parallel dispatcher picks the
    documented route on shape corners, VMEM boundary rows included
    (LightGBM-style selection table — SNIPPETS.md Snippet 3)."""
    from repro.kernels import ops as kops
    got_route, got_reason = kops.sparse_solver_plan(
        n_local, nnz, d, B, model_lanes=M)
    assert got_route == route
    if reason_part is None:
        assert got_reason is None
        # misfit agrees: some kernel fits
        assert kops.sparse_kernel_misfit(n_local, nnz, d, B,
                                         model_lanes=M) is None
    else:
        assert reason_part in got_reason
        assert kops.sparse_kernel_misfit(
            n_local, nnz, d, B, model_lanes=M) == got_reason


def test_sparse_sharded_pallas_on_mesh_bitwise():
    """Feature-sharded sparse `local_solver='pallas'` through
    launch/glm.py on a 2x2 (data x model) mesh is BITWISE-identical to
    the slice-masked XLA scan on the same layout (deterministic
    collectives; interpret-mode kernels on CPU).  d=250 exercises
    uneven slices + sublane padding."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.glm import GLMScale, make_sparse_epoch
        from repro.launch.mesh import make_host_mesh
        from repro.data import make_sparse_classification

        n, d, nnz = 256, 250, 8
        (idx, val), y, _ = make_sparse_classification(n=n, d=d, nnz=nnz,
                                                      seed=2)
        idx, val, y = (jnp.asarray(t) for t in (idx, val, y))
        a0, v0 = jnp.zeros(n), jnp.zeros(d)
        mesh = make_host_mesh(pod=1, data=2, model=2)
        outs = {}
        for solver in ("xla", "pallas"):
            sc = GLMScale("s", "sparse", n=n, d=d, nnz=nnz, bucket=8,
                          chunks=2, lam=1e-2, compress_pod=False,
                          deterministic=True, local_solver=solver,
                          feature_shard=True)
            with mesh:
                ep = jax.jit(make_sparse_epoch(sc, mesh, interpret=True))
                st = (idx, val, y, a0, v0)
                for e in range(2):
                    st = ep(*st, jnp.int32(e))
            outs[solver] = [np.asarray(t) for t in st]
        for xa, pa in zip(outs["xla"], outs["pallas"]):
            assert np.array_equal(xa, pa)
        assert np.abs(outs["pallas"][4]).max() > 0
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_sparse_sharded_auto_acceptance_webspam_scale():
    """The PR-6 acceptance pin: a workload whose d exceeds the
    replicated kernel's resident-v VMEM budget trains through the
    feature-sharded sparse Pallas path on a model-axis mesh, bitwise
    equal to the XLA scan under deterministic=True, with
    local_solver='auto' selecting it WITHOUT env overrides (backend
    patched to 'tpu'; warnings-as-errors pins that auto did not take
    the misfit fallback).  Also pins the layout default: real webspam
    feature-shards, criteo does not."""
    r = _run("""
        import warnings
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels import ops as kops
        from repro.kernels.sdca_sparse_bucket import V_VMEM_BUDGET_BYTES
        from repro.launch.glm import (GLMScale, make_sparse_epoch,
                                      scale_for_dataset)
        from repro.launch.mesh import make_host_mesh
        from repro.data import make_sparse_classification

        d = V_VMEM_BUDGET_BYTES // 4 + 8    # past the replicated budget
        n, nnz, B = 32, 8, 8
        assert kops.sparse_solver_plan(n, nnz, d, B, model_lanes=2) == \\
            ("pallas-sharded", None)
        assert scale_for_dataset("webspam").feature_shard
        assert not scale_for_dataset("criteo-kaggle-sub").feature_shard

        (idx, val), y, _ = make_sparse_classification(n=n, d=d, nnz=nnz,
                                                      seed=3)
        idx, val, y = (jnp.asarray(t) for t in (idx, val, y))
        a0, v0 = jnp.zeros(n), jnp.zeros(d)
        mesh = make_host_mesh(pod=1, data=2, model=2)
        jax.default_backend = lambda: "tpu"   # auto resolves to pallas
        outs = {}
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            for solver in ("xla", "auto"):
                sc = GLMScale("w", "sparse", n=n, d=d, nnz=nnz, bucket=B,
                              chunks=2, lam=1e-2, compress_pod=False,
                              deterministic=True, local_solver=solver,
                              feature_shard=True)
                with mesh:
                    ep = jax.jit(make_sparse_epoch(sc, mesh,
                                                   interpret=True))
                    st = ep(idx, val, y, a0, v0, jnp.int32(0))
                outs[solver] = [np.asarray(t) for t in st]
        for xa, pa in zip(outs["xla"], outs["auto"]):
            assert np.array_equal(xa, pa)
        assert np.abs(outs["auto"][4]).max() > 0
        print("OK")
    """, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_pallas_local_solver_on_distributed_path():
    """local_solver='pallas' is selectable through launch/glm.py and
    matches the XLA local solver to <=1e-5 after one epoch (interpret
    mode on CPU)."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.glm import GLMScale, make_dense_epoch
        from repro.launch.mesh import make_host_mesh
        from repro.data import make_dense_classification

        K = 8; n, d = 1024, 64
        X, y = make_dense_classification(n=n, d=d, seed=0)
        X, y = jnp.asarray(X), jnp.asarray(y)
        a0, v0 = jnp.zeros(n), jnp.zeros(d)
        mesh = make_host_mesh(pod=1, data=K, model=1)
        outs = {}
        for solver in ("xla", "pallas"):
            sc = GLMScale("p", "dense", n=n, d=d, bucket=8, chunks=2,
                          lam=1e-2, compress_pod=False,
                          local_solver=solver)
            with mesh:
                ep = jax.jit(make_dense_epoch(sc, mesh))
                outs[solver] = [np.asarray(t) for t in
                                ep(X, y, a0, v0, jnp.int32(0))]
        for xa, pa in zip(outs["xla"], outs["pallas"]):
            np.testing.assert_allclose(xa, pa, atol=1e-5, rtol=1e-5)
        assert np.abs(outs["pallas"][3]).max() > 0
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr
