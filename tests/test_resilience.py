"""Fault-tolerant runtime (DESIGN.md S15): deterministic injection,
crash-safe streamed epochs, typed corruption recovery, health rollback.

The oracle throughout is the repo's bitwise-determinism contract: under
``deterministic=True`` a recovered run must equal the uninterrupted run
bit-for-bit, because schedules are pure functions of (seed, epoch) and
every recovery path resumes from an exact snapshot."""
import json

import numpy as np
import pytest

from repro.api import HealthMonitor, HealthPolicy, Session
from repro.core import EngineConfig
from repro.data import (make_dense_classification,
                        make_sparse_classification, registry)
from repro.data.cache import TileCorruptionError
from repro.resilience import (FaultInjectedIOError, FaultInjector,
                              FaultyFeed, KernelBuildError,
                              ResilientChunkFeed, SimulatedCrash,
                              parse_schedule)

CFG = EngineConfig.make(pods=2, lanes=2, bucket=8, chunks=4,
                        partition="hierarchical", deterministic=True,
                        local_solver="xla")
RES_CFG = EngineConfig.make(pods=1, lanes=2, bucket=8, chunks=2,
                            partition="hierarchical", deterministic=True,
                            local_solver="xla")
EPOCHS = 3
KINDS = ["dense", "sparse"]


def _maker(kind, root):
    """Cache (re)builder for one synthetic dataset — byte-stable, so a
    rebuild after quarantine is bitwise-identical to the original."""
    def mk():
        return registry.materialize(f"synthetic-{kind}", root, bucket=8,
                                    pods=2, n=512, d=64, pad_multiple=256)
    return mk


def _resident_source(kind):
    if kind == "dense":
        X, y = make_dense_classification(n=256, d=32, seed=0)
        return dict(data=(np.asarray(X), np.asarray(y)))
    (idx, val), y, d = make_sparse_classification(n=256, d=64, nnz=8,
                                                  seed=1)
    return dict(data=((idx, val), y), d=d)


def _fit(source, *, cfg=CFG, until=EPOCHS, **kw):
    s = Session(source, cfg=cfg, lam=1e-3, objective="logistic", **kw)
    res = s.fit(until=until, tol=0)
    return s, res


# -- fault grammar ----------------------------------------------------------

def test_parse_schedule_grammar():
    specs = parse_schedule("fetch-error@n3x2; kill@e1c2; flip-tile@t5")
    assert [s.kind for s in specs] == ["fetch-error", "kill", "flip-tile"]
    assert specs[0].nth == 3 and specs[0].times == 2
    assert specs[1].epoch == 1 and specs[1].chunk == 2
    assert specs[2].tile == 5
    with pytest.raises(ValueError):
        parse_schedule("melt-cpu@e1")          # unknown fault kind
    with pytest.raises(ValueError):
        parse_schedule("kill@q9")              # unknown site token


def test_injector_from_env_is_none_when_unset(monkeypatch):
    """Zero-overhead contract: no $REPRO_FAULTS means no injector, no
    journal, and no health monitor object on a default Session."""
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    assert FaultInjector.from_env() is None
    src = _resident_source("dense")
    s = Session(src.pop("data"), cfg=RES_CFG, lam=1e-3,
                objective="logistic", **src)
    assert s._faults is None and s._journal is None


# -- tile corruption: typed error, quarantine, bitwise rebuild --------------

def test_tile_corruption_error_is_typed_and_localized(tmp_path):
    cache = _maker("dense", tmp_path)()
    FaultInjector("flip-tile@t5", seed=7).apply_disk_faults(cache.path)
    with pytest.raises(TileCorruptionError) as ei:
        _maker("dense", tmp_path)().verify_tiles()
    err = ei.value
    a = cache.arrays[err.array]
    tile_nbytes = a.reshape((cache.meta.n_buckets,) + a.shape[2:])[0].nbytes
    assert err.tile == 5 and err.offset == 5 * tile_nbytes
    assert err.array and str(err.path).endswith(f"{err.array}.bin")
    assert "quarantine" in str(err)


@pytest.mark.parametrize("kind", KINDS)
def test_corruption_quarantine_rebuild_bitwise(tmp_path, kind):
    mk = _maker(kind, tmp_path)
    _, ref = _fit(mk(), streamed=True)
    FaultInjector("flip-tile@t5", seed=7).apply_disk_faults(mk().path)
    feed = ResilientChunkFeed(mk().feed(verify=True), rebuild=mk,
                              sleep=lambda t: None)
    s, _ = _fit(feed)
    assert np.array_equal(np.asarray(s.v), np.asarray(ref.v))
    quarantined = list(tmp_path.glob(".quarantine.*"))
    assert quarantined, "corrupt cache dir must be kept for forensics"
    mk().verify_tiles()                        # rebuilt cache is clean


def test_corruption_without_rebuilder_raises(tmp_path):
    mk = _maker("dense", tmp_path)
    cache = mk()
    FaultInjector("flip-tile@t2", seed=7).apply_disk_faults(cache.path)
    feed = ResilientChunkFeed(mk().feed(verify=True))   # no rebuild=
    with pytest.raises(TileCorruptionError):
        _fit(feed)


# -- crash-safe epochs: kill mid-epoch / at epoch boundary, resume ----------

@pytest.mark.parametrize("kind", KINDS)
def test_kill_and_resume_streamed_bitwise(tmp_path, kind):
    """SIGKILL simulation between chunk 1 and 2 of epoch 1: a fresh
    process resumes from the journal at the chunk boundary and finishes
    bitwise-identical to the uninterrupted run."""
    mk = _maker(kind, tmp_path / "c")
    _, ref = _fit(mk(), streamed=True)
    jd = tmp_path / "journal"
    with pytest.raises(SimulatedCrash):
        _fit(mk(), streamed=True, journal_dir=jd,
             faults=FaultInjector("kill@e1c2"))
    s2 = Session(mk(), cfg=CFG, lam=1e-3, objective="logistic",
                 streamed=True, journal_dir=jd)
    assert s2.epochs_done == 1                 # epoch 0 was committed
    res = s2.fit(until=EPOCHS, tol=0)
    assert np.array_equal(np.asarray(res.v), np.asarray(ref.v))
    assert np.array_equal(np.asarray(res.alpha), np.asarray(ref.alpha))


@pytest.mark.parametrize("kind", KINDS)
def test_kill_and_resume_resident_bitwise(tmp_path, kind):
    """Epoch-boundary kill on the resident path: the journal's
    committed-epoch record alone is enough to resume bitwise."""
    src = _resident_source(kind)
    kw = dict(cfg=RES_CFG, lam=1e-3, objective="logistic",
              **{k: v for k, v in src.items() if k != "data"})
    ref = Session(src["data"], **kw)
    ref.fit(until=EPOCHS, tol=0)
    jd = tmp_path / "journal"
    crashing = Session(src["data"], **kw, journal_dir=jd,
                       faults=FaultInjector("kill@e2"))
    with pytest.raises(SimulatedCrash):
        crashing.fit(until=EPOCHS, tol=0)
    resumed = Session(src["data"], **kw, journal_dir=jd)
    assert resumed.epochs_done == 2
    resumed.fit(until=EPOCHS, tol=0)
    assert np.array_equal(np.asarray(resumed.v), np.asarray(ref.v))


def test_kill_resume_emits_event_log(tmp_path, fault_env):
    """$REPRO_FAULTS end-to-end: the schedule arms from the
    environment, and the event log is a byte-stable (timestamp-free,
    sorted-key) JSON-lines stream the chaos job can diff."""
    log = fault_env("kill@e1c1")
    mk = _maker("dense", tmp_path / "c")
    jd = tmp_path / "journal"
    with pytest.raises(SimulatedCrash):
        _fit(mk(), streamed=True, journal_dir=jd)
    events = [json.loads(ln) for ln in log.read_text().splitlines()]
    names = [e["event"] for e in events]
    assert "journal.chunk" in names and "inject.kill" in names
    for raw, e in zip(log.read_text().splitlines(), events):
        assert raw == json.dumps(e, sort_keys=True)   # stable bytes
        assert "time" not in e and "timestamp" not in e


# -- transient I/O errors: retry with backoff -------------------------------

@pytest.mark.parametrize("kind", KINDS)
def test_retry_after_transient_bitwise(tmp_path, kind):
    mk = _maker(kind, tmp_path)
    _, ref = _fit(mk(), streamed=True)
    inj = FaultInjector("fetch-error@n3x2")
    delays = []
    feed = ResilientChunkFeed(FaultyFeed(mk().feed(), inj),
                              retries=3, backoff=0.01,
                              sleep=delays.append)
    s, _ = _fit(feed)
    assert np.array_equal(np.asarray(s.v), np.asarray(ref.v))
    assert delays == [0.01, 0.02]              # capped exponential


def test_transient_retries_exhausted_raises(tmp_path):
    mk = _maker("dense", tmp_path)
    inj = FaultInjector("fetch-error@n1x5")
    feed = ResilientChunkFeed(FaultyFeed(mk().feed(), inj),
                              retries=2, sleep=lambda t: None)
    with pytest.raises(FaultInjectedIOError):
        _fit(feed)


# -- numerical health: rollback + remediate ---------------------------------

@pytest.mark.parametrize("kind", KINDS)
def test_nan_chunk_rollback_streamed_bitwise(tmp_path, kind):
    """A NaN-poisoned chunk trips the health guard at epoch end; it
    rolls back to the last healthy snapshot and the retry (the fault is
    one-shot) converges bitwise with the clean run."""
    mk = _maker(kind, tmp_path)
    _, ref = _fit(mk(), streamed=True)
    monitor = HealthMonitor(HealthPolicy(retries=1))
    inj = FaultInjector("nan-chunk@n6")
    s, res = _fit(FaultyFeed(mk().feed(), inj), health=monitor)
    assert np.array_equal(np.asarray(s.v), np.asarray(ref.v))
    assert not res.diverged
    assert monitor.trips == 1
    assert "non-finite" in monitor.events[0]["reason"]


@pytest.mark.parametrize("kind", KINDS)
def test_nan_epoch_rollback_resident_bitwise(kind):
    src = _resident_source(kind)
    kw = dict(cfg=RES_CFG, lam=1e-3, objective="logistic",
              **{k: v for k, v in src.items() if k != "data"})
    ref = Session(src["data"], **kw)
    ref.fit(until=EPOCHS, tol=0)
    monitor = HealthMonitor(HealthPolicy(retries=1))
    s = Session(src["data"], **kw, faults=FaultInjector("nan-epoch@e1"))
    res = s.fit(until=EPOCHS, tol=0, health=monitor)
    assert np.array_equal(np.asarray(s.v), np.asarray(ref.v))
    assert not res.diverged and monitor.trips == 1


def test_health_gives_up_past_max_trips():
    """A fault that re-fires every epoch exhausts the policy; fit
    reports divergence instead of looping forever."""
    src = _resident_source("dense")
    monitor = HealthMonitor(HealthPolicy(retries=0, remedy="fallback",
                                         max_trips=2))
    s = Session(src["data"], cfg=RES_CFG, lam=1e-3, objective="logistic",
                faults=FaultInjector("nan-epoch@x99"))
    res = s.fit(until=EPOCHS, tol=0, health=monitor)
    assert monitor.gave_up and res.diverged


def test_health_policy_validates_remedy():
    with pytest.raises(ValueError):
        HealthPolicy(remedy="reboot")


# -- kernel failures: retry, then fall back to the XLA solver ---------------

def test_persistent_kernel_fail_falls_back_to_xla(tmp_path):
    """A kernel that fails at every epoch under local_solver="pallas"
    exhausts the retry budget; the fallback remedy reroutes to the XLA
    solver, which is bitwise-identical under deterministic=True."""
    mk = _maker("dense", tmp_path)
    _, ref = _fit(mk(), streamed=True)         # xla reference
    cfgp = EngineConfig.make(pods=2, lanes=2, bucket=8, chunks=4,
                             partition="hierarchical", deterministic=True,
                             local_solver="pallas")
    monitor = HealthMonitor(HealthPolicy(retries=1))
    s = Session(mk(), cfg=cfgp, lam=1e-3, objective="logistic",
                streamed=True, faults=FaultInjector("kernel-fail@x99"))
    res = s.fit(until=EPOCHS, tol=0, health=monitor)
    assert s.spec.algo.local_solver == "xla"
    assert not res.diverged
    assert np.array_equal(np.asarray(s.v), np.asarray(ref.v))
    assert any(e["action"] == "fallback:xla" for e in monitor.events)


def test_kernel_fail_without_monitor_raises(tmp_path):
    mk = _maker("dense", tmp_path)
    cfgp = EngineConfig.make(pods=2, lanes=2, bucket=8, chunks=4,
                             partition="hierarchical", deterministic=True,
                             local_solver="pallas")
    s = Session(mk(), cfg=cfgp, lam=1e-3, objective="logistic",
                streamed=True, faults=FaultInjector("kernel-fail@e0"))
    with pytest.raises(KernelBuildError):
        s.fit(until=1, tol=0)


# -- cache build atomicity: meta.json is the validity marker ----------------

def test_interrupted_build_without_marker_is_rebuilt(tmp_path):
    """A build killed before its final meta.json write leaves a
    directory without the validity marker; materialize must quarantine
    it and rebuild rather than serve half-written tiles."""
    mk = _maker("dense", tmp_path)
    path = mk().path
    (path / "meta.json").unlink()              # simulate the torn build
    cache = mk()
    cache.verify_tiles()
    assert (cache.path / "meta.json").exists()
    assert list(tmp_path.glob(".quarantine.*"))


def test_truncated_meta_marker_is_rebuilt(tmp_path):
    mk = _maker("dense", tmp_path)
    path = mk().path
    full = (path / "meta.json").read_text()
    (path / "meta.json").write_text(full[:len(full) // 2])
    cache = mk()                               # quarantines + rebuilds
    cache.verify_tiles()
    assert json.loads((cache.path / "meta.json").read_text())


def test_rebuilt_cache_is_byte_identical(tmp_path):
    """Quarantine-and-rebuild only preserves bitwise training because
    cache builds themselves are byte-stable; pin that property."""
    mk = _maker("dense", tmp_path)
    path = mk().path
    bins = {p.name: p.read_bytes() for p in sorted(path.glob("*.bin"))}
    (path / "meta.json").unlink()
    rebuilt = mk().path
    for name, blob in bins.items():
        assert (rebuilt / name).read_bytes() == blob


# -- mesh-streamed path (DESIGN.md S16): same guarantees on a real mesh -----
#
# These need >= 2 devices (the chaos CI job forces host devices); runs
# with fewer skip rather than fake a mesh.

def _mesh2():
    import jax
    if jax.device_count() < 2:
        pytest.skip(f"{jax.device_count()} device(s) < 2")
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(pod=1, data=2, model=1)


MESH_CFG = EngineConfig.make(pods=1, lanes=2, bucket=8, chunks=4,
                             partition="alltoall", deterministic=True,
                             local_solver="xla", compress_pod=False)


def test_kill_and_resume_mesh_streamed_bitwise(tmp_path):
    """SIGKILL simulation between chunk 1 and 2 of epoch 1 on the
    MESH-streamed path: a fresh process resumes from the journal at
    the chunk boundary and finishes bitwise-identical — the
    `MeshSchedule` is pure in (seed, epoch), so the resumed epoch
    replays exactly the not-yet-applied chunks."""
    mesh = _mesh2()
    mk = _maker("dense", tmp_path / "c")
    kw = dict(cfg=MESH_CFG, lam=1e-3, objective="logistic",
              streamed=True, mesh=mesh)
    ref = Session(mk(), **kw)
    ref.fit(until=EPOCHS, tol=0)
    jd = tmp_path / "journal"
    with pytest.raises(SimulatedCrash):
        Session(mk(), **kw, journal_dir=jd,
                faults=FaultInjector("kill@e1c2")).fit(until=EPOCHS,
                                                       tol=0)
    s2 = Session(mk(), **kw, journal_dir=jd)
    assert s2.epochs_done == 1                 # epoch 0 was committed
    res = s2.fit(until=EPOCHS, tol=0)
    assert np.array_equal(np.asarray(res.v), np.asarray(ref.v))
    assert np.array_equal(np.asarray(res.alpha), np.asarray(ref.alpha))


def test_corruption_quarantine_rebuild_mesh_streamed(tmp_path):
    """A `ResilientChunkFeed` wrapped around the mesh pipeline keeps
    its quarantine-and-rebuild semantics: the corrupt cache dir is
    swapped out via `MeshChunkFeed.rebind` (the sharded feed — explicit
    shardings, compaction width — survives the rebuild) and training
    stays bitwise the clean run."""
    from repro.core import engine as core_engine

    mesh = _mesh2()
    mk = _maker("dense", tmp_path)
    kw = dict(cfg=MESH_CFG, lam=1e-3, objective="logistic", mesh=mesh)
    ref = Session(mk(), streamed=True, **kw)
    ref.fit(until=EPOCHS, tol=0)
    FaultInjector("flip-tile@t5", seed=7).apply_disk_faults(mk().path)
    feed = ResilientChunkFeed(mk().feed(verify=True), rebuild=mk,
                              sleep=lambda t: None)
    s = Session(feed, **kw)
    s.fit(until=EPOCHS, tol=0)
    assert np.array_equal(np.asarray(s.v), np.asarray(ref.v))
    assert list(tmp_path.glob(".quarantine.*"))
    # the in-place upgrade + rebind kept the mesh feed alive
    assert isinstance(feed.feed, core_engine.MeshChunkFeed)
    mk().verify_tiles()                        # rebuilt cache is clean
