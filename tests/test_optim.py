"""Optimizer substrate: AdamW dtype variants, LBFGS, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw
from repro.optim.lbfgs import glm_objective, lbfgs
from repro.core.objectives import LOGISTIC
from repro.data import make_dense_classification


def _quadratic_problem(seed=0, d=32):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((d, d)).astype(np.float32)
    A = A @ A.T / d + np.eye(d, dtype=np.float32)
    b = rng.standard_normal(d).astype(np.float32)

    def loss(p):
        x = p["x"]
        return 0.5 * x @ jnp.asarray(A) @ x - jnp.asarray(b) @ x

    return loss, {"x": jnp.zeros(d)}


@pytest.mark.parametrize("state_dtype", [jnp.float32, jnp.bfloat16,
                                         "int8"])
def test_adamw_converges_all_state_dtypes(state_dtype):
    loss, params = _quadratic_problem()
    cfg = adamw.AdamWConfig(lr=5e-2, weight_decay=0.0,
                            state_dtype=state_dtype)
    state = adamw.init(params, cfg)
    step = jax.jit(lambda p, s: adamw.apply(
        p, jax.grad(loss)(p), s, cfg))
    l0 = float(loss(params))
    for _ in range(200):
        params, state, _ = step(params, state)
    l1 = float(loss(params))
    assert l1 < l0 - 0.5 * abs(l0), (l0, l1)


def test_adamw_int8_tracks_f32():
    """int8 block-quantized moments must track the f32 trajectory."""
    loss, params = _quadratic_problem(seed=1)
    traj = {}
    for name, dt in (("f32", jnp.float32), ("int8", "int8")):
        cfg = adamw.AdamWConfig(lr=3e-2, weight_decay=0.0,
                                state_dtype=dt)
        p = jax.tree.map(lambda x: x, params)
        s = adamw.init(p, cfg)
        step = jax.jit(lambda p, s: adamw.apply(
            p, jax.grad(loss)(p), s, cfg))
        for _ in range(100):
            p, s, _ = step(p, s)
        traj[name] = float(loss(p))
    assert abs(traj["int8"] - traj["f32"]) < 0.1 * abs(traj["f32"]) + 0.05


def test_adamw_int8_memory_shape():
    cfg = adamw.AdamWConfig(state_dtype="int8")
    params = {"w": jnp.zeros((64, 128), jnp.bfloat16)}
    st = adamw.init(params, cfg)
    assert st.mu["w"].q.dtype == jnp.int8
    assert st.mu["w"].scale.shape == (64, 1)


def test_lbfgs_matches_sdca_optimum():
    """Both solvers must find the same regularized-logistic optimum."""
    from repro.core import GLMTrainer, SolverConfig
    X, y = make_dense_classification(n=512, d=16, seed=5)
    lam = 1e-2
    vg = glm_objective(LOGISTIC, jnp.asarray(X), jnp.asarray(y), lam)
    w, _ = lbfgs(vg, jnp.zeros(16), max_iters=200, tol=1e-9)
    tr = GLMTrainer(X, y, objective="logistic", lam=lam,
                    cfg=SolverConfig(bucket=8))
    tr.fit(max_epochs=60, tol=1e-6)
    np.testing.assert_allclose(np.asarray(tr.v), np.asarray(w),
                               rtol=2e-2, atol=2e-3)
