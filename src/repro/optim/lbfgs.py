"""L-BFGS for GLMs — the 'scikit-learn solver' stand-in for Fig 6.

Two-loop recursion with backtracking Armijo line search, pure JAX.
Used by benchmarks/fig6_solvers.py as the general-purpose baseline the
paper compares its SDCA against (scikit-learn lbfgs/liblinear).
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.objectives import Objective


def glm_objective(obj: Objective, X, y, lam: float) -> Callable:
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    n = y.shape[0]

    def f(w):
        m = X.T @ w
        return jnp.sum(obj.loss(m, y)) / n + 0.5 * lam * jnp.sum(w * w)

    return jax.jit(jax.value_and_grad(f))


def lbfgs(value_and_grad: Callable, w0, *, max_iters: int = 500,
          m: int = 10, tol: float = 1e-7):
    """Returns (w, history) — history rows: (iter, t, f, |g|)."""
    w = jnp.asarray(w0)
    f, g = value_and_grad(w)
    S, Y = [], []
    hist = [(0, 0.0, float(f), float(jnp.linalg.norm(g)))]
    t0 = time.perf_counter()
    for it in range(1, max_iters + 1):
        q = g
        alphas = []
        for s, yv in zip(reversed(S), reversed(Y)):
            rho = 1.0 / jnp.vdot(yv, s)
            a = rho * jnp.vdot(s, q)
            q = q - a * yv
            alphas.append((a, rho))
        gamma = (jnp.vdot(S[-1], Y[-1]) / jnp.vdot(Y[-1], Y[-1])
                 if S else 1.0)
        r = gamma * q
        for (a, rho), s, yv in zip(reversed(alphas), S, Y):
            b = rho * jnp.vdot(yv, r)
            r = r + (a - b) * s
        d = -r
        # Armijo backtracking
        step, c1 = 1.0, 1e-4
        gtd = jnp.vdot(g, d)
        for _ in range(30):
            f_new, g_new = value_and_grad(w + step * d)
            if f_new <= f + c1 * step * gtd:
                break
            step *= 0.5
        s = step * d
        yv = g_new - g
        if jnp.vdot(s, yv) > 1e-10:
            S.append(s)
            Y.append(yv)
            if len(S) > m:
                S.pop(0)
                Y.pop(0)
        w, f, g = w + s, f_new, g_new
        gn = float(jnp.linalg.norm(g))
        hist.append((it, time.perf_counter() - t0, float(f), gn))
        if gn < tol:
            break
    return w, hist


def gradient_descent(value_and_grad: Callable, w0, *, lr: float = 1.0,
                     max_iters: int = 2000, tol: float = 1e-7):
    """Plain GD with backtracking — the 'sag-like' slow baseline."""
    w = jnp.asarray(w0)
    hist = []
    t0 = time.perf_counter()
    for it in range(max_iters):
        f, g = value_and_grad(w)
        gn = float(jnp.linalg.norm(g))
        hist.append((it, time.perf_counter() - t0, float(f), gn))
        if gn < tol:
            break
        step = lr
        for _ in range(20):
            f_new, _ = value_and_grad(w - step * g)
            if f_new < f:
                break
            step *= 0.5
        w = w - step * g
    return w, hist
