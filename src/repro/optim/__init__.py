"""Optimizers and distributed-optimization tricks."""
from . import adamw, compression, lbfgs
from .adamw import AdamWConfig, AdamWState

__all__ = ["adamw", "compression", "lbfgs", "AdamWConfig", "AdamWState"]
