"""Int8 error-feedback gradient compression for cross-pod reductions.

The paper's hierarchy communicates only the d-sized shared vector across
the slow interconnect; at datacenter scale the analogous trick is to
compress the cross-pod reduction.  We implement deterministic int8
quantization with error feedback (the residual is carried to the next
round, so the compression bias vanishes over time — EF-SGD style).

Usage (inside shard_map):
    q, new_err = compress(x + err)
    summed = jax.lax.psum(dequantize(q), "pod")
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Quantized(NamedTuple):
    q: Array          # int8 payload
    scale: Array      # f32 per-row (or scalar) scale


def compress(x: Array, *, axis: int | None = None
             ) -> tuple[Quantized, Array]:
    """Quantize to int8; returns (payload, error_residual)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=axis is not None)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    err = xf - q.astype(jnp.float32) * scale
    return Quantized(q, scale), err.astype(x.dtype)


def dequantize(qz: Quantized) -> Array:
    return qz.q.astype(jnp.float32) * qz.scale


def ef_allreduce(x: Array, err: Array, axis_name: str
                 ) -> tuple[Array, Array]:
    """Error-feedback int8 all-reduce over `axis_name` (4x fewer bytes
    on the wire than f32).  Returns (reduced_f32, new_error)."""
    qz, new_err = compress(x + err)
    reduced = jax.lax.psum(dequantize(qz), axis_name)
    return reduced, new_err
