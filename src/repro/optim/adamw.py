"""AdamW with dtype-configurable states and ZeRO-compatible sharding.

Optimizer states take their own PartitionSpecs (launch/steps.py): under
ZeRO-1 they are additionally sharded over 'data' while the params stay
replicated — one grad all-reduce + one update all-gather per STEP,
instead of per-layer weight gathers (the measured ZeRO-3 cost on the
20B dense archs; EXPERIMENTS.md SPerf).

State dtype: f32 for fidelity, bf16 to halve optimizer HBM, "int8" for
8-bit-Adam-style block-quantized moments (per-row f32 scales) — the
latter is what fits the 1T-param MoE's moments on a single 256-chip pod
(16 GiB HBM each; EXPERIMENTS.md SPerf kimi iteration).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: Any = jnp.float32     # f32 | bf16 | "int8"
    grad_clip: float = 1.0


class QMoment(NamedTuple):
    """int8 moment with per-row (last-dim) f32 scales — 8-bit-Adam style
    block quantization, ~1.004 bytes/param.  Moments are re-quantized
    from fresh f32 values each step, so quantization noise does not
    accumulate beyond one step's contribution."""
    q: Array
    scale: Array


def _quant(x: Array) -> QMoment:
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    return QMoment(jnp.clip(jnp.round(x / scale), -127, 127
                            ).astype(jnp.int8), scale)


def _dequant(m) -> Array:
    if isinstance(m, QMoment):
        return m.q.astype(jnp.float32) * m.scale
    return m.astype(jnp.float32)


def _requant_like(x32: Array, m):
    if isinstance(m, QMoment):
        return _quant(x32)
    return x32.astype(m.dtype)


class AdamWState(NamedTuple):
    step: Array
    mu: Any
    nu: Any


def init(params, cfg: AdamWConfig) -> AdamWState:
    def z(p):
        if cfg.state_dtype == "int8":
            return QMoment(jnp.zeros(p.shape, jnp.int8),
                           jnp.full(p.shape[:-1] + (1,), 1e-30,
                                    jnp.float32))
        return jnp.zeros(p.shape, cfg.state_dtype)

    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(z, params),
                      nu=jax.tree.map(z, params))


def apply(params, grads, state: AdamWState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0
    step = state.step + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = _dequant(m) * cfg.b1 + (1 - cfg.b1) * g
        v32 = _dequant(v) * cfg.b2 + (1 - cfg.b2) * g * g
        u = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - cfg.lr * u
        return (newp.astype(p.dtype), _requant_like(m32, m),
                _requant_like(v32, v))

    # flatten against the PARAM treedef so QMoment leaves stay whole
    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(state.mu)
    leaves_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(leaves_p, leaves_g, leaves_m, leaves_v)]
    newp = treedef.unflatten([t[0] for t in out])
    newm = treedef.unflatten([t[1] for t in out])
    newv = treedef.unflatten([t[2] for t in out])
    return newp, AdamWState(step, newm, newv), {"grad_norm": gnorm}
