"""Parallel linear-model training on TPU meshes (JAX/Pallas).

Reproduction of "Parallel training of linear models without
compromising convergence": bucketed CoCoA+/SDCA with dynamic partition
exchange, VMEM-resident Pallas bucket kernels, a versioned on-disk tile
cache, sklearn-compatible estimators, and a system-aware geometry
planner (SySCD).  Start at `repro.api` (estimators + `Session`);
see README.md and DESIGN.md for the map.
"""
