"""Real-dataset ingestion: svmlight/libsvm and dense CSV parsers.

The paper's evaluation datasets (criteo-kaggle, higgs, epsilon,
webspam) all ship in one of two text formats:

  * svmlight/libsvm — ``label [qid:q] idx:val idx:val ...`` per line,
    the distribution format of every LIBSVM-hosted dataset;
  * dense CSV — ``label,f1,f2,...`` per line (higgs/epsilon are dense).

Parsers produce the engine's two layouts directly: padded-CSR
``(idx (n, nnz) int32, val (n, nnz) float32)`` for sparse data and
column-major ``X (d, n) float32`` for dense data.  Everything is
deterministic: row order is preserved, padding is idx=0/val=0, and the
writers (`dump_svmlight`/`dump_csv`) emit shortest-exact float32 reprs
so parse -> dump -> parse is the identity (pinned by
tests/test_pipeline.py round-trip properties).

One-based svmlight feature ids (the LIBSVM convention) are shifted to
zero-based with ``zero_based=False`` (the default).
"""
from __future__ import annotations

import array
import os
from typing import IO, Iterable, Union

import numpy as np

__all__ = [
    "parse_svmlight", "parse_csv", "dump_svmlight", "dump_csv",
    "to_dense", "nonzero_duplicate_rows", "raise_on_duplicate_nonzeros",
    "zero_duplicates",
]

Source = Union[str, os.PathLike, IO[str], Iterable[str]]


def _as_lines(source: Source) -> Iterable[str]:
    """Accept a path, an open file, raw text, or an iterable of lines.

    Files are streamed line by line (never read whole — real datasets
    run to tens of GB); raw text is split in memory.
    """
    if hasattr(source, "read"):
        return source
    if isinstance(source, os.PathLike):
        return _stream_file(source)
    if isinstance(source, str):
        if "\n" not in source and not os.path.exists(source):
            # a single line with no record separators (space/comma/
            # colon) cannot be svmlight or CSV data — it is a mistyped
            # path; raise instead of silently parsing zero examples
            if (not any(c in source for c in " ,:")
                    or "/" in source or os.sep in source):
                raise FileNotFoundError(
                    f"{source!r} looks like a path but does not exist")
        if "\n" in source or not os.path.exists(source):
            return source.splitlines()
        return _stream_file(source)
    return source


def _stream_file(path) -> Iterable[str]:
    with open(path, "r") as f:
        yield from f


def _f32_repr(x: float) -> str:
    """Shortest decimal that parses back to the exact same float32.

    float32 -> float64 is exact and repr(float64) round-trips, so the
    f64 repr of the f32 value re-parses to the identical f32.
    """
    return repr(float(np.float32(x)))


# ---------------------------------------------------------------------------
# svmlight / libsvm
# ---------------------------------------------------------------------------


def parse_svmlight(source: Source, *, nnz: int | None = None,
                   d: int | None = None, zero_based: bool = False):
    """Parse svmlight text into padded CSR.

    Returns ``((idx, val), y, d)`` with idx/val of shape (n, nnz): nnz
    defaults to the max row length; rows are padded with idx=0/val=0
    (a zero value never contributes to a margin, so padding is inert).
    Rows longer than an explicit ``nnz`` raise.  ``d`` defaults to
    1 + max feature id seen.

    Memory: the file is streamed and features accumulate in compact
    typed buffers (4 B/entry), so peak footprint is the same order as
    the padded output arrays — real multi-GB datasets ingest without
    holding text or per-feature Python objects.
    """
    labels = array.array("f")
    flat_idx = array.array("i")        # feature ids, rows concatenated
    flat_val = array.array("f")
    row_len = array.array("i")
    shift = 0 if zero_based else 1
    max_id = -1
    for lineno, line in enumerate(_as_lines(source), start=1):
        line = line.split("#", 1)[0].strip()
        if not line:
            continue
        toks = line.split()
        try:
            labels.append(float(toks[0]))
        except ValueError:
            raise ValueError(
                f"svmlight line {lineno}: bad label {toks[0]!r}")
        k = 0
        for tok in toks[1:]:
            key, _, sval = tok.partition(":")
            if key == "qid":          # ranking group id — not a feature
                continue
            j = int(key) - shift
            if j < 0:
                raise ValueError(
                    f"svmlight line {lineno}: feature id {key} < "
                    f"{shift} (set zero_based={not zero_based}?)")
            flat_idx.append(j)
            flat_val.append(float(sval))   # C float == float32 rounding
            k += 1
            if j > max_id:
                max_id = j
        row_len.append(k)

    n = len(row_len)
    lens = np.frombuffer(row_len, dtype=np.int32) if n else \
        np.zeros(0, np.int32)
    width = int(lens.max()) if n else 0
    if nnz is None:
        nnz = max(width, 1)
    elif width > nnz:
        raise ValueError(f"row with {width} features exceeds nnz={nnz}")
    if d is None:
        d = max_id + 1
    elif max_id >= d:
        raise ValueError(f"feature id {max_id} out of range for d={d}")

    idx = np.zeros((n, nnz), dtype=np.int32)
    val = np.zeros((n, nnz), dtype=np.float32)
    mask = np.arange(nnz) < lens[:, None]      # row-major == flat order
    idx[mask] = np.frombuffer(flat_idx, dtype=np.int32)
    val[mask] = np.frombuffer(flat_val, dtype=np.float32)
    return (idx, val), np.frombuffer(labels, dtype=np.float32).copy(), d


def dump_svmlight(idx: np.ndarray, val: np.ndarray, y: np.ndarray, *,
                  zero_based: bool = False) -> str:
    """Padded CSR -> svmlight text (zero-valued/padded entries omitted)."""
    shift = 0 if zero_based else 1
    out = []
    for i in range(val.shape[0]):
        parts = [_f32_repr(y[i])]
        for j, x in zip(idx[i], val[i]):
            if x != 0.0:
                parts.append(f"{int(j) + shift}:{_f32_repr(x)}")
        out.append(" ".join(parts))
    return "\n".join(out) + ("\n" if out else "")


def to_dense(idx: np.ndarray, val: np.ndarray, d: int) -> np.ndarray:
    """Padded CSR -> dense X (d, n); duplicate ids accumulate."""
    n, nnz = val.shape
    X = np.zeros((d, n), dtype=np.float32)
    cols = np.repeat(np.arange(n), nnz)
    np.add.at(X, (idx.reshape(-1), cols), val.reshape(-1))
    return X


def nonzero_duplicate_rows(idx: np.ndarray, val: np.ndarray) -> np.ndarray:
    """Per-row mask: True where a row repeats a feature id with NONZERO
    values — the invariant violation `zero_duplicates` sanitizes away
    and the sparse Pallas kernel's bitwise contract forbids (the
    kernel wrapper's host-side check shares this helper).

    Zero-valued duplicates (padding, already-sanitized rows) don't
    count, so zero-valued entries are masked to a sentinel id BEFORE
    the adjacency compare: a plain duplicate check on sorted ids would
    miss an A,0,A pattern where a zero-valued duplicate sorts between
    two nonzero ones.
    """
    ids = np.where(val != 0, idx, -1)   # keeps idx's dtype: no copy blowup
    s = np.sort(ids, axis=1)
    dup = (s[:, 1:] == s[:, :-1]) & (s[:, 1:] >= 0)
    return dup.any(axis=1)


def raise_on_duplicate_nonzeros(idx: np.ndarray, val: np.ndarray,
                                context: str) -> None:
    """Raise the shared CSR-invariant error if `nonzero_duplicate_rows`
    flags any row.  `context` names the caller's data provenance; the
    error is THE one message for this contract (kernels.ops and
    api.session both raise through here — keep it single-sourced).
    """
    bad = nonzero_duplicate_rows(idx, val)
    if not bad.any():
        return
    row = int(np.argmax(bad))
    s = np.sort(np.where(val[row] != 0, idx[row], -1))
    feat = int(s[1:][(s[1:] == s[:-1]) & (s[1:] >= 0)][0])
    raise ValueError(
        f"{context} violate the CSR no-duplicate-nonzero invariant "
        f"(row {row} repeats feature id {feat} with nonzero values); "
        f"the sparse Pallas kernel's bitwise-vs-XLA contract does not "
        f"hold for such rows.  Sanitize with "
        f"data.formats.zero_duplicates(idx, val) first, or use "
        f"local_solver='xla'.")


def zero_duplicates(idx: np.ndarray, val: np.ndarray) -> np.ndarray:
    """Enforce the padded-CSR invariant: at most one NONZERO value per
    feature id per row (DESIGN.md S11).

    Real svmlight/CSR data satisfies this by construction; synthetic
    samplers that draw ids with replacement do not.  The repeated
    entries' values are zeroed (first occurrence wins), which keeps
    margins/updates well-defined AND is what makes the sparse Pallas
    kernel's per-bucket scatter bitwise-identical to the per-coordinate
    XLA scan (zero-valued duplicates contribute exact zeros on both
    paths).  Returns the cleaned val; idx is left untouched.
    """
    order = np.argsort(idx, axis=1, kind="stable")
    sorted_idx = np.take_along_axis(idx, order, axis=1)
    dup_sorted = np.zeros_like(sorted_idx, dtype=bool)
    dup_sorted[:, 1:] = sorted_idx[:, 1:] == sorted_idx[:, :-1]
    dup = np.zeros_like(dup_sorted)
    np.put_along_axis(dup, order, dup_sorted, axis=1)
    return np.where(dup, np.zeros((), val.dtype), val)


# ---------------------------------------------------------------------------
# dense CSV
# ---------------------------------------------------------------------------


def parse_csv(source: Source, *, label_col: int = 0):
    """Parse ``label,f1,f2,...`` rows into (X (d, n) f32, y (n,) f32).

    A non-numeric first row is treated as a header and skipped.  The
    file is streamed; features accumulate in a compact typed buffer
    (4 B/value), not per-row Python objects.
    """
    flat = array.array("f")
    labels = array.array("f")
    width = None
    for lineno, line in enumerate(_as_lines(source), start=1):
        line = line.strip()
        if not line:
            continue
        toks = line.split(",")
        if width is None:
            try:
                float(toks[label_col])
            except ValueError:
                continue                       # header row
            width = len(toks)
        if len(toks) != width:
            raise ValueError(
                f"csv line {lineno}: {len(toks)} fields, expected {width}")
        labels.append(float(toks[label_col]))
        for i, tok in enumerate(toks):
            if i != label_col:
                flat.append(float(tok))
    n = len(labels)
    if not n:
        return np.zeros((0, 0), np.float32), np.zeros((0,), np.float32)
    X = np.frombuffer(flat, dtype=np.float32).reshape(n, width - 1).T
    return np.ascontiguousarray(X), np.frombuffer(
        labels, dtype=np.float32).copy()


def dump_csv(X: np.ndarray, y: np.ndarray) -> str:
    """(X (d, n), y) -> ``label,f1,...`` text with exact-f32 reprs."""
    out = []
    for i in range(X.shape[1]):
        out.append(",".join([_f32_repr(y[i])]
                            + [_f32_repr(x) for x in X[:, i]]))
    return "\n".join(out) + ("\n" if out else "")
