"""Seeded synthetic datasets shaped like the paper's three benchmarks.

No network access in this environment, so the evaluation datasets are
stand-ins whose *character* matches the originals (documented scale
factor; the benchmark harness records it):

  criteo_like   — sparse binary classification (criteo-kaggle: 45M x 1M,
                  ~39 nnz/example, skewed feature popularity)
  higgs_like    — dense, narrow (HIGGS: 11M x 28)
  epsilon_like  — dense, wide (epsilon: 400k x 2000, normalized)

plus the two synthetic sets used in Fig 1/2 of the paper (100k examples;
dense d=100, sparse d=1000 @ 1% uniform sparsity).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "make_dense_classification", "make_dense_regression",
    "make_sparse_classification", "criteo_like", "higgs_like",
    "epsilon_like",
]


def _labels_from_logits(rng, logits):
    p = 1.0 / (1.0 + np.exp(-logits))
    return (rng.uniform(size=logits.shape) < p).astype(np.float32) * 2 - 1


def make_dense_classification(n: int = 100_000, d: int = 100, *,
                              seed: int = 0, scale: float = 1.0,
                              normalize: bool = True):
    """Paper's dense synthetic dataset (Fig 1a).  X: (d, n), y in {-1,+1}."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((d, n)).astype(np.float32) * scale
    if normalize:
        X /= np.maximum(np.linalg.norm(X, axis=0, keepdims=True), 1e-12)
    w = rng.standard_normal(d).astype(np.float32)
    y = _labels_from_logits(rng, 4.0 * (w @ X) / np.linalg.norm(w))
    return X, y.astype(np.float32)


def make_dense_regression(n: int = 50_000, d: int = 100, *, seed: int = 0,
                          noise: float = 0.1):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((d, n)).astype(np.float32)
    X /= np.maximum(np.linalg.norm(X, axis=0, keepdims=True), 1e-12)
    w = rng.standard_normal(d).astype(np.float32)
    y = w @ X + noise * rng.standard_normal(n)
    return X, y.astype(np.float32)


def make_sparse_classification(n: int = 100_000, d: int = 1_000, *,
                               nnz: int = 10, seed: int = 0,
                               skew: float = 0.0):
    """Paper's sparse synthetic dataset (Fig 1b): 1% uniform sparsity.

    Returns padded-CSR (idx (n,nnz) int32, val (n,nnz) f32), y, d.
    skew>0 draws feature ids from a Zipf-ish distribution (criteo-like
    popularity skew) instead of uniform.
    """
    rng = np.random.default_rng(seed)
    if skew > 0:
        p = (1.0 / np.arange(1, d + 1) ** skew)
        p /= p.sum()
        idx = rng.choice(d, size=(n, nnz), p=p).astype(np.int32)
    else:
        idx = rng.integers(0, d, size=(n, nnz)).astype(np.int32)
    val = (rng.standard_normal((n, nnz)) / np.sqrt(nnz)).astype(np.float32)
    # real CSR rows never repeat a feature id; sampling with replacement
    # does, so zero the repeats (keeps the padded-CSR invariant every
    # solver path — including the sparse Pallas kernel — relies on)
    from .formats import zero_duplicates
    val = zero_duplicates(idx, val)
    w = rng.standard_normal(d).astype(np.float32)
    logits = (val * w[idx]).sum(axis=1) * 4.0
    y = _labels_from_logits(rng, logits)
    return (idx, val), y.astype(np.float32), d


# -- stand-ins for the paper's three evaluation datasets -------------------

def criteo_like(n: int = 131_072, d: int = 65_536, *, seed: int = 1):
    """criteo-kaggle stand-in: sparse, skewed, ~39 nnz.  Scale ~1/350."""
    return make_sparse_classification(n=n, d=d, nnz=39, seed=seed, skew=1.1)


def higgs_like(n: int = 262_144, *, seed: int = 2):
    """HIGGS stand-in: dense, 28 features.  Scale ~1/42 in n."""
    return make_dense_classification(n=n, d=28, seed=seed)


def epsilon_like(n: int = 65_536, *, seed: int = 3):
    """epsilon stand-in: dense, 2000 normalized features.  Scale ~1/6."""
    return make_dense_classification(n=n, d=2_000, seed=seed)
