"""Versioned, memory-mappable bucket-tile cache (DESIGN.md S9).

Cold-start ingest (text parsing, padding, layout packing) is paid ONCE:
`build_cache` packs a dataset into the on-disk analogue of the engine's
VMEM tile — examples grouped into buckets of B, each bucket stored as
one contiguous (d_pad x B) tile (dense) or (B x nnz) idx/val tile pair
(sparse), bucket-major, pod-sharded on the leading axis:

    X.bin    (pods, nb_pod, d_pad, B)  float32     [dense]
    idx.bin  (pods, nb_pod, B, nnz)    int32       [sparse]
    val.bin  (pods, nb_pod, B, nnz)    float32     [sparse]
    y.bin    (pods, nb_pod, B)         float32
    meta.json  — magic/version, shapes, true example count, crc32s

Epoch start is then an mmap + gather: `TileCache.gather_buckets` fancy-
indexes the memmap with global bucket ids, touching only the tiles a
chunk visits, and `TileFeed` device-puts the result — the `ChunkFeed`
the engine's streamed loop consumes.  Bucket b lives at
``tiles[b // nb_pod, b % nb_pod]``, matching `PartitionPlan`'s static
pod ranges, so a pod's epoch reads only its own shard of the file.

Determinism: the writer is a pure function of the input arrays (fixed
dtypes, C order, sorted-key JSON, no timestamps), so two builds of the
same dataset are byte-identical across processes — pinned by
tests/test_pipeline.py.

Padding: n is padded up to a multiple of ``pods * bucket`` (or the
caller's stricter ``pad_multiple``) with x=0 / y=+1 examples.  A zero
example never moves the shared vector v (its margin and update are
identically zero-weighted), so training is unaffected; diagnostics over
the padded set count the pad examples' flat loss terms, which shrink
with 1/n and are recorded via ``n_examples``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import zlib

import numpy as np

__all__ = [
    "CACHE_MAGIC", "CACHE_VERSION", "CacheMeta", "TileCache",
    "TileCorruptionError",
    "ArrayFeed", "TileFeed", "build_cache", "compact_slice_rows",
    "open_cache", "pad_examples",
]

CACHE_MAGIC = "repro-tile-cache"
# v2: synthetic sparse rows are deduplicated (formats.zero_duplicates)
# and criteo sub rows are 40 wide — pre-PR4 caches hold different bytes
# (including duplicate-nonzero rows that break the sparse Pallas
# kernel's bitwise contract), so they must not be silently reused.
# v3: per-tile crc32 sidecar (tilecrc.bin) so corruption is localized
# to a bucket tile (TileCorruptionError carries tile id + byte offset,
# enabling quarantine + targeted rebuild — DESIGN.md S15), and
# meta.json is committed LAST and atomically, so an interrupted build
# can never pass validation.
CACHE_VERSION = 3

_SUBLANE = 8          # pad d to the VPU sublane multiple

_TILECRC_FILE = "tilecrc.bin"


class TileCorruptionError(ValueError):
    """A cache tile's bytes no longer match their recorded crc32.

    Carries enough to act on (quarantine the cache, rebuild the tile
    from source): ``path`` is the corrupt ``.bin`` file, ``array`` its
    logical name, ``tile`` the GLOBAL bucket id of the first bad tile
    (None when only the whole-array checksum is available), ``offset``
    the byte offset of that tile inside the file.  Raised by
    `open_cache(verify=True)`, `TileCache.verify_tiles`, and
    `TileFeed(verify=True)`; classified as non-transient (no retry —
    the bytes will not get better) by
    `repro.resilience.ResilientChunkFeed`, which quarantines and
    rebuilds instead.
    """

    def __init__(self, path, array: str, tile: int | None = None,
                 offset: int | None = None):
        self.path = pathlib.Path(path)
        self.array = array
        self.tile = tile
        self.offset = offset
        loc = (f" (tile {tile} at byte offset {offset})"
               if tile is not None else "")
        super().__init__(
            f"{self.path}: crc32 mismatch for array {array!r}{loc} — "
            f"cache is corrupt; quarantine and rebuild from source")


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def compact_slice_rows(idx: np.ndarray, val: np.ndarray, lo: int,
                       hi: int, *, nnz_multiple: int = 8,
                       positions: bool = False,
                       width: int | None = None):
    """Compact padded-CSR rows to the entries in feature slice [lo, hi).

    The host half of the slice-compacted streamed feed (DESIGN.md
    S12/S16), shared by `TileCache.slice_gather` and the mesh feed's
    array-backed path.  Entries are kept IN ROW ORDER (stable
    left-compaction — the kernels' bitwise contract depends on
    within-row summation order) and right-padded to a common width
    ``w``: the max kept count ceiled to ``nnz_multiple``, or exactly
    ``width`` when given (so streamed chunks share one static shape;
    raises if a row overflows it).

    Two modes:

      * ``positions=False`` (default): keep nonzeros with
        ``lo <= idx < hi``, REBASE ids to slice-local coordinates
        (idx - lo).  Returns ``(idx_loc, val_loc)`` — the sharded
        kernels' slice-local layout.
      * ``positions=True``: the transfer format for exact on-device
        row reassembly.  Keeps every in-slice entry that is not
        (idx=0, val=0) padding — including explicit zero-VALUE entries
        (`formats.zero_duplicates` products), which a reassembled row
        must reproduce — and returns ``(idx, val, pos)`` with GLOBAL
        ids plus each entry's original within-row position; pad slots
        carry the sentinel ``pos = nnz`` so a `mode="drop"` scatter
        into a zeros base rebuilds the original row bitwise.

    All outputs are (*lead, w): idx/pos int32, val float32.
    """
    if not 0 <= lo < hi:
        raise ValueError(f"bad feature slice [{lo}, {hi})")
    in_slice = (idx >= lo) & (idx < hi)
    own = in_slice & (((val != 0) | (idx != 0)) if positions
                      else (val != 0))
    # stable left-compaction: sort each row by (not owned) so owned
    # entries keep their relative order
    order = np.argsort(~own, axis=-1, kind="stable")
    idx_s = np.take_along_axis(idx, order, axis=-1)
    val_s = np.take_along_axis(val, order, axis=-1)
    own_s = np.take_along_axis(own, order, axis=-1)
    need = max(int(own.sum(axis=-1).max(initial=0)), 1)
    if width is None:
        w = _ceil_to(need, nnz_multiple)
    else:
        w = int(width)
        if need > w:
            raise ValueError(
                f"width={w} too narrow: a row holds {need} entries "
                f"in slice [{lo}, {hi})")
    nnz = idx.shape[-1]
    val_c = np.where(own_s, val_s, 0.0).astype(np.float32)
    if positions:
        idx_c = np.where(own_s, idx_s, 0).astype(np.int32)
        pos = np.where(own_s, order, nnz).astype(np.int32)
        outs = [idx_c, val_c, pos]
        fills = [0, 0.0, nnz]     # pad slots keep the drop sentinel
    else:
        idx_c = np.where(own_s, idx_s - lo, 0).astype(np.int32)
        outs = [idx_c, val_c]
        fills = [0, 0.0]
    if w > nnz:                   # raw caches with unaligned nnz
        pad = [(0, 0)] * (idx_c.ndim - 1) + [(0, w - nnz)]
        outs = [np.pad(o, pad, constant_values=f)
                for o, f in zip(outs, fills)]
    return tuple(np.ascontiguousarray(o[..., :w]) for o in outs)


@dataclasses.dataclass(frozen=True)
class CacheMeta:
    """Everything needed to mmap the arrays back + provenance."""
    name: str
    kind: str                  # dense | sparse
    n: int                     # padded example count (what training sees)
    n_examples: int            # true example count before padding
    d: int
    d_pad: int                 # dense tile row count (d rounded up)
    bucket: int
    pods: int
    nnz: int                   # sparse only; 0 for dense
    objective: str
    version: int = CACHE_VERSION
    magic: str = CACHE_MAGIC

    @property
    def n_buckets(self) -> int:
        return self.n // self.bucket

    @property
    def nb_pod(self) -> int:
        return self.n_buckets // self.pods

    def array_specs(self) -> dict[str, tuple[tuple[int, ...], str]]:
        """name -> (shape, dtype) of every .bin file."""
        P, nbp, B = self.pods, self.nb_pod, self.bucket
        if self.kind == "dense":
            arrs = {"X": ((P, nbp, self.d_pad, B), "float32")}
        else:
            arrs = {"idx": ((P, nbp, B, self.nnz), "int32"),
                    "val": ((P, nbp, B, self.nnz), "float32")}
        arrs["y"] = ((P, nbp, B), "float32")
        return arrs


def pad_examples(y: np.ndarray, multiple: int, *,
                 X: np.ndarray | None = None,
                 idx: np.ndarray | None = None,
                 val: np.ndarray | None = None):
    """Pad n up to `multiple` with inert examples (x=0, y=+1)."""
    n = y.shape[0]
    n_pad = _ceil_to(max(n, 1), multiple)
    if n_pad == n:
        return y, X, idx, val
    extra = n_pad - n
    y = np.concatenate([y, np.ones(extra, dtype=y.dtype)])
    if X is not None:
        X = np.concatenate(
            [X, np.zeros((X.shape[0], extra), dtype=X.dtype)], axis=1)
    if idx is not None:
        idx = np.concatenate(
            [idx, np.zeros((extra, idx.shape[1]), dtype=idx.dtype)])
        val = np.concatenate(
            [val, np.zeros((extra, val.shape[1]), dtype=val.dtype)])
    return y, X, idx, val


def build_cache(path, name: str, *, y, X=None, idx=None, val=None,
                d: int | None = None, kind: str | None = None,
                bucket: int = 16, pods: int = 1,
                pad_multiple: int | None = None,
                nnz_multiple: int | None = None,
                objective: str = "logistic") -> "TileCache":
    """Pack arrays into bucket tiles and write a cache directory.

    Dense input: ``X (d, n)``; sparse input: ``idx/val (n, nnz)`` plus
    ``d``.  ``pad_multiple`` defaults to ``pods * bucket`` — callers
    that know the training topology pass the stricter
    pods*lanes*lanes*chunks*bucket so every partition mode divides.
    ``nnz_multiple`` (sparse only) zero-pads the row width with inert
    idx=0/val=0 columns up to that multiple, so cached tiles land
    lane-aligned for the sparse Pallas kernel (which needs nnz % 8 == 0
    — DESIGN.md S11); padding columns never change margins or updates.
    """
    path = pathlib.Path(path)
    if kind is None:
        kind = "dense" if X is not None else "sparse"
    y = np.ascontiguousarray(np.asarray(y, np.float32))
    n_examples = y.shape[0]
    mult = pad_multiple or (pods * bucket)
    mult = _ceil_to(mult, pods * bucket)

    if kind == "dense":
        X = np.ascontiguousarray(np.asarray(X, np.float32))
        d = X.shape[0]
        y, X, _, _ = pad_examples(y, mult, X=X)
        n = y.shape[0]
        d_pad = _ceil_to(d, _SUBLANE)
        nb = n // bucket
        Xp = np.zeros((d_pad, n), dtype=np.float32)
        Xp[:d] = X
        # (d_pad, nb, B) -> bucket-major tiles (pods, nb_pod, d_pad, B)
        tiles = np.transpose(Xp.reshape(d_pad, nb, bucket), (1, 0, 2))
        arrays = {"X": np.ascontiguousarray(tiles).reshape(
            pods, nb // pods, d_pad, bucket)}
        nnz = 0
    else:
        idx = np.ascontiguousarray(np.asarray(idx, np.int32))
        val = np.ascontiguousarray(np.asarray(val, np.float32))
        if d is None:
            raise ValueError("sparse build_cache requires d")
        if nnz_multiple:
            pad_w = _ceil_to(max(idx.shape[1], 1), nnz_multiple) \
                - idx.shape[1]
            if pad_w:
                idx = np.pad(idx, ((0, 0), (0, pad_w)))
                val = np.pad(val, ((0, 0), (0, pad_w)))
        y, _, idx, val = pad_examples(y, mult, idx=idx, val=val)
        n = y.shape[0]
        nnz = idx.shape[1]
        nb = n // bucket
        arrays = {
            "idx": idx.reshape(pods, nb // pods, bucket, nnz),
            "val": val.reshape(pods, nb // pods, bucket, nnz)}
        d_pad = d
    arrays["y"] = y.reshape(pods, nb // pods, bucket)

    meta = CacheMeta(name=name, kind=kind, n=n, n_examples=n_examples,
                     d=d, d_pad=d_pad, bucket=bucket, pods=pods,
                     nnz=nnz, objective=objective)
    path.mkdir(parents=True, exist_ok=True)
    crcs = {}
    tile_crcs = []
    for aname, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        crcs[aname] = zlib.crc32(arr.tobytes())
        tile_crcs.append(_tile_crcs(arr, meta.n_buckets))
        arr.tofile(path / f"{aname}.bin")
    # Sidecar next (arrays in array_specs order), meta.json LAST and
    # ATOMICALLY: meta.json is the validity marker, so a build killed
    # at any earlier point leaves a directory open_cache rejects (no
    # meta, or a stale-version one) and registry.materialize rebuilds.
    np.concatenate(tile_crcs).tofile(path / _TILECRC_FILE)
    doc = dict(dataclasses.asdict(meta), crc32=crcs)
    tmp = path / ".meta.json.tmp"
    tmp.write_text(json.dumps(doc, sort_keys=True, indent=1) + "\n")
    os.replace(tmp, path / "meta.json")
    return open_cache(path)


def _tile_crcs(arr: np.ndarray, n_buckets: int) -> np.ndarray:
    """crc32 of each bucket tile's bytes, as little-endian uint32."""
    flat = np.ascontiguousarray(arr).reshape(n_buckets, -1)
    return np.array([zlib.crc32(row.tobytes()) for row in flat],
                    dtype="<u4")


def _load_tilecrc(path: pathlib.Path,
                  meta: CacheMeta) -> dict[str, np.ndarray] | None:
    """Read the per-tile crc sidecar back into {array: (n_buckets,)}."""
    f = path / _TILECRC_FILE
    specs = meta.array_specs()
    want = meta.n_buckets * len(specs)
    if not f.exists() or f.stat().st_size != want * 4:
        return None
    raw = np.fromfile(f, dtype="<u4", count=want)
    return {aname: raw[i * meta.n_buckets:(i + 1) * meta.n_buckets]
            for i, aname in enumerate(specs)}


def open_cache(path, *, verify: bool = False) -> "TileCache":
    """mmap an existing cache directory; validates magic/version/sizes."""
    path = pathlib.Path(path)
    doc = json.loads((path / "meta.json").read_text())
    if doc.get("magic") != CACHE_MAGIC:
        raise ValueError(f"{path}: not a {CACHE_MAGIC} directory")
    if doc.get("version") != CACHE_VERSION:
        raise ValueError(f"{path}: cache version {doc.get('version')} != "
                         f"supported {CACHE_VERSION}; rebuild the cache")
    crcs = doc.pop("crc32", {})
    meta = CacheMeta(**{f.name: doc[f.name]
                        for f in dataclasses.fields(CacheMeta)})
    tilecrc = _load_tilecrc(path, meta)
    arrays = {}
    for aname, (shape, dtype) in meta.array_specs().items():
        f = path / f"{aname}.bin"
        want = int(np.prod(shape)) * np.dtype(dtype).itemsize
        if f.stat().st_size != want:
            raise ValueError(
                f"{f}: {f.stat().st_size} bytes on disk, expected {want} "
                f"for shape {shape} — cache is truncated or corrupt")
        mm = np.memmap(f, dtype=dtype, mode="r", shape=shape)
        arrays[aname] = mm
    cache = TileCache(meta=meta, path=path, arrays=arrays, tilecrc=tilecrc)
    if verify:
        if tilecrc is not None:
            cache.verify_tiles()
        else:
            for aname, mm in arrays.items():
                if zlib.crc32(mm.tobytes()) != crcs.get(aname):
                    raise TileCorruptionError(path / f"{aname}.bin", aname)
    return cache


@dataclasses.dataclass
class TileCache:
    """An opened cache: meta + read-only memmaps of the tile arrays."""
    meta: CacheMeta
    path: pathlib.Path
    arrays: dict[str, np.memmap]
    tilecrc: dict[str, np.ndarray] | None = None

    def _flat(self, name: str) -> np.ndarray:
        """(pods, nb_pod, ...) view -> (n_buckets, ...) for id math."""
        a = self.arrays[name]
        return a.reshape((self.meta.n_buckets,) + a.shape[2:])

    def verify_tiles(self, bids: np.ndarray | None = None) -> None:
        """Check the crc32 of bucket tiles against the sidecar.

        ``bids`` is a set of GLOBAL bucket ids (any shape); None means
        every tile.  Raises `TileCorruptionError` pointing at the first
        bad tile.  Cost scales with the bytes actually checked, so a
        streamed feed can verify only the tiles a chunk touches.
        """
        if self.tilecrc is None:
            raise ValueError(
                f"{self.path}: no {_TILECRC_FILE} sidecar — rebuild the "
                f"cache to enable per-tile verification")
        ids = (np.arange(self.meta.n_buckets) if bids is None
               else np.unique(np.asarray(bids).reshape(-1)))
        for aname in self.meta.array_specs():
            flat = self._flat(aname)
            tile_nbytes = int(np.prod(flat.shape[1:])) * flat.dtype.itemsize
            want = self.tilecrc[aname]
            for b in ids:
                b = int(b)
                if zlib.crc32(np.ascontiguousarray(
                        flat[b]).tobytes()) != int(want[b]):
                    raise TileCorruptionError(
                        self.path / f"{aname}.bin", aname, tile=b,
                        offset=b * tile_nbytes)

    # -- bulk load (the in-memory path) ----------------------------------
    def load_arrays(self):
        """Unpack tiles to flat example order, fully in memory.

        Dense: (X (d, n), y).  Sparse: ((idx, val), y).  Exactly the
        arrays `build_cache` packed (padding included), so in-memory
        and streamed training see identical data.
        """
        m = self.meta
        y = np.ascontiguousarray(self._flat("y")).reshape(m.n)
        if m.kind == "dense":
            t = np.ascontiguousarray(self._flat("X"))  # (nb, d_pad, B)
            X = np.transpose(t, (1, 0, 2)).reshape(m.d_pad, m.n)[:m.d]
            return np.ascontiguousarray(X), y
        idx = np.ascontiguousarray(self._flat("idx")).reshape(m.n, m.nnz)
        val = np.ascontiguousarray(self._flat("val")).reshape(m.n, m.nnz)
        return (idx, val), y

    # -- tile gather (the out-of-core path) ------------------------------
    def gather_buckets(self, bids: np.ndarray):
        """Gather whole bucket tiles by GLOBAL bucket id.

        bids (*lead, nb) int -> dense  (data (*lead, d, nb*B), y ...)
                              -> sparse ((idx, val) (*lead, nb*B, nnz), y)
        Only the touched tiles are read from the mmap.
        """
        m = self.meta
        bids = np.asarray(bids)
        lead, nb = bids.shape[:-1], bids.shape[-1]
        y = self._flat("y")[bids].reshape(lead + (nb * m.bucket,))
        if m.kind == "dense":
            t = self._flat("X")[bids]          # (*lead, nb, d_pad, B)
            t = np.swapaxes(t, -3, -2).reshape(
                lead + (m.d_pad, nb * m.bucket))
            return t[..., :m.d, :], y
        idx = self._flat("idx")[bids].reshape(
            lead + (nb * m.bucket, m.nnz))
        val = self._flat("val")[bids].reshape(
            lead + (nb * m.bucket, m.nnz))
        return (idx, val), y

    def slice_gather(self, bids: np.ndarray, lo: int, hi: int, *,
                     nnz_multiple: int = 8, positions: bool = False,
                     width: int | None = None, gathered=None):
        """Gather sparse bucket tiles compacted to a feature slice [lo, hi).

        Building block for streamed feature-sharded feeds (DESIGN.md
        S12/S16): a model-axis lane that owns rows [lo, hi) of the
        shared vector only needs the nonzeros landing in its slice.
        Compaction semantics (row-order preserved, padded to a common
        width) live in `compact_slice_rows` — ``positions``/``width``
        pass through: the default mode returns slice-LOCAL
        ``((idx_loc, val_loc), y)``, while ``positions=True`` returns
        the mesh transfer format ``((idx, val, pos), y)`` with global
        ids + original within-row positions, which
        `engine.MeshChunkFeed` ships per model lane and the mesh step
        scatters back into exact full rows (the per-lane
        slice-compacted feed — ~M-fold fewer per-lane H2D bytes).

        ``gathered`` short-circuits the tile read with the result of a
        prior ``gather_buckets(bids)`` call, so a feed compacting the
        same chunk for M lanes reads the mmap once.
        """
        m = self.meta
        if m.kind != "sparse":
            raise ValueError("slice_gather is sparse-only")
        (idx, val), y = (gathered if gathered is not None
                         else self.gather_buckets(bids))
        out = compact_slice_rows(idx, val, lo, hi,
                                 nnz_multiple=nnz_multiple,
                                 positions=positions, width=width)
        return out, y

    def feed(self, *, verify: bool = False) -> "TileFeed":
        return TileFeed(self, verify=verify)


# ---------------------------------------------------------------------------
# ChunkFeed implementations (the protocol lives in core.engine)
# ---------------------------------------------------------------------------


class TileFeed:
    """`ChunkFeed` over a `TileCache`: mmap gather + device put.

    ``verify=True`` crc-checks exactly the tiles each fetch touches
    against the per-tile sidecar before handing them to the engine
    (raising `TileCorruptionError` so `ResilientChunkFeed` can
    quarantine + rebuild).  Default off: the fault-free hot loop pays
    zero checksum cost.
    """

    def __init__(self, cache: TileCache, *, verify: bool = False):
        self.cache = cache
        self.verify = verify
        m = cache.meta
        self.n, self.d, self.bucket = m.n, m.d, m.bucket
        self.sparse = m.kind == "sparse"

    def fetch(self, bids: np.ndarray):
        import jax
        if self.verify:
            self.cache.verify_tiles(bids)
        data, y = self.cache.gather_buckets(bids)
        if self.sparse:
            idx, val = data
            return ((jax.device_put(idx), jax.device_put(val)),
                    jax.device_put(y))
        return jax.device_put(np.ascontiguousarray(data)), jax.device_put(y)


class ArrayFeed:
    """`ChunkFeed` over resident host arrays — the in-memory twin of
    `TileFeed`, used by tests to separate cache exactness from the
    streamed-loop contract."""

    def __init__(self, y, *, X=None, idx=None, val=None,
                 d: int | None = None, bucket: int = 16):
        self.y = np.asarray(y, np.float32)
        self.n, self.bucket = self.y.shape[0], bucket
        self.sparse = X is None
        if self.sparse:
            self.idx = np.asarray(idx, np.int32)
            self.val = np.asarray(val, np.float32)
            self.d = int(d)
        else:
            self.X = np.asarray(X, np.float32)
            self.d = self.X.shape[0]

    def _cols(self, bids: np.ndarray) -> np.ndarray:
        B = self.bucket
        return (bids[..., None] * B
                + np.arange(B, dtype=np.int32)).reshape(
                    bids.shape[:-1] + (-1,))

    def fetch(self, bids: np.ndarray):
        import jax
        cols = self._cols(np.asarray(bids))
        y = jax.device_put(self.y[cols])
        if self.sparse:
            return ((jax.device_put(self.idx[cols]),
                     jax.device_put(self.val[cols])), y)
        data = np.moveaxis(self.X[:, cols], 0, -2)   # (*lead, d, m)
        return jax.device_put(np.ascontiguousarray(data)), y
