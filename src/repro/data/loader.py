"""Sharded batching: hierarchical (pod-static, lane-dynamic) data layout.

The GLM path consumes whole datasets (SDCA is a full-pass algorithm);
the LM path consumes token batches.  Both apply the paper's hierarchy:
examples are statically assigned to pods (data never crosses the slow
interconnect) and dynamically re-dealt across the lanes within a pod
every epoch (the paper's dynamic partitioning, applied to the input
pipeline — see DESIGN.md S4).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class ShardedBatcher:
    """Deterministic, restartable batcher with hierarchical shuffling.

    State is (seed, step) only — restart from a checkpointed step is
    bit-exact, and the schedule is a pure function so elastic re-runs at
    a different lane count re-deal the same global order.
    """
    n: int
    global_batch: int
    pods: int = 1
    lanes: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.global_batch % (self.pods * self.lanes):
            raise ValueError("global_batch must divide by pods*lanes")
        self.per_pod = self.n // self.pods

    def epoch_order(self, epoch: int) -> np.ndarray:
        """(pods, per_pod) example ids: static across pods, shuffled within."""
        rng = np.random.default_rng((self.seed, epoch))
        base = np.arange(self.pods * self.per_pod).reshape(
            self.pods, self.per_pod)
        for p in range(self.pods):
            rng.shuffle(base[p])
        return base

    def batches(self, epoch: int) -> Iterator[np.ndarray]:
        """Yields (global_batch,) index arrays laid out (pod-major) so a
        reshape to (pods, lanes, -1) matches the mesh layout."""
        order = self.epoch_order(epoch)
        per_pod_batch = self.global_batch // self.pods
        steps = self.per_pod // per_pod_batch
        for s in range(steps):
            cols = order[:, s * per_pod_batch:(s + 1) * per_pod_batch]
            yield cols.reshape(-1)


def markov_batch(vocab: int, batch: int, seq: int, *, table_seed: int = 0,
                 step: int = 0) -> dict:
    """One deterministic batch of a FIXED seeded order-1 Markov chain.

    The transition table depends only on table_seed (stable structure to
    learn, so the LM loss decreases); the trajectories depend on
    (table_seed, step), so a restart at step s regenerates the identical
    batch — the property the checkpoint/restart tests rely on.
    """
    table_rng = np.random.default_rng(table_seed)
    succ = table_rng.integers(0, vocab, size=(vocab, 4))
    rng = np.random.default_rng((table_seed, step))
    out = np.empty((batch, seq + 1), dtype=np.int32)
    out[:, 0] = rng.integers(0, vocab, size=batch)
    for t in range(seq):
        pick = succ[out[:, t], rng.integers(0, 4, size=batch)]
        noise = rng.integers(0, vocab, size=batch)
        use_noise = rng.uniform(size=batch) < 0.1
        out[:, t + 1] = np.where(use_noise, noise, pick)
    return {"tokens": out[:, :-1], "labels": out[:, 1:]}


def lm_token_batches(vocab: int, batch: int, seq: int, *, seed: int = 0,
                     steps: Optional[int] = None):
    """Deterministic stream of markov_batch()es."""
    step = 0
    while steps is None or step < steps:
        yield markov_batch(vocab, batch, seq, table_seed=seed, step=step)
        step += 1
