"""Data substrate: synthetic GLM datasets + LM token pipeline."""
from .synthetic import (criteo_like, epsilon_like, higgs_like,
                        make_dense_classification, make_dense_regression,
                        make_sparse_classification)
from .loader import ShardedBatcher, lm_token_batches

__all__ = [
    "criteo_like", "epsilon_like", "higgs_like",
    "make_dense_classification", "make_dense_regression",
    "make_sparse_classification",
    "ShardedBatcher", "lm_token_batches",
]
