"""Data substrate: synthetic GLM datasets, real-dataset ingestion
(svmlight/CSV -> packed bucket-tile cache -> streamed epochs), and the
LM token pipeline."""
from .synthetic import (criteo_like, epsilon_like, higgs_like,
                        make_dense_classification, make_dense_regression,
                        make_sparse_classification)
from .loader import ShardedBatcher, lm_token_batches
from .formats import (dump_csv, dump_svmlight, parse_csv, parse_svmlight,
                      to_dense)
from .cache import (ArrayFeed, TileCache, TileFeed, build_cache,
                    open_cache)
from .registry import (REGISTRY, Dataset, DatasetSpec, get_dataset,
                       get_spec, materialize)

__all__ = [
    "criteo_like", "epsilon_like", "higgs_like",
    "make_dense_classification", "make_dense_regression",
    "make_sparse_classification",
    "ShardedBatcher", "lm_token_batches",
    "dump_csv", "dump_svmlight", "parse_csv", "parse_svmlight",
    "to_dense",
    "ArrayFeed", "TileCache", "TileFeed", "build_cache", "open_cache",
    "REGISTRY", "Dataset", "DatasetSpec", "get_dataset", "get_spec",
    "materialize",
]
