"""Dataset registry: the paper's evaluation datasets as named specs.

Each entry declares the REAL dataset's shape/objective (what
`launch/glm.py` sizes the distributed program for) plus a reduced
"sub" shape and a deterministic synthetic fallback, so every test,
benchmark, and CI run works offline: `get_dataset` ingests a real
svmlight/CSV file when one is present under ``data_dir`` (or
``$REPRO_DATA_DIR``) and otherwise falls back to a seeded stand-in of
the same character (sparsity, skew, feature width).

`materialize` is the bridge to the tile cache: it resolves a spec,
builds the packed bucket-tile cache under a shape-keyed directory if
missing (cold-start ingest paid once), and returns the opened
`TileCache` ready for in-memory loading or out-of-core streaming.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pathlib
import tempfile
from typing import Optional

import numpy as np

from . import cache as tile_cache
from . import formats, synthetic

__all__ = ["DatasetSpec", "Dataset", "REGISTRY", "get_spec",
           "get_dataset", "materialize", "cache_root"]


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """One named workload: real shape + offline fallback shape."""
    name: str
    kind: str                  # dense | sparse
    objective: str             # default training objective
    full_n: int                # real dataset example count
    full_d: int
    sub_n: int                 # offline fallback default shape
    sub_d: int
    nnz: int = 0               # real (padded) row width, sparse only
    sub_nnz: int = 0           # fallback row width
    skew: float = 0.0          # Zipf-ish feature popularity (sparse)
    lam: float = 1e-3
    seed: int = 0
    source: str = ""           # provenance / download pointer


REGISTRY = {
    # criteo-kaggle: the paper's headline workload (45M x 1M, ~39 nnz
    # — the REAL row width; the synthetic fallback draws 40-wide rows
    # so offline tiles land kernel-aligned and local_solver="pallas"
    # works out of the box, and raw-file ingests align via
    # materialize(..., nnz_multiple=8) / Session(nnz_multiple=8)).
    # "-sub" marks that offline runs use a documented-scale subsample.
    "criteo-kaggle-sub": DatasetSpec(
        "criteo-kaggle-sub", "sparse", "logistic",
        full_n=45_840_617, full_d=1_000_000, nnz=39,
        sub_n=8_192, sub_d=4_096, sub_nnz=40, skew=1.1, seed=1,
        source="https://labs.criteo.com/2014/02/"
               "kaggle-display-advertising-challenge-dataset/"),
    # HIGGS: dense, narrow — every chip is an example-parallel worker.
    "higgs": DatasetSpec(
        "higgs", "dense", "logistic",
        full_n=11_000_000, full_d=28, sub_n=16_384, sub_d=28, seed=2,
        source="https://archive.ics.uci.edu/dataset/280/higgs"),
    # epsilon: dense, wide, pre-normalized — the TP (feature-shard) case.
    "epsilon": DatasetSpec(
        "epsilon", "dense", "logistic",
        full_n=400_000, full_d=2_000, sub_n=4_096, sub_d=2_000, seed=3,
        source="https://www.csie.ntu.edu.tw/~cjlin/libsvmtools/"
               "datasets/binary.html#epsilon"),
    # webspam (trigram): extreme-d sparse (the paper's 4th dataset).
    # ~3727 nnz is the REAL row width (mirroring criteo's 39 above);
    # the synthetic fallback in get_dataset ceils it to a multiple of 8
    # so offline tiles land kernel-aligned for the (sharded) sparse
    # Pallas kernel, and raw-file ingests align via nnz_multiple=8.
    "webspam": DatasetSpec(
        "webspam", "sparse", "logistic",
        full_n=350_000, full_d=16_609_143, nnz=3_727,
        sub_n=4_096, sub_d=16_384, sub_nnz=64, skew=1.0, seed=4,
        source="https://www.csie.ntu.edu.tw/~cjlin/libsvmtools/"
               "datasets/binary.html#webspam"),
    # small fully-synthetic entries (paper Fig 1 shapes) for tests/CI
    "synthetic-dense": DatasetSpec(
        "synthetic-dense", "dense", "logistic",
        full_n=100_000, full_d=100, sub_n=2_048, sub_d=64, seed=0,
        source="data/synthetic.py (paper Fig 1a)"),
    "synthetic-sparse": DatasetSpec(
        "synthetic-sparse", "sparse", "logistic",
        full_n=100_000, full_d=1_000, nnz=10,
        sub_n=2_048, sub_d=256, sub_nnz=8, seed=0,
        source="data/synthetic.py (paper Fig 1b)"),
}


@dataclasses.dataclass
class Dataset:
    """A materialized (in-memory) dataset + where it came from."""
    spec: DatasetSpec
    y: np.ndarray
    d: int
    sparse: bool
    X: Optional[np.ndarray] = None             # dense (d, n)
    idx: Optional[np.ndarray] = None           # sparse (n, nnz)
    val: Optional[np.ndarray] = None
    provenance: str = "synthetic"              # synthetic | file:<path>

    @property
    def n(self) -> int:
        return self.y.shape[0]

    @property
    def scale(self) -> float:
        """Fraction of the real dataset's n this materialization holds."""
        return self.n / self.spec.full_n


def get_spec(name: str) -> DatasetSpec:
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown dataset {name!r}; registered: {sorted(REGISTRY)}")


def _find_raw_file(name: str, data_dir) -> Optional[pathlib.Path]:
    data_dir = data_dir or os.environ.get("REPRO_DATA_DIR")
    if not data_dir:
        return None
    base = pathlib.Path(data_dir)
    for ext in (".svm", ".svmlight", ".libsvm", ".txt", ".csv"):
        p = base / f"{name}{ext}"
        if p.exists():
            return p
    return None


def get_dataset(name: str, *, n: Optional[int] = None,
                d: Optional[int] = None, data_dir=None) -> Dataset:
    """Resolve a registry name to in-memory arrays.

    Real file wins when present (svmlight/CSV under data_dir or
    $REPRO_DATA_DIR, optionally truncated to ``n``); otherwise the
    seeded synthetic fallback at (n or sub_n, d or sub_d).
    """
    spec = get_spec(name)
    raw = _find_raw_file(name, data_dir)
    if raw is not None:
        if raw.suffix == ".csv":
            X, y = formats.parse_csv(raw)
            if n is not None:
                X, y = X[:, :n], y[:n]
            if spec.kind == "sparse":
                raise ValueError(f"{raw}: CSV ingest is dense-only")
            return Dataset(spec, y, X.shape[0], False, X=X,
                           provenance=f"file:{raw}")
        (idx, val), y, d_seen = formats.parse_svmlight(raw, d=d)
        if n is not None:
            idx, val, y = idx[:n], val[:n], y[:n]
        if spec.kind == "dense":
            X = formats.to_dense(idx, val, d_seen)
            return Dataset(spec, y, d_seen, False, X=X,
                           provenance=f"file:{raw}")
        return Dataset(spec, y, d_seen, True, idx=idx, val=val,
                       provenance=f"file:{raw}")

    n = n or spec.sub_n
    d = d or spec.sub_d
    if spec.kind == "dense":
        X, y = synthetic.make_dense_classification(n=n, d=d,
                                                   seed=spec.seed)
        return Dataset(spec, y, d, False, X=X)
    # Synthetic fallbacks draw kernel-aligned rows: specs carry the REAL
    # row width (criteo 39, webspam 3727) but the sparse Pallas kernels
    # require nnz % 8 == 0, so ceil to the lane multiple here — the same
    # nnz_multiple treatment raw ingests get in materialize().  This is
    # what lets the synthetic webspam shape exercise the feature-sharded
    # kernel instead of erroring on alignment.
    nnz = -(-(spec.sub_nnz or spec.nnz) // 8) * 8
    (idx, val), y, d = synthetic.make_sparse_classification(
        n=n, d=d, nnz=nnz, seed=spec.seed, skew=spec.skew)
    return Dataset(spec, y, d, True, idx=idx, val=val)


def cache_root(cache_dir=None) -> pathlib.Path:
    """Resolve the cache directory: arg > $REPRO_CACHE_DIR > ~/.cache.

    Holds the versioned bucket-tile caches (`data.cache`, one
    subdirectory per materialized workload) and, under ``plans/``, the
    solver planner's cached `SolverPlan` JSONs (`core.planner`, keyed
    by dataset x topology fingerprint) — one $REPRO_CACHE_DIR move
    relocates both.
    """
    if cache_dir is not None:
        return pathlib.Path(cache_dir)
    env = os.environ.get("REPRO_CACHE_DIR")
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro-glm"


def materialize(name: str, cache_dir=None, *, bucket: int = 16,
                pods: int = 1, n: Optional[int] = None,
                d: Optional[int] = None, pad_multiple: Optional[int] = None,
                nnz_multiple: Optional[int] = None,
                data_dir=None) -> tile_cache.TileCache:
    """Dataset name -> opened `TileCache`, building it if missing.

    The cache directory is keyed by everything that changes the bytes
    (shape, bucket, pod sharding, nnz padding, cache version), so
    different training topologies coexist and a version bump
    invalidates cleanly.  ``nnz_multiple`` pads sparse row widths with
    inert columns so tiles land lane-aligned for the sparse Pallas
    kernel (raw svmlight ingests with odd nnz need this to train with
    local_solver="pallas"; the synthetic specs are pre-aligned).
    """
    spec = get_spec(name)
    root = cache_root(cache_dir)
    mult = pad_multiple or (pods * bucket)
    raw = _find_raw_file(name, data_dir)
    # n=None means "full file" for raw ingests (keyed 'nall' so it can
    # never collide with an explicit-n build) and sub_n for synthetics.
    # Raw files also key on (size, mtime) so replacing the file on disk
    # invalidates the cache instead of silently serving stale tiles.
    n_key = n if n is not None else ("all" if raw is not None
                                     else spec.sub_n)
    raw_key = ""
    if raw is not None:
        st = raw.stat()
        fp = hashlib.sha1(
            f"{st.st_size}-{st.st_mtime_ns}".encode()).hexdigest()[:10]
        raw_key = f"-raw{fp}"
    nnz_key = f"-z{nnz_multiple}" if nnz_multiple else ""
    key = (f"{name}-n{n_key}-d{d or spec.sub_d}"
           f"-b{bucket}-p{pods}-m{mult}{nnz_key}{raw_key}"
           f"-v{tile_cache.CACHE_VERSION}")
    path = root / key

    def _quarantine():
        # Move the bad directory aside (kept for forensics under a
        # dot-prefixed name that cache-key lookups can never match)
        # and rebuild below.
        import shutil
        quarantine = path.parent / f".quarantine.{path.name}"
        shutil.rmtree(quarantine, ignore_errors=True)
        os.rename(path, quarantine)

    if (path / "meta.json").exists():
        try:
            return tile_cache.open_cache(path)
        except (ValueError, KeyError, OSError):
            # Torn build or corrupt/stale tiles.
            # audit: except-ok — invalid cache is quarantined and
            # rebuilt from source; the rebuild path re-raises real
            # failures.
            _quarantine()
    elif path.exists():
        # meta.json is build_cache's final write, so a cache directory
        # without it is a build that died mid-way: never open it.
        _quarantine()
    ds = get_dataset(name, n=n, d=d, data_dir=data_dir)
    # build into a private temp dir and rename into place: concurrent
    # materialize calls (pytest workers, threads, parallel benchmarks)
    # and mid-build crashes can never corrupt the shared cache dir.
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = pathlib.Path(tempfile.mkdtemp(
        dir=path.parent, prefix=f".{path.name}.tmp-"))
    if ds.sparse:
        tile_cache.build_cache(
            tmp, name, y=ds.y, idx=ds.idx, val=ds.val, d=ds.d,
            kind="sparse", bucket=bucket, pods=pods, pad_multiple=mult,
            nnz_multiple=nnz_multiple, objective=spec.objective)
    else:
        tile_cache.build_cache(
            tmp, name, y=ds.y, X=ds.X, kind="dense", bucket=bucket,
            pods=pods, pad_multiple=mult, objective=spec.objective)
    try:
        os.rename(tmp, path)
    except OSError:
        # another process won the race; its (byte-identical) build wins
        import shutil
        shutil.rmtree(tmp, ignore_errors=True)
    return tile_cache.open_cache(path)
