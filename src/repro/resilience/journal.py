"""Crash-safe epoch journal for the streamed training loop.

Two commit levels, both written through `checkpoint.manager.save_tree`
(atomic stage-swap protocol, so a kill at ANY instant leaves a
complete, loadable record):

  * ``<root>/epoch``    — state after the last COMPLETED epoch
    (alpha, v, epochs_done).  Committed by `Session.epoch`.
  * ``<root>/inflight`` — mid-epoch snapshot at a chunk boundary
    (alpha, pod-replicated v and v_in, chunk cursor), written every
    ``every`` chunks by `run_epoch_streamed`.  Because the partition
    schedule is a pure function of (seed, epoch), resuming from chunk
    cursor ``c`` replays exactly the chunks the killed run had not yet
    applied — the finished epoch is bitwise-identical to one that was
    never interrupted (pinned by tests/test_resilience.py).

The journal is strictly opt-in (``journal_dir=`` on `Session` /
`StreamedGLMTrainer`): with no journal the streamed loop runs two
``is not None`` checks per chunk and nothing else — zero overhead, no
host sync.

The optional `FaultInjector` hook is how kill-and-resume tests place
`SimulatedCrash` exactly at a chunk boundary; production journals
never set it.
"""
from __future__ import annotations

import pathlib
import shutil
from typing import Optional

import numpy as np

from ..checkpoint.manager import restore_tree, save_tree
from . import faultinject

__all__ = ["EpochJournal"]


class EpochJournal:
    """Chunk-cursor + state journal under one directory."""

    def __init__(self, root, *, every: int = 1,
                 injector: Optional["faultinject.FaultInjector"] = None):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.every = max(1, int(every))
        self.injector = injector

    @property
    def _inflight(self) -> pathlib.Path:
        return self.root / "inflight"

    @property
    def _epoch(self) -> pathlib.Path:
        return self.root / "epoch"

    @staticmethod
    def _complete(path: pathlib.Path) -> bool:
        return ((path / "keys.json").exists()
                or (path.with_name(f".old.{path.name}")
                    / "keys.json").exists())

    # -- mid-epoch (called from run_epoch_streamed) ----------------------
    def pre_chunk(self, epoch: int, c: int) -> None:
        if self.injector is not None:
            self.injector.maybe_kill(int(epoch), c)

    def post_chunk(self, epoch: int, c: int, alpha, v, v_in,
                   total: int) -> None:
        done = c + 1
        if done >= total or done % self.every:
            return          # the final chunk is covered by commit_epoch
        save_tree(self._inflight,
                  {"alpha": alpha, "v": v, "v_in": v_in},
                  meta={"epoch": int(epoch), "chunk": done})
        faultinject.log_event("journal.chunk", epoch=int(epoch),
                              chunk=done)

    def load_inflight(self, epoch: int, alpha, v, v_in):
        """-> (start_chunk, alpha, v, v_in) when a matching mid-epoch
        snapshot exists, else None.  The passed arrays are only shape/
        dtype templates for `restore_tree`."""
        if not self._complete(self._inflight):
            return None
        tree, meta = restore_tree(
            self._inflight, {"alpha": alpha, "v": v, "v_in": v_in})
        if meta.get("epoch") != int(epoch):
            return None     # stale snapshot from an earlier epoch
        faultinject.log_event("journal.resume", epoch=int(epoch),
                              chunk=int(meta["chunk"]))
        return (int(meta["chunk"]), tree["alpha"], tree["v"],
                tree["v_in"])

    def clear_inflight(self) -> None:
        """Drop the mid-epoch snapshot (and its swap siblings) — on
        epoch commit, and on health rollback, where an inflight record
        downstream of a poisoned chunk must never be resumed."""
        for name in ("inflight", ".old.inflight", ".tmp.inflight"):
            shutil.rmtree(self.root / name, ignore_errors=True)

    # -- epoch level (called from Session) -------------------------------
    def commit_epoch(self, alpha, v, epochs_done: int) -> None:
        save_tree(self._epoch, {"alpha": alpha, "v": v},
                  meta={"epochs_done": int(epochs_done)})
        self.clear_inflight()

    def load_epoch(self, alpha, v):
        """-> (alpha, v, epochs_done) from the last committed epoch, or
        None when the journal holds no completed epoch yet."""
        if not self._complete(self._epoch):
            return None
        tree, meta = restore_tree(self._epoch, {"alpha": alpha, "v": v})
        faultinject.log_event("journal.restore",
                              epochs_done=int(meta["epochs_done"]))
        return (np.asarray(tree["alpha"]), np.asarray(tree["v"]),
                int(meta["epochs_done"]))
