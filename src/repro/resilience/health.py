"""Numerical-health guard: detect bad state, roll back, remediate.

The solver's failure modes at scale are numerical, not just mechanical:
a poisoned chunk puts NaN into alpha/v, an over-aggressive aggregation
diverges, a kernel miscompiles at a new shape.  `HealthMonitor` is a
`Session.fit` callback (plus an ``on_epoch_error`` hook for exceptions
raised by the epoch program itself) that keeps a host-side snapshot of
the last HEALTHY (alpha, v, epoch) and, when an epoch ends unhealthy:

  1. rolls the session back to that snapshot (and re-commits it over
     any journal state downstream of the poison),
  2. re-runs the epoch — a plain retry first (``retries``), which is
     bitwise-exact for transient faults because schedules are pure
     functions of (seed, epoch),
  3. then applies the policy remedy: ``"fallback"`` reroutes the local
     solver pallas→xla through `Session._switch_local_solver` (the
     engine's `_auto_fallback` idiom, made stateful), ``"damp"``
     multiplies the update aggressiveness (the CoCoA ``dv_scale``
     knob) by ``damp_factor``, ``"raise"`` re-raises immediately,
  4. gives up after ``max_trips`` (the fit reports ``diverged``).

Unhealthy means: non-finite alpha or v, ``max|v|`` above
``diverge_above``, the epoch program raising, or the monitored series
(gap when present, else rel_change) increasing ``divergence_streak``
epochs in a row.

Zero-overhead contract: the monitor only exists when a `HealthPolicy`
is supplied — `Session.fit` without one runs its original loop with no
extra host syncs (the built-in divergence check already read
``max|v|``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import faultinject

__all__ = ["HealthPolicy", "HealthMonitor"]


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Knobs for detection and remediation (see module docstring)."""
    diverge_above: float = 1e8     # trip when max|v| exceeds this
    divergence_streak: int = 3     # trip after N straight increases
    retries: int = 1               # plain re-runs before the remedy
    remedy: str = "fallback"       # fallback | damp | raise
    damp_factor: float = 0.5       # dv_scale multiplier per damp trip
    max_trips: int = 5             # then give up (fit -> diverged)
    snapshot_every: int = 1        # healthy-state snapshot cadence

    def __post_init__(self):
        if self.remedy not in ("fallback", "damp", "raise"):
            raise ValueError(f"unknown remedy {self.remedy!r}")


class HealthMonitor:
    """`Session.fit` callback implementing a `HealthPolicy`.

    Duck-typed against `repro.api.callbacks.Callback` (bind /
    on_epoch_end) plus the fit-loop-only ``on_epoch_error``.  One
    monitor instance carries trip state across the whole fit; pass the
    same instance to successive fits to keep counting.
    """

    needs_gap = False

    def __init__(self, policy: Optional[HealthPolicy] = None):
        self.policy = policy or HealthPolicy()
        self.trips = 0
        self.gave_up = False
        self.events: list[dict] = []
        self._snap = None               # (epochs_done, alpha, v) host
        self._streak = 0
        self._prev = None               # last monitored value

    def bind(self, session) -> None:
        self.session = session
        if self._snap is None:
            self._snapshot()            # pre-training state is healthy

    def _snapshot(self) -> None:
        s = self.session
        self._snap = (s.epochs_done, np.asarray(s.alpha),
                      np.asarray(s.v))

    # -- detection -------------------------------------------------------
    def _check(self) -> Optional[str]:
        s = self.session
        amax = float(np.max(np.abs(np.asarray(s.alpha))))
        vmax = float(np.max(np.abs(np.asarray(s.v))))
        if not (np.isfinite(amax) and np.isfinite(vmax)):
            return "non-finite alpha/v"
        if vmax > self.policy.diverge_above:
            return f"max|v|={vmax:.3e} above {self.policy.diverge_above:g}"
        return None

    def on_epoch_end(self, metrics: dict) -> bool:
        reason = self._check()
        if reason is None:
            val = metrics.get("gap", metrics.get("rel_change"))
            if (val is not None and self._prev is not None
                    and np.isfinite(val) and val > self._prev):
                self._streak += 1
                if self._streak >= self.policy.divergence_streak:
                    reason = (f"monitored value rose {self._streak} "
                              f"epochs in a row")
            else:
                self._streak = 0
            self._prev = val
        if reason is not None:
            return self._trip(reason, metrics)
        if (self.session.epochs_done - self._snap[0]
                >= self.policy.snapshot_every):
            self._snapshot()
        return False

    def on_epoch_error(self, err: Exception) -> None:
        """Exception escaped the epoch program (kernel failure,
        feed error past its retries).  Same rollback/remedy path; the
        exception re-raises when the policy is exhausted."""
        stop = self._trip(f"{type(err).__name__}: {err}", None, err=err)
        if stop:
            raise err

    # -- remediation -----------------------------------------------------
    def _trip(self, reason: str, metrics: Optional[dict],
              err: Optional[Exception] = None) -> bool:
        self.trips += 1
        s = self.session
        event = {"trip": self.trips, "epoch": int(s.epochs_done),
                 "reason": reason}

        # roll back to the last healthy snapshot, and make the journal
        # agree — an inflight record downstream of the poison must not
        # survive the rollback
        import jax.numpy as jnp
        ep, alpha, v = self._snap
        s.alpha, s.v = jnp.asarray(alpha), jnp.asarray(v)
        s.epochs_done = ep
        journal = getattr(s, "_journal", None)
        if journal is not None:
            journal.commit_epoch(s.alpha, s.v, ep)
        self._streak = 0
        self._prev = None

        if self.trips > self.policy.max_trips:
            event["action"] = "give-up"
            self.gave_up = True
        elif self.trips <= self.policy.retries:
            event["action"] = "retry"
        elif self.policy.remedy == "fallback":
            event["action"] = "fallback:xla"
            s._switch_local_solver("xla")
        elif self.policy.remedy == "damp":
            s._damp *= self.policy.damp_factor
            event["action"] = f"damp:{s._damp:g}"
            s._rebuild_epoch_fn()
        else:                           # "raise"
            event["action"] = "raise"
            self.gave_up = True
        self.events.append(event)
        if metrics is not None:
            metrics["health"] = event
        faultinject.log_event("health.trip", **event)
        if self.policy.remedy == "raise" and err is None \
                and event["action"] == "raise":
            raise RuntimeError(f"health trip: {reason}")
        return self.gave_up
