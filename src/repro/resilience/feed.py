"""Failure-classifying retry wrapper over the `ChunkFeed` protocol.

`ResilientChunkFeed` is the feed-layer pillar of the fault-tolerant
runtime (DESIGN.md S15).  It distinguishes two failure classes on
fetch:

  * TRANSIENT (OSError/TimeoutError by default): retried in place with
    capped exponential backoff — NFS hiccups, throttled object stores,
    injected `FaultInjectedIOError`.  The retried fetch returns the
    same bytes a clean fetch would, so training stays bitwise-exact.
  * CORRUPTION (`TileCorruptionError` from the per-tile crc check):
    never retried — the bytes will not get better.  The backing cache
    directory is quarantined aside and rebuilt from source via the
    ``rebuild`` callback; because cache builds are byte-stable (pinned
    by tests/test_pipeline.py), the rebuilt tiles are identical and
    training continues bitwise-exact.

The wrapper adds zero overhead to the fault-free path: no checksum, no
thread, no host sync — one try/except around the underlying fetch
(per-fetch timeouts opt in via ``timeout=``, which routes the fetch
through a single worker thread).
"""
from __future__ import annotations

import os
import shutil
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional

import numpy as np

from ..data.cache import TileCorruptionError
from . import faultinject

__all__ = ["ResilientChunkFeed"]


class ResilientChunkFeed:
    """`ChunkFeed` wrapper: retry transients, quarantine corruption.

    Parameters
    ----------
    feed : ChunkFeed
        The wrapped feed (`TileFeed`, `ArrayFeed`, `FaultyFeed`, ...).
    retries : int
        Max transient retries per fetch before re-raising.
    backoff, backoff_cap : float
        Initial / maximum sleep between transient retries (seconds,
        doubled each attempt).
    timeout : float | None
        Per-fetch timeout in seconds; a timed-out fetch counts as
        transient.  None (default) calls the feed directly — no extra
        thread, no overhead.
    transient : tuple[type, ...]
        Exception classes treated as retryable.
    rebuild : callable | None
        Zero-arg callback returning a fresh `TileCache` (or feed) after
        corruption — typically ``lambda: registry.materialize(...)``.
        Without it, corruption re-raises to the caller.
    sleep : callable
        Injection point for tests (default `time.sleep`).
    """

    def __init__(self, feed, *, retries: int = 3, backoff: float = 0.05,
                 backoff_cap: float = 2.0,
                 timeout: Optional[float] = None,
                 transient: tuple = (OSError, TimeoutError),
                 rebuild: Optional[Callable] = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.feed = feed
        self.retries = int(retries)
        self.backoff = float(backoff)
        self.backoff_cap = float(backoff_cap)
        self.timeout = timeout
        self.transient = transient
        self.rebuild = rebuild
        self.sleep = sleep
        self._pool: Optional[ThreadPoolExecutor] = None

    # `self.feed` can be swapped by a corruption rebuild, so the
    # protocol attributes forward dynamically instead of being copied.
    @property
    def n(self) -> int:
        return self.feed.n

    @property
    def d(self) -> int:
        return self.feed.d

    @property
    def bucket(self) -> int:
        return self.feed.bucket

    @property
    def sparse(self) -> bool:
        return self.feed.sparse

    @property
    def cache(self):
        return getattr(self.feed, "cache", None)

    def _fetch_once(self, bids: np.ndarray):
        if self.timeout is None:
            return self.feed.fetch(bids)
        if self._pool is None:
            self._pool = ThreadPoolExecutor(max_workers=1)
        return self._pool.submit(self.feed.fetch, bids).result(
            timeout=self.timeout)

    def _recover_corruption(self, err: TileCorruptionError) -> None:
        """Quarantine the corrupt cache dir and swap in a rebuilt one."""
        if self.rebuild is None:
            raise err
        cache = self.cache
        if cache is not None:
            p = cache.path
            q = p.parent / f".quarantine.{p.name}"
            shutil.rmtree(q, ignore_errors=True)
            os.rename(p, q)
            faultinject.log_event(
                "recover.quarantine", path=str(p), array=err.array,
                tile=err.tile, offset=err.offset)
        new = self.rebuild()
        if hasattr(self.feed, "rebind") and hasattr(new, "gather_buckets"):
            # mesh-sharded feeds (engine.MeshChunkFeed) survive the
            # rebuild: swap only the backing cache so the explicit
            # shardings + compaction width stay intact — downgrading to
            # a plain TileFeed would break the mesh step's layout
            self.feed.rebind(new)
        else:
            if hasattr(new, "feed"):      # TileCache -> its ChunkFeed
                new = new.feed(verify=getattr(self.feed, "verify", False))
            self.feed = new
        faultinject.log_event("recover.rebuilt", array=err.array,
                              tile=err.tile)

    def fetch(self, bids: np.ndarray):
        attempt = 0
        rebuilt = False
        delay = self.backoff
        while True:
            try:
                return self._fetch_once(bids)
            except TileCorruptionError as err:
                if rebuilt:               # rebuilt bytes are bad too
                    raise
                self._recover_corruption(err)
                rebuilt = True
            except self.transient as err:
                attempt += 1
                if attempt > self.retries:
                    raise
                faultinject.log_event(
                    "recover.retry", attempt=attempt,
                    error=f"{type(err).__name__}: {err}")
                self.sleep(delay)
                delay = min(delay * 2.0, self.backoff_cap)
