"""Seeded, deterministic fault injection for the training runtime.

Every recovery path in `repro.resilience` is proven, not trusted: this
module turns a schedule string (``$REPRO_FAULTS`` or an explicit
`FaultInjector`) into exact, reproducible failures at exact points in
the training program, so tests can assert the recovered model is
bitwise-identical to an uninterrupted `deterministic=True` run.

Schedule grammar (semicolon-separated specs)::

    kind@tokens[:arg]

    tokens:  e<N> epoch    c<N> chunk    n<N> Nth fetch (1-based)
             t<N> tile id  x<N> fire count (default 1)

    kinds:   fetch-error   raise a transient OSError on the Nth fetch
             nan-chunk     poison the Nth fetched chunk's labels w/ NaN
             kill          raise SimulatedCrash at an epoch/chunk
                           boundary (chunk-level needs a journal)
             kernel-fail   raise KernelBuildError when the epoch
                           program runs on a Pallas solver route
             nan-epoch     poison alpha/v after the epoch completes
             flip-tile     XOR one seeded byte of tile t on disk
                           (arg = array name, default first data array)

    example: "fetch-error@n2x2;kill@e1c3;kernel-fail@e2;flip-tile@t7:val"

Faults are pure functions of (schedule, seed, call sequence) — no
randomness at fire time beyond the seeded byte position — so a failed
CI chaos run replays exactly.  Events (injections AND recoveries) are
appended as sorted-key JSON lines to ``$REPRO_FAULT_LOG`` when set; the
log carries no timestamps so two identical runs produce identical logs.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import re
from typing import Optional

import numpy as np

__all__ = [
    "SimulatedCrash", "FaultInjectedIOError", "KernelBuildError",
    "FaultSpec", "FaultInjector", "FaultyFeed", "parse_schedule",
    "log_event",
]

FAULT_KINDS = ("fetch-error", "nan-chunk", "kill", "kernel-fail",
               "nan-epoch", "flip-tile")


class SimulatedCrash(BaseException):
    """An injected process kill.

    Deliberately a BaseException (like KeyboardInterrupt): recovery
    machinery catches `Exception`, and a kill must never be absorbed
    by a retry loop — it has to unwind the whole process so the
    kill-and-resume tests exercise the real restart path.
    """


class FaultInjectedIOError(OSError):
    """An injected TRANSIENT I/O failure (retryable by design)."""


class KernelBuildError(RuntimeError):
    """An injected kernel build/runtime failure (pallas routes only)."""


_TOKEN = re.compile(r"([ecnxt])(\d+)")
_TOKEN_FIELD = {"e": "epoch", "c": "chunk", "n": "nth",
                "x": "times", "t": "tile"}


@dataclasses.dataclass
class FaultSpec:
    """One parsed fault: a kind plus its firing coordinates."""
    kind: str
    epoch: Optional[int] = None
    chunk: Optional[int] = None
    nth: Optional[int] = None
    tile: Optional[int] = None
    times: int = 1
    arg: str = ""
    fired: int = 0

    def live(self) -> bool:
        return self.fired < self.times


def parse_schedule(schedule: str) -> list[FaultSpec]:
    specs = []
    for part in schedule.split(";"):
        part = part.strip()
        if not part:
            continue
        kind, _, rest = part.partition("@")
        if kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {kind!r} in {part!r}; "
                f"known: {FAULT_KINDS}")
        tokens, _, arg = rest.partition(":")
        fields: dict = {"kind": kind, "arg": arg}
        pos = 0
        for m in _TOKEN.finditer(tokens):
            if m.start() != pos:
                raise ValueError(f"bad fault tokens {tokens!r} in {part!r}")
            pos = m.end()
            fields[_TOKEN_FIELD[m.group(1)]] = int(m.group(2))
        if pos != len(tokens):
            raise ValueError(f"bad fault tokens {tokens!r} in {part!r}")
        specs.append(FaultSpec(**fields))
    return specs


def log_event(event: str, *, log_path=None, **fields) -> None:
    """Append one sorted-key JSON line to the fault/recovery event log.

    No-op unless ``log_path`` or ``$REPRO_FAULT_LOG`` names a file, so
    the fault-free hot loop pays nothing.  Used by injection sites AND
    by the recovery machinery (retry, rollback, quarantine), giving the
    CI chaos job a single artifact that tells the whole story.  The
    file destination is keyword ``log_path`` (NOT ``path``) so event
    payloads can carry a ``path=`` data field without colliding.
    """
    log_path = log_path or os.environ.get("REPRO_FAULT_LOG")
    if not log_path:
        return
    rec = {"event": event, **fields}
    with open(log_path, "a") as f:
        f.write(json.dumps(rec, sort_keys=True) + "\n")


class FaultInjector:
    """Deterministic fault scheduler; one per training run.

    Each ``maybe_*`` probe is called from a specific point in the
    training program; a probe raises (or returns a poison directive)
    exactly when a live `FaultSpec` matches its coordinates, then
    consumes one firing.  Thread-safety: probes are only called from
    the training loop and the single prefetch thread, and each spec
    fires a bounded number of times, so a plain counter suffices.
    """

    def __init__(self, schedule: str = "", *, seed: int = 0,
                 log_path=None):
        self.specs = (parse_schedule(schedule)
                      if isinstance(schedule, str) else list(schedule))
        self.seed = seed
        self.fetches = 0
        self.log_path = log_path

    @classmethod
    def from_env(cls) -> Optional["FaultInjector"]:
        """Build from ``$REPRO_FAULTS`` (None when unset/empty)."""
        schedule = os.environ.get("REPRO_FAULTS", "")
        if not schedule:
            return None
        return cls(schedule, seed=int(os.environ.get("REPRO_SEED", "0")))

    def log(self, event: str, **fields) -> None:
        log_event(event, log_path=self.log_path, **fields)

    def _take(self, kind: str, *, epoch=None, chunk=None, nth=None
              ) -> Optional[FaultSpec]:
        for s in self.specs:
            if s.kind != kind or not s.live():
                continue
            if s.nth is not None and not (
                    nth is not None and s.nth <= nth < s.nth + s.times):
                continue
            if s.epoch is not None and s.epoch != epoch:
                continue
            # chunk-level specs only fire at chunk boundaries and
            # epoch-level specs only at epoch boundaries — a kill@e1
            # must not also fire inside epoch 1's chunk loop.
            if kind == "kill" and (s.chunk is None) != (chunk is None):
                continue
            if s.chunk is not None and s.chunk != chunk:
                continue
            s.fired += 1
            return s
        return None

    # -- probes, one per program point -----------------------------------
    def on_fetch(self) -> Optional[str]:
        """Called by `FaultyFeed` before each fetch; may raise, or
        return ``"nan"`` to poison the fetched labels."""
        self.fetches += 1
        n = self.fetches
        if self._take("fetch-error", nth=n) is not None:
            self.log("inject.fetch-error", nth=n)
            raise FaultInjectedIOError(
                f"injected transient I/O fault on fetch {n}")
        if self._take("nan-chunk", nth=n) is not None:
            self.log("inject.nan-chunk", nth=n)
            return "nan"
        return None

    def maybe_kill(self, epoch: int, chunk: Optional[int] = None) -> None:
        if self._take("kill", epoch=int(epoch), chunk=chunk) is not None:
            self.log("inject.kill", epoch=int(epoch), chunk=chunk)
            raise SimulatedCrash(
                f"injected kill at epoch {epoch}, chunk {chunk}")

    def maybe_kernel_fail(self, epoch: int) -> None:
        for s in self.specs:
            if s.kind == "kernel-fail" and s.live() and (
                    s.epoch is None or s.epoch == int(epoch)):
                s.fired += 1
                self.log("inject.kernel-fail", epoch=int(epoch))
                raise KernelBuildError(
                    f"injected kernel failure at epoch {epoch}")

    def nan_epoch(self, epoch: int) -> bool:
        """True when this epoch's result should be poisoned with NaN
        (the resident-path twin of nan-chunk)."""
        if self._take("nan-epoch", epoch=int(epoch)) is not None:
            self.log("inject.nan-epoch", epoch=int(epoch))
            return True
        return False

    # -- disk faults (applied once, before training) ---------------------
    def apply_disk_faults(self, cache_path) -> int:
        """Apply all live flip-tile specs to a cache directory; returns
        the number of bytes flipped.  The byte position inside the tile
        is seeded by (seed, tile), the flip is XOR 0xFF — always a real
        change, always the same change for the same schedule."""
        from ..data import cache as tile_cache
        path = pathlib.Path(cache_path)
        doc = json.loads((path / "meta.json").read_text())
        meta = tile_cache.CacheMeta(
            **{f.name: doc[f.name]
               for f in dataclasses.fields(tile_cache.CacheMeta)})
        specs_by_array = meta.array_specs()
        flipped = 0
        for s in self.specs:
            if s.kind != "flip-tile" or not s.live():
                continue
            s.fired += 1
            aname = s.arg or next(a for a in specs_by_array if a != "y")
            shape, dtype = specs_by_array[aname]
            tile_nbytes = (int(np.prod(shape[2:]))
                           * np.dtype(dtype).itemsize)
            tile = s.tile or 0
            rng = np.random.default_rng([self.seed, tile])
            off = tile * tile_nbytes + int(rng.integers(tile_nbytes))
            with open(path / f"{aname}.bin", "r+b") as f:
                f.seek(off)
                b = f.read(1)
                f.seek(off)
                f.write(bytes([b[0] ^ 0xFF]))
            flipped += 1
            self.log("inject.flip-tile", array=aname, tile=tile,
                     offset=off)
        return flipped


class FaultyFeed:
    """`ChunkFeed` wrapper that injects scheduled faults on fetch.

    Sits UNDER `ResilientChunkFeed` in tests (resilient wrapper sees
    the injected failures exactly as it would see real ones) and is
    harmless in production — with an empty schedule every fetch passes
    straight through.
    """

    def __init__(self, feed, injector: FaultInjector):
        self.feed = feed
        self.injector = injector
        self.n, self.d = feed.n, feed.d
        self.bucket, self.sparse = feed.bucket, feed.sparse
        self.cache = getattr(feed, "cache", None)

    def fetch(self, bids: np.ndarray):
        action = self.injector.on_fetch()
        data, y = self.feed.fetch(bids)
        if action == "nan":
            import jax.numpy as jnp
            y = jnp.full(jnp.shape(y), jnp.nan, jnp.asarray(y).dtype)
        return data, y
