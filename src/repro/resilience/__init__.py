"""Fault-tolerant training runtime (DESIGN.md S15).

Four pillars, each opt-in and zero-cost when unused:

  * `EpochJournal`        — crash-safe streamed epochs: chunk-cursor +
                            state journal; a killed run resumes at the
                            last committed chunk boundary, bitwise.
  * `ResilientChunkFeed`  — feed-layer retry/timeout/backoff; transient
                            I/O is retried, `TileCorruptionError` is
                            quarantined + rebuilt from source.
  * `HealthPolicy` /
    `HealthMonitor`       — numerical-health guard: non-finite or
                            diverging state rolls back to the last
                            healthy snapshot, then retry / damp /
                            pallas→xla fallback.
  * `faultinject`         — seeded deterministic fault schedules
                            (``$REPRO_FAULTS``) proving every recovery
                            path in CI, with a JSON event log
                            (``$REPRO_FAULT_LOG``).

Operator guide: docs/robustness.md.
"""
from .faultinject import (FaultInjectedIOError, FaultInjector, FaultyFeed,
                          KernelBuildError, SimulatedCrash, log_event,
                          parse_schedule)
from .feed import ResilientChunkFeed
from .health import HealthMonitor, HealthPolicy
from .journal import EpochJournal

__all__ = [
    "EpochJournal", "ResilientChunkFeed", "HealthMonitor", "HealthPolicy",
    "FaultInjector", "FaultyFeed", "SimulatedCrash",
    "FaultInjectedIOError", "KernelBuildError", "parse_schedule",
    "log_event",
]
