"""Mixture-of-Experts with expert parallelism over the 'model' mesh axis.

Sort-based capacity dispatch (MaxText-style): no (T x E x C) one-hot —
token slots are computed with an argsort + per-expert rank, tokens are
scattered into an (E, C, d) buffer (sharded over 'model' on E), pushed
through a grouped einsum, and gathered back weighted by the router.
Dropped tokens (beyond capacity) fall back to the residual path, i.e.
contribute zero from the MoE branch — standard capacity semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import ParamSpec, act_fn

Array = jax.Array


def moe_specs(cfg) -> dict:
    """Expert parallelism: E shards over 'data', d_model over 'model'.

    Experts stay RESIDENT — only tokens move (an all-to-all-shaped
    reshard of the dispatch buffer), never the expert weights.  The
    first sharding (E over 'model' + ZeRO-3 'data' on d) made XLA
    all-gather 33.8 GB of expert weights per layer per chip on the 1T
    MoE — 25.6 TB/step/chip of collective traffic (EXPERIMENTS.md SPerf
    kimi iteration 1, refuted layout).  Token traffic is ~100x smaller
    at these batch sizes.
    """
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    sp = {
        "router": ParamSpec((d, E), P(None, None), jnp.float32),
        "w_gate": ParamSpec((E, d, ff), P("data", "model", None)),
        "w_up": ParamSpec((E, d, ff), P("data", "model", None)),
        "w_down": ParamSpec((E, ff, d), P("data", None, "model")),
    }
    if cfg.n_shared_experts:
        sff = cfg.moe_d_ff * cfg.n_shared_experts
        sp["shared"] = {
            "w_gate": ParamSpec((d, sff), P(None, "model")),
            "w_up": ParamSpec((d, sff), P(None, "model")),
            "w_down": ParamSpec((sff, d), P("model", None)),
        }
    return sp


def capacity(tokens: int, n_experts: int, top_k: int,
             factor: float = 1.25) -> int:
    c = int(tokens * top_k * factor / n_experts) + 1
    return max(8, -(-c // 8) * 8)   # round up to 8


def moe_apply(p: dict, x: Array, cfg, *, act: str = "silu") -> Array:
    """x: (..., d) -> (..., d).  Flattens leading dims to tokens."""
    orig_shape = x.shape
    d, E, k = cfg.d_model, cfg.n_experts, cfg.top_k
    xt = x.reshape(-1, d)
    T = xt.shape[0]
    C = capacity(T, E, k, cfg.moe_capacity)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_ids = jax.lax.top_k(probs, k)               # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # --- slot assignment (sort-based, no one-hot) ---
    flat_ids = gate_ids.reshape(-1)                          # (T*k,)
    order = jnp.argsort(flat_ids, stable=True)               # (T*k,)
    sorted_ids = flat_ids[order]
    counts = jnp.bincount(flat_ids, length=E)                # (T*k? no: E,)
    starts = jnp.cumsum(counts) - counts                     # (E,)
    rank = jnp.arange(T * k) - starts[sorted_ids]            # rank in expert
    keep = rank < C
    slot = sorted_ids * C + jnp.minimum(rank, C - 1)         # (T*k,)
    src_tok = order // k                                     # token of slot

    from repro.sharding import constrain
    buf = jnp.zeros((E * C, d), xt.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xt[src_tok], 0))
    buf = buf.reshape(E, C, d)
    # EP layout: experts over 'data' (tokens all-to-all into place),
    # hidden dim over 'model' (per-expert matmuls are TP'd)
    buf = constrain(buf, "data", None, "model")

    a = act_fn(act)
    h = a(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, d)
    out_buf = constrain(out_buf.reshape(E, C, d), "data", None, "model"
                        ).reshape(E * C, d)

    w_sorted = gate_w.reshape(-1)[order]
    contrib = out_buf[slot] * (w_sorted * keep)[:, None].astype(out_buf.dtype)
    out = jnp.zeros((T, d), out_buf.dtype).at[src_tok].add(contrib)

    if cfg.n_shared_experts:
        sp = p["shared"]
        out = out + (a(xt @ sp["w_gate"]) * (xt @ sp["w_up"])) @ sp["w_down"]
    return out.reshape(orig_shape).astype(x.dtype)
