"""Recurrent blocks: RG-LRU (RecurrentGemma) and xLSTM (mLSTM / sLSTM).

Training/prefill paths are parallel-friendly (associative scan for
RG-LRU, masked quadratic "linear attention" form for mLSTM, lax.scan for
sLSTM); decode paths are O(1)-per-token state updates — which is what
makes these architectures runnable at the long_500k shape.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import ParamSpec

Array = jax.Array
_RG_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin): in -> (x-branch, gate-branch) -> conv1d
#   -> RG-LRU -> out-proj, gated by GeLU branch
# ---------------------------------------------------------------------------

def rglru_block_specs(cfg) -> dict:
    d, dr = cfg.d_model, cfg.rglru_dim
    return {
        "w_x": ParamSpec((d, dr), P(None, "model")),
        "w_gate": ParamSpec((d, dr), P(None, "model")),
        "conv_w": ParamSpec((4, dr), P(None, "model"), jnp.float32,
                            scale=0.5),
        "conv_b": ParamSpec((dr,), P("model"), jnp.float32, "zeros"),
        "a_param": ParamSpec((dr,), P("model"), jnp.float32, "ones"),
        "gate_a_w": ParamSpec((dr, dr), P(None, "model")),
        "gate_x_w": ParamSpec((dr, dr), P(None, "model")),
        "w_out": ParamSpec((dr, d), P("model", None)),
    }


def _a_log(a_param: Array) -> Array:
    # parameterize a in (0,1): a = sigmoid(a_param)^(1); log a < 0
    return jax.nn.log_sigmoid(a_param.astype(jnp.float32))


def _causal_conv(x: Array, w: Array, b: Array,
                 state: Optional[Array] = None):
    """Depthwise causal conv, width 4.  x: (B,S,D); state: (B,3,D)."""
    B, S, D = x.shape
    if state is None:
        state = jnp.zeros((B, 3, D), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)           # (B, S+3, D)
    out = sum(xp[:, i:i + S] * w[i] for i in range(4)) + b
    return out.astype(x.dtype), xp[:, -3:]


def _rglru_scan(x: Array, a_log: Array, ga: Array, gx: Array,
                h0: Array) -> tuple[Array, Array]:
    """Associative-scan RG-LRU.  x/ga/gx: (B,S,D); h0: (B,D)."""
    r = jax.nn.sigmoid(ga.astype(jnp.float32))
    i = jax.nn.sigmoid(gx.astype(jnp.float32))
    log_a = _RG_C * a_log * r                         # (B,S,D)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) \
        * (i * x.astype(jnp.float32))
    # fold h0 into the first step: h_t = a_t h_{t-1} + b_t
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    A, Bc = jax.lax.associative_scan(combine, (a, b), axis=1)
    return Bc.astype(x.dtype), Bc[:, -1]


def rglru_block_fwd(p: dict, x: Array, cfg) -> Array:
    """Training/prefill.  x: (B,S,d)."""
    xb = x @ p["w_x"]
    gb = jax.nn.gelu(x @ p["w_gate"])
    xb, _ = _causal_conv(xb, p["conv_w"], p["conv_b"])
    ga = xb @ p["gate_a_w"]
    gx = xb @ p["gate_x_w"]
    h0 = jnp.zeros((x.shape[0], cfg.rglru_dim), jnp.float32)
    h, _ = _rglru_scan(xb, _a_log(p["a_param"]), ga, gx, h0)
    return (h * gb) @ p["w_out"]


def rglru_cache_shape(cfg, batch: int) -> dict:
    dr = cfg.rglru_dim
    return {"h": jax.ShapeDtypeStruct((batch, dr), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, 3, dr), jnp.bfloat16)}


def rglru_block_decode(p: dict, x: Array, cache: dict, cfg
                       ) -> tuple[Array, dict]:
    """x: (B,1,d) one token."""
    xb = x @ p["w_x"]
    gb = jax.nn.gelu(x @ p["w_gate"])
    xb, conv_state = _causal_conv(xb, p["conv_w"], p["conv_b"],
                                  cache["conv"].astype(xb.dtype))
    ga = xb @ p["gate_a_w"]
    gx = xb @ p["gate_x_w"]
    a_log = _a_log(p["a_param"])
    r = jax.nn.sigmoid(ga[:, 0].astype(jnp.float32))
    i = jax.nn.sigmoid(gx[:, 0].astype(jnp.float32))
    log_a = _RG_C * a_log * r
    at = jnp.exp(log_a)
    bt = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) \
        * (i * xb[:, 0].astype(jnp.float32))
    h = at * cache["h"] + bt
    out = (h[:, None].astype(x.dtype) * gb) @ p["w_out"]
    return out, {"h": h, "conv": conv_state.astype(jnp.bfloat16)}


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM, xLSTM):  parallel quadratic form for
# training/prefill, recurrent state (C, n, m) for decode.
# ---------------------------------------------------------------------------

def mlstm_specs(cfg) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    return {
        "wq": ParamSpec((d, d), P(None, "model")),
        "wk": ParamSpec((d, d), P(None, "model")),
        "wv": ParamSpec((d, d), P(None, "model")),
        # per-head gates: H is small (4) — replicate, never shard
        "w_i": ParamSpec((d, H), P(None, None), jnp.float32),
        "w_f": ParamSpec((d, H), P(None, None), jnp.float32),
        "w_o": ParamSpec((d, d), P(None, "model")),
        "wo": ParamSpec((d, d), P("model", None)),
        "ln_g": ParamSpec((d,), P("model"), jnp.float32, "ones"),
    }


def _mlstm_heads(p, x, cfg):
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, H, hd) / math.sqrt(hd)
    v = (x @ p["wv"]).reshape(B, S, H, hd)
    i_pre = (x @ p["w_i"]).astype(jnp.float32)          # (B,S,H)
    f_pre = (x @ p["w_f"]).astype(jnp.float32)
    return q, k, v, i_pre, f_pre


def mlstm_fwd(p: dict, x: Array, cfg) -> Array:
    """Stabilized CHUNKWISE-parallel mLSTM forward.

    The naive parallel form materializes (B,S,S,H) — 17 TB at the 32k
    prefill shape — so the sequence is processed in chunks of size c:
    intra-chunk quadratic (c x c) + inter-chunk recurrent state
    (C, n, m) carried by lax.scan, exactly the decode recurrence run
    once per chunk.  O(S*c) memory, O(S*(c + hd)) work per head-dim —
    this is the sub-quadratic engine behind the xLSTM long_500k cells.
    """
    from .layers import rmsnorm
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    c = min(getattr(cfg, "attn_chunk", 256) or 256, S)
    assert S % c == 0, (S, c)
    nc = S // c
    q, k, v, i_pre, f_pre = _mlstm_heads(p, x, cfg)
    qf = q.astype(jnp.float32).reshape(B, nc, c, H, hd)
    kf = k.astype(jnp.float32).reshape(B, nc, c, H, hd)
    vf = v.astype(jnp.float32).reshape(B, nc, c, H, hd)
    logf = jax.nn.log_sigmoid(f_pre).reshape(B, nc, c, H)
    ii = i_pre.reshape(B, nc, c, H)

    mask = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(carry, inp):
        C0, n0, m0 = carry                 # (B,H,hd,hd), (B,H,hd), (B,H)
        qc, kc, vc, lf, ic = inp           # (B,c,H,*)
        F = jnp.cumsum(lf, axis=1)         # within-chunk cumulative log f
        # stabilizer per position: max(F_t + m0, max_{s<=t} F_t - F_s + i_s)
        Dm = (F[:, :, None, :] - F[:, None, :, :]
              + ic[:, None, :, :])                       # (B,t,s,H)
        Dm = jnp.where(mask[None, :, :, None], Dm, -jnp.inf)
        m_intra = Dm.max(axis=2)                          # (B,c,H)
        m_t = jnp.maximum(F + m0[:, None, :], m_intra)    # (B,c,H)
        # inter-chunk: h_inter_t = exp(F_t + m0 - m_t) * q_t^T C0
        w_inter = jnp.exp(F + m0[:, None, :] - m_t)       # (B,c,H)
        h_inter = jnp.einsum("bchk,bhkv->bchv", qc, C0) * w_inter[..., None]
        n_inter = jnp.einsum("bchk,bhk->bch", qc, n0) * w_inter
        # intra-chunk: scores weighted by exp(Dm - m_t)
        Dexp = jnp.exp(Dm - m_t[:, :, None, :])           # (B,t,s,H)
        sc = jnp.einsum("bthd,bshd->btsh", qc, kc) * Dexp
        h_intra = jnp.einsum("btsh,bshd->bthd", sc, vc)
        n_intra = sc.sum(axis=2)                           # (B,c,H)
        den = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_t))
        h = (h_inter + h_intra) / den[..., None]
        # chunk-end state (t = c)
        Fc = F[:, -1]                                      # (B,H)
        m_c = m_t[:, -1]
        wC = jnp.exp(Fc + m0 - m_c)                        # (B,H)
        wk = jnp.exp(Fc[:, None, :] - F + ic - m_c[:, None, :])  # (B,c,H)
        C1 = wC[..., None, None] * C0 + jnp.einsum(
            "bshk,bshv->bhkv", kc * wk[..., None], vc)
        n1 = wC[..., None] * n0 + jnp.einsum("bsh,bshk->bhk", wk, kc)
        return (C1, n1, m_c), h

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    args = (qf.swapaxes(0, 1), kf.swapaxes(0, 1), vf.swapaxes(0, 1),
            logf.swapaxes(0, 1), ii.swapaxes(0, 1))
    _, hs = jax.lax.scan(chunk_step, (C0, n0, m0), args)
    h = hs.swapaxes(0, 1).reshape(B, S, d)
    o = jax.nn.sigmoid((x @ p["w_o"]).astype(jnp.float32))
    out = rmsnorm(h.astype(x.dtype), p["ln_g"]) * o.astype(x.dtype)
    return out @ p["wo"]


def mlstm_cache_shape(cfg, batch: int) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    return {"C": jax.ShapeDtypeStruct((batch, H, hd, hd), jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, H, hd), jnp.float32),
            "m": jax.ShapeDtypeStruct((batch, H), jnp.float32)}


def mlstm_decode(p: dict, x: Array, cache: dict, cfg
                 ) -> tuple[Array, dict]:
    from .layers import rmsnorm
    B, _, d = x.shape
    H = cfg.n_heads
    hd = d // H
    q, k, v, i_pre, f_pre = _mlstm_heads(p, x, cfg)
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]             # (B,H)

    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + cache["m"], i_pre)
    f_sc = jnp.exp(logf + cache["m"] - m_new)[..., None]
    i_sc = jnp.exp(i_pre - m_new)[..., None]
    C = f_sc[..., None] * cache["C"] \
        + i_sc[..., None] * jnp.einsum("bhk,bhv->bhkv", k, v)
    n = f_sc * cache["n"] + i_sc * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)),
                      jnp.exp(-m_new))[..., None]
    h = (num / den).reshape(B, 1, d).astype(x.dtype)
    o = jax.nn.sigmoid((x @ p["w_o"]).astype(jnp.float32))
    out = rmsnorm(h, p["ln_g"]) * o.astype(x.dtype)
    return out @ p["wo"], {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with exponential gating) — strictly sequential
# ---------------------------------------------------------------------------

def slstm_specs(cfg) -> dict:
    d = cfg.d_model
    return {
        "w_z": ParamSpec((d, d), P(None, "model")),
        "w_i": ParamSpec((d, d), P(None, "model"), jnp.float32),
        "w_f": ParamSpec((d, d), P(None, "model"), jnp.float32),
        "w_o": ParamSpec((d, d), P(None, "model")),
        "r_z": ParamSpec((d, d), P(None, "model")),
        "wo": ParamSpec((d, d), P("model", None)),
    }


def slstm_cache_shape(cfg, batch: int) -> dict:
    d = cfg.d_model

    def z():
        return jax.ShapeDtypeStruct((batch, d), jnp.float32)

    return {"c": z(), "n": z(), "m": z(), "h": z()}


def _slstm_step(p, xt, st):
    """xt: (B,d) f32 pre-projections applied outside for speed."""
    zt, it, ft, ot, rz = xt
    h_prev = st["h"]
    z = jnp.tanh(zt + h_prev @ rz)
    m_new = jnp.maximum(ft + st["m"], it)
    i_sc = jnp.exp(it - m_new)
    f_sc = jnp.exp(ft + st["m"] - m_new)
    c = f_sc * st["c"] + i_sc * z
    n = f_sc * st["n"] + i_sc
    h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h}


def slstm_fwd(p: dict, x: Array, cfg) -> Array:
    B, S, d = x.shape
    xf = x
    z = (xf @ p["w_z"]).astype(jnp.float32)
    i = (xf @ p["w_i"]).astype(jnp.float32)
    f = jax.nn.log_sigmoid((xf @ p["w_f"]).astype(jnp.float32))
    o = (xf @ p["w_o"]).astype(jnp.float32)
    rz = p["r_z"].astype(jnp.float32)
    st0 = {k: jnp.zeros((B, d), jnp.float32) for k in ("c", "n", "h")}
    st0["m"] = jnp.full((B, d), -1e30, jnp.float32)

    def step(st, inp):
        zt, it, ft, ot = inp
        st = _slstm_step(p, (zt, it, ft, ot, rz), st)
        return st, st["h"]

    _, hs = jax.lax.scan(step, st0,
                         (z.swapaxes(0, 1), i.swapaxes(0, 1),
                          f.swapaxes(0, 1), o.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(x.dtype) @ p["wo"]


def slstm_decode(p: dict, x: Array, cache: dict, cfg
                 ) -> tuple[Array, dict]:
    z = (x @ p["w_z"]).astype(jnp.float32)[:, 0]
    i = (x @ p["w_i"]).astype(jnp.float32)[:, 0]
    f = jax.nn.log_sigmoid((x @ p["w_f"]).astype(jnp.float32))[:, 0]
    o = (x @ p["w_o"]).astype(jnp.float32)[:, 0]
    st = _slstm_step(p, (z, i, f, o, p["r_z"].astype(jnp.float32)), cache)
    return (st["h"][:, None].astype(x.dtype)) @ p["wo"], st
