"""LM assembly: block patterns, scan-over-layers, train/prefill/decode.

Layer stacking: each architecture is described by a repeating block
pattern (e.g. ("rec","rec","attn") for RecurrentGemma's 1:2 ratio).  The
repeated section is stacked and driven by jax.lax.scan (compact HLO,
essential for 61-layer dry-run compiles); non-repeating head/tail layers
are unrolled.  The scan body is wrapped in jax.checkpoint (remat) for
training.

Modes:
  train   — full-sequence forward, logits + CE loss
  prefill — full-sequence forward that also materializes the KV/state
            caches (the inference-prefill dry-run cells)
  decode  — one token against a seq_len cache (inference-decode cells)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn
from . import moe as moe_lib
from . import recurrent as rec
from .layers import ParamSpec, layernorm, mlp_apply, mlp_specs, rmsnorm

Array = jax.Array
BATCH_AXES = ("pod", "data")


# ---------------------------------------------------------------------------
# Pattern derivation
# ---------------------------------------------------------------------------

def layer_layout(cfg) -> tuple[list[str], list[str], int, list[str]]:
    """-> (head_kinds, pattern, n_rep, tail_kinds)."""
    if cfg.is_encoder_decoder:
        return [], ["xattn"], cfg.n_layers, []
    if cfg.block_pattern:
        pat = list(cfg.block_pattern)
        n_rep, rem = divmod(cfg.n_layers, len(pat))
        return [], pat, n_rep, pat[:rem]
    if cfg.n_experts:
        fd = cfg.first_dense_layers
        return ["attn"] * fd, ["moe"], cfg.n_layers - fd, []
    return [], ["attn"], cfg.n_layers, []


# ---------------------------------------------------------------------------
# Per-block specs / apply
# ---------------------------------------------------------------------------

def _norm_specs(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"g": ParamSpec((d,), P(None), jnp.float32, "ones"),
                "b": ParamSpec((d,), P(None), jnp.float32, "zeros")}
    return {"g": ParamSpec((d,), P(None), jnp.float32, "ones")}


def _norm(p, x, cfg):
    if "b" in p:
        return layernorm(x, p["g"], p["b"])
    return rmsnorm(x, p["g"])


def _attn_specs(cfg):
    return attn.mla_specs(cfg) if cfg.attention == "mla" \
        else attn.gqa_specs(cfg)


def block_specs(cfg, kind: str) -> dict:
    sp: dict[str, Any] = {"ln1": _norm_specs(cfg)}
    if kind in ("attn", "attn_local", "enc_attn"):
        sp["attn"] = _attn_specs(cfg)
        sp["ln2"] = _norm_specs(cfg)
        sp["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp)
    elif kind == "moe":
        sp["attn"] = _attn_specs(cfg)
        sp["ln2"] = _norm_specs(cfg)
        sp["moe"] = moe_lib.moe_specs(cfg)
    elif kind == "xattn":            # decoder block with cross-attention
        sp["attn"] = _attn_specs(cfg)
        sp["ln_x"] = _norm_specs(cfg)
        sp["xattn"] = attn.gqa_specs(cfg, cross=True)
        sp["ln2"] = _norm_specs(cfg)
        sp["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp)
    elif kind == "rec":
        sp["rec"] = rec.rglru_block_specs(cfg)
        sp["ln2"] = _norm_specs(cfg)
        sp["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp)
    elif kind == "mlstm":
        sp["core"] = rec.mlstm_specs(cfg)
    elif kind == "slstm":
        sp["core"] = rec.slstm_specs(cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return sp


def block_cache_shape(cfg, kind: str, batch: int, max_seq: int) -> dict:
    if kind in ("attn", "attn_local", "moe"):
        if cfg.attention == "mla":
            return attn.mla_cache_shape(cfg, batch, max_seq)
        if kind == "attn_local" or (kind == "attn"
                                    and cfg.attention == "local"):
            # ring buffer: local attention only ever sees the last
            # `window` keys, so the cache is O(window) not O(seq) —
            # this is what makes 524k-context decode feasible.
            return attn.gqa_cache_shape(cfg, batch,
                                        min(cfg.window, max_seq))
        return attn.gqa_cache_shape(cfg, batch, max_seq)
    if kind == "xattn":
        c = attn.gqa_cache_shape(cfg, batch, max_seq)
        enc = attn.gqa_cache_shape(cfg, batch, cfg.enc_seq)
        return {"self": c, "cross_k": enc["k"], "cross_v": enc["v"]}
    if kind == "rec":
        return rec.rglru_cache_shape(cfg, batch)
    if kind == "mlstm":
        return rec.mlstm_cache_shape(cfg, batch)
    if kind == "slstm":
        return rec.slstm_cache_shape(cfg, batch)
    raise ValueError(kind)


def _attn_kind(cfg, kind: str) -> str:
    if kind == "enc_attn":
        return "full"
    if kind == "attn_local":
        return "local"
    if kind == "attn" and cfg.attention == "local":
        return "local"
    return "causal"


def apply_block(p: dict, x: Array, cfg, kind: str, *, positions=None,
                mode: str = "train", cache=None, pos=None, enc_out=None):
    """Returns (x_new, new_cache)."""
    h = _norm(p["ln1"], x, cfg)
    new_cache = cache

    if kind in ("attn", "attn_local", "moe", "enc_attn", "xattn"):
        akind = _attn_kind(cfg, kind)
        if mode == "decode":
            if cfg.attention == "mla":
                a, new_cache = attn.mla_decode(p["attn"], h, cache
                                               if kind != "xattn"
                                               else cache["self"],
                                               cfg, pos=pos)
            else:
                c = cache if kind != "xattn" else cache["self"]
                a, c_new = attn.gqa_decode(p["attn"], h, c, cfg, pos=pos,
                                           kind=akind,
                                           use_rope=cfg.use_rope)
                new_cache = c_new
            if kind == "xattn":
                new_cache = dict(cache, self=new_cache)
        else:
            if cfg.attention == "mla":
                a = attn.mla_fwd(p["attn"], h, cfg, positions=positions)
            else:
                a = attn.gqa_fwd(p["attn"], h, cfg, positions=positions,
                                 kind=akind, use_rope=cfg.use_rope)
            if mode == "prefill":
                new_cache = _prefill_cache(p["attn"], h, cfg, positions)
                if kind == "xattn":
                    new_cache = {"self": new_cache}
        x = x + a
        if kind == "xattn":
            hx = _norm(p["ln_x"], x, cfg)
            if mode == "decode":
                B = x.shape[0]
                H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
                q = (hx @ p["xattn"]["wq"]).reshape(B, 1, H, hd)
                ck, cv = cache["cross_k"], cache["cross_v"]
                qg = q.reshape(B, Hkv, H // Hkv, hd).astype(jnp.float32)
                s = jnp.einsum("bkgh,bskh->bkgs", qg,
                               ck.astype(jnp.float32)) * (hd ** -0.5)
                w = jax.nn.softmax(s, axis=-1)
                o = jnp.einsum("bkgs,bskh->bkgh", w,
                               cv.astype(jnp.float32))
                a = o.reshape(B, 1, H * hd).astype(x.dtype) \
                    @ p["xattn"]["wo"]
            else:
                a = attn.gqa_fwd(p["xattn"], hx, cfg, positions=positions,
                                 kind="full", kv_x=enc_out, use_rope=False)
                if mode == "prefill":
                    B = x.shape[0]
                    Hkv, hd = cfg.n_kv_heads, cfg.head_dim
                    Se = enc_out.shape[1]
                    ck = (enc_out @ p["xattn"]["wk"]).reshape(
                        B, Se, Hkv, hd).astype(jnp.bfloat16)
                    cv = (enc_out @ p["xattn"]["wv"]).reshape(
                        B, Se, Hkv, hd).astype(jnp.bfloat16)
                    new_cache = dict(new_cache, cross_k=ck, cross_v=cv)
            x = x + a
        h2 = _norm(p["ln2"], x, cfg)
        if kind == "moe":
            f = moe_lib.moe_apply(p["moe"], h2, cfg, act=cfg.act)
        else:
            f = mlp_apply(p["mlp"], h2, cfg.act)
        return x + f, new_cache

    if kind == "rec":
        if mode == "decode":
            r, new_cache = rec.rglru_block_decode(p["rec"], h, cache, cfg)
        else:
            r = rec.rglru_block_fwd(p["rec"], h, cfg)
            if mode == "prefill":
                new_cache = _rec_prefill_cache(p["rec"], h, cfg)
        x = x + r
        h2 = _norm(p["ln2"], x, cfg)
        return x + mlp_apply(p["mlp"], h2, cfg.act), new_cache

    if kind in ("mlstm", "slstm"):
        mod = rec.mlstm_decode if kind == "mlstm" else rec.slstm_decode
        fwd = rec.mlstm_fwd if kind == "mlstm" else rec.slstm_fwd
        if mode == "decode":
            r, new_cache = mod(p["core"], h, cache, cfg)
        else:
            r = fwd(p["core"], h, cfg)
            if mode == "prefill":
                new_cache = _xlstm_prefill_cache(p["core"], h, cfg, kind)
        return x + r, new_cache

    raise ValueError(kind)


def _prefill_cache(p, h, cfg, positions):
    """Recompute K/V (cheap projections) to fill the decode cache."""
    B, S, _ = h.shape
    if cfg.attention == "mla":
        from .layers import rmsnorm as _rms
        kvr = cfg.kv_lora_rank
        kv_a = h @ p["wkv_a"]
        c_kv = _rms(kv_a[..., :kvr], p["kv_norm"])
        k_rope = attn.apply_rope(kv_a[..., kvr:][:, :, None, :], positions,
                                 cfg.rope_theta)[:, :, 0, :]
        return {"c_kv": c_kv.astype(jnp.bfloat16),
                "k_rope": k_rope.astype(jnp.bfloat16)}
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = (h @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (h @ p["wv"]).reshape(B, S, Hkv, hd)
    if cfg.use_rope:
        k = attn.apply_rope(k, positions, cfg.rope_theta)
    if cfg.attention == "local" and S > cfg.window:
        # ring cache: keep the last `window` keys, laid out at slot
        # (abs_pos % window) so decode's pos%W writes line up.
        W = cfg.window
        k, v = k[:, -W:], v[:, -W:]
        slots = (jnp.arange(S - W, S)) % W
        inv = jnp.argsort(slots)
        k, v = k[:, inv], v[:, inv]
    return {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}


def _rec_prefill_cache(p, h, cfg):
    xb = h @ p["w_x"]
    xb_c, conv_state = rec._causal_conv(xb, p["conv_w"], p["conv_b"])
    ga = xb_c @ p["gate_a_w"]
    gx = xb_c @ p["gate_x_w"]
    h0 = jnp.zeros((h.shape[0], cfg.rglru_dim), jnp.float32)
    _, h_last = rec._rglru_scan(xb_c, rec._a_log(p["a_param"]), ga, gx, h0)
    return {"h": h_last.astype(jnp.float32),
            "conv": conv_state.astype(jnp.bfloat16)}


def _xlstm_prefill_cache(p, h, cfg, kind):
    # run the decode recurrence over the sequence to obtain final state
    B, S, d = h.shape
    shp = (rec.mlstm_cache_shape if kind == "mlstm"
           else rec.slstm_cache_shape)(cfg, B)
    st = jax.tree.map(
        lambda s: (jnp.full(s.shape, -1e30, s.dtype)
                   if kind == "slstm" and False else
                   jnp.zeros(s.shape, s.dtype)), shp)
    if kind == "slstm":
        st["m"] = jnp.full_like(st["m"], -1e30)

    step_fn = rec.mlstm_decode if kind == "mlstm" else rec.slstm_decode

    def step(st, xt):
        _, st = step_fn(p, xt[:, None, :], st, cfg)
        return st, None

    st, _ = jax.lax.scan(step, st, h.swapaxes(0, 1))
    return st


# ---------------------------------------------------------------------------
# Full-model specs
# ---------------------------------------------------------------------------

def param_specs(cfg) -> dict:
    head, pat, n_rep, tail = layer_layout(cfg)
    sp: dict[str, Any] = {
        "embed": ParamSpec((cfg.padded_vocab, cfg.d_model),
                           P(None, "model"), scale=0.02),
        "final_norm": _norm_specs(cfg),
        "lm_head": ParamSpec((cfg.d_model, cfg.padded_vocab),
                             P(None, "model"), scale=0.02),
    }
    if cfg.learned_pos:
        sp["pos_embed"] = ParamSpec((cfg.max_seq, cfg.d_model),
                                    P(None, None), scale=0.02)
    sp["head_blocks"] = [block_specs(cfg, k) for k in head]
    if n_rep:
        stacked = {str(i): block_specs(cfg, k) for i, k in enumerate(pat)}
        sp["blocks"] = jax.tree.map(
            lambda s: ParamSpec((n_rep,) + s.shape,
                                P(*((None,) + tuple(s.pspec))), s.dtype,
                                s.init, s.scale),
            stacked, is_leaf=lambda x: isinstance(x, ParamSpec))
    sp["tail_blocks"] = [block_specs(cfg, k) for k in tail]
    if cfg.is_encoder_decoder:
        sp["enc_blocks"] = [block_specs(cfg, "enc_attn")
                            for _ in range(cfg.n_enc_layers)]
        sp["enc_norm"] = _norm_specs(cfg)
        if cfg.learned_pos:
            sp["enc_pos"] = ParamSpec((cfg.enc_seq, cfg.d_model),
                                      P(None, None), scale=0.02)
    return sp


def cache_shapes(cfg, batch: int, max_seq: int) -> dict:
    head, pat, n_rep, tail = layer_layout(cfg)
    out: dict[str, Any] = {
        "head": [block_cache_shape(cfg, k, batch, max_seq) for k in head],
        "tail": [block_cache_shape(cfg, k, batch, max_seq) for k in tail],
    }
    if n_rep:
        per = {str(i): block_cache_shape(cfg, k, batch, max_seq)
               for i, k in enumerate(pat)}
        out["blocks"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_rep,) + s.shape, s.dtype), per)
    else:
        out["blocks"] = {}
    return out


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _shard_act(x, cfg=None):
    from repro.sharding import constrain
    axes = cfg.batch_axes if cfg is not None else BATCH_AXES
    if cfg is not None and cfg.shard_resid and cfg.layout != "fsdp":
        # sequence-parallel-style residual: the remat'd layer-boundary
        # activations shard over 'model' too, or 61 layers of (B,S,d)
        # bf16 at d=7168 cannot fit HBM (EXPERIMENTS.md SPerf, kimi)
        return constrain(x, axes, *([None] * (x.ndim - 2)), "model")
    return constrain(x, axes, *([None] * (x.ndim - 1)))


def _embed(params, tokens, cfg, *, pos_offset=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.learned_pos:
        S = tokens.shape[1]
        off = 0 if pos_offset is None else pos_offset
        pe = jax.lax.dynamic_slice_in_dim(
            params["pos_embed"].astype(x.dtype), off, S, axis=0)
        x = x + pe[None]
    return x.astype(cfg.dtype)


def encoder_fwd(params, frames, cfg):
    """frames: (B, enc_seq, d) precomputed stub embeddings."""
    x = frames.astype(cfg.dtype)
    if cfg.learned_pos:
        x = x + params["enc_pos"][None, :x.shape[1]].astype(cfg.dtype)
    positions = jnp.arange(x.shape[1])
    for bp in params["enc_blocks"]:
        x, _ = apply_block(bp, x, cfg, "enc_attn", positions=positions)
    return _norm(params["enc_norm"], x, cfg)


def forward(params, tokens, cfg, *, mode: str = "train", cache=None,
            pos=None, enc_out=None, extra_embeds=None):
    """tokens: (B,S) int32 (S=1 for decode).  Returns (logits, cache)."""
    head, pat, n_rep, tail = layer_layout(cfg)
    if cfg.is_encoder_decoder:
        head, pat, n_rep, tail = [], ["xattn"], cfg.n_layers, []

    x = _embed(params, tokens, cfg,
               pos_offset=pos if mode == "decode" else None)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    x = _shard_act(x, cfg)
    S = x.shape[1]
    positions = jnp.arange(S) if mode != "decode" else None

    new_head_caches, new_tail_caches = [], []
    for i, (kind, bp) in enumerate(zip(head, params["head_blocks"])):
        c = cache["head"][i] if cache is not None else None
        x, c = apply_block(bp, x, cfg, kind, positions=positions, mode=mode,
                           cache=c, pos=pos, enc_out=enc_out)
        new_head_caches.append(c)

    if n_rep:
        def superblock(carry, xs):
            x = carry
            bp, c_in = xs
            c_out = {}
            for i, kind in enumerate(pat):
                ci = c_in[str(i)] if c_in is not None else None
                x, cn = apply_block(bp[str(i)], x, cfg, kind,
                                    positions=positions, mode=mode,
                                    cache=ci, pos=pos, enc_out=enc_out)
                c_out[str(i)] = cn if cn is not None else 0
            return x, c_out

        body = superblock
        if mode == "train" and cfg.remat:
            body = jax.checkpoint(superblock)
        blk_cache = cache["blocks"] if cache is not None else None
        if cfg.unroll_layers:
            # HLO counting mode: python loop so cost_analysis sees every
            # layer (XLA:CPU counts a while body once; see dryrun.py)
            outs = []
            for r in range(n_rep):
                bp = jax.tree.map(lambda t: t[r], params["blocks"])
                ci = (jax.tree.map(lambda t: t[r], blk_cache)
                      if blk_cache is not None else None)
                x, co = body(x, (bp, ci))
                outs.append(co)
            new_blk_cache = (jax.tree.map(
                lambda *ls: jnp.stack(ls), *outs)
                if mode in ("prefill", "decode") else {})
        elif blk_cache is None:
            # scan requires real arrays; pass params only and thread None
            x, new_blk_cache = jax.lax.scan(
                lambda c, bp: body(c, (bp, None)), x, params["blocks"])
        else:
            x, new_blk_cache = jax.lax.scan(
                lambda c, xs: body(c, xs), x,
                (params["blocks"], blk_cache))
    else:
        new_blk_cache = {}

    for i, (kind, bp) in enumerate(zip(tail, params["tail_blocks"])):
        c = cache["tail"][i] if cache is not None else None
        x, c = apply_block(bp, x, cfg, kind, positions=positions, mode=mode,
                           cache=c, pos=pos, enc_out=enc_out)
        new_tail_caches.append(c)

    from repro.sharding import constrain
    x = _norm(params["final_norm"], x, cfg)
    logits = x @ params["lm_head"].astype(cfg.dtype)
    logits = constrain(logits, cfg.batch_axes, None,
                       None if cfg.layout == "fsdp" else "model")
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"head": new_head_caches, "blocks": new_blk_cache,
                     "tail": new_tail_caches}
    return logits, new_cache


def lm_loss(logits: Array, labels: Array, mask: Optional[Array] = None
            ) -> Array:
    """Cross-entropy in f32; labels (B,S) int32; mask (B,S) optional."""
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
