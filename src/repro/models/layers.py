"""Shared building blocks: param specs, norms, MLPs, embeddings, RoPE."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


# ---------------------------------------------------------------------------
# Parameter specification: one source of truth for shapes, dtypes, sharding
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    pspec: P
    dtype: Any = jnp.bfloat16
    init: str = "normal"       # normal | zeros | ones
    scale: float | None = None  # stddev; default 1/sqrt(fan_in)

    def initializer(self, key: Array) -> Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[0] if len(self.shape) > 1 else self.shape[-1]
        std = self.scale if self.scale is not None else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, self.shape, jnp.float32)
                * std).astype(self.dtype)


def materialize(specs, key: Array):
    """specs: pytree of ParamSpec -> pytree of initialized arrays."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [s.initializer(k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract(specs, mesh=None):
    """specs -> pytree of ShapeDtypeStruct (with NamedSharding if mesh)."""
    from jax.sharding import NamedSharding

    def conv(s: ParamSpec):
        if mesh is None:
            return jax.ShapeDtypeStruct(s.shape, s.dtype)
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, s.pspec))
    return jax.tree.map(conv, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def pspecs_of(specs):
    """specs -> pytree of PartitionSpec (for in_shardings)."""
    return jax.tree.map(lambda s: s.pspec, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Norms / activations / MLP
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * scale) * gamma.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: Array, gamma: Array, beta: Array,
              eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)
            + beta.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str) -> Callable[[Array], Array]:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def mlp_specs(d: int, ff: int, *, gated: bool = True,
              dtype=jnp.bfloat16) -> dict:
    """SwiGLU (gated) or plain 2-layer MLP.  TP: ff sharded over 'model'."""
    sp = {"w_up": ParamSpec((d, ff), P(None, "model"), dtype),
          "w_down": ParamSpec((ff, d), P("model", None), dtype)}
    if gated:
        sp["w_gate"] = ParamSpec((d, ff), P(None, "model"), dtype)
    return sp


def mlp_apply(p: dict, x: Array, act: str = "silu") -> Array:
    a = act_fn(act)
    if "w_gate" in p:
        h = a(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = a(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 1e4) -> Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                             # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
