"""Neural building blocks kept alongside the GLM core.

Attention, gated-recurrent (RG-LRU), MoE, and small LM assemblies used
by the non-GLM benchmarks and kernel exercises; independent of the
CoCoA+/SDCA training path.
"""
