"""Attention variants: GQA/MQA full, sliding-window (local), MLA, cross.

All softmax math in f32.  Prefill/training uses an online-softmax blocked
formulation (lax.scan over KV chunks) so the 32k-prefill cells never
materialize (S x S) score tensors.  Decode is one-token with a KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import ParamSpec, apply_rope

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blocked (online-softmax) grouped attention core
# ---------------------------------------------------------------------------

def attention(q: Array, k: Array, v: Array, *, q_positions: Array,
              kind: str = "causal", window: int = 0,
              kv_len: Optional[Array] = None, chunk: int = 512,
              use_flash: bool = True) -> Array:
    """Dispatch: Pallas flash kernel on TPU (tile-skipped causal, VMEM
    online softmax — see kernels/flash_attention.py), jnp blocked
    online-softmax elsewhere (and under cross-attention padding masks,
    which the kernel does not need: it masks by true kv length)."""
    if use_flash and jax.default_backend() == "tpu" and kv_len is None:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, kind=kind, window=window)
    return blocked_attention(q, k, v, q_positions=q_positions, kind=kind,
                             window=window, kv_len=kv_len, chunk=chunk)


def blocked_attention(q: Array, k: Array, v: Array, *,
                      q_positions: Array, kind: str = "causal",
                      window: int = 0, kv_len: Optional[Array] = None,
                      chunk: int = 512) -> Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, Hkv, hd); grouped heads (H % Hkv == 0).

    kind: causal | local (causal within `window`) | full (bidirectional).
    kv_len: optional (B,) valid KV length (cross attention padding).
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]          # may differ from hd (MLA: qk=nope+rope, v=vd)
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32)
    scale = hd ** -0.5
    chunk = min(chunk, Sk)
    if Sk % chunk:              # pad KV to a chunk multiple; mask the tail
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = jnp.full((B,), Sk, jnp.int32)
        Sk = Sk + pad
    n_chunks = Sk // chunk

    kc = k.reshape(B, n_chunks, chunk, Hkv, hd)
    vc = v.reshape(B, n_chunks, chunk, Hkv, hd_v)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, c_idx = inp
        kpos = c_idx * chunk + jnp.arange(chunk)            # (chunk,)
        s = jnp.einsum("bqkgh,bckh->bkgqc", qg,
                       kb.astype(jnp.float32)) * scale      # (B,Hkv,G,Sq,c)
        if kind == "causal":
            ok = q_positions[:, None] >= kpos[None, :]
        elif kind == "local":
            dist = q_positions[:, None] - kpos[None, :]
            ok = (dist >= 0) & (dist < window)
        else:
            ok = jnp.ones((Sq, chunk), bool)
        ok = jnp.broadcast_to(ok, (B, Sq, chunk))
        if kv_len is not None:
            ok = ok & (kpos[None, None, :] < kv_len[:, None, None])
        s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqc,bckh->bkgqh", p, vb.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
         jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd_v).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA / MQA / local attention layer
# ---------------------------------------------------------------------------

def gqa_specs(cfg, *, cross: bool = False) -> dict:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sp = {
        "wq": ParamSpec((d, H * hd), P(None, "model")),
        "wk": ParamSpec((d, Hkv * hd), P(None, "model")),
        "wv": ParamSpec((d, Hkv * hd), P(None, "model")),
        "wo": ParamSpec((H * hd, d), P("model", None)),
    }
    return sp


def gqa_fwd(p: dict, x: Array, cfg, *, positions: Array,
            kind: str = "causal", kv_x: Optional[Array] = None,
            use_rope: bool = True) -> Array:
    """Full-sequence forward (training / prefill).  kv_x for cross-attn."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (src @ p["wk"]).reshape(B, src.shape[1], Hkv, hd)
    v = (src @ p["wv"]).reshape(B, src.shape[1], Hkv, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if kv_x is None:
            k = apply_rope(k, positions, cfg.rope_theta)
    out = attention(q, k, v, q_positions=positions, kind=kind,
                    window=cfg.window, chunk=cfg.attn_chunk)
    return out.reshape(B, S, H * hd) @ p["wo"]


def gqa_cache_shape(cfg, batch: int, max_seq: int) -> dict:
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim
    shp = (batch, max_seq, Hkv, hd)
    return {"k": jax.ShapeDtypeStruct(shp, jnp.bfloat16),
            "v": jax.ShapeDtypeStruct(shp, jnp.bfloat16)}


def gqa_decode(p: dict, x: Array, cache: dict, cfg, *, pos: Array,
               kind: str = "causal", use_rope: bool = True
               ) -> tuple[Array, dict]:
    """x: (B, 1, d); cache k/v: (B, Smax, Hkv, hd); pos: scalar int32.

    Local attention uses a RING cache: when Smax <= window the slot is
    pos % Smax and the ring itself enforces the window (O(window) memory
    at any context length); a larger cache falls back to masked lookup.
    """
    B, _, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Smax = cache["k"].shape[1]
    ring = kind == "local" and Smax <= cfg.window
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    k = (x @ p["wk"]).reshape(B, 1, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, 1, Hkv, hd)
    if use_rope:
        pp = pos[None] if pos.ndim == 0 else pos
        q = apply_rope(q, pp.reshape(1, 1), cfg.rope_theta)
        k = apply_rope(k, pp.reshape(1, 1), cfg.rope_theta)
    slot = jax.lax.rem(pos, Smax) if ring else pos
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)

    kpos = jnp.arange(Smax)
    # ring: every slot holds one of the last Smax(<=window) keys once
    # pos >= Smax-1, and `kpos <= pos` is then all-true; before that,
    # slots above pos are unwritten and masked — same predicate.
    ok = kpos <= pos
    if kind == "local" and not ring:
        ok &= kpos > pos - cfg.window
    qg = q.reshape(B, Hkv, H // Hkv, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qg,
                   ck.astype(jnp.float32)) * (hd ** -0.5)
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", w, cv.astype(jnp.float32))
    o = o.reshape(B, 1, H * hd).astype(x.dtype)
    return o @ p["wo"], {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------

def mla_specs(cfg) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    sp = {
        "wkv_a": ParamSpec((d, kvr + rd), P(None, None)),
        "kv_norm": ParamSpec((kvr,), P(None), jnp.float32, "ones"),
        "wkv_b": ParamSpec((kvr, H * (nd + vd)), P(None, "model")),
        "wo": ParamSpec((H * vd, d), P("model", None)),
    }
    if qr:
        sp["wq_a"] = ParamSpec((d, qr), P(None, None))
        sp["q_norm"] = ParamSpec((qr,), P(None), jnp.float32, "ones")
        sp["wq_b"] = ParamSpec((qr, H * (nd + rd)), P(None, "model"))
    else:
        sp["wq"] = ParamSpec((d, H * (nd + rd)), P(None, "model"))
    return sp


def _mla_q(p, x, cfg):
    from .layers import rmsnorm
    B, S, _ = x.shape
    H = cfg.n_heads
    nd, rd = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        q = rmsnorm(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    return q.reshape(B, S, H, nd + rd)


def mla_fwd(p: dict, x: Array, cfg, *, positions: Array) -> Array:
    """Training/prefill: materialize per-head K/V from the latent."""
    from .layers import rmsnorm
    B, S, d = x.shape
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank

    q = _mla_q(p, x, cfg)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"]                              # (B,S,kvr+rd)
    c_kv = rmsnorm(kv_a[..., :kvr], p["kv_norm"])
    k_rope = apply_rope(kv_a[..., kvr:][:, :, None, :], positions,
                        cfg.rope_theta)                # (B,S,1,rd) shared
    kv = (c_kv @ p["wkv_b"]).reshape(B, S, H, nd + vd)
    k_nope, v = kv[..., :nd], kv[..., nd:]

    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rd))], axis=-1)
    # match standard MLA scaling: 1/sqrt(nd + rd)
    out = attention(qf, kf, v, q_positions=positions, kind="causal",
                    chunk=cfg.attn_chunk)
    return out.reshape(B, S, H * vd) @ p["wo"]


def mla_cache_shape(cfg, batch: int, max_seq: int) -> dict:
    return {
        "c_kv": jax.ShapeDtypeStruct(
            (batch, max_seq, cfg.kv_lora_rank), jnp.bfloat16),
        "k_rope": jax.ShapeDtypeStruct(
            (batch, max_seq, cfg.qk_rope_dim), jnp.bfloat16),
    }


def mla_decode(p: dict, x: Array, cache: dict, cfg, *, pos: Array
               ) -> tuple[Array, dict]:
    """Latent (absorbed) decode: attention runs in the kv_lora space."""
    from .layers import rmsnorm
    B, _, d = x.shape
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    Smax = cache["c_kv"].shape[1]

    q = _mla_q(p, x, cfg)                                # (B,1,H,nd+rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    pp = pos.reshape(1, 1)
    q_rope = apply_rope(q_rope, pp, cfg.rope_theta)

    kv_a = x @ p["wkv_a"]
    c_new = rmsnorm(kv_a[..., :kvr], p["kv_norm"])       # (B,1,kvr)
    kr_new = apply_rope(kv_a[..., kvr:][:, :, None, :], pp,
                        cfg.rope_theta)[:, :, 0, :]      # (B,1,rd)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=1)

    wkv_b = p["wkv_b"].reshape(kvr, H, nd + vd)
    w_uk, w_uv = wkv_b[..., :nd], wkv_b[..., nd:]        # (kvr,H,nd/vd)
    # absorb W_uk into q: q_lat (B,H,kvr)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    s = (jnp.einsum("bhr,bsr->bhs", q_lat,
                    c_kv.astype(jnp.float32))
         + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                      k_rope.astype(jnp.float32)))
    s *= (nd + rd) ** -0.5
    ok = jnp.arange(Smax) <= pos
    s = jnp.where(ok[None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", w, c_kv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv.astype(jnp.float32))
    o = o.reshape(B, 1, H * vd).astype(x.dtype)
    return o @ p["wo"], {"c_kv": c_kv, "k_rope": k_rope}
