"""Pallas TPU kernel: one worker's bucketed SDCA sub-epoch.

This is the paper's cache-line bucket, re-blocked for the TPU memory
hierarchy (DESIGN.md S2/S6):

  * the shared-vector replica v (d_pad x 1) is pinned in VMEM for the
    whole sub-epoch via input/output aliasing + a constant index map —
    the VMEM analogue of the paper keeping the hot state cache-resident;
  * each grid step streams ONE bucket tile X_b (d_pad x B) HBM->VMEM and
    uses it three times (margins, Gram, v-update) — one HBM pass where
    the unbucketed algorithm does B strided passes;
  * margins + Gram go through the MXU (two matmuls), the in-bucket
    recursion is O(B^2) scalar work on VMEM-resident vectors.

Grid is 1-D over buckets with "arbitrary" dimension semantics: buckets
are processed IN ORDER, which is what makes the kernel bit-equivalent to
sequential SDCA over the same visiting order.

d_pad must be a multiple of 8 (f32 sublane tile); B a multiple of 8 and
<= 512.  Zero-padded feature rows are harmless (they contribute 0 to
every inner product).  Scalars (lam*n, sigma') ride in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.objectives import Objective
from .pallas_compat import compiler_params as _compiler_params

Array = jax.Array

#: Largest bucket the in-bucket Gram recursion supports (docstring
#: contract above; beyond this the (B, B) Gram + serial recursion stop
#: paying for themselves anyway).
MAX_BUCKET = 512

#: Total VMEM the kernel's buffers may claim together — same budget
#: discipline as sdca_sparse_bucket.TOTAL_VMEM_BUDGET_BYTES: exceeding
#: VMEM inside Mosaic is an opaque OOM, not a Python error.
TOTAL_VMEM_BUDGET_BYTES = 14 * 2 ** 20


def vmem_bytes_estimate(B: int, d_pad: int) -> int:
    """Upper-bound VMEM footprint of one grid step: the resident v,
    the double-buffered (d_pad, B) bucket tile, and the (B, B) Gram.
    Shared with `ops.dense_kernel_misfit` so the "auto" path can
    pre-check static shapes and fall back instead of raising."""
    v = d_pad * 4
    tiles = 2 * d_pad * B * 4
    gram = B * B * 4
    return v + tiles + gram


def _kernel(obj: Objective, x_ref, y_ref, a_ref, scal_ref, v_ref,
            aout_ref, vout_ref):
    """Body for one bucket (one grid step)."""
    first = pl.program_id(0) == 0

    # v lives in the aliased output block; seed it from the input once.
    @pl.when(first)
    def _():
        vout_ref[...] = v_ref[...]

    x = x_ref[0].astype(jnp.float32)            # (d_pad, B)
    y = y_ref[0].astype(jnp.float32)            # (B,)
    a0 = a_ref[0].astype(jnp.float32)           # (B,)
    lam_n = scal_ref[0]
    sig = scal_ref[1]
    v = vout_ref[...]                           # (d_pad, 1) f32

    m0 = (x.T @ v)[:, 0]                        # (B,)   MXU
    G = x.T @ x                                 # (B,B)  MXU
    gdiag = jnp.diag(G)

    B = m0.shape[0]

    def body(i, carry):
        m, deltas = carry
        q = sig * jax.lax.dynamic_index_in_dim(gdiag, i, keepdims=False) \
            / lam_n
        mi = jax.lax.dynamic_index_in_dim(m, i, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(a0, i, keepdims=False)
        yi = jax.lax.dynamic_index_in_dim(y, i, keepdims=False)
        d = obj.delta(mi, ai, yi, q)
        grow = jax.lax.dynamic_slice_in_dim(G, i, 1, axis=0)[0]   # (B,)
        m = m + (sig * d / lam_n) * grow
        deltas = jax.lax.dynamic_update_index_in_dim(deltas, d, i, axis=0)
        return m, deltas

    _, deltas = jax.lax.fori_loop(0, B, body, (m0, jnp.zeros_like(m0)))

    vout_ref[...] = v + (sig / lam_n) * (x @ deltas[:, None])
    aout_ref[0] = (a0 + deltas).astype(aout_ref.dtype)


@functools.partial(jax.jit, static_argnums=(0, 6, 7))
def sdca_bucket_kernel(obj: Objective, xb: Array, yb: Array, ab: Array,
                       v0: Array, scal: Array, interpret: bool = False,
                       source: str = "ad-hoc arrays"
                       ) -> tuple[Array, Array]:
    """Run the sub-epoch kernel.

    xb: (nb, d_pad, B) bucket tiles in visiting order
    yb, ab: (nb, B);  v0: (d_pad, 1) f32;  scal: (2,) f32 = [lam*n, sigma']
    Returns (a_new (nb, B), v_final (d_pad, 1)).  v_final includes the
    sigma'-scaled local evolution (callers unscale the global delta).
    `source` names where the tiles came from (tile cache vs ad-hoc
    arrays) so alignment errors point at the right fix.
    """
    nb, d_pad, B = xb.shape
    if d_pad % 8 or B % 8:
        raise ValueError(
            f"dense bucket tiles from {source} have (d_pad={d_pad}, "
            f"B={B}); the Pallas kernel needs both to be multiples of 8 "
            f"(f32 sublane tile).  Fix: rebuild the tile cache at an "
            f"aligned bucket size for cached tiles, or route ad-hoc "
            f"arrays through ops.sdca_bucket_subepoch (it zero-pads "
            f"d and B automatically).")
    if B > MAX_BUCKET:
        raise ValueError(
            f"dense bucket tiles from {source} have B={B}; the kernel's "
            f"in-bucket Gram recursion supports B <= {MAX_BUCKET}.  Use "
            f"a smaller bucket, or local_solver='xla'.")
    need = vmem_bytes_estimate(B, d_pad)
    if need > TOTAL_VMEM_BUDGET_BYTES:
        raise ValueError(
            f"dense bucket tiles from {source} with (d_pad={d_pad}, "
            f"B={B}) need ~{need} bytes of VMEM (double-buffered tile "
            f"+ resident v + Gram), over the kernel's "
            f"{TOTAL_VMEM_BUDGET_BYTES}-byte total budget.  Use "
            f"local_solver='xla' (HBM-resident v) for this workload, "
            f"shard features, or shrink the bucket.")

    grid = (nb,)
    a_new, v_fin = pl.pallas_call(
        functools.partial(_kernel, obj),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d_pad, B), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, B), ab.dtype),
            jax.ShapeDtypeStruct((d_pad, 1), jnp.float32),
        ],
        input_output_aliases={4: 1},   # v0 buffer reused as v_final
        compiler_params=_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(xb, yb, ab, scal, v0)
    return a_new, v_fin
