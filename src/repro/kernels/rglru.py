"""Pallas TPU kernel: RG-LRU gated linear recurrence (Griffin / RecurrentGemma).

    r_t = sigmoid(gate_a_t);  i_t = sigmoid(gate_x_t)
    a_t = exp(c * a_log * r_t),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

TPU blocking: grid over time blocks; the hidden state h (1 x D tile)
stays VMEM-resident across grid steps (aliased accumulator, "arbitrary"
semantics), each grid step streams a (block_t x D) slab of x/gates
HBM->VMEM, fuses the gate math, and walks the recurrence with D-wide VPU
ops.  This is the same "pin the sequential hot state in fast memory,
stream the bulk data in blocks" shape as the sdca_bucket kernel — the
paper's central systems idea applied to the recurrence that makes the
hybrid/SSM architectures sub-quadratic at 500k context.

D must be a multiple of 128 (lane tile); block_t a multiple of 8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_compat import compiler_params as _compiler_params


_C = 8.0


def _kernel(x_ref, ga_ref, gx_ref, alog_ref, h0_ref, out_ref, h_ref):
    first = pl.program_id(0) == 0

    @pl.when(first)
    def _():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    x = x_ref[...].astype(jnp.float32)          # (bt, D)
    ga = ga_ref[...].astype(jnp.float32)
    gx = gx_ref[...].astype(jnp.float32)
    alog = alog_ref[...].astype(jnp.float32)    # (1, D)

    r = jax.nn.sigmoid(ga)
    i = jax.nn.sigmoid(gx)
    log_a = _C * alog * r                        # (bt, D), alog broadcasts
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x)

    bt = x.shape[0]

    def body(t, carry):
        h, out = carry
        at = jax.lax.dynamic_slice_in_dim(a, t, 1, axis=0)   # (1, D)
        bt_ = jax.lax.dynamic_slice_in_dim(b, t, 1, axis=0)
        h = at * h + bt_
        out = jax.lax.dynamic_update_slice_in_dim(out, h, t, axis=0)
        return h, out

    h, out = jax.lax.fori_loop(
        0, bt, body, (h_ref[...], jnp.zeros_like(x)))
    out_ref[...] = out.astype(out_ref.dtype)
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rglru_kernel(x, a_log, gate_a, gate_x, h0, *, block_t: int = 128,
                 interpret: bool = False):
    """x, gate_a, gate_x: (T, D); a_log: (D,); h0: (D,) -> h: (T, D)."""
    T, D = x.shape
    if T % block_t:
        raise ValueError(f"T={T} must divide by block_t={block_t}")
    if D % 128 and not interpret:
        raise ValueError(f"D={D} must be a multiple of 128 on TPU")
    grid = (T // block_t,)

    out, _ = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, D), lambda i: (i, 0)),
            pl.BlockSpec((block_t, D), lambda i: (i, 0)),
            pl.BlockSpec((block_t, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, D), x.dtype),
            jax.ShapeDtypeStruct((1, D), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, gate_a, gate_x, a_log.reshape(1, D), h0.reshape(1, D))
    return out
