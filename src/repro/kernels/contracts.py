"""Kernel contract registry: every Pallas entry point's guard rails.

Each live kernel entry point (a module-level function that issues a
``pallas_call``) registers the misfit predicate that routes infeasible
workloads away from it at trace time and the VMEM model that budgets
its footprint.  References are lazy ``"module:attr"`` strings so this
module stays stdlib-importable (the static-analysis lint layer reads
it without jax); `repro.analysis.lint.resolve_contract_refs` import-
checks them, and the LINT-KERNEL-CONTRACT rule fails the build when a
new pallas_call entry point lands unregistered.

Keys are ``<module-stem>.<function-name>``.  Quarantined seed kernels
(flash_attention, rglru — see `repro.analysis.config.QUARANTINE`) are
out of scope: they are not reachable from the solver paths.
"""
from __future__ import annotations

__all__ = ["KERNEL_CONTRACTS"]

KERNEL_CONTRACTS: dict[str, dict[str, str]] = {
    # dense bucket kernel: whole (d_pad, B) tiles + Gram recursion
    "sdca_bucket.sdca_bucket_kernel": {
        "misfit": "repro.kernels.ops:dense_kernel_misfit",
        "vmem_estimate": "repro.kernels.sdca_bucket:vmem_bytes_estimate",
    },
    # sparse replicated kernel: VMEM-resident v over CSR tiles
    "sdca_sparse_bucket.sdca_sparse_bucket_kernel": {
        "misfit": "repro.kernels.ops:sparse_kernel_misfit",
        "vmem_estimate":
            "repro.kernels.sdca_sparse_bucket:vmem_bytes_estimate",
    },
    # sharded-v pair (DESIGN.md S12): both halves of one bucket step
    # share the sharded feasibility predicate + footprint model
    "sdca_sparse_bucket.sdca_sparse_gather_bucket": {
        "misfit": "repro.kernels.ops:sparse_kernel_misfit",
        "vmem_estimate":
            "repro.kernels.sdca_sparse_bucket:vmem_bytes_estimate_sharded",
    },
    "sdca_sparse_bucket.sdca_sparse_sharded_bucket": {
        "misfit": "repro.kernels.ops:sparse_kernel_misfit",
        "vmem_estimate":
            "repro.kernels.sdca_sparse_bucket:vmem_bytes_estimate_sharded",
    },
}
