"""Pure-jnp oracles for the Pallas kernels.

Deliberately written as the NAIVE per-coordinate algorithm (no Gram
trick), so the kernel test also cross-validates the bucket/Gram
reformulation used everywhere else.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.objectives import Objective

Array = jax.Array


def sdca_subepoch_ref(obj: Objective, X: Array, y: Array, a: Array,
                      v0: Array, lam_n, sig) -> tuple[Array, Array]:
    """Per-coordinate sequential SDCA over columns of X (d, n_local).

    Returns (a_new, v_final) with v_final = v0 + sigma'/lam_n * X@(da).
    """
    X = X.astype(jnp.float32)
    y = y.astype(jnp.float32)
    a = a.astype(jnp.float32)
    lam_n = jnp.float32(lam_n)
    sig = jnp.float32(sig)

    def step(v, inp):
        x, yi, ai = inp
        m = jnp.vdot(x, v)
        q = sig * jnp.vdot(x, x) / lam_n
        d = obj.delta(m, ai, yi, q)
        return v + (sig * d / lam_n) * x, ai + d

    v1, a_new = jax.lax.scan(step, v0.astype(jnp.float32),
                             (X.T, y, a))
    return a_new, v1


def rglru_ref(x: Array, a_log: Array, gate_a: Array, gate_x: Array,
              h0: Array) -> Array:
    """RG-LRU linear recurrence oracle (see kernels/rglru.py).

    x, gate_a, gate_x: (T, D); a_log: (D,) base decay log(a) < 0;
    h0: (D,). Returns h: (T, D) with

        r_t  = sigmoid(gate_a_t);  i_t = sigmoid(gate_x_t)
        a_t  = exp(c * a_log * r_t)            (c = 8, per the paper)
        h_t  = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
    """
    c = 8.0

    def step(h, inp):
        xt, ga, gx = inp
        r = jax.nn.sigmoid(ga)
        i = jax.nn.sigmoid(gx)
        log_a = c * a_log * r
        at = jnp.exp(log_a)
        mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        h = at * h + mult * (i * xt)
        return h, h

    _, hs = jax.lax.scan(step, h0, (x, gate_a, gate_x))
    return hs
