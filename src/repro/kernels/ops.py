"""Jit'd wrappers around the Pallas kernels, with padding + CPU fallback.

`sdca_bucket_subepoch` is call-compatible with
`repro.core.sdca.dense_local_subepoch` so the epoch drivers can route
through the kernel with cfg.use_kernel=True.
"""
from __future__ import annotations


import weakref

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objectives import Objective
from . import sdca_bucket, sdca_sparse_bucket, rglru as _rglru


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


class MisfitCode:
    """Stable enum-style codes for kernel misfit reasons.

    The human-readable reason strings below are free to change
    wording; tools (the planner's `SolverPlan.reason_code`, BENCH json
    consumers, the static auditor's report) key on these instead.
    """
    BUCKET_INDIVISIBLE = "BUCKET_INDIVISIBLE"   # B does not divide n_local
    ALIGNMENT = "ALIGNMENT"                     # B/nnz off the sublane tile
    BUCKET_CAP = "BUCKET_CAP"                   # dense recursion cap B<=512
    VMEM_V = "VMEM_V"                           # resident v over budget
    VMEM_TOTAL = "VMEM_TOTAL"                   # total footprint over budget


class Misfit(str):
    """A misfit reason string carrying its stable `MisfitCode`.

    Subclasses ``str`` so every existing consumer (equality and
    substring assertions, `SolverPlan.reason`, log lines) sees the
    plain reason text; code-aware consumers read ``.code``.
    """
    __slots__ = ("code",)
    code: str

    def __new__(cls, code: str, text: str) -> "Misfit":
        self = super().__new__(cls, text)
        self.code = code
        return self


def sparse_slice_width(d: int, model_lanes: int) -> int:
    """Per-lane slice width d_loc of the feature-sharded sparse kernel.

    The ONE formula shared by the kernel driver
    (`sdca_sparse_sharded_subepoch`), the masked XLA twin
    (`engine.sparse_sharded_xla_solver`), and the analytic cost models:
    ceil(d_pad / M) rounded up to the f32 sublane tile.  Slices are
    contiguous, disjoint, and cover [0, d) because d_loc * M >= d_pad.
    """
    d_pad = _round_up(max(d, 8), 8)
    M = max(int(model_lanes), 1)
    return _round_up(-(-d_pad // M), 8)


def sparse_solver_plan(n_local: int, nnz: int, d: int, bucket: int, *,
                       model_lanes: int = 1) -> tuple[str, str | None]:
    """Data-parallel vs feature-parallel selection on static shapes.

    -> (route, reason): route is one of "pallas-replicated" (whole v in
    VMEM — the PR-4 kernel), "pallas-sharded" (each of `model_lanes`
    lanes owns a d/M slice of v), or "xla" (HBM-resident v scan), with
    `reason` the misfit string for "xla" routes and None otherwise.
    Prefers replicated (no per-bucket exchange) when v fits, mirroring
    LightGBM's data-parallel vs feature-parallel decision by
    #feature/#data shape (SNIPPETS.md Snippet 3) with VMEM budgets as
    the thresholds.  Mirrors the wrapper/kernel guards (bucket
    divisibility, B/nnz sublane alignment, VMEM budgets) so the
    engine's backend-picked "auto" path and launch/glm.py's layout
    default can route misfits at trace time instead of raising.
    """
    if bucket <= 0 or n_local % bucket:
        return "xla", Misfit(
            MisfitCode.BUCKET_INDIVISIBLE,
            f"bucket={bucket} does not divide n_local={n_local}")
    if bucket % 8 or nnz % 8:
        return "xla", Misfit(
            MisfitCode.ALIGNMENT,
            f"(B={bucket}, nnz={nnz}) must both be multiples of 8 "
            f"(f32 sublane tile)")
    d_pad = _round_up(max(d, 8), 8)
    M = max(int(model_lanes), 1)
    if (d_pad * 4 <= sdca_sparse_bucket.V_VMEM_BUDGET_BYTES
            and sdca_sparse_bucket.vmem_bytes_estimate(bucket, nnz, d_pad)
            <= sdca_sparse_bucket.TOTAL_VMEM_BUDGET_BYTES):
        return "pallas-replicated", None
    if M > 1:
        d_loc = sparse_slice_width(d, M)
        if (d_loc * 4 <= sdca_sparse_bucket.V_VMEM_BUDGET_BYTES
                and sdca_sparse_bucket.vmem_bytes_estimate_sharded(
                    bucket, nnz, d_loc)
                <= sdca_sparse_bucket.TOTAL_VMEM_BUDGET_BYTES):
            return "pallas-sharded", None
    if d_pad * 4 > sdca_sparse_bucket.V_VMEM_BUDGET_BYTES:
        text = (f"shared vector of d={d} features exceeds the "
                f"{sdca_sparse_bucket.V_VMEM_BUDGET_BYTES}-byte "
                f"resident-v VMEM budget")
        if M > 1:
            text += (f" (and its d/{M} model-axis slice does not fit "
                     f"the sharded kernel either)")
        reason = Misfit(MisfitCode.VMEM_V, text)
    else:
        need = sdca_sparse_bucket.vmem_bytes_estimate(bucket, nnz, d_pad)
        reason = Misfit(
            MisfitCode.VMEM_TOTAL,
            f"~{need}-byte VMEM footprint for (B={bucket}, "
            f"nnz={nnz}, d_pad={d_pad}) exceeds the "
            f"{sdca_sparse_bucket.TOTAL_VMEM_BUDGET_BYTES}-byte "
            f"total budget")
    return "xla", reason


def plan_solver(n: int, d: int, *, nnz: int = 0, sparse: bool = False,
                name: str = "", bucket: int | None = None,
                chunks: int | None = None,
                nnz_multiple: int | None = None, model_lanes: int = 1,
                streamed: bool = False, cache_dir=None, probe_fn=None):
    """System-aware geometry + route for a workload: -> `SolverPlan`.

    The kernels-side door into `core.planner` (DESIGN.md S13): builds
    the workload signature from (n, d, nnz, sparse), detects the live
    topology from the jax backend, and resolves a plan honoring
    ``$REPRO_PLAN`` (off | on | search | probe) with disk caching per
    (dataset fingerprint, topology) next to the tile cache.  Knobs
    passed explicitly (bucket/chunks/nnz_multiple) are never
    overridden — the planner only decides what was left open — and
    every emitted plan passes the misfit predicates above (the PR-4
    never-regress contract; any planner failure degrades warn-and-safe
    to the static resolution).

    ``streamed=True`` marks the workload as mesh-streamed (DESIGN.md
    S16): plan scoring adds the host->device ingest term
    (`planner.streamed_transfer_bytes` over the slow H2D link) and the
    disk-cache fingerprint gains a ``|st1`` suffix so streamed and
    resident plans never collide.
    """
    from repro.core import planner
    sig = planner.WorkloadSignature(n=int(n), d=int(d), nnz=int(nnz),
                                    sparse=bool(sparse), name=name,
                                    streamed=bool(streamed))
    topo = planner.Topology.detect(model_lanes=model_lanes)
    return planner.resolve_plan(sig, topo, bucket=bucket, chunks=chunks,
                                nnz_multiple=nnz_multiple,
                                cache_dir=cache_dir, probe_fn=probe_fn)


def sparse_kernel_misfit(n_local: int, nnz: int, d: int, bucket: int,
                         model_lanes: int = 1) -> str | None:
    """Why NO sparse Pallas kernel can run this workload, or None.

    The boolean view of `sparse_solver_plan`: None when either the
    replicated or (given `model_lanes` > 1) the sharded kernel fits —
    replicated-feasible shapes are always sharded-feasible too, the
    slice and its single-buffered tiles never outgrow the replicated
    footprint — so callers on a feature-sharded layout can use it as a
    sharded-feasibility verdict directly.
    """
    route, reason = sparse_solver_plan(n_local, nnz, d, bucket,
                                       model_lanes=model_lanes)
    return reason if route == "xla" else None


def dense_kernel_misfit(d: int, n_local: int, bucket: int) -> str | None:
    """Why the dense Pallas kernel CANNOT run this workload, or None.

    The dense wrapper below zero-pads d and B to sublane multiples, so
    the only hard misfits are bucket divisibility, the kernel's B cap,
    and the VMEM footprint of the padded tiles.  Used by the engine's
    backend-picked "auto" path, like `sparse_kernel_misfit`.
    """
    if bucket <= 0 or n_local % bucket:
        return Misfit(MisfitCode.BUCKET_INDIVISIBLE,
                      f"bucket={bucket} does not divide n_local={n_local}")
    B_pad = _round_up(max(bucket, 8), 8)
    if B_pad > sdca_bucket.MAX_BUCKET:
        return Misfit(MisfitCode.BUCKET_CAP,
                      f"bucket={bucket} exceeds the kernel's in-bucket "
                      f"recursion cap of B <= {sdca_bucket.MAX_BUCKET}")
    d_pad = _round_up(max(d, 8), 8)
    need = sdca_bucket.vmem_bytes_estimate(B_pad, d_pad)
    if need > sdca_bucket.TOTAL_VMEM_BUDGET_BYTES:
        return Misfit(MisfitCode.VMEM_TOTAL,
                      f"~{need}-byte VMEM footprint for (B={B_pad}, "
                      f"d_pad={d_pad}) exceeds the "
                      f"{sdca_bucket.TOTAL_VMEM_BUDGET_BYTES}-byte budget")
    return None


# weak-identity memo of (idx, val) pairs that already passed the
# CSR-invariant check, so eager epoch loops don't re-sort the same
# chunk every epoch (keyed on BOTH arrays: the invariant depends on
# the values, not just the ids)
_csr_checked: dict[tuple[int, int], tuple] = {}


def _csr_was_checked(idx, val) -> bool:
    entry = _csr_checked.get((id(idx), id(val)))
    return (entry is not None
            and entry[0]() is idx and entry[1]() is val)


def _csr_mark_checked(idx, val) -> None:
    # only immutable jax.Arrays are safe to memoize by identity —
    # a numpy array can be mutated in place after passing, which would
    # silently stale the memo and skip the check forever after
    if not (isinstance(idx, jax.Array) and isinstance(val, jax.Array)):
        return
    key = (id(idx), id(val))

    def _drop(_ref, _key=key):
        _csr_checked.pop(_key, None)
    try:
        _csr_checked[key] = (weakref.ref(idx, _drop),
                             weakref.ref(val, _drop))
    except TypeError:
        pass


#: provenances whose rows are vouched for upstream: cache builds run
#: `zero_duplicates`; array feeds are checked at Session entry
#: (api/session.py) or built from cached/registry data, and opaque
#: ChunkFeeds carry the invariant as part of the engine.ChunkFeed
#: protocol contract; resident shards only reach here as tracers.
#: Every OTHER label, including relabeled ad-hoc variants, gets
#: checked: the gate fails safe instead of keying on one magic string.
_TRUSTED_SOURCES = ("tile cache", "array feed", "resident shard arrays")


def _check_csr_invariant(idx, val, source: str) -> None:
    """Host-side check of the no-duplicate-nonzero CSR invariant.

    Runs on CONCRETE arrays from any untrusted provenance (tracers —
    i.e. calls from inside jitted epoch programs — are skipped; so are
    `_TRUSTED_SOURCES`, deduped upstream).  Violations silently break
    the bitwise-vs-XLA contract, so they get a loud error here.
    Arrays that pass are memoized by weak identity so eager training
    loops only pay the device-to-host copy + sort once per chunk, not
    once per epoch.
    """
    if any(source.startswith(s) for s in _TRUSTED_SOURCES):
        return
    if isinstance(idx, jax.core.Tracer) or isinstance(val, jax.core.Tracer):
        return
    if _csr_was_checked(idx, val):
        return
    from repro.data.formats import raise_on_duplicate_nonzeros
    raise_on_duplicate_nonzeros(np.asarray(idx), np.asarray(val),
                                f"{source}: sparse rows")
    _csr_mark_checked(idx, val)


def sdca_bucket_subepoch(obj: Objective, Xl, yl, al, v0, lam_n, sig, *,
                         bucket: int, interpret: bool | None = None,
                         source: str = "ad-hoc arrays"):
    """One worker's sub-epoch via the Pallas kernel.

    Xl: (d, n_local) columns in visiting order; returns (a_new, dv_raw)
    where dv_raw is the UNSCALED global delta (CoCoA+ convention, same as
    dense_local_subepoch).  `source` labels the data's provenance
    (tile cache vs ad-hoc arrays) in alignment errors.
    """
    if interpret is None:
        interpret = _interpret_default()
    d, n_local = Xl.shape
    B = bucket
    nb = n_local // B
    d_pad = _round_up(max(d, 8), 8)
    B_pad = _round_up(max(B, 8), 8)

    xb = Xl.reshape(d, nb, B).transpose(1, 0, 2)
    if d_pad != d or B_pad != B:
        xb = jnp.pad(xb, ((0, 0), (0, d_pad - d), (0, B_pad - B)))
    yb = yl.reshape(nb, B)
    ab = al.reshape(nb, B)
    if B_pad != B:
        # padded coordinates: x column is all-zero => q=0, m=0.  Give them
        # y such that delta(0, 0, y, 0) == 0 for every objective:
        # ridge: (y-0-0)/(1+0) = y -> needs y=0;  hinge/logistic are safe
        # with y=+1 & a=0?  hinge: clip(0*1 + (1-0)/max(q,eps)) -> huge.
        # Zero columns make the v-update a no-op regardless of delta, and
        # alpha updates on padding are discarded, so any finite y works;
        # use y=0 for ridge-neutrality and rely on eps-guards elsewhere.
        yb = jnp.pad(yb, ((0, 0), (0, B_pad - B)))
        ab = jnp.pad(ab, ((0, 0), (0, B_pad - B)))

    v0p = jnp.zeros((d_pad, 1), jnp.float32).at[:d, 0].set(
        v0.astype(jnp.float32))
    scal = jnp.stack([jnp.float32(lam_n), jnp.float32(sig)])

    a_new, v_fin = sdca_bucket.sdca_bucket_kernel(
        obj, xb, yb, ab, v0p, scal, interpret, source)

    a_out = a_new[:, :B].reshape(-1)
    dv = (v_fin[:d, 0] - v0.astype(jnp.float32)) / jnp.float32(sig)
    return a_out.astype(al.dtype), dv.astype(v0.dtype)


def sdca_sparse_bucket_subepoch(obj: Objective, idx, val, yl, al, v0,
                                lam_n, sig, *, bucket: int,
                                interpret: bool | None = None,
                                source: str = "ad-hoc arrays"):
    """One worker's SPARSE sub-epoch via the Pallas kernel.

    idx/val: (n_local, nnz) padded-CSR rows in visiting order; v0: (d,)
    replicated shared vector.  Returns (a_new, dv_raw) with dv_raw the
    UNSCALED global delta — call-compatible with
    `core.sdca.sparse_local_subepoch` and BITWISE-identical to it for
    rows obeying the CSR no-duplicate-nonzero invariant (see
    kernels/sdca_sparse_bucket.py) — concrete ad-hoc arrays are
    checked host-side here; violating rows must be sanitized with
    `data.formats.zero_duplicates` first.  Unlike the dense wrapper
    there is no silent B/nnz padding: tile alignment is a data-layout
    contract (the cache stores tiles pre-aligned) and misalignment
    raises with the fix spelled out.  Only d is padded (zero rows,
    never indexed).
    """
    if interpret is None:
        interpret = _interpret_default()
    _check_csr_invariant(idx, val, source)
    n_local, nnz = idx.shape
    B = bucket
    if B <= 0 or n_local % B:
        raise ValueError(
            f"bucket={B} must divide the {source} chunk's row count "
            f"{n_local} (the engine hands the kernel whole buckets)")
    d = v0.shape[0]
    d_pad = _round_up(max(d, 8), 8)

    idxb = idx.reshape(n_local // B, B, nnz)
    valb = val.reshape(n_local // B, B, nnz)
    yb = yl.reshape(n_local // B, B)
    ab = al.reshape(n_local // B, B)
    # per-row curvature at FULL chunk shape — the scan's exact
    # expression; the kernel must not recompute it per tile (see
    # sdca_sparse_bucket._kernel on why this is bitwise-load-bearing)
    valf = val.astype(jnp.float32)
    qb = jnp.sum(valf * valf, axis=1).reshape(n_local // B, B)
    v0p = jnp.zeros((d_pad, 1), jnp.float32).at[:d, 0].set(
        v0.astype(jnp.float32))
    scal = jnp.stack([jnp.float32(lam_n), jnp.float32(sig)])

    a_new, v_fin = sdca_sparse_bucket.sdca_sparse_bucket_kernel(
        obj, idxb, valb, yb, ab, qb, v0p, scal, interpret, source)

    a_out = a_new.reshape(-1)
    dv = (v_fin[:d, 0] - v0.astype(jnp.float32)) / jnp.float32(sig)
    return a_out.astype(al.dtype), dv.astype(v0.dtype)


def sdca_sparse_sharded_subepoch(obj: Objective, idx, val, yl, al, v0,
                                 lam_n, sig, *, bucket: int,
                                 model_axis: str | None = None,
                                 model_lanes: int = 1,
                                 lane=None,
                                 interpret: bool | None = None,
                                 source: str = "ad-hoc arrays"):
    """One LANE's feature-sharded sparse sub-epoch (DESIGN.md S12).

    Call-compatible with `sdca_sparse_bucket_subepoch` plus the model-
    axis knobs: v0 is the (d,) REPLICATED shared vector, but this lane
    keeps only its contiguous `sparse_slice_width(d, model_lanes)` rows
    resident (in VMEM on TPU) and, per bucket, (1) gathers its partial
    working set, (2) all-gathers the partials over `model_axis` and
    keeps the owning lane's bits per entry — pure data movement, so the
    assembled W is BITWISE the replicated kernel's W; a psum of partial
    margins would reorder the sums and break the contract — then
    (3) runs the shared in-bucket recursion and scatters its owned
    entries.  Returns (a_new, dv) with dv the UNSCALED global delta
    whose support is ONLY this lane's slice: the engine's ordered
    model-axis dv sync adds the disjoint slices (plus exact zeros)
    back into the serial dv, entry for entry.

    With model_axis=None the exchange is the identity and `lane`
    (default 0) picks the slice — the single-process form the kernel
    tests drive lane by lane.
    """
    if interpret is None:
        interpret = _interpret_default()
    _check_csr_invariant(idx, val, source)
    n_local, nnz = idx.shape
    B = bucket
    if B <= 0 or n_local % B:
        raise ValueError(
            f"bucket={B} must divide the {source} chunk's row count "
            f"{n_local} (the engine hands the kernel whole buckets)")
    d = v0.shape[0]
    M = max(int(model_lanes), 1)
    d_loc = sparse_slice_width(d, M)
    d_pad = d_loc * M
    nb = n_local // B

    if model_axis is not None:
        # audit: collective-ok lane id seeds the lo carry (threaded below)
        lane_ix = jax.lax.axis_index(model_axis).astype(jnp.int32)
    else:
        lane_ix = jnp.int32(0 if lane is None else lane)
    lo0 = lane_ix * jnp.int32(d_loc)

    idxb = idx.reshape(nb, B, nnz)
    valb = val.reshape(nb, B, nnz)
    yb = yl.reshape(nb, B)
    ab = al.reshape(nb, B)
    # per-row curvature at FULL chunk shape — bitwise-load-bearing,
    # exactly as in the replicated wrapper (and replicated over lanes:
    # every lane sees the same q bits the scan uses)
    valf = val.astype(jnp.float32)
    qb = jnp.sum(valf * valf, axis=1).reshape(nb, B)
    v_pad = jnp.zeros((d_pad, 1), jnp.float32).at[:d, 0].set(
        v0.astype(jnp.float32))
    v_loc0 = jax.lax.dynamic_slice(v_pad, (lo0, 0), (d_loc, 1))
    scal = jnp.stack([jnp.float32(lam_n), jnp.float32(sig)])

    # lo rides in the scan carry: shard_map treats closed-over
    # axis_index-derived values inside loops as loop-invariant-
    # replicated on current jax (see engine.run_epoch's unrolled chunk
    # loop) — carrying it through keeps every lane on its own slice.
    def _step(carry, tile):
        v_loc, lo = carry
        idx_t, val_t, y_t, a_t, q_t = tile
        w_loc = sdca_sparse_bucket.sdca_sparse_gather_bucket(
            idx_t, v_loc, lo, interpret, source)
        if model_axis is not None and M > 1:
            # audit: collective-ok all-gather + owner-select (no psum)
            gathered = jax.lax.all_gather(w_loc, model_axis)
            owner = (idx_t // jnp.int32(d_loc)).astype(jnp.int32)
            w = jnp.take_along_axis(gathered, owner[None], axis=0)[0]
        else:
            w = w_loc
        a_new_t, v_loc = sdca_sparse_bucket.sdca_sparse_sharded_bucket(
            obj, idx_t, val_t, y_t, a_t, q_t, w, v_loc, scal, lo,
            interpret, source)
        return (v_loc, lo), a_new_t

    (v_fin, _), a_new = jax.lax.scan(
        _step, (v_loc0, lo0), (idxb, valb, yb, ab, qb))

    dv_loc = (v_fin[:, 0] - v_loc0[:, 0]) / jnp.float32(sig)
    dv = jax.lax.dynamic_update_slice(
        jnp.zeros((d_pad,), jnp.float32), dv_loc, (lo0,))[:d]
    return a_new.reshape(-1).astype(al.dtype), dv.astype(v0.dtype)


def rglru_scan(x, a_log, gate_a, gate_x, h0, *, block_t: int = 128,
               interpret: bool | None = None):
    """Blocked RG-LRU linear recurrence; see kernels/rglru.py."""
    if interpret is None:
        interpret = _interpret_default()
    return _rglru.rglru_kernel(x, a_log, gate_a, gate_x, h0,
                               block_t=block_t, interpret=interpret)


def flash_attention(q, k, v, *, kind: str = "causal", window: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool | None = None):
    """(B, S, H, hd) flash attention via the Pallas kernel.

    Pads Sq/Sk to block multiples and hd to the 128-lane tile; the true
    kv length rides in as a mask bound.  On non-TPU backends callers
    should prefer models.attention.blocked_attention (this wrapper runs
    the kernel in interpret mode there — correct but slow).
    """
    from . import flash_attention as _fa
    if interpret is None:
        interpret = _interpret_default()
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    G = H // Hkv
    bq_ = min(bq, _round_up(Sq, 8))
    bk_ = min(bk, _round_up(Sk, 8))
    sq_p = _round_up(Sq, bq_)
    sk_p = _round_up(Sk, bk_)
    hd_p = _round_up(hd, 128) if not interpret else hd
    hdv_p = _round_up(hd_v, 128) if not interpret else hd_v

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd_v)
    qf = jnp.pad(qf, ((0, 0), (0, sq_p - Sq), (0, hd_p - hd)))
    kf = jnp.pad(kf, ((0, 0), (0, sk_p - Sk), (0, hd_p - hd)))
    vf = jnp.pad(vf, ((0, 0), (0, sk_p - Sk), (0, hdv_p - hd_v)))

    o = _fa.flash_attention_kernel(qf, kf, vf, kind=kind, window=window,
                                   bq=bq_, bk=bk_, group=G, seq_k=Sk,
                                   interpret=interpret)
    o = o[:, :Sq, :hd_v].reshape(B, H, Sq, hd_v)
    return o.transpose(0, 2, 1, 3)
