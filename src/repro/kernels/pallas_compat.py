"""Version-compat shims for the Pallas TPU API (one home, three users).

jax renamed ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``;
every kernel imports the resolved constructor from here so the next
rename is a one-line fix (the AbstractMesh analogue lives in
launch/mesh.py).
"""
from jax.experimental.pallas import tpu as pltpu

compiler_params = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
