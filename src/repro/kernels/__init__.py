"""Pallas TPU kernels for the perf-critical hot loops.

sdca_bucket — the paper's bucketed SDCA sub-epoch (VMEM-resident shared
              vector, streamed bucket tiles, MXU Gram/margin matmuls).
rglru       — RG-LRU gated linear recurrence (RecurrentGemma hot loop).

Each kernel ships ops.py (jit'd wrapper + padding + CPU interpret
fallback) and ref.py (pure-jnp oracle used by the allclose sweeps).
"""
from . import ops, ref, rglru, sdca_bucket

__all__ = ["ops", "ref", "rglru", "sdca_bucket"]
