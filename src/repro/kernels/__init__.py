"""Pallas TPU kernels for the perf-critical hot loops.

sdca_bucket        — the paper's bucketed SDCA sub-epoch, dense path
                     (VMEM-resident shared vector, streamed bucket
                     tiles, MXU Gram/margin matmuls).
sdca_sparse_bucket — the sparse twin over padded-CSR (B x nnz) tiles:
                     v pinned in VMEM for the whole sub-epoch, one
                     gather/scatter per bucket, bitwise-identical to
                     the XLA gather/scatter scan (DESIGN.md S11).
rglru              — RG-LRU gated linear recurrence (RecurrentGemma
                     hot loop).

Each kernel ships ops.py (jit'd wrapper + padding + CPU interpret
fallback) and ref.py (pure-jnp oracle used by the allclose sweeps).
"""
from . import ops, ref, rglru, sdca_bucket, sdca_sparse_bucket

__all__ = ["ops", "ref", "rglru", "sdca_bucket", "sdca_sparse_bucket"]
