"""Pallas TPU kernel: flash attention (online-softmax, causal block skip).

The dry-run baselines show the jnp blocked-attention path is the memory
bottleneck of every full-attention train/prefill cell: its f32 score
tensors are HLO-level buffers (e.g. 25.6 s/step of HBM time on
minicpm3-4b train_4k vs 1.19 s of compute).  This kernel is the TPU
answer (DESIGN.md S2's "the kernel IS the locality policy"):

  * grid = (B*H, n_q_blocks, n_kv_blocks), kv innermost with
    "arbitrary" semantics; the (m, l, acc) online-softmax state lives in
    VMEM scratch across the kv sweep — score tiles NEVER touch HBM;
  * causal/local masking is applied at tile granularity, and tiles that
    are fully masked are SKIPPED (pl.when on block indices): causal
    attention does ~half the work the jnp path does;
  * GQA folds q-heads into the batch grid dim; the kv BlockSpec maps
    q-head h to kv-head h // (H // Hkv), so MQA/GQA reuse kv tiles.

Validated in interpret mode against ref.flash_attention_ref over
shape/dtype/mask sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import compiler_params as _compiler_params


NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            kind: str, window: int, bq: int, bk: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    q_start = qi * bq
    k_start = ki * bk

    # tile-level skip: causal/local tiles entirely above the diagonal
    # (or beyond the window) are never computed
    if kind == "causal":
        run = k_start <= q_start + bq - 1
    elif kind == "local":
        run = (k_start <= q_start + bq - 1) & \
              (k_start + bk - 1 >= q_start - window + 1)
    else:
        run = True

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(run)
    def _tile():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0].astype(jnp.float32)          # (bk, hd_v)
        s = q @ k.T * (q.shape[-1] ** -0.5)       # (bq, bk)  MXU

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = kpos < seq_k                         # kv padding
        if kind == "causal":
            ok &= qpos >= kpos
        elif kind == "local":
            ok &= (qpos >= kpos) & (qpos - kpos < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "kind", "window", "bq", "bk", "group", "seq_k", "interpret"))
def flash_attention_kernel(q, k, v, *, kind: str = "causal",
                           window: int = 0, bq: int = 128, bk: int = 128,
                           group: int = 1, seq_k: int = 0,
                           interpret: bool = False):
    """q: (BH, Sq, hd); k/v: (BHkv, Sk_pad, hd/hd_v); BH = BHkv * group.

    seq_k: true (unpadded) kv length.  Returns (BH, Sq, hd_v).
    """
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    hd_v = v.shape[-1]
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    grid = (BH, Sq // bq, Sk // bk)
    seq_k = seq_k or Sk

    return pl.pallas_call(
        functools.partial(_kernel, kind=kind, window=window, bq=bq,
                          bk=bk, seq_k=seq_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, g=group:
                         (b // g, j, 0)),
            pl.BlockSpec((1, bk, hd_v), lambda b, i, j, g=group:
                         (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd_v), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # m
            pltpu.VMEM((bq, 1), jnp.float32),      # l
            pltpu.VMEM((bq, hd_v), jnp.float32),   # acc
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
