"""Pallas TPU kernels: one worker's SPARSE bucketed SDCA sub-epoch.

The sparse twin of kernels/sdca_bucket.py (DESIGN.md S11/S12).  The XLA
formulation (`core.sdca.sparse_local_subepoch`) is a per-coordinate
`lax.scan` whose carry is the FULL shared vector v: every coordinate
pays a v-sized gather + scatter through HBM.  Here the paper's
cache-resident shared vector maps onto VMEM:

  * v (d_pad x 1, f32) is pinned in VMEM for the whole sub-epoch via
    input/output aliasing + a constant index map — idx/val tiles are
    the ONLY per-bucket HBM traffic;
  * each grid step streams one (B, nnz) idx/val tile pair HBM->VMEM —
    exactly the mmap-aligned layout `data/cache.py` stores, so cached
    tiles DMA straight in;
  * the touched feature rows are gathered once per bucket into a
    bucket-local working set W (B, nnz) at bucket entry;
  * the in-bucket recursion runs on VMEM-resident state only: O(B*nnz)
    gather/scatter scalars + an O(B) delta recursion whose cross-
    coordinate margin corrections are vectorized (B, nnz) x nnz
    compare/accumulate VPU work (no Gram matrix: a sparse-sparse Gram
    needs the same index matching but materializes B^2 values that are
    almost all zero);
  * v is written back once per bucket (one scatter pass in visiting
    order) instead of once per coordinate.

Bit-equivalence contract: for the same visiting order the kernel is
BITWISE-identical to `sparse_local_subepoch` (pinned by interpret-mode
tests on CPU).  Two things make that hold and must not be "simplified"
away:

  * every floating-point add applies the exact values the scan adds —
    the per-coordinate update row u = (sigma' * delta / lam_n) * val
    is computed ONCE (same association as the scan) and only ever
    ADDED elementwise; folding the multiply into the adds lets XLA
    fuse them into FMAs and drifts low bits;
  * rows must satisfy the CSR invariant: no duplicate feature id with
    a nonzero value within a row (padding with idx=0/val=0 is fine —
    zero-valued duplicates add exact zeros on both paths).  Real
    svmlight/CSR data satisfies this by construction;
    `data/formats.zero_duplicates` enforces it for synthetic data.

Grid is 1-D over buckets with "arbitrary" dimension semantics: buckets
are processed IN ORDER (sequential SDCA semantics).

Alignment: B and nnz must be multiples of 8 (f32 sublane tile), d_pad
a multiple of 8, and v must fit the VMEM budget below.  Scalars
(lam*n, sigma') ride in SMEM.

Feature-sharded variant (DESIGN.md S12): when d_pad rows of v cannot
fit one core's VMEM budget, each `model`-axis lane owns ONE contiguous
d_loc = roundup(ceil(d_pad / M), 8) slice of v instead.  The sub-epoch
becomes a per-bucket pair of kernels around one model-axis exchange:

  * `_gather_slice_kernel`: gather the bucket's touched rows that fall
    in this lane's slice (out-of-slice entries read as exact 0.0);
  * the ENGINE all-gathers the per-lane partial working sets and each
    lane keeps, entry for entry, the owning lane's bits
    (`ops.sdca_sparse_sharded_subepoch`) — pure data movement, so the
    assembled W is bitwise the replicated kernel's W.  A psum of
    per-lane partial margins would be cheaper on the wire but changes
    the summation order and breaks the bitwise-vs-scan contract;
  * `_sharded_kernel`: run the SAME in-bucket recursion
    (`_bucket_recursion`, shared code) on the assembled W — every lane
    redundantly, O(B*nnz) VPU work — then scatter only the owned
    entries back into the slice, in visiting order.

One exchange (M*B*nnz f32) per bucket is the whole model-axis wire
cost, amortized over B coordinates — the bucket optimization's payoff
on this axis too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.objectives import Objective
from .pallas_compat import compiler_params as _compiler_params

Array = jax.Array

#: VMEM bytes the resident shared vector may occupy (~half a v5e core's
#: 16 MB, leaving room for double-buffered idx/val tiles + the working
#: set).  d above this must use local_solver="xla" (HBM-resident v) or
#: shard features.
V_VMEM_BUDGET_BYTES = 8 * 2 ** 20

#: Total VMEM the kernel's buffers may claim together (a v5e core has
#: ~16 MiB; leave headroom for Mosaic spills/scratch).  On wide tiles
#: the per-coordinate (B, nnz, nnz) match tensor dominates and must be
#: budgeted up front — exceeding VMEM inside Mosaic is an opaque OOM,
#: not a Python error.
TOTAL_VMEM_BUDGET_BYTES = 14 * 2 ** 20


def vmem_bytes_estimate(B: int, nnz: int, d_pad: int) -> int:
    """Upper-bound VMEM footprint of one grid step.

    Counts the resident v, the double-buffered idx(int32)/val(f32)
    tiles, the W/U/vals/corr working sets, and the per-coordinate
    (B, nnz, nnz) match tensors — the bool compare mask (1 B/elt) AND
    the f32 `jnp.where` product (4 B/elt) are live together in the
    recursion body.  Shared with `ops.sparse_kernel_misfit` so the
    "auto" path can pre-check static shapes and fall back instead of
    raising.
    """
    v = d_pad * 4
    tiles = 2 * B * nnz * (4 + 4)
    work = 4 * B * nnz * 4
    match = B * nnz * nnz * (4 + 1)
    return v + tiles + work + match


def vmem_bytes_estimate_sharded(B: int, nnz: int, d_loc: int) -> int:
    """Upper-bound VMEM footprint of ONE bucket of the sharded pair.

    The update kernel dominates: the resident v SLICE, one (not
    double-buffered — one bucket per call) idx/val tile pair, the
    exchanged working set W, the U/vals/corr working sets, and the same
    (B, nnz, nnz) match tensors as the replicated kernel.  Shared with
    `ops.sparse_solver_plan` so the dispatcher can pre-check the
    sharded route on static shapes.
    """
    v = d_loc * 4
    tiles = B * nnz * (4 + 4)
    wexch = B * nnz * 4
    work = 4 * B * nnz * 4
    match = B * nnz * nnz * (4 + 1)
    return v + tiles + wexch + work + match


def _gather_rows(idx, read):
    """W[i, k] = read(idx[i, k]) via a scalar loop over the tile.

    Shared by the replicated kernel (read = v lookup) and the sharded
    gather kernel (read = masked slice lookup): the loop structure must
    stay identical so both produce the same W bits for owned entries.
    """
    B, nnz = idx.shape

    def gather(t, W):
        i = t // nnz
        k = t - i * nnz
        p = jax.lax.dynamic_slice(idx, (i, k), (1, 1))[0, 0]
        w = read(p)
        return jax.lax.dynamic_update_slice(W, w[None, None], (i, k))

    return jax.lax.fori_loop(0, B * nnz, gather,
                             jnp.zeros((B, nnz), jnp.float32))


def _bucket_recursion(obj: Objective, idx, vals, y, a0, qrow, lam_n, sig,
                      W):
    """The in-bucket delta recursion on a gathered working set W.

    -> (U, deltas): the per-coordinate update rows (computed ONCE each,
    see the module docstring's bitwise contract) and the alpha deltas.
    Shared VERBATIM by the replicated and sharded kernels — the sharded
    path's bitwise claim is exactly "same W bits in, same U bits out".
    After coordinate i, later rows' working-set entries that alias a
    feature i touched receive the SAME u-element the scan scatter-adds
    into v, so margins stay bit-equal.
    """
    B, nnz = idx.shape

    def body(i, carry):
        W, U, deltas = carry
        vi = jax.lax.dynamic_slice_in_dim(vals, i, 1, 0)[0]    # (nnz,)
        ii = jax.lax.dynamic_slice_in_dim(idx, i, 1, 0)[0]
        wi = jax.lax.dynamic_slice_in_dim(W, i, 1, 0)[0]
        m = jnp.sum(wi * vi)
        q = jax.lax.dynamic_index_in_dim(qrow, i, keepdims=False)
        yi = jax.lax.dynamic_index_in_dim(y, i, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(a0, i, keepdims=False)
        d = obj.delta(m, ai, yi, sig * q / lam_n)
        # the scan's update row, computed once with its association
        u = (sig * d / lam_n) * vi
        match = idx[:, :, None] == ii[None, None, :]   # (B, nnz, nnz)
        corr = jnp.sum(jnp.where(match, u[None, None, :], 0.0), axis=-1)
        hit = jnp.any(match, axis=-1)
        W = jnp.where(hit, W + corr, W)
        U = jax.lax.dynamic_update_slice_in_dim(U, u[None], i, axis=0)
        deltas = jax.lax.dynamic_update_index_in_dim(deltas, d, i, axis=0)
        return W, U, deltas

    _, U, deltas = jax.lax.fori_loop(
        0, B, body, (W, jnp.zeros((B, nnz), jnp.float32),
                     jnp.zeros((B,), jnp.float32)))
    return U, deltas


def _kernel(obj: Objective, idx_ref, val_ref, y_ref, a_ref, q_ref,
            scal_ref, v_ref, aout_ref, vout_ref):
    """Body for one bucket (one grid step) — replicated v."""
    first = pl.program_id(0) == 0

    # v lives in the aliased output block; seed it from the input once.
    @pl.when(first)
    def _():
        vout_ref[...] = v_ref[...]

    idx = idx_ref[0]                            # (B, nnz) int32
    vals = val_ref[0].astype(jnp.float32)       # (B, nnz)
    y = y_ref[0].astype(jnp.float32)            # (B,)
    a0 = a_ref[0].astype(jnp.float32)           # (B,)
    # per-row curvature ||x_i||^2, PRECOMPUTED by the wrapper with the
    # scan's exact whole-array row-sum: recomputing it per tile inside
    # the kernel lets XLA vectorize the reduction differently and
    # drifts q by 1 ulp on some rows, which the bisection amplifies —
    # the bitwise contract dies there (found the hard way).
    qrow = q_ref[0].astype(jnp.float32)         # (B,)
    lam_n = scal_ref[0]
    sig = scal_ref[1]
    B, nnz = idx.shape

    # 1. bucket entry: gather the touched rows into the working set
    #    W[i, k] = v[idx[i, k]]  (the only reads of v this bucket)
    W = _gather_rows(idx, lambda p: vout_ref[p, 0])

    # 2. in-bucket recursion entirely on VMEM-resident state
    U, deltas = _bucket_recursion(obj, idx, vals, y, a0, qrow, lam_n,
                                  sig, W)

    # 3. scatter back into v ONCE per bucket, rows in visiting order so
    #    shared features accumulate in the scan's sequence
    def scatter(t, carry):
        i = t // nnz
        k = t - i * nnz
        p = jax.lax.dynamic_slice(idx, (i, k), (1, 1))[0, 0]
        u = jax.lax.dynamic_slice(U, (i, k), (1, 1))[0, 0]
        vout_ref[p, 0] = vout_ref[p, 0] + u
        return carry

    jax.lax.fori_loop(0, B * nnz, scatter, 0)
    aout_ref[0] = (a0 + deltas).astype(aout_ref.dtype)


@functools.partial(jax.jit, static_argnums=(0, 8, 9))
def sdca_sparse_bucket_kernel(obj: Objective, idx: Array, val: Array,
                              yb: Array, ab: Array, qb: Array,
                              v0: Array, scal: Array,
                              interpret: bool = False,
                              source: str = "ad-hoc arrays"
                              ) -> tuple[Array, Array]:
    """Run the sparse sub-epoch kernel.

    idx/val: (nb, B, nnz) bucket tiles in visiting order (the tile
    cache's on-disk layout); yb, ab, qb: (nb, B) — qb is the per-row
    curvature sum(val^2) precomputed at full-chunk shape (see _kernel);
    v0: (d_pad, 1) f32; scal: (2,) f32 = [lam*n, sigma'].  Returns
    (a_new (nb, B), v_final (d_pad, 1)); v_final includes the
    sigma'-scaled local evolution (callers unscale the global delta).
    `source` names where the tiles came from so alignment errors point
    at the right fix.
    """
    nb, B, nnz = idx.shape
    d_pad = v0.shape[0]
    if B % 8 or nnz % 8:
        raise ValueError(
            f"sparse bucket tiles from {source} have (B={B}, nnz={nnz}); "
            f"the Pallas kernel needs both to be multiples of 8 "
            f"(f32 sublane tile).  Fix: rebuild the tile cache with "
            f"build_cache(..., nnz_multiple=8) / materialize(..., "
            f"nnz_multiple=8) for cached tiles, or zero-pad ad-hoc "
            f"idx/val arrays with idx=0/val=0 columns (and pick a "
            f"bucket size that is a multiple of 8).")
    if d_pad % 8:
        raise ValueError(
            f"v tile from {source} has d_pad={d_pad}, which must be a "
            f"multiple of 8; pad the shared vector with zero rows "
            f"(ops.sdca_sparse_bucket_subepoch does this automatically)")
    if d_pad * 4 > V_VMEM_BUDGET_BYTES:
        raise ValueError(
            f"shared vector of d_pad={d_pad} features ({d_pad * 4} "
            f"bytes) exceeds the sparse kernel's VMEM budget "
            f"({V_VMEM_BUDGET_BYTES} bytes, ~{V_VMEM_BUDGET_BYTES // 4} "
            f"features).  Use local_solver='xla' (HBM-resident v) for "
            f"this workload, or shard features.")
    need = vmem_bytes_estimate(B, nnz, d_pad)
    if need > TOTAL_VMEM_BUDGET_BYTES:
        raise ValueError(
            f"sparse bucket tiles from {source} with (B={B}, nnz={nnz}, "
            f"d_pad={d_pad}) need ~{need} bytes of VMEM — the per-"
            f"coordinate (B, nnz, nnz) match tensor alone is "
            f"{B * nnz * nnz * 5} bytes (bool mask + f32 product) — "
            f"over the kernel's "
            f"{TOTAL_VMEM_BUDGET_BYTES}-byte total budget.  Use "
            f"local_solver='xla' (HBM-resident v) for this workload, or "
            f"shrink bucket/nnz so the tiles fit.")

    grid = (nb,)
    a_new, v_fin = pl.pallas_call(
        functools.partial(_kernel, obj),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, B, nnz), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, B, nnz), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, B), ab.dtype),
            jax.ShapeDtypeStruct((d_pad, 1), jnp.float32),
        ],
        input_output_aliases={6: 1},   # v0 buffer reused as v_final
        compiler_params=_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(idx, val, yb, ab, qb, scal, v0)
    return a_new, v_fin


# ---------------------------------------------------------------------------
# Feature-sharded (model-axis) variant: per-bucket kernel pair around one
# engine-side exchange (see module docstring + DESIGN.md S12).  Driven by
# ops.sdca_sparse_sharded_subepoch, which owns the bucket scan and the
# all-gather/owner-select exchange between the two calls.
# ---------------------------------------------------------------------------


def _gather_slice_kernel(idx_ref, lo_ref, v_ref, w_ref):
    """W_loc[i, k] = v_slice[idx[i, k] - lo] when owned, else exact 0.0.

    The masked read keeps the owned entries' bits identical to the
    replicated kernel's gather; unowned entries are filled by the
    owning lane after the exchange.
    """
    idx = idx_ref[...]                          # (B, nnz) int32
    lo = lo_ref[0]
    d_loc = v_ref.shape[0]

    def read(p):
        q = p - lo
        ok = jnp.logical_and(q >= 0, q < d_loc)
        qc = jnp.where(ok, q, 0)
        return jnp.where(ok, v_ref[qc, 0], jnp.float32(0.0))

    w_ref[...] = _gather_rows(idx, read)


def _sharded_kernel(obj: Objective, idx_ref, val_ref, y_ref, a_ref,
                    q_ref, w_ref, scal_ref, lo_ref, v_ref, aout_ref,
                    vout_ref):
    """One bucket's recursion + owned-slice scatter, given the
    EXCHANGED working set W (full bits on every lane)."""
    vout_ref[...] = v_ref[...]
    idx = idx_ref[...]                          # (B, nnz) int32
    vals = val_ref[...].astype(jnp.float32)     # (B, nnz)
    y = y_ref[0].astype(jnp.float32)            # (B,)
    a0 = a_ref[0].astype(jnp.float32)           # (B,)
    qrow = q_ref[0].astype(jnp.float32)         # (B,)
    W = w_ref[...].astype(jnp.float32)          # (B, nnz)
    lam_n = scal_ref[0]
    sig = scal_ref[1]
    lo = lo_ref[0]
    B, nnz = idx.shape
    d_loc = v_ref.shape[0]

    # every lane runs the full recursion on the same W bits (redundant
    # O(B*nnz) VPU work — the price of one exchange per bucket)
    U, deltas = _bucket_recursion(obj, idx, vals, y, a0, qrow, lam_n,
                                  sig, W)

    # scatter the OWNED entries in visiting order; unowned writes put
    # the unchanged bits back (no FP op), so each v row accumulates its
    # hits in exactly the replicated kernel's sequence on its one owner
    def scatter(t, carry):
        i = t // nnz
        k = t - i * nnz
        p = jax.lax.dynamic_slice(idx, (i, k), (1, 1))[0, 0] - lo
        ok = jnp.logical_and(p >= 0, p < d_loc)
        pc = jnp.where(ok, p, 0)
        u = jax.lax.dynamic_slice(U, (i, k), (1, 1))[0, 0]
        cur = vout_ref[pc, 0]
        vout_ref[pc, 0] = jnp.where(ok, cur + u, cur)
        return carry

    jax.lax.fori_loop(0, B * nnz, scatter, 0)
    aout_ref[0] = (a0 + deltas).astype(aout_ref.dtype)


def _check_sharded_tile(B: int, nnz: int, d_loc: int, source: str):
    if B % 8 or nnz % 8:
        raise ValueError(
            f"sparse bucket tiles from {source} have (B={B}, nnz={nnz}); "
            f"the sharded Pallas kernel needs both to be multiples of 8 "
            f"(f32 sublane tile) — rebuild the tile cache with "
            f"nnz_multiple=8 or zero-pad ad-hoc idx/val arrays.")
    if d_loc % 8:
        raise ValueError(
            f"v slice from {source} has d_loc={d_loc}, which must be a "
            f"multiple of 8 (ops.sdca_sparse_sharded_subepoch sizes "
            f"slices to the sublane tile automatically)")
    if d_loc * 4 > V_VMEM_BUDGET_BYTES:
        raise ValueError(
            f"per-lane v slice of d_loc={d_loc} rows ({d_loc * 4} bytes) "
            f"exceeds the sparse kernel's VMEM budget "
            f"({V_VMEM_BUDGET_BYTES} bytes) even feature-sharded.  Add "
            f"model-axis lanes or use local_solver='xla' "
            f"(HBM-resident v).")
    need = vmem_bytes_estimate_sharded(B, nnz, d_loc)
    if need > TOTAL_VMEM_BUDGET_BYTES:
        raise ValueError(
            f"sharded sparse bucket tiles from {source} with (B={B}, "
            f"nnz={nnz}, d_loc={d_loc}) need ~{need} bytes of VMEM — "
            f"the per-coordinate (B, nnz, nnz) match tensor alone is "
            f"{B * nnz * nnz * 5} bytes — over the kernel's "
            f"{TOTAL_VMEM_BUDGET_BYTES}-byte total budget.  Use "
            f"local_solver='xla' for this workload, or shrink "
            f"bucket/nnz so the tiles fit.")


@functools.partial(jax.jit, static_argnums=(3, 4))
def sdca_sparse_gather_bucket(idx_t: Array, v_loc: Array, lo: Array,
                              interpret: bool = False,
                              source: str = "ad-hoc arrays") -> Array:
    """Gather ONE bucket's per-lane partial working set.

    idx_t: (B, nnz) int32 feature ids; v_loc: (d_loc, 1) f32 this
    lane's v slice; lo: () int32 the slice's first global row.  Returns
    W_loc (B, nnz) f32 with this lane's rows and exact zeros elsewhere.
    """
    B, nnz = idx_t.shape
    d_loc = v_loc.shape[0]
    _check_sharded_tile(B, nnz, d_loc, source)
    return pl.pallas_call(
        _gather_slice_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((B, nnz), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((d_loc, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B, nnz), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nnz), jnp.float32),
        compiler_params=_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(idx_t, lo.astype(jnp.int32).reshape(1), v_loc)


@functools.partial(jax.jit, static_argnums=(0, 10, 11))
def sdca_sparse_sharded_bucket(obj: Objective, idx_t: Array, val_t: Array,
                               y_t: Array, a_t: Array, q_t: Array,
                               W: Array, v_loc: Array, scal: Array,
                               lo: Array, interpret: bool = False,
                               source: str = "ad-hoc arrays"
                               ) -> tuple[Array, Array]:
    """Run ONE bucket's recursion + owned scatter on the v slice.

    idx_t/val_t: (B, nnz); y_t/a_t/q_t: (B,); W: (B, nnz) the EXCHANGED
    full working set (every lane the same bits); v_loc: (d_loc, 1) this
    lane's slice (aliased into the output); scal: (2,) [lam*n, sigma'];
    lo: () int32.  Returns (a_new (B,), v_loc_new (d_loc, 1)).
    """
    B, nnz = idx_t.shape
    d_loc = v_loc.shape[0]
    _check_sharded_tile(B, nnz, d_loc, source)
    a_new, v_fin = pl.pallas_call(
        functools.partial(_sharded_kernel, obj),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((B, nnz), lambda i: (0, 0)),
            pl.BlockSpec((B, nnz), lambda i: (0, 0)),
            pl.BlockSpec((1, B), lambda i: (0, 0)),
            pl.BlockSpec((1, B), lambda i: (0, 0)),
            pl.BlockSpec((1, B), lambda i: (0, 0)),
            pl.BlockSpec((B, nnz), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((d_loc, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (0, 0)),
            pl.BlockSpec((d_loc, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, B), a_t.dtype),
            jax.ShapeDtypeStruct((d_loc, 1), jnp.float32),
        ],
        input_output_aliases={8: 1},   # v slice reused as output
        compiler_params=_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(idx_t, val_t, y_t[None], a_t[None], q_t[None], W, scal,
      lo.astype(jnp.int32).reshape(1), v_loc)
    return a_new[0], v_fin
