"""Pallas TPU kernel: one worker's SPARSE bucketed SDCA sub-epoch.

The sparse twin of kernels/sdca_bucket.py (DESIGN.md S11).  The XLA
formulation (`core.sdca.sparse_local_subepoch`) is a per-coordinate
`lax.scan` whose carry is the FULL shared vector v: every coordinate
pays a v-sized gather + scatter through HBM.  Here the paper's
cache-resident shared vector maps onto VMEM:

  * v (d_pad x 1, f32) is pinned in VMEM for the whole sub-epoch via
    input/output aliasing + a constant index map — idx/val tiles are
    the ONLY per-bucket HBM traffic;
  * each grid step streams one (B, nnz) idx/val tile pair HBM->VMEM —
    exactly the mmap-aligned layout `data/cache.py` stores, so cached
    tiles DMA straight in;
  * the touched feature rows are gathered once per bucket into a
    bucket-local working set W (B, nnz) at bucket entry;
  * the in-bucket recursion runs on VMEM-resident state only: O(B*nnz)
    gather/scatter scalars + an O(B) delta recursion whose cross-
    coordinate margin corrections are vectorized (B, nnz) x nnz
    compare/accumulate VPU work (no Gram matrix: a sparse-sparse Gram
    needs the same index matching but materializes B^2 values that are
    almost all zero);
  * v is written back once per bucket (one scatter pass in visiting
    order) instead of once per coordinate.

Bit-equivalence contract: for the same visiting order the kernel is
BITWISE-identical to `sparse_local_subepoch` (pinned by interpret-mode
tests on CPU).  Two things make that hold and must not be "simplified"
away:

  * every floating-point add applies the exact values the scan adds —
    the per-coordinate update row u = (sigma' * delta / lam_n) * val
    is computed ONCE (same association as the scan) and only ever
    ADDED elementwise; folding the multiply into the adds lets XLA
    fuse them into FMAs and drifts low bits;
  * rows must satisfy the CSR invariant: no duplicate feature id with
    a nonzero value within a row (padding with idx=0/val=0 is fine —
    zero-valued duplicates add exact zeros on both paths).  Real
    svmlight/CSR data satisfies this by construction;
    `data/formats.zero_duplicates` enforces it for synthetic data.

Grid is 1-D over buckets with "arbitrary" dimension semantics: buckets
are processed IN ORDER (sequential SDCA semantics).

Alignment: B and nnz must be multiples of 8 (f32 sublane tile), d_pad
a multiple of 8, and v must fit the VMEM budget below.  Scalars
(lam*n, sigma') ride in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.objectives import Objective
from .pallas_compat import compiler_params as _compiler_params

Array = jax.Array

#: VMEM bytes the resident shared vector may occupy (~half a v5e core's
#: 16 MB, leaving room for double-buffered idx/val tiles + the working
#: set).  d above this must use local_solver="xla" (HBM-resident v) or
#: shard features.
V_VMEM_BUDGET_BYTES = 8 * 2 ** 20

#: Total VMEM the kernel's buffers may claim together (a v5e core has
#: ~16 MiB; leave headroom for Mosaic spills/scratch).  On wide tiles
#: the per-coordinate (B, nnz, nnz) match tensor dominates and must be
#: budgeted up front — exceeding VMEM inside Mosaic is an opaque OOM,
#: not a Python error.
TOTAL_VMEM_BUDGET_BYTES = 14 * 2 ** 20


def vmem_bytes_estimate(B: int, nnz: int, d_pad: int) -> int:
    """Upper-bound VMEM footprint of one grid step.

    Counts the resident v, the double-buffered idx(int32)/val(f32)
    tiles, the W/U/vals/corr working sets, and the per-coordinate
    (B, nnz, nnz) match tensors — the bool compare mask (1 B/elt) AND
    the f32 `jnp.where` product (4 B/elt) are live together in the
    recursion body.  Shared with `ops.sparse_kernel_misfit` so the
    "auto" path can pre-check static shapes and fall back instead of
    raising.
    """
    v = d_pad * 4
    tiles = 2 * B * nnz * (4 + 4)
    work = 4 * B * nnz * 4
    match = B * nnz * nnz * (4 + 1)
    return v + tiles + work + match


def _kernel(obj: Objective, idx_ref, val_ref, y_ref, a_ref, q_ref,
            scal_ref, v_ref, aout_ref, vout_ref):
    """Body for one bucket (one grid step)."""
    first = pl.program_id(0) == 0

    # v lives in the aliased output block; seed it from the input once.
    @pl.when(first)
    def _():
        vout_ref[...] = v_ref[...]

    idx = idx_ref[0]                            # (B, nnz) int32
    vals = val_ref[0].astype(jnp.float32)       # (B, nnz)
    y = y_ref[0].astype(jnp.float32)            # (B,)
    a0 = a_ref[0].astype(jnp.float32)           # (B,)
    # per-row curvature ||x_i||^2, PRECOMPUTED by the wrapper with the
    # scan's exact whole-array row-sum: recomputing it per tile inside
    # the kernel lets XLA vectorize the reduction differently and
    # drifts q by 1 ulp on some rows, which the bisection amplifies —
    # the bitwise contract dies there (found the hard way).
    qrow = q_ref[0].astype(jnp.float32)         # (B,)
    lam_n = scal_ref[0]
    sig = scal_ref[1]
    B, nnz = idx.shape

    # 1. bucket entry: gather the touched rows into the working set
    #    W[i, k] = v[idx[i, k]]  (the only reads of v this bucket)
    def gather(t, W):
        i = t // nnz
        k = t - i * nnz
        p = jax.lax.dynamic_slice(idx, (i, k), (1, 1))[0, 0]
        w = vout_ref[p, 0]
        return jax.lax.dynamic_update_slice(W, w[None, None], (i, k))

    W = jax.lax.fori_loop(0, B * nnz, gather,
                          jnp.zeros((B, nnz), jnp.float32))

    # 2. in-bucket recursion entirely on VMEM-resident state.  After
    #    coordinate i, later rows' working-set entries that alias a
    #    feature i touched receive the SAME u-element the scan
    #    scatter-adds into v, so margins stay bit-equal.
    def body(i, carry):
        W, U, deltas = carry
        vi = jax.lax.dynamic_slice_in_dim(vals, i, 1, 0)[0]    # (nnz,)
        ii = jax.lax.dynamic_slice_in_dim(idx, i, 1, 0)[0]
        wi = jax.lax.dynamic_slice_in_dim(W, i, 1, 0)[0]
        m = jnp.sum(wi * vi)
        q = jax.lax.dynamic_index_in_dim(qrow, i, keepdims=False)
        yi = jax.lax.dynamic_index_in_dim(y, i, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(a0, i, keepdims=False)
        d = obj.delta(m, ai, yi, sig * q / lam_n)
        # the scan's update row, computed once with its association
        u = (sig * d / lam_n) * vi
        match = idx[:, :, None] == ii[None, None, :]   # (B, nnz, nnz)
        corr = jnp.sum(jnp.where(match, u[None, None, :], 0.0), axis=-1)
        hit = jnp.any(match, axis=-1)
        W = jnp.where(hit, W + corr, W)
        U = jax.lax.dynamic_update_slice_in_dim(U, u[None], i, axis=0)
        deltas = jax.lax.dynamic_update_index_in_dim(deltas, d, i, axis=0)
        return W, U, deltas

    _, U, deltas = jax.lax.fori_loop(
        0, B, body, (W, jnp.zeros((B, nnz), jnp.float32),
                     jnp.zeros((B,), jnp.float32)))

    # 3. scatter back into v ONCE per bucket, rows in visiting order so
    #    shared features accumulate in the scan's sequence
    def scatter(t, carry):
        i = t // nnz
        k = t - i * nnz
        p = jax.lax.dynamic_slice(idx, (i, k), (1, 1))[0, 0]
        u = jax.lax.dynamic_slice(U, (i, k), (1, 1))[0, 0]
        vout_ref[p, 0] = vout_ref[p, 0] + u
        return carry

    jax.lax.fori_loop(0, B * nnz, scatter, 0)
    aout_ref[0] = (a0 + deltas).astype(aout_ref.dtype)


@functools.partial(jax.jit, static_argnums=(0, 8, 9))
def sdca_sparse_bucket_kernel(obj: Objective, idx: Array, val: Array,
                              yb: Array, ab: Array, qb: Array,
                              v0: Array, scal: Array,
                              interpret: bool = False,
                              source: str = "ad-hoc arrays"
                              ) -> tuple[Array, Array]:
    """Run the sparse sub-epoch kernel.

    idx/val: (nb, B, nnz) bucket tiles in visiting order (the tile
    cache's on-disk layout); yb, ab, qb: (nb, B) — qb is the per-row
    curvature sum(val^2) precomputed at full-chunk shape (see _kernel);
    v0: (d_pad, 1) f32; scal: (2,) f32 = [lam*n, sigma'].  Returns
    (a_new (nb, B), v_final (d_pad, 1)); v_final includes the
    sigma'-scaled local evolution (callers unscale the global delta).
    `source` names where the tiles came from so alignment errors point
    at the right fix.
    """
    nb, B, nnz = idx.shape
    d_pad = v0.shape[0]
    if B % 8 or nnz % 8:
        raise ValueError(
            f"sparse bucket tiles from {source} have (B={B}, nnz={nnz}); "
            f"the Pallas kernel needs both to be multiples of 8 "
            f"(f32 sublane tile).  Fix: rebuild the tile cache with "
            f"build_cache(..., nnz_multiple=8) / materialize(..., "
            f"nnz_multiple=8) for cached tiles, or zero-pad ad-hoc "
            f"idx/val arrays with idx=0/val=0 columns (and pick a "
            f"bucket size that is a multiple of 8).")
    if d_pad % 8:
        raise ValueError(
            f"v tile from {source} has d_pad={d_pad}, which must be a "
            f"multiple of 8; pad the shared vector with zero rows "
            f"(ops.sdca_sparse_bucket_subepoch does this automatically)")
    if d_pad * 4 > V_VMEM_BUDGET_BYTES:
        raise ValueError(
            f"shared vector of d_pad={d_pad} features ({d_pad * 4} "
            f"bytes) exceeds the sparse kernel's VMEM budget "
            f"({V_VMEM_BUDGET_BYTES} bytes, ~{V_VMEM_BUDGET_BYTES // 4} "
            f"features).  Use local_solver='xla' (HBM-resident v) for "
            f"this workload, or shard features.")
    need = vmem_bytes_estimate(B, nnz, d_pad)
    if need > TOTAL_VMEM_BUDGET_BYTES:
        raise ValueError(
            f"sparse bucket tiles from {source} with (B={B}, nnz={nnz}, "
            f"d_pad={d_pad}) need ~{need} bytes of VMEM — the per-"
            f"coordinate (B, nnz, nnz) match tensor alone is "
            f"{B * nnz * nnz * 5} bytes (bool mask + f32 product) — "
            f"over the kernel's "
            f"{TOTAL_VMEM_BUDGET_BYTES}-byte total budget.  Use "
            f"local_solver='xla' (HBM-resident v) for this workload, or "
            f"shrink bucket/nnz so the tiles fit.")

    grid = (nb,)
    a_new, v_fin = pl.pallas_call(
        functools.partial(_kernel, obj),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, B, nnz), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, B, nnz), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, B), ab.dtype),
            jax.ShapeDtypeStruct((d_pad, 1), jnp.float32),
        ],
        input_output_aliases={6: 1},   # v0 buffer reused as v_final
        compiler_params=_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(idx, val, yb, ab, qb, scal, v0)
    return a_new, v_fin
