"""The ONE deprecation seam for the legacy entry points.

Every pre-`repro.api` training entry point (`GLMTrainer`,
`StreamedGLMTrainer`, `fit_dataset`, `cocoa.epoch_sim*`) funnels its
warning through `warn_deprecated`, so the deprecation surface is
greppable in one place and tests can assert on one warning class
(`ReproDeprecationWarning`, exported from `repro.api`).

The class subclasses `DeprecationWarning`, so standard tooling
(`-W error::DeprecationWarning`, pytest `filterwarnings`) sees it, and
each (old, new) pair is warned at most once per process to keep shim
call sites (benchmark loops, epoch-per-call wrappers) quiet.
"""
from __future__ import annotations

import warnings

__all__ = ["ReproDeprecationWarning", "warn_deprecated"]


class ReproDeprecationWarning(DeprecationWarning):
    """A legacy repro training entry point was used."""


_seen: set[tuple[str, str]] = set()


def warn_deprecated(old: str, replacement: str, *,
                    stacklevel: int = 3) -> None:
    """Warn (once per process per pair) that `old` should become
    `replacement`."""
    key = (old, replacement)
    if key in _seen:
        return
    _seen.add(key)
    warnings.warn(
        f"{old} is deprecated; use {replacement} instead "
        "(see DESIGN.md S10 for the migration map)",
        ReproDeprecationWarning, stacklevel=stacklevel)


def reset_deprecation_registry() -> None:
    """Forget which warnings fired (tests use this to re-assert)."""
    _seen.clear()
