"""sklearn-compatible estimators over the solver engine (DESIGN.md S10).

The paper's bottom line is a 42x speedup over scikit-learn; this module
is the drop-in surface that makes the comparison one import wide:

    from repro.api import LogisticRegression
    clf = LogisticRegression(lam=1e-3, lanes=8).fit(X, y)   # X (n, d)
    clf.predict(X), clf.predict_proba(X), clf.score(X, y)

Estimators follow the sklearn protocol (`fit/predict/score/get_params/
set_params`, `coef_`/`classes_`/`n_iter_` post-fit attributes, keyword-
only constructor params so `sklearn.clone` works) and speak sklearn's
ROW-major layout `X (n_samples, n_features)`; the underlying `Session`
speaks the engine's column-major `(d, n)`.  `fit` accepts everything a
Session does — arrays, scipy CSR matrices, padded-CSR `(idx, val)`
pairs, registry dataset names, `TileCache`s, `ChunkFeed`s — so the same
estimator trains in memory or out of core.

`save(path)`/`Estimator.load(path)` round-trip the WHOLE estimator
(hyperparameters + solver state) through the atomic checkpoint layer;
a loaded estimator predicts immediately and `fit` resumes training
bitwise under `deterministic=True` (pinned by tests/test_api.py).
"""
from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence

import numpy as np

from repro.core.config import EngineConfig

from .session import Session, margins

__all__ = ["GLMEstimator", "LogisticRegression", "LinearSVC", "Ridge",
           "load"]


class NotFittedError(ValueError, AttributeError):
    """Estimator used before `fit` (mirrors sklearn's exception MRO)."""


def _csr_to_padded(sp) -> tuple[np.ndarray, np.ndarray]:
    """scipy CSR/CSC/COO -> engine padded-CSR (idx, val), (n, nnz_max).

    Pad slots use idx=0/val=0 — a zero value contributes nothing to any
    margin or update, so padding is inert by construction.
    """
    sp = sp.tocsr()
    n = sp.shape[0]
    row_nnz = np.diff(sp.indptr)
    nnz = max(int(row_nnz.max(initial=0)), 1)
    idx = np.zeros((n, nnz), np.int32)
    val = np.zeros((n, nnz), np.float32)
    rows = np.repeat(np.arange(n), row_nnz)
    cols = np.arange(len(sp.indices)) - np.repeat(sp.indptr[:-1], row_nnz)
    idx[rows, cols] = sp.indices
    val[rows, cols] = sp.data
    return idx, val


def _is_scipy_sparse(X) -> bool:
    return hasattr(X, "tocsr") and not isinstance(X, (tuple, list))


class GLMEstimator:
    """Shared estimator machinery; subclasses pin the objective.

    Hyperparameters mirror `EngineConfig` (algorithm x deployment
    layers) plus the fit budget; everything is keyword-only and stored
    under its own name, which is exactly what `get_params`/`set_params`
    (and therefore `sklearn.base.clone`) require.
    """

    _objective = "logistic"
    _classifier = True

    def __init__(self, *, lam: float = 1e-3, max_epochs: int = 100,
                 tol: float = 1e-3, bucket: int = 8, pods: int = 1,
                 lanes: int = 1, chunks: int = 1,
                 partition: str = "hierarchical",
                 aggregation: str = "adding", local_solver: str = "auto",
                 redeal_frac: float = 1.0, compress_sync: bool = False,
                 compress_pod: bool = False, deterministic: bool = False,
                 seed: int = 0, gap_every: int = 0, verbose: bool = False,
                 streamed: bool = False, cache_dir=None, data_dir=None,
                 n_features: Optional[int] = None,
                 callbacks: Optional[Sequence] = None,
                 health=None, journal_dir=None):
        self.lam = lam
        self.max_epochs = max_epochs
        self.tol = tol
        self.bucket = bucket
        self.pods = pods
        self.lanes = lanes
        self.chunks = chunks
        self.partition = partition
        self.aggregation = aggregation
        self.local_solver = local_solver
        self.redeal_frac = redeal_frac
        self.compress_sync = compress_sync
        self.compress_pod = compress_pod
        self.deterministic = deterministic
        self.seed = seed
        self.gap_every = gap_every
        self.verbose = verbose
        self.streamed = streamed
        self.cache_dir = cache_dir
        self.data_dir = data_dir
        self.n_features = n_features
        self.callbacks = callbacks
        # resilience knobs (DESIGN.md S15): `health` is a HealthPolicy/
        # True for the numerical-health guard, `journal_dir` enables
        # crash-safe epochs on streamed fits — both forwarded to Session
        self.health = health
        self.journal_dir = journal_dir
        self._resume_state: Optional[dict[str, Any]] = None

    # -- sklearn parameter protocol ---------------------------------------

    @classmethod
    def _param_names(cls) -> list[str]:
        sig = inspect.signature(cls.__init__)
        return [p for p in sig.parameters if p != "self"]

    def get_params(self, deep: bool = True) -> dict[str, Any]:
        """Constructor parameters as a dict (sklearn protocol)."""
        return {name: getattr(self, name) for name in self._param_names()}

    def set_params(self, **params) -> "GLMEstimator":
        """Set constructor parameters in place; returns self (sklearn protocol)."""
        valid = set(self._param_names())
        for name, value in params.items():
            if name not in valid:
                raise ValueError(
                    f"invalid parameter {name!r} for {type(self).__name__};"
                    f" valid: {sorted(valid)}")
            setattr(self, name, value)
        return self

    def engine_config(self) -> EngineConfig:
        """The `EngineConfig` this estimator's parameters resolve to."""
        return EngineConfig.make(
            pods=self.pods, lanes=self.lanes, bucket=self.bucket,
            chunks=self.chunks, partition=self.partition,
            aggregation=self.aggregation, local_solver=self.local_solver,
            redeal_frac=self.redeal_frac, compress_sync=self.compress_sync,
            compress_pod=self.compress_pod,
            deterministic=self.deterministic, seed=self.seed)

    # -- fitting -----------------------------------------------------------

    def _label_transform(self, y) -> np.ndarray:
        """Map arbitrary binary labels onto the engine's {-1, +1}."""
        y = np.asarray(y)
        classes = np.unique(y)
        if classes.shape[0] != 2:
            raise ValueError(
                f"{type(self).__name__} is a binary classifier; got "
                f"{classes.shape[0]} classes")
        if self._resume_state is not None and hasattr(self, "classes_") \
                and not np.array_equal(classes, self.classes_):
            raise ValueError("resumed fit saw different classes than the "
                             f"checkpoint: {classes} vs {self.classes_}")
        self.classes_ = classes
        return np.where(y == classes[1], 1.0, -1.0).astype(np.float32)

    def _make_session(self, X, y) -> Session:
        kw = dict(objective=self._objective, lam=self.lam,
                  cfg=self.engine_config(), streamed=self.streamed,
                  cache_dir=self.cache_dir, data_dir=self.data_dir,
                  bucket=self.bucket, health=self.health,
                  journal_dir=self.journal_dir)
        if isinstance(X, str) or hasattr(X, "gather_buckets") \
                or hasattr(X, "fetch"):
            if y is not None:
                raise ValueError("labels come from the dataset/feed "
                                 "itself; pass y=None")
            if self._classifier and not hasattr(self, "classes_"):
                # dataset/cache/feed labels are already in the engine's
                # {-1, +1} space
                self.classes_ = np.array([-1.0, 1.0], np.float32)
            return Session(X, **kw)
        if y is None:
            raise ValueError("array input requires y")
        if self._classifier:
            y = self._label_transform(y)
        else:
            y = np.asarray(y, np.float32)
        if _is_scipy_sparse(X):
            idx, val = _csr_to_padded(X)
            return Session((idx, val), y, d=int(X.shape[1]), **kw)
        if isinstance(X, (tuple, list)):          # engine (idx, val) pair
            idx, val = X
            d = self.n_features or int(np.asarray(idx).max()) + 1
            return Session((idx, val), y, d=d, **kw)
        X = np.asarray(X, np.float32)
        if X.ndim != 2:
            raise ValueError(f"X must be 2-D (n_samples, n_features); "
                             f"got shape {X.shape}")
        return Session(X.T, y, **kw)              # sklearn -> engine layout

    def fit(self, X, y=None) -> "GLMEstimator":
        """Train to `max_epochs` TOTAL epochs (or `tol` convergence).

        On an estimator restored by `load`, training resumes from the
        checkpointed epoch and runs the REMAINING epochs — so
        `fit(3); save; load; fit()` equals one uninterrupted fit
        (bitwise under `deterministic=True`).
        """
        self.session_ = self._make_session(X, y)
        if self._resume_state is not None:
            st = self._resume_state
            if st["v"].shape[0] != self.session_.d:
                raise ValueError(
                    f"checkpoint d={st['v'].shape[0]} != data "
                    f"d={self.session_.d}")
            if st["alpha"].shape[0] != self.session_.n:
                raise ValueError(
                    f"checkpoint n={st['alpha'].shape[0]} != data "
                    f"n={self.session_.n} (after padding); resume needs "
                    "the same examples the checkpoint was trained on")
            self.session_.load_state_dict(st)
            self._resume_state = None
        res = self.session_.fit(
            until=self.max_epochs, tol=self.tol, gap_every=self.gap_every,
            callbacks=self.callbacks or (), verbose=self.verbose)
        self.fit_result_ = res
        self.coef_ = np.asarray(res.v)
        self.intercept_ = 0.0
        self.n_iter_ = res.epochs
        return self

    # -- inference ---------------------------------------------------------

    def _check_fitted(self) -> None:
        if not hasattr(self, "coef_"):
            raise NotFittedError(
                f"this {type(self).__name__} instance is not fitted yet; "
                "call fit(X, y) first")

    def _margins(self, X) -> np.ndarray:
        self._check_fitted()
        if _is_scipy_sparse(X):
            X = _csr_to_padded(X)
        if isinstance(X, (tuple, list)):
            return np.asarray(margins(self.coef_, tuple(X)))
        X = np.asarray(X, np.float32)
        return np.asarray(margins(self.coef_, X.T))

    def decision_function(self, X) -> np.ndarray:
        """Signed margins x_i^T w, shape (n_samples,)."""
        return self._margins(X)

    def predict(self, X) -> np.ndarray:
        """Class labels for classifiers, real-valued predictions otherwise."""
        m = self._margins(X)
        if not self._classifier:
            return m
        return np.asarray(self.classes_)[(m > 0).astype(int)]

    def score(self, X, y) -> float:
        """Accuracy (classifiers) / R^2 (regressors) — sklearn's default."""
        y = np.asarray(y)
        if self._classifier:
            return float(np.mean(self.predict(X) == y))
        resid = y - self.predict(X)
        denom = np.sum((y - y.mean()) ** 2)
        return float(1.0 - np.sum(resid ** 2) / max(denom, 1e-30))

    # -- whole-estimator checkpointing ------------------------------------

    def save(self, path) -> None:
        """Atomic snapshot: hyperparameters + solver state + classes.

        Path-like params are stored as strings; params that cannot be
        serialized (e.g. callback objects) are dropped with a warning —
        re-attach them after `load`."""
        self._check_fitted()
        import os
        import warnings as _warnings
        from repro.checkpoint import save_tree
        params = {k: (os.fspath(v) if isinstance(v, os.PathLike) else v)
                  for k, v in self.get_params().items()}
        dropped = sorted(k for k, v in params.items()
                         if not _jsonable(v))
        if dropped:
            _warnings.warn(
                f"estimator params not serializable, dropped from the "
                f"checkpoint (re-set them after load): {dropped}",
                UserWarning, stacklevel=2)
        meta = {"estimator": type(self).__name__,
                "params": {k: v for k, v in params.items()
                           if _jsonable(v)},
                "n": int(self.session_.n), "d": int(self.session_.d)}
        if self._classifier and hasattr(self, "classes_"):
            meta["classes"] = np.asarray(self.classes_).tolist()
        save_tree(path, self.session_.state_dict(), meta=meta)

    @classmethod
    def load(cls, path) -> "GLMEstimator":
        """Restore an estimator saved by `save` (module-level `load`
        dispatches on the stored class name)."""
        from repro.checkpoint import restore_tree
        target = _state_target(path)
        st, meta = restore_tree(path, target)
        klass = _ESTIMATORS.get(meta.get("estimator"), cls)
        if cls is not GLMEstimator and klass is not cls:
            raise ValueError(f"{path} holds a {meta.get('estimator')}, "
                             f"not a {cls.__name__}")
        est = klass(**meta.get("params", {}))
        if "classes" in meta:
            est.classes_ = np.asarray(meta["classes"])
        est._resume_state = st
        est.coef_ = np.asarray(st["v"])
        est.intercept_ = 0.0
        est.n_iter_ = int(st["epoch"])
        return est


def _jsonable(v) -> bool:
    return isinstance(v, (int, float, str, bool, type(None)))


def _state_target(path) -> dict[str, np.ndarray]:
    """Shape the restore target from the checkpoint's own manifest."""
    import json
    import pathlib
    manifest = json.loads(
        (pathlib.Path(path) / "keys.json").read_text())
    return {m["key"]: np.zeros(m["shape"], dtype=m["dtype"])
            for m in manifest}


class LogisticRegression(GLMEstimator):
    """Binary logistic regression — paper's headline objective.

    Regularization: minimizes ``(1/n) sum log-loss + (lam/2)||w||^2``
    (no intercept).  sklearn equivalence: ``C = 1 / (lam * n)`` with
    ``fit_intercept=False`` — the fig3/fig6 parity arm uses exactly
    that mapping.
    """

    _objective = "logistic"
    _classifier = True

    def predict_proba(self, X) -> np.ndarray:
        """(n, 2) probabilities, columns ordered like `classes_`."""
        m = self._margins(X)
        p1 = 1.0 / (1.0 + np.exp(-m))
        return np.stack([1.0 - p1, p1], axis=1)

    def predict_log_proba(self, X) -> np.ndarray:
        """Log of `predict_proba`, clipped away from -inf."""
        return np.log(np.maximum(self.predict_proba(X), 1e-30))


class LinearSVC(GLMEstimator):
    """Linear SVM (hinge loss, box-constrained dual)."""

    _objective = "hinge"
    _classifier = True


class Ridge(GLMEstimator):
    """Ridge regression (squared loss); `score` is R^2."""

    _objective = "ridge"
    _classifier = False


_ESTIMATORS = {c.__name__: c
               for c in (LogisticRegression, LinearSVC, Ridge)}


def load(path) -> GLMEstimator:
    """Restore whichever estimator class `path` holds."""
    return GLMEstimator.load(path)
