"""The public training API: estimators + Session (DESIGN.md S10).

One front door for every backend and data source:

    from repro import api
    clf = api.LogisticRegression(lanes=8, bucket=8).fit(X, y)
    s = api.Session("higgs", streamed=True); s.fit(until=20)

Everything older (`GLMTrainer`, `StreamedGLMTrainer`, `fit_dataset`,
`cocoa.epoch_sim*`) is a deprecation shim over these — see the
migration map in DESIGN.md S10 and `ReproDeprecationWarning`.
"""
from .callbacks import (BenchmarkRecorder, Callback, CheckpointHook,
                        EarlyStopping, GapLogger)
from .deprecation import ReproDeprecationWarning, warn_deprecated
from .estimators import (GLMEstimator, LinearSVC, LogisticRegression,
                         NotFittedError, Ridge, load)
from .session import Session, margins
# resilience surface (repro.resilience re-exported here so the fault-
# tolerant knobs live next to the estimators that take them)
from repro.resilience import HealthMonitor, HealthPolicy

__all__ = [
    "BenchmarkRecorder", "Callback", "CheckpointHook", "EarlyStopping",
    "GapLogger",
    "ReproDeprecationWarning", "warn_deprecated",
    "GLMEstimator", "LinearSVC", "LogisticRegression", "NotFittedError",
    "Ridge", "load",
    "Session", "margins",
    "HealthMonitor", "HealthPolicy",
]
