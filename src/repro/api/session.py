"""`Session`: the ONE owner of GLM solver state for every front end.

Every way of training the paper's solver — resident arrays, registry
dataset names, bucket-tile caches, out-of-core `ChunkFeed`s — used to
have its own driver (`GLMTrainer`, `StreamedGLMTrainer`, `fit_dataset`,
`cocoa.epoch_sim*`).  A `Session` subsumes them: it resolves the data
source once, owns the engine state (`alpha`, `v`, epoch counter, the
jitted epoch program), and exposes epoch-level control:

    s = Session((X, y), objective="logistic", lam=1e-3, cfg=cfg)
    s.epoch()                 # run exactly one epoch, get metrics back
    s.fit(until=10)           # train up to absolute epoch 10
    s.fit(max_epochs=5)       # ... or 5 more epochs from wherever we are

`fit` drives a callback protocol (`on_epoch_end(metrics) -> stop?`,
see `repro.api.callbacks`) used for early stopping, gap logging,
checkpoint hooks, and benchmark recording.  The sklearn-style
estimators in `repro.api.estimators` are thin facades over a Session;
the legacy trainers are deprecation shims over it (DESIGN.md S10).

Data sources accepted by the constructor, uniformly:

  * ``(X, y)``            dense arrays, engine layout ``X (d, n)``;
  * ``((idx, val), y)``   padded-CSR sparse (requires ``d=``);
  * ``"higgs"``           any `repro.data.registry` name (honouring
                          ``streamed=``/``cache_dir=``/``data_dir=``);
  * a `TileCache`         in-memory (``streamed=False``) or out-of-core;
  * a `ChunkFeed`         streamed training over any feed.

Examples are PADDED (x=0, y=+1 — inert, a zero row never moves v) up
to the multiple the chosen topology needs, so any sklearn-shaped n
trains without manual padding; ``n_examples`` records the true count.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, objectives
from repro.core.bucketing import BucketPlan, make_plan
from repro.core.config import EngineConfig, as_engine_config
from repro.core.objectives import Objective, get_objective
from repro.core.partition import PartitionPlan
from repro.core.trainer import FitResult

Array = jax.Array

__all__ = ["Session", "margins"]


def margins(v, data) -> jnp.ndarray:
    """Decision margins x_i^T v for engine-layout data.

    ``data`` is dense ``X (d, n)`` or a padded-CSR ``(idx, val)`` pair;
    returns ``(n,)``.  The one margin kernel shared by estimator
    ``decision_function``/``predict`` and the serving batch path.
    """
    if isinstance(data, (tuple, list)):
        idx, val = data
        return jnp.sum(jnp.asarray(v)[jnp.asarray(idx)]
                       * jnp.asarray(val), axis=1)
    return jnp.asarray(data).T @ jnp.asarray(v)


def _pad_multiple(spec: EngineConfig, bucket: int) -> int:
    """Example-count multiple every partition mode divides: the same
    pods*lanes*lanes*chunks*bucket rule the tile cache builds with."""
    dep, algo = spec.deployment, spec.algo
    return dep.pods * dep.lanes * dep.lanes * algo.chunks * max(bucket, 1)


def _check_sparse_kernel_invariant(spec: EngineConfig, idx: np.ndarray,
                                   val: np.ndarray, d: int,
                                   bucket: int) -> None:
    """Ad-hoc sparse rows headed for the Pallas kernel must hold the
    CSR no-duplicate-nonzero invariant (DESIGN.md S11) — checked HERE,
    while the arrays are still concrete host arrays: inside the jitted
    epoch program they are tracers and `kernels.ops` cannot see the
    values.  Only enforced when the kernel will actually run them: the
    XLA scan accumulates duplicates fine, so "auto" off-TPU, explicit
    "xla", and backend-picked "auto" workloads the engine's misfit
    fallback routes to the scan anyway all keep accepting such rows.
    `bucket` must be the RESOLVED bucket (the one make_plan/the feed
    will run with), not spec.algo.bucket — the two differ when the
    Session bucket kwarg overrides the config.
    """
    kind = spec.algo.local_solver
    if kind not in ("pallas", "auto"):
        return
    if kind == "auto":
        kind, explicit = engine._resolve_auto()
        if kind != "pallas":
            return
        if not explicit:
            from repro.kernels import ops as kops
            B = max(bucket, 1)
            # n_local=B: divisibility is guaranteed by Session padding,
            # so only the shape/budget misfits matter here
            if kops.sparse_kernel_misfit(B, idx.shape[1], d, B):
                return   # engine falls back to the XLA scan per-workload
    from repro.data.formats import raise_on_duplicate_nonzeros
    raise_on_duplicate_nonzeros(idx, val, "ad-hoc sparse rows")


class Session:
    """Engine state + epoch control over one resolved data source."""

    def __init__(self, data, y=None, *, objective: str | Objective | None
                 = None, lam: Optional[float] = None,
                 cfg: Any = None, d: Optional[int] = None,
                 bucket: Optional[int] = None, streamed: bool = False,
                 mesh=None,
                 cache_dir=None, data_dir=None, n: Optional[int] = None,
                 nnz_multiple: Optional[int] = None,
                 pad: bool = True, jit_step: bool = True,
                 health=None, journal_dir=None, journal_every: int = 1,
                 faults=None):
        self.spec = as_engine_config(cfg) if cfg is not None \
            else EngineConfig()
        self.cfg = cfg if cfg is not None else self.spec
        self.streamed = streamed
        # `mesh=` routes the streamed loop through the real-mesh input
        # pipeline (launch.glm.make_streamed_epoch_mesh / DESIGN.md
        # S16): chunks land pre-sharded via double-buffered device_put
        # instead of the stacked-sim layout.  `stream_stats` collects
        # the last epoch's ingest-overlap metrics on that path.
        self._mesh = mesh
        self.stream_stats: dict[str, float] = {}
        self.cache = None
        self.feed = None
        self.solver_plan = None       # set when "auto" routes via planner
        self.history: list[dict[str, float]] = []
        # resilience runtime (DESIGN.md S15) — all opt-in, all zero
        # overhead when left at the defaults.  `health` is a
        # HealthPolicy/HealthMonitor (or True for the defaults) that
        # fit() turns into a monitor callback; `journal_dir` enables
        # the crash-safe epoch journal; `faults` injects a deterministic
        # FaultInjector (tests), defaulting to $REPRO_FAULTS.
        from repro.resilience import EpochJournal, FaultInjector
        self._health = health
        self._damp = 1.0
        self._jit_step = jit_step
        self._faults = (faults if faults is not None
                        else FaultInjector.from_env())
        self._journal = (EpochJournal(journal_dir, every=journal_every,
                                      injector=self._faults)
                         if journal_dir is not None else None)

        # `Session((X, y))` / `Session(((idx, val), y))` sugar — only
        # when the second element is labels-shaped (1-D), so a
        # forgotten-y `Session((idx, val))` still raises clearly below
        if (y is None and isinstance(data, (tuple, list))
                and len(data) == 2 and not hasattr(data[0], "fetch")
                and np.ndim(data[1]) == 1):
            data, y = data

        if isinstance(data, str):
            self._init_from_registry(
                data, objective=objective, lam=lam, bucket=bucket,
                streamed=streamed, cache_dir=cache_dir,
                data_dir=data_dir, n=n, d=d,
                nnz_multiple=nnz_multiple, jit_step=jit_step)
        elif hasattr(data, "gather_buckets"):      # TileCache
            self._init_from_cache(data, objective=objective, lam=lam,
                                  streamed=streamed, jit_step=jit_step)
        elif hasattr(data, "fetch"):               # ChunkFeed
            self._init_from_feed(data, objective=objective, lam=lam,
                                 jit_step=jit_step)
        else:                                      # arrays
            if y is None:
                raise TypeError("array data requires labels: "
                                "Session((X, y)) or Session(X, y)")
            self._init_from_arrays(data, y, objective=objective, lam=lam,
                                   d=d, bucket=bucket, pad=pad,
                                   jit_step=jit_step)
        if self._mesh is not None and self.feed is None:
            raise ValueError(
                "mesh= streams chunks onto the mesh, so it needs a "
                "streamed source: pass streamed=True (arrays/registry/"
                "cache) or a ChunkFeed")
        if self._journal is not None:
            # restart path: pick up the last committed epoch state, so
            # a re-constructed Session (new process after a crash)
            # continues exactly where the journal says — any mid-epoch
            # inflight record is consumed by the streamed loop itself
            got = self._journal.load_epoch(self.alpha, self.v)
            if got is not None:
                alpha, v, done = got
                self.alpha, self.v = jnp.asarray(alpha), jnp.asarray(v)
                self.epochs_done = done

    # -- construction: one per data source --------------------------------

    def _resolve_obj(self, objective, lam, default_obj="logistic",
                     default_lam=1e-3) -> None:
        objective = objective or default_obj
        self.obj = (objective if isinstance(objective, Objective)
                    else get_objective(objective))
        self.lam = float(default_lam if lam is None else lam)

    def _init_from_arrays(self, data, y, *, objective, lam, d, bucket,
                          pad, jit_step: bool = True,
                          trusted_rows: bool = False) -> None:
        """Resident-array setup.  When padding grows n -> n', lam is
        rescaled by n/n' so the padded objective

            (1/n') [sum_real loss + const] + (lam n / (2 n')) ||w||^2
          = (n/n') * [user objective] + const/n'

        keeps the USER's argmin exactly (and lam*n — the dual scaling —
        is unchanged); the inert rows' primal/dual terms cancel in the
        gap once their duals settle, so the certificate stays valid."""
        self._resolve_obj(objective, lam)
        sparse = isinstance(data, (tuple, list))
        y = np.asarray(y, np.float32)
        self.n_examples = y.shape[0]
        algo = self.spec.algo
        force = bucket if bucket is not None else (algo.bucket or None)
        B = force if force else 1
        # local_solver="auto" routes through the system-aware planner
        # (DESIGN.md S13).  Under the default $REPRO_PLAN=on|off the
        # geometry below stays bitwise today's static resolution (the
        # plan only records the route); $REPRO_PLAN=search|probe lets
        # the planner pick bucket/chunks when the caller left them at
        # the defaults (bucket kwarg unset and algo.bucket <= 1).
        self.solver_plan = None
        from repro.core import planner
        if (algo.local_solver == "auto" and (not sparse or d is not None)
                and planner.plan_mode() != "off"):
            open_geom = ((bucket is None and (algo.bucket or 1) == 1)
                         and planner.plan_mode() in ("search", "probe"))
            sig = planner.WorkloadSignature(
                n=int(y.shape[0]),
                d=int(d) if sparse else int(np.shape(data)[0]),
                nnz=int(np.shape(data[0])[1]) if sparse else 0,
                sparse=sparse)
            self.solver_plan = planner.resolve_plan(
                sig, planner.Topology.detect(self.spec),
                bucket=None if open_geom else B,
                chunks=None if open_geom else algo.chunks)
            if open_geom:
                force = B = self.solver_plan.bucket
                if self.solver_plan.chunks != algo.chunks:
                    algo = dataclasses.replace(
                        algo, chunks=self.solver_plan.chunks)
                    self.spec = dataclasses.replace(self.spec, algo=algo)
        idx = val = X = None
        if sparse:
            idx = np.asarray(data[0], np.int32)
            val = np.asarray(data[1], np.float32)
            if d is None:
                raise ValueError("sparse array data requires d")
            if not trusted_rows:
                # B is the resolved bucket make_plan/ArrayFeed run with
                _check_sparse_kernel_invariant(self.spec, idx, val,
                                               int(d), B)
            if pad:
                from repro.data.cache import pad_examples
                y, _, idx, val = pad_examples(
                    y, _pad_multiple(self.spec, B), idx=idx, val=val)
            self.n, self.d = int(y.shape[0]), int(d)
        else:
            X = np.asarray(data, np.float32)
            self.d = int(X.shape[0])
            if pad:
                from repro.data.cache import pad_examples
                y, X, _, _ = pad_examples(
                    y, _pad_multiple(self.spec, B), X=X)
            self.n = int(y.shape[0])
        if self.n > self.n_examples:
            self.lam *= self.n_examples / self.n

        if self.streamed:
            # arrays + streamed=True: drive the out-of-core loop over an
            # ArrayFeed built from the HOST arrays — nothing
            # example-sized goes device-resident (only alpha/v do)
            from repro.data.cache import ArrayFeed
            if sparse:
                feed = ArrayFeed(y, idx=idx, val=val, d=self.d, bucket=B)
            else:
                feed = ArrayFeed(y, X=X, bucket=B)
            self._init_from_feed(feed, objective=self.obj, lam=self.lam,
                                 jit_step=jit_step, rows_checked=True,
                                 lam_scaled=True)
            return

        if sparse:
            self.idx = jnp.asarray(idx)
            self.val = jnp.asarray(val)
        else:
            self.X = jnp.asarray(X)
        self.y = jnp.asarray(y)
        self.sparse = sparse

        dep = self.spec.deployment
        self.bplan = make_plan(self.n, self.d, force=force or 1)
        if self.bplan.bucket != algo.bucket:
            # keep the plan's bucket authoritative (run_epoch chunks by
            # algo.bucket; single source of truth)
            algo = dataclasses.replace(algo, bucket=self.bplan.bucket)
            self.spec = dataclasses.replace(self.spec, algo=algo)
        self.plan = PartitionPlan(
            n_buckets=self.bplan.n_buckets, pods=dep.pods,
            lanes=dep.lanes, mode=algo.partition, seed=algo.seed,
            redeal_frac=algo.redeal_frac)
        self._init_state()
        self._rebuild_epoch_fn()

    def _init_from_cache(self, cache, *, objective, lam, streamed,
                         jit_step) -> None:
        meta = cache.meta
        self._resolve_obj(objective, lam, default_obj=meta.objective)
        if meta.n > meta.n_examples:
            # cache tiles arrive PRE-padded (pad=False / feed below), so
            # `_init_from_arrays`' padded-objective lam rescale never
            # fires on this path — apply the same n_examples/n factor
            # here so the inert rows keep the user's argmin exactly
            # (see _init_from_arrays' docstring for the algebra)
            self.lam *= meta.n_examples / meta.n
        algo = self.spec.algo
        if algo.bucket not in (0, 1, meta.bucket):
            raise ValueError(
                f"cfg bucket={algo.bucket} != cache bucket={meta.bucket}; "
                f"rebuild the cache at the training bucket size")
        if not streamed:
            arrays, y = cache.load_arrays()
            # cache builds dedupe rows (CACHE_VERSION 2) — don't re-sort
            # the whole dataset at construction to re-prove it
            kw = dict(objective=self.obj, lam=self.lam,
                      bucket=meta.bucket, pad=False, trusted_rows=True)
            if meta.kind == "sparse":
                self._init_from_arrays(arrays, y, d=meta.d, **kw)
            else:
                self._init_from_arrays(arrays, y, d=None, **kw)
            self.cache = cache
            self.n_examples = meta.n_examples
            return
        self.cache = cache
        self.streamed = True
        self._init_from_feed(cache.feed(), objective=self.obj,
                             lam=self.lam, jit_step=jit_step,
                             rows_checked=True, lam_scaled=True)

    def _init_from_feed(self, feed, *, objective, lam, jit_step,
                        rows_checked: bool = False,
                        lam_scaled: bool = False) -> None:
        self._resolve_obj(objective, lam)
        self.feed = feed
        self.streamed = True
        self.sparse = bool(feed.sparse)
        self.n, self.d = int(feed.n), int(feed.d)
        if (not rows_checked and self.sparse
                and getattr(feed, "cache", None) is None):
            # a user-supplied feed: check its rows here if it exposes
            # them as concrete host arrays (ArrayFeed); opaque
            # ChunkFeeds are bound by the protocol's documented CSR
            # invariant instead (engine.ChunkFeed)
            fidx = getattr(feed, "idx", None)
            fval = getattr(feed, "val", None)
            if fidx is not None and fval is not None:
                _check_sparse_kernel_invariant(
                    self.spec, np.asarray(fidx), np.asarray(fval),
                    self.d, int(feed.bucket))
        src_cache = getattr(feed, "cache", None)
        if src_cache is not None:
            self.n_examples = src_cache.meta.n_examples
            if not lam_scaled and self.n > self.n_examples:
                # a cache-backed feed handed to Session directly:
                # same padded-objective lam rescale as _init_from_cache
                # (which passes lam_scaled=True to not apply it twice)
                self.lam *= self.n_examples / self.n
        elif not hasattr(self, "n_examples"):
            self.n_examples = self.n
        algo, dep = self.spec.algo, self.spec.deployment
        if algo.bucket not in (0, 1, feed.bucket):
            raise ValueError(
                f"cfg bucket={algo.bucket} != feed bucket={feed.bucket}")
        self.bplan = BucketPlan(n=self.n, bucket=feed.bucket,
                                n_buckets=self.n // feed.bucket)
        self.plan = PartitionPlan(
            n_buckets=self.bplan.n_buckets, pods=dep.pods,
            lanes=dep.lanes, mode=algo.partition, seed=algo.seed,
            redeal_frac=algo.redeal_frac)
        self._init_state()
        self._rebuild_epoch_fn()

    def _init_from_registry(self, name, *, objective, lam, bucket,
                            streamed, cache_dir, data_dir, n, d,
                            nnz_multiple=None, jit_step=True) -> None:
        from repro.data import registry

        spec = registry.get_spec(name)
        objective = objective or spec.objective
        lam = spec.lam if lam is None else lam
        algo, dep = self.spec.algo, self.spec.deployment
        B = bucket or max(algo.bucket, 1)
        if streamed or cache_dir is not None:
            # nnz_multiple is the user-facing end of the sparse-kernel
            # alignment contract: raw svmlight ingests with odd row
            # widths pass nnz_multiple=8 HERE (or via fit_dataset) and
            # the built tiles land lane-aligned (DESIGN.md S11)
            cache = registry.materialize(
                name, cache_dir, bucket=B, pods=dep.pods, n=n, d=d,
                pad_multiple=_pad_multiple(self.spec, B),
                nnz_multiple=nnz_multiple, data_dir=data_dir)
            self._init_from_cache(cache, objective=objective, lam=lam,
                                  streamed=streamed, jit_step=jit_step)
            return
        ds = registry.get_dataset(name, n=n, d=d, data_dir=data_dir)
        if ds.sparse:
            # registry rows are deduped at the source (synthetic
            # samplers run zero_duplicates; svmlight holds the
            # invariant by construction)
            self._init_from_arrays((ds.idx, ds.val), ds.y,
                                   objective=objective, lam=lam,
                                   d=ds.d, bucket=B, pad=True,
                                   trusted_rows=True)
        else:
            self._init_from_arrays(ds.X, ds.y, objective=objective,
                                   lam=lam, d=None, bucket=B, pad=True)

    def _init_state(self) -> None:
        if not hasattr(self, "n_examples"):
            self.n_examples = self.n
        self.alpha = jnp.zeros(self.n, jnp.float32)
        self.v = jnp.zeros(self.d, jnp.float32)
        self.epochs_done = 0

    def _rebuild_epoch_fn(self) -> None:
        """(Re)compile the epoch program from the current spec/damp —
        called at construction and by health remedies (solver reroute,
        damping) that change how an epoch runs."""
        if self.feed is not None and self._mesh is not None:
            from repro.launch import glm
            dep = self.spec.deployment
            kw: dict[str, Any] = {}
            if self.sparse:
                kw["feature_shard"] = dep.feature_shard
                nnz = getattr(self.feed, "nnz", None)  # MeshChunkFeed
                if not nnz:
                    inner = getattr(self.feed, "feed", self.feed)
                    fidx = getattr(inner, "idx", None)
                    if fidx is not None:
                        nnz = int(np.shape(fidx)[-1])
                if nnz:
                    kw["nnz"] = int(nnz)
            scale = glm.scale_for_estimator(self, **kw)
            self._epoch_fn = glm.make_streamed_epoch_mesh(
                scale, self._mesh, self.feed, obj=self.obj,
                journal=self._journal, damp=self._damp,
                stats=self.stream_stats, jit_step=self._jit_step)
        elif self.feed is not None:
            self._epoch_fn = engine.make_streamed_epoch(
                self.obj, self.spec, self.plan, self.feed, lam=self.lam,
                jit_step=self._jit_step, journal=self._journal,
                damp=self._damp)
        elif self.sparse:
            self._epoch_fn = jax.jit(
                lambda a, v, e: engine.sim_epoch_sparse(
                    self.obj, self.idx, self.val, self.y, a, v, self.lam,
                    self.plan, self.bplan, self.spec, e,
                    dv_scale_mul=self._damp))
        else:
            self._epoch_fn = jax.jit(
                lambda a, v, e: engine.sim_epoch_dense(
                    self.obj, self.X, self.y, a, v, self.lam,
                    self.plan, self.bplan, self.spec, e,
                    dv_scale_mul=self._damp))

    def _switch_local_solver(self, kind: str) -> None:
        """Reroute the local solver (the health guard's pallas→xla
        fallback — `_auto_fallback`'s warn-and-reroute idiom, made
        stateful) and rebuild the epoch program."""
        algo = dataclasses.replace(self.spec.algo, local_solver=kind)
        self.spec = dataclasses.replace(self.spec, algo=algo)
        self._rebuild_epoch_fn()

    # -- epoch-level control ----------------------------------------------

    def epoch(self) -> dict[str, float]:
        """Run exactly one epoch; returns {'epoch', 'rel_change', 't'}.

        't' is this epoch's duration when called standalone; inside
        `fit` the same record's 't' is rewritten to the cumulative
        fit wall-clock (one shared record, also kept in `history`)."""
        t0 = time.perf_counter()
        if self._faults is not None:
            # deterministic fault probes ($REPRO_FAULTS / tests):
            # epoch-boundary kill, kernel failure on pallas routes,
            # post-epoch NaN poisoning (the resident twin of nan-chunk)
            self._faults.maybe_kill(self.epochs_done)
            if self.spec.algo.local_solver != "xla":
                self._faults.maybe_kernel_fail(self.epochs_done)
        v_prev = self.v
        self.alpha, self.v = self._epoch_fn(
            self.alpha, self.v, jnp.int32(self.epochs_done))
        if self._faults is not None \
                and self._faults.nan_epoch(self.epochs_done):
            self.v = self.v * jnp.float32(float("nan"))
        self.epochs_done += 1
        if self._journal is not None:
            self._journal.commit_epoch(self.alpha, self.v,
                                       self.epochs_done)
        rel = float(jnp.linalg.norm(self.v - v_prev)
                    / jnp.maximum(jnp.linalg.norm(self.v), 1e-30))
        rec = {"epoch": self.epochs_done, "rel_change": rel,
               "t": time.perf_counter() - t0}
        self.history.append(rec)
        return rec

    def fit(self, *, until: Optional[int] = None,
            max_epochs: Optional[int] = None, tol: float = 1e-3,
            gap_every: int = 0, callbacks: Sequence = (),
            verbose: bool = False, diverge_above: float = 1e8,
            health=None) -> FitResult:
        """Train to `until` (absolute epoch) or `max_epochs` more epochs.

        Stops early when the relative model change drops below `tol`
        (the paper's stopping rule), when the iterate diverges, or when
        any callback's `on_epoch_end(metrics)` returns truthy.
        Re-entrant: a second `fit` continues from the current state, and
        schedules are pure functions of (seed, epoch), so
        stop/checkpoint/resume reproduces an uninterrupted run bitwise.

        ``health`` (a `HealthPolicy`, `HealthMonitor`, or True for the
        defaults; falls back to the Session's ``health=`` kwarg)
        installs the numerical-health guard: instead of the built-in
        break on divergence, an unhealthy epoch (or one that raises)
        rolls back to the last healthy snapshot and is retried /
        remediated per the policy (repro.resilience.health).
        """
        if until is None:
            until = self.epochs_done + (100 if max_epochs is None
                                        else max_epochs)
        elif max_epochs is not None:
            raise TypeError("pass either until= or max_epochs=, not both")
        from repro.resilience import HealthMonitor, HealthPolicy
        cbs = list(callbacks)
        monitor = next((cb for cb in cbs
                        if isinstance(cb, HealthMonitor)), None)
        health = health if health is not None else self._health
        if monitor is None and health is not None:
            if isinstance(health, HealthMonitor):
                monitor = health
            elif isinstance(health, HealthPolicy):
                monitor = HealthMonitor(health)
            else:                      # health=True -> default policy
                monitor = HealthMonitor()
            # first in line: it must see (and repair) the state before
            # other callbacks consume the epoch record
            cbs.insert(0, monitor)
        for cb in cbs:
            bind = getattr(cb, "bind", None)
            if bind is not None:
                bind(self)
        needs_gap = any(getattr(cb, "needs_gap", False) for cb in cbs)

        history: list[dict[str, float]] = []
        t0 = time.perf_counter()
        converged = diverged = False
        while self.epochs_done < until:
            try:
                rec = self.epoch()
            except Exception as err:
                # Only a health monitor may absorb an epoch failure —
                # it rolls back and remediates, re-raising when the
                # policy is exhausted.  SimulatedCrash is a
                # BaseException precisely so it can never land here.
                if monitor is None:
                    raise
                monitor.on_epoch_error(err)
                continue
            # mutate the record in place so self.history and the
            # returned FitResult.history stay the SAME objects
            rec["t"] = time.perf_counter() - t0
            want_gap = needs_gap or (
                gap_every and self.epochs_done % gap_every == 0)
            vmax = float(jnp.max(jnp.abs(self.v)))
            if not np.isfinite(vmax) or vmax > diverge_above:
                if monitor is None:
                    diverged = True
                    history.append(rec)
                    break
                want_gap = False       # gap over non-finite v is noise
            if want_gap:
                rec["gap"] = self.gap()
            history.append(rec)
            if verbose:
                print(f"epoch {self.epochs_done:4d} "
                      f"rel={rec['rel_change']:.3e} "
                      + (f"gap={rec['gap']:.3e}" if "gap" in rec else ""))
            stop = False
            for cb in cbs:
                fn = getattr(cb, "on_epoch_end", cb)
                stop = bool(fn(rec)) or stop
            if rec["rel_change"] < tol:
                converged = True
                break
            if stop:
                break
        if monitor is not None and monitor.gave_up:
            diverged = True
        if not history:
            # until <= epochs_done (e.g. a loaded estimator that already
            # used its budget): report the CURRENT state honestly rather
            # than an empty history with a nan gap
            history = [{"epoch": self.epochs_done, "rel_change": 0.0,
                        "t": 0.0, "gap": self.gap()}]
        elif "gap" not in history[-1]:
            history[-1]["gap"] = self.gap() if not diverged else float("inf")
        return FitResult(
            epochs=self.epochs_done, converged=converged,
            diverged=diverged, v=np.asarray(self.v),
            alpha=np.asarray(self.alpha), history=history,
            wall_time=time.perf_counter() - t0)

    # -- diagnostics -------------------------------------------------------

    @property
    def mesh_feed(self):
        """The `MeshChunkFeed` driving a mesh-streamed session (h2d
        byte/seconds counters live there); None off the mesh path."""
        if self._mesh is None:
            return None
        return getattr(self._epoch_fn, "feed", None)

    def _streamed_primal_dual(self, gbuckets: int = 256
                              ) -> tuple[float, float]:
        """One streaming pass over the feed/cache: primal + dual sums."""
        src = self.cache if self.cache is not None else self.feed
        nb = self.bplan.n_buckets
        B = self.bplan.bucket
        loss_sum = conj_sum = 0.0
        alpha = np.asarray(self.alpha)
        v = self.v
        for start in range(0, nb, gbuckets):
            bids = np.arange(start, min(start + gbuckets, nb))
            if self.cache is not None:
                data, yb = src.gather_buckets(bids)
            else:
                # mesh feeds (possibly under a ResilientChunkFeed, whose
                # inner feed `make_streamed_epoch_mesh` upgrades in
                # place) expose host_fetch: raw uncompacted rows — the
                # sliced per-lane compaction `fetch` ships is not
                # margin-kernel shaped
                hf = getattr(src, "host_fetch", None) or getattr(
                    getattr(src, "feed", None), "host_fetch", None)
                data, yb = hf(bids) if hf is not None else src.fetch(bids)
            yb = jnp.asarray(yb)
            m = margins(v, data)
            loss_sum += float(jnp.sum(self.obj.loss(m, yb)))
            a = jnp.asarray(alpha[start * B:start * B + yb.shape[0]])
            conj_sum += float(jnp.sum(self.obj.conj_neg(a, yb)))
        reg = 0.5 * self.lam * float(jnp.sum(v ** 2))
        primal = loss_sum / self.n + reg
        dual = -conj_sum / self.n - reg
        return primal, dual

    def primal(self) -> float:
        """Primal objective P(v) at the current shared vector."""
        if self.streamed:
            return self._streamed_primal_dual()[0]
        if self.sparse:
            m = margins(self.v, (self.idx, self.val))
            return float(jnp.sum(self.obj.loss(m, self.y)) / self.n
                         + 0.5 * self.lam * jnp.sum(self.v ** 2))
        return float(objectives.primal_value(
            self.obj, self.v, self.X, self.y, self.lam))

    def gap(self) -> float:
        """Duality gap P(v) - D(alpha) — the convergence certificate."""
        if self.streamed:
            p, dv = self._streamed_primal_dual()
            return p - dv
        if self.sparse:
            dval = objectives.dual_value(self.obj, self.alpha, self.v,
                                         self.y, self.lam)
            return self.primal() - float(dval)
        return float(objectives.duality_gap(
            self.obj, self.alpha, self.v, self.X, self.y, self.lam))

    # -- checkpoint/restart ------------------------------------------------

    def state_dict(self) -> dict[str, Any]:
        """Training state (alpha, v, epoch) as host arrays for checkpointing."""
        return {"alpha": np.asarray(self.alpha), "v": np.asarray(self.v),
                "epoch": np.int64(self.epochs_done)}

    def load_state_dict(self, st: dict[str, Any]) -> None:
        """Restore training state produced by `state_dict`."""
        self.alpha = jnp.asarray(st["alpha"])
        self.v = jnp.asarray(st["v"])
        self.epochs_done = int(st["epoch"])

    def save(self, path, *, meta: Optional[dict] = None) -> None:
        """Atomic on-disk snapshot of the solver state (+ meta)."""
        from repro.checkpoint import save_tree
        save_tree(path, self.state_dict(),
                  meta=dict(meta or {}, epochs_done=self.epochs_done))

    def load(self, path) -> dict:
        """Restore solver state saved by `save`; returns the meta dict."""
        from repro.checkpoint import restore_tree
        st, meta = restore_tree(path, self.state_dict())
        self.load_state_dict(st)
        return meta
