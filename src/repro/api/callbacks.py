"""Epoch callbacks for `Session.fit` (DESIGN.md S10).

The contract is one method:

    on_epoch_end(metrics: dict) -> bool | None

`metrics` is the epoch record (`epoch`, `rel_change`, cumulative `t`,
and `gap` when computed); a truthy return stops training after the
current epoch.  A bare callable works too.  Two optional extensions:

  * ``needs_gap = True``  — ask `fit` to compute the duality gap every
    epoch (it is a full data pass, so only callbacks that consume it
    should request it);
  * ``bind(session)``     — called once before the loop for callbacks
    that need solver state (checkpoint hooks).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

__all__ = ["Callback", "EarlyStopping", "GapLogger", "CheckpointHook",
           "BenchmarkRecorder"]


class Callback:
    """Base class (optional — any `on_epoch_end(metrics)` works)."""

    needs_gap: bool = False

    def bind(self, session) -> None:
        """Attach the owning `Session` before the first epoch."""
        self.session = session

    def on_epoch_end(self, metrics: dict) -> Optional[bool]:
        """Called after every epoch; return True to stop training."""
        return None


class EarlyStopping(Callback):
    """Stop on a target value or on stalled improvement.

    * ``threshold``: stop as soon as `monitor` drops below it (e.g.
      gap < 1e-4 — the certificate-based rule the paper could not use,
      available here because the engine tracks the dual).
    * ``patience``: stop after this many consecutive epochs without
      `min_delta` improvement of the monitored value.
    """

    def __init__(self, monitor: str = "gap",
                 threshold: Optional[float] = None,
                 patience: Optional[int] = None,
                 min_delta: float = 0.0):
        self.monitor = monitor
        self.threshold = threshold
        self.patience = patience
        self.min_delta = min_delta
        self.needs_gap = monitor == "gap"
        self.best = float("inf")
        self.stale = 0

    def on_epoch_end(self, metrics: dict) -> bool:
        """Stop when the monitored metric hits its target or stalls."""
        val = metrics.get(self.monitor)
        if val is None:
            return False
        if self.threshold is not None and val < self.threshold:
            return True
        if self.patience is None:
            return False
        if val < self.best - self.min_delta:
            self.best = val
            self.stale = 0
        else:
            self.stale += 1
        return self.stale >= self.patience


class GapLogger(Callback):
    """Print (or collect) the duality-gap trajectory every `every`
    epochs — the paper's Fig-3 convergence trace, as a callback.

    Does NOT set `needs_gap` (which would force the full-data gap pass
    on every epoch): on logging epochs it uses the gap already in
    `metrics` if some other consumer requested it, else computes it
    lazily through the bound session — so only 1 in `every` epochs
    pays the pass."""

    def __init__(self, every: int = 1,
                 printer: Optional[Callable[[str], None]] = print):
        self.every = every
        self.printer = printer
        self.trace: list[tuple[int, float]] = []

    def on_epoch_end(self, metrics: dict) -> None:
        """Record and (every `every` epochs) print the duality gap."""
        ep = int(metrics["epoch"])
        if ep % self.every:
            return
        gap = metrics.get("gap")
        if gap is None:
            gap = self.session.gap()
            metrics["gap"] = gap       # share with later callbacks
        self.trace.append((ep, gap))
        if self.printer is not None:
            self.printer(f"[gap] epoch {ep:4d}  gap={gap:.3e}  "
                         f"rel={metrics['rel_change']:.3e}")


class CheckpointHook(Callback):
    """Save session state every `every` epochs via `CheckpointManager`
    (atomic commits, keep-N GC) so long fits restart mid-run."""

    def __init__(self, root, *, every: int = 1, keep_n: int = 3,
                 meta: Optional[dict] = None):
        from repro.checkpoint import CheckpointManager
        self.mgr = CheckpointManager(root, keep_n=keep_n)
        self.every = every
        self.meta = meta or {}

    def on_epoch_end(self, metrics: dict) -> None:
        """Save a checkpoint every `every` epochs."""
        ep = int(metrics["epoch"])
        if ep % self.every:
            return
        self.mgr.save(ep, self.session.state_dict(),
                      meta=dict(self.meta, epoch=ep))


class BenchmarkRecorder(Callback):
    """Collect per-epoch records (+ wall-clock) for benchmark emitters —
    what fig3/fig6's estimator arms feed from."""

    def __init__(self):
        self.records: list[dict] = []
        self._t0 = time.perf_counter()

    def bind(self, session) -> None:
        """Attach the session and restart the wall clock."""
        super().bind(session)
        self._t0 = time.perf_counter()

    def on_epoch_end(self, metrics: dict) -> None:
        """Append this epoch's metrics stamped with elapsed wall time."""
        self.records.append(
            dict(metrics, wall=time.perf_counter() - self._t0))

    @property
    def wall_time(self) -> float:
        """Wall-clock seconds from bind to the latest recorded epoch."""
        return self.records[-1]["wall"] if self.records else 0.0
