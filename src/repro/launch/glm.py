"""Distributed GLM training: the paper's algorithm as a 3-axis SPMD program.

shard_map over ("pod","data","model") implements the paper's hierarchy
with real collectives (DESIGN.md S2):

  * static partition of examples across pods — data never crosses the
    pod interconnect; only the d-sized v delta does, once per epoch
    (optionally int8 error-feedback compressed: 4x fewer wire bytes);
  * DYNAMIC partition within a pod — every epoch each lane shuffles its
    buckets locally, splits them into K groups and exchanges via ONE
    balanced all-to-all over 'data', so each new per-lane block mixes
    buckets from every old block (the TPU-native form of the paper's
    re-shuffling, O(local data) ICI cost).  NOTE: a cheaper ring
    rotation of whole blocks was tried first and REFUTED — rotating
    ownership of fixed blocks leaves the subproblem sets unchanged and
    converges like static (see core/partition.py + EXPERIMENTS.md);
  * feature sharding over 'model' (TP) for wide datasets — per-bucket
    Gram/margin partial sums are psum'd, amortizing ONE model-axis
    collective over B coordinates (the bucket optimization's TP payoff);
  * v replicas sync over 'data' once per chunk (sync_interval), so
    compute and the data-axis psum interleave across chunks.

Workers = pods x data-lanes (x model-lanes too when features are
replicated — narrow datasets use the whole mesh as example-parallel
workers).  sigma' = #workers (CoCoA+ additive aggregation).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import sdca
from repro.core.objectives import LOGISTIC, Objective
from repro.optim.compression import compress

# check_vma=False: v is *mathematically* invariant over unmentioned axes
# (every lane adds the same psum'd delta to the same replica), but the
# static VMA tracker cannot see through the chunked carry + the int8
# all-gather pod reduce, so we assert replication via out_specs instead.
try:
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except (ImportError, TypeError):                        # older jax
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f, mesh, in_specs, out_specs):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


@dataclasses.dataclass(frozen=True)
class GLMScale:
    """One deployment-scale GLM workload (paper dataset, full size)."""
    name: str
    kind: str                 # dense | sparse
    n: int
    d: int
    nnz: int = 0              # sparse only (padded)
    bucket: int = 16
    chunks: int = 4           # v syncs per epoch over 'data'
    feature_shard: bool = False   # wide dense data: shard d over 'model'
    lam: float = 1e-3
    compress_pod: bool = True     # int8 EF for the cross-pod reduce
    compress_sync: bool = False   # int8 two-phase data-axis dv reduction
    redeal_frac: float = 1.0      # bucket fraction re-dealt per epoch


GLM_CONFIGS = {
    # criteo-kaggle: 45M examples, 1M features, ~39 nnz (padded to 40)
    "glm-criteo": GLMScale("glm-criteo", "sparse", n=45_088_768,
                           d=1_048_576, nnz=40, bucket=16, chunks=4),
    # HIGGS: 11M examples, 28 dense features — narrow: replicate features,
    # use every chip as an example-parallel worker
    "glm-higgs": GLMScale("glm-higgs", "dense", n=11_010_048, d=28,
                          bucket=8, chunks=4, feature_shard=False),
    # epsilon: 400k examples, 2000 dense features — wide: TP over 'model'
    "glm-epsilon": GLMScale("glm-epsilon", "dense", n=409_600, d=2_000,
                            bucket=16, chunks=8, feature_shard=True),
    # beyond-paper optimized variant (SPerf glm iteration): int8
    # two-phase chunk reductions + 25% partial re-deal
    "glm-criteo-opt": GLMScale("glm-criteo-opt", "sparse", n=45_088_768,
                               d=1_048_576, nnz=40, bucket=16, chunks=4,
                               compress_sync=True, redeal_frac=0.25),
}


def _axes(mesh, scale: GLMScale):
    """-> (example_axes, sync_axes, has_pod, model_is_tp)."""
    names = mesh.axis_names
    has_pod = "pod" in names
    if scale.kind == "dense" and scale.feature_shard:
        ex = tuple(a for a in ("pod", "data") if a in names)
        sync = ("data",)
        tp = True
    else:
        ex = tuple(a for a in ("pod", "data", "model") if a in names)
        sync = tuple(a for a in ("data", "model") if a in names)
        tp = False
    return ex, sync, has_pod, tp


def _worker_count(mesh, scale: GLMScale) -> int:
    ex, _, _, _ = _axes(mesh, scale)
    n = 1
    for a in ex:
        n *= mesh.shape[a]
    return n


def _q_psum(x, axis_name: str, size: int):
    """int8 two-phase reduction over `axis_name` (quantized
    reduce-scatter then quantized all-gather): ~2 bytes/element on the
    wire instead of all-reduce's ~8 — the glm-criteo SPerf iteration.
    """
    if size <= 1:
        return x
    n = x.shape[0]
    pad = (-n) % size
    if pad:
        x = jnp.pad(x, (0, pad))
    qz, _ = compress(x)
    # phase 1: exchange int8 shards, sum locally in f32
    shards = jax.lax.all_to_all(
        qz.q.reshape(size, -1), axis_name, split_axis=0, concat_axis=0,
        tiled=False)                                  # (size, n/size)
    scales = jax.lax.all_gather(qz.scale, axis_name)  # (size,)
    part = jnp.sum(shards.astype(jnp.float32)
                   * scales.reshape(size, 1), axis=0)  # my shard, reduced
    # phase 2: int8 all-gather of the reduced shards
    qz2, _ = compress(part)
    q_all = jax.lax.all_gather(qz2.q, axis_name)       # (size, n/size)
    s_all = jax.lax.all_gather(qz2.scale, axis_name)
    out = (q_all.astype(jnp.float32)
           * s_all.reshape(size, 1)).reshape(x.shape)
    return out[:n] if pad else out


def _redeal(arrs, axis_name: str, size: int, nb: int, key,
            frac: float = 1.0):
    """Balanced all-to-all bucket re-deal over `axis_name` (the paper's
    dynamic partitioning, TPU-native).

    arrs: tuple of (array, example_axis); the example axis holds n_local
    examples grouped in `nb` equal buckets.  Each lane shuffles its
    buckets locally (per-chip key), then a tiled all-to-all sends the
    g-th slice to lane g — every new block mixes buckets drawn from
    every old block.  frac < 1 exchanges only that fraction of buckets
    (fewer wire bytes, slightly more epochs — fig5a / SPerf).
    """
    if size <= 1 or frac <= 0:
        return tuple(x for x, _ in arrs)
    perm = jax.random.permutation(key, nb).astype(jnp.int32)
    exch = max(int(nb * frac) // size * size, size)

    def one(x, example_axis):
        xb = jnp.moveaxis(x, example_axis, 0)      # (n_local, ...)
        shp = xb.shape
        rows = shp[0] // nb
        xb = xb.reshape((nb, rows) + shp[1:])[perm]
        head = xb[:exch].reshape((exch * rows,) + shp[1:])
        head = jax.lax.all_to_all(head, axis_name, split_axis=0,
                                  concat_axis=0, tiled=True)
        xb = jnp.concatenate(
            [head.reshape((exch, rows) + shp[1:]), xb[exch:]], axis=0)
        return jnp.moveaxis(xb.reshape(shp), 0, example_axis)

    return tuple(one(x, ax) for x, ax in arrs)


def _pod_reduce(v_new, v_in, has_pod: bool, compress_pod: bool):
    """Cross-pod combine of per-pod v deltas (optionally int8 EF)."""
    if not has_pod:
        return v_new
    dv = v_new - v_in
    if compress_pod:
        qz, _err = compress(dv)        # EF residual handled by caller state
        q_all = jax.lax.all_gather(qz.q, "pod")          # int8 on the wire
        s_all = jax.lax.all_gather(qz.scale, "pod")
        dv_sum = jnp.sum(q_all.astype(jnp.float32)
                         * s_all.reshape((-1,) + (1,) * dv.ndim), axis=0)
    else:
        dv_sum = jax.lax.psum(dv, "pod")
    return v_in + dv_sum


def make_dense_epoch(scale: GLMScale, mesh, obj: Objective = LOGISTIC):
    """-> jit-ready epoch fn over global arrays (X, y, alpha, v, epoch)."""
    ex_axes, sync_axes, has_pod, tp = _axes(mesh, scale)
    W = _worker_count(mesh, scale)
    n_local = scale.n // W
    B = scale.bucket
    nb_local = n_local // B
    per_chunk = nb_local // scale.chunks
    lam_n = scale.lam * scale.n
    sig = float(W)
    data_size = mesh.shape.get("data", 1)
    mesh_ax_size = {a: mesh.shape.get(a, 1) for a in ("data", "model")}
    model_axis = "model" if tp else None

    def epoch_fn(X, y, a, v, epoch):
        # X: (d_loc, n_local) f32; y/a: (n_local,); v: (d_loc,)
        me = sum(jax.lax.axis_index(ax) * 10_007 ** i
                 for i, ax in enumerate(ex_axes))
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), epoch), me)
        # 1. dynamic partitioning: balanced all-to-all bucket re-deal
        #    across the pod's lanes (data never leaves the pod)
        X, y, a = _redeal(((X, 1), (y, 0), (a, 0)), "data", data_size,
                          nb_local, key, frac=scale.redeal_frac)
        # 2. per-chip random visit order over the received buckets
        perm = jax.random.permutation(jax.random.fold_in(key, 1),
                                      nb_local).astype(jnp.int32)
        v_in = v

        def chunk(c, carry):
            a_loc, v_loc = carry
            ids = jax.lax.dynamic_slice_in_dim(
                perm, c * per_chunk, per_chunk)
            cols = (ids[:, None] * B
                    + jnp.arange(B, dtype=jnp.int32)).reshape(-1)
            a_new, dv = sdca.dense_local_subepoch(
                obj, X[:, cols], y[cols], a_loc[cols], v_loc,
                jnp.asarray(lam_n, X.dtype), jnp.asarray(sig, X.dtype),
                B, model_axis=model_axis)
            for ax in sync_axes:
                if scale.compress_sync:
                    dv = _q_psum(dv, ax, mesh_ax_size[ax])
                else:
                    dv = jax.lax.psum(dv, ax)
            return a_loc.at[cols].set(a_new), v_loc + dv

        a, v = jax.lax.fori_loop(0, scale.chunks, chunk, (a, v))
        # 3. hierarchical: per-pod replicas reduced once per epoch
        v = _pod_reduce(v, v_in, has_pod, scale.compress_pod)
        return X, y, a, v

    x_spec = P("model" if tp else None, ex_axes)
    e_spec = P(ex_axes)
    v_spec = P("model") if tp else P(None)
    return shard_map(
        epoch_fn, mesh,
        in_specs=(x_spec, e_spec, e_spec, v_spec, P()),
        out_specs=(x_spec, e_spec, e_spec, v_spec))


def make_sparse_epoch(scale: GLMScale, mesh, obj: Objective = LOGISTIC):
    ex_axes, sync_axes, has_pod, _ = _axes(mesh, scale)
    W = _worker_count(mesh, scale)
    n_local = scale.n // W
    B = scale.bucket
    nb_local = n_local // B
    per_chunk = nb_local // scale.chunks
    lam_n = scale.lam * scale.n
    sig = float(W)
    data_size = mesh.shape.get("data", 1)
    mesh_ax_size = {a: mesh.shape.get(a, 1) for a in ("data", "model")}

    def epoch_fn(idx, val, y, a, v, epoch):
        # idx/val: (n_local, nnz); v: (d,) replicated (gather/scatter)
        me = sum(jax.lax.axis_index(ax) * 10_007 ** i
                 for i, ax in enumerate(ex_axes))
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), epoch), me)
        idx, val, y, a = _redeal(
            ((idx, 0), (val, 0), (y, 0), (a, 0)), "data", data_size,
            nb_local, key, frac=scale.redeal_frac)
        perm = jax.random.permutation(jax.random.fold_in(key, 1),
                                      nb_local).astype(jnp.int32)
        v_in = v

        def chunk(c, carry):
            a_loc, v_loc = carry
            ids = jax.lax.dynamic_slice_in_dim(
                perm, c * per_chunk, per_chunk)
            rows = (ids[:, None] * B
                    + jnp.arange(B, dtype=jnp.int32)).reshape(-1)
            a_new, dv = sdca.sparse_local_subepoch(
                obj, idx[rows], val[rows], y[rows], a_loc[rows], v_loc,
                jnp.asarray(lam_n, val.dtype), jnp.asarray(sig, val.dtype))
            for ax in sync_axes:
                if scale.compress_sync:
                    dv = _q_psum(dv, ax, mesh_ax_size[ax])
                else:
                    dv = jax.lax.psum(dv, ax)
            return a_loc.at[rows].set(a_new), v_loc + dv

        a, v = jax.lax.fori_loop(0, scale.chunks, chunk, (a, v))
        v = _pod_reduce(v, v_in, has_pod, scale.compress_pod)
        return idx, val, y, a, v

    r_spec = P(ex_axes, None)
    e_spec = P(ex_axes)
    return shard_map(
        epoch_fn, mesh,
        in_specs=(r_spec, r_spec, e_spec, e_spec, P(None), P()),
        out_specs=(r_spec, r_spec, e_spec, e_spec, P(None)))


def glm_input_specs(scale: GLMScale, mesh):
    ex_axes, _, _, tp = _axes(mesh, scale)

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))

    e_spec = P(ex_axes)
    if scale.kind == "sparse":
        return (sds((scale.n, scale.nnz), jnp.int32, P(ex_axes, None)),
                sds((scale.n, scale.nnz), jnp.float32, P(ex_axes, None)),
                sds((scale.n,), jnp.float32, e_spec),
                sds((scale.n,), jnp.float32, e_spec),
                sds((scale.d,), jnp.float32, P(None)),
                jax.ShapeDtypeStruct((), jnp.int32))
    x_spec = P("model" if tp else None, ex_axes)
    v_spec = P("model") if tp else P(None)
    return (sds((scale.d, scale.n), jnp.float32, x_spec),
            sds((scale.n,), jnp.float32, e_spec),
            sds((scale.n,), jnp.float32, e_spec),
            sds((scale.d,), jnp.float32, v_spec),
            jax.ShapeDtypeStruct((), jnp.int32))


def lower_glm(arch: str, mesh):
    scale = GLM_CONFIGS[arch]
    make = make_sparse_epoch if scale.kind == "sparse" else make_dense_epoch
    epoch = make(scale, mesh)
    inputs = glm_input_specs(scale, mesh)
    return jax.jit(epoch, donate_argnums=tuple(range(len(inputs) - 1))) \
        .lower(*inputs)


# ---------------------------------------------------------------------------
# Analytic per-epoch cost (GLM epochs scan coordinates inside while loops,
# which XLA:CPU's cost_analysis counts once — see counting.py; the closed
# form below is exact for this algorithm and is used for the roofline)
# ---------------------------------------------------------------------------

_BISECT_FLOPS = 40 * 12       # logistic delta: 40 bisection iters


def glm_analytic(scale: GLMScale, mesh) -> dict:
    """Per-device per-epoch {flops, bytes accessed, coll} estimates."""
    W = _worker_count(mesh, scale)
    ex_axes, sync_axes, has_pod, tp = _axes(mesh, scale)
    n_local = scale.n // W
    B = scale.bucket
    nb = n_local // B
    d_loc = scale.d // mesh.shape["model"] if tp else scale.d

    if scale.kind == "dense":
        # per bucket: margins 2*d_loc*B + Gram d_loc*B^2 + v-update
        # 2*d_loc*B + recursion B * (B axpy + bisection)
        per_bucket = (2 * d_loc * B + d_loc * B * B + 2 * d_loc * B
                      + B * (2 * B + _BISECT_FLOPS))
        flops = nb * per_bucket
        x_bytes = d_loc * n_local * 4
        # X streamed once per chunked pass + rotated once (read+write)
        bytes_acc = x_bytes * 3 + scale.chunks * d_loc * 4 * 2
    else:
        per_coord = (2 * scale.nnz * 3 + _BISECT_FLOPS)
        flops = n_local * per_coord
        x_bytes = n_local * scale.nnz * 8
        bytes_acc = x_bytes * 3 + n_local * scale.nnz * 4 * 2  # v gather/scatter

    # collectives (result-shape convention, per device):
    #   chunk reductions of dv over sync axes (f32 all-reduce: 4 B/elem;
    #   int8 two-phase: ~2 B/elem) + the bucket re-deal (all-to-all of
    #   redeal_frac of the local shard) + cross-pod int8 all-gather
    sync_bytes = 2 if scale.compress_sync else 4
    dv_len = scale.d if scale.kind == "sparse" else d_loc
    coll = scale.chunks * dv_len * sync_bytes * len(sync_axes)
    coll += (x_bytes + n_local * 4 * 2) * scale.redeal_frac
    if has_pod:
        coll += (scale.d if scale.kind == "sparse" else d_loc) * 1 * \
            mesh.shape.get("pod", 1)               # int8 payload gather
    return {"flops": float(flops), "bytes accessed": float(bytes_acc),
            "coll": float(coll), "method": "analytic-closed-form"}


def glm_model_flops(scale: GLMScale, mesh) -> float:
    """Useful work per device-epoch: one pass of coordinate updates.

    For SDCA the 'model flops' are the margin + v-update inner products:
    4*d*nnz-equivalents per coordinate — the irreducible work of one
    epoch of the sequential algorithm, divided over chips.
    """
    W = _worker_count(mesh, scale)
    n_local = scale.n // W
    if scale.kind == "sparse":
        return float(n_local * 4 * scale.nnz)
    d_loc = scale.d // mesh.shape["model"] \
        if scale.feature_shard else scale.d
    return float(n_local * 4 * d_loc)
