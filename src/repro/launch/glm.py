"""Distributed GLM training: the solver engine as a 3-axis SPMD program.

shard_map over ("pod","data","model") implements the paper's hierarchy
with real collectives (DESIGN.md S2).  The epoch program itself —
re-deal -> chunked local sub-epoch -> sync -> pod reduce — lives in
`repro.core.engine` and is shared verbatim with the vmap simulator;
this module only binds it to a mesh:

  * static partition of examples across pods — data never crosses the
    pod interconnect; only the d-sized v delta does, once per epoch
    (optionally int8 error-feedback compressed: 4x fewer wire bytes);
  * DYNAMIC partition within a pod — every epoch each lane shuffles its
    buckets locally, splits them into K groups and exchanges via ONE
    balanced all-to-all over 'data' (`MeshCollectives.redeal`); a ring
    rotation of whole blocks was tried first and REFUTED — see
    core/partition.py + EXPERIMENTS.md;
  * feature sharding over 'model' (TP) for wide datasets — dense: v
    rows are sharded and per-bucket Gram/margin partial sums are
    psum'd; sparse: each model lane owns a contiguous d/M slice of v
    (VMEM-resident in the sharded Pallas kernel, DESIGN.md S12), one
    working-set exchange per bucket, and the model axis joins the dv
    SYNC axes so the ordered reduction reassembles the slices — in
    both cases ONE model-axis collective amortized over B coordinates
    (the bucket optimization's TP payoff);
  * v replicas sync over 'data' once per chunk, so compute and the
    data-axis reduction interleave across chunks.

Workers = pods x data-lanes (x model-lanes too when features are
replicated — narrow datasets use the whole mesh as example-parallel
workers).  sigma' = #workers (CoCoA+ additive aggregation).

`GLMScale.local_solver="pallas"` routes each worker's sub-epoch through
the Pallas bucket kernels — dense (kernels/sdca_bucket.py) AND sparse
(kernels/sdca_sparse_bucket.py, VMEM-resident shared vector over CSR
tiles) — instead of the XLA scans; "auto" picks pallas on TPU backends
(DESIGN.md S11).  It is the same `LocalSolver` seam the simulator uses.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import engine
from repro.core.config import AlgoConfig, DeploymentConfig, EngineConfig
from repro.core.objectives import LOGISTIC, Objective

# The version-compat shard_map wrapper (check_vma/check_rep off — see
# the note in core/engine.py) moved into the engine with the streamed
# mesh path; re-exported here for existing importers.
from repro.core.engine import shard_map  # noqa: F401  (re-export)


@dataclasses.dataclass(frozen=True)
class GLMScale:
    """One deployment-scale GLM workload (paper dataset, full size)."""
    name: str
    kind: str                 # dense | sparse
    n: int
    d: int
    nnz: int = 0              # sparse only (padded)
    bucket: int = 16
    chunks: int = 4           # v syncs per epoch over 'data'
    feature_shard: bool = False   # wide data: shard d over 'model'
    #   (dense: TP v rows + psum; sparse: sharded-v solver slices)
    lam: float = 1e-3
    compress_pod: bool = True     # int8 EF for the cross-pod reduce
    compress_sync: bool = False   # int8 two-phase data-axis dv reduction
    redeal_frac: float = 1.0      # bucket fraction re-dealt per epoch
    local_solver: str = "auto"    # auto|xla|pallas (engine LocalSolver)
    deterministic: bool = False   # ordered gather-sums (bit-stable)
    # the mesh backend supports the two PHYSICAL partition modes:
    # "alltoall" (the TPU-native dynamic re-deal) and "static"
    partition: str = "alltoall"
    aggregation: str = "adding"   # CoCoA(+) sigma' rule
    seed: int = 0                 # schedule/re-deal PRNG root

    def engine_config(self, mesh=None) -> EngineConfig:
        """The layered engine view of this workload's solver knobs."""
        dep = DeploymentConfig(
            pods=mesh.shape.get("pod", 1) if mesh is not None else 1,
            lanes=(_worker_count(mesh, self)
                   // mesh.shape.get("pod", 1)) if mesh is not None else 1,
            feature_shard=self.feature_shard,
            compress_pod=self.compress_pod,
            deterministic=self.deterministic)
        return EngineConfig(
            algo=AlgoConfig(bucket=self.bucket, chunks=self.chunks,
                            aggregation=self.aggregation,
                            partition=self.partition,
                            redeal_frac=self.redeal_frac,
                            local_solver=self.local_solver,
                            compress_sync=self.compress_sync,
                            seed=self.seed),
            deployment=dep)


GLM_CONFIGS = {
    # criteo-kaggle: 45M examples, 1M features, ~39 nnz (padded to 40)
    "glm-criteo": GLMScale("glm-criteo", "sparse", n=45_088_768,
                           d=1_048_576, nnz=40, bucket=16, chunks=4),
    # HIGGS: 11M examples, 28 dense features — narrow: replicate features,
    # use every chip as an example-parallel worker
    "glm-higgs": GLMScale("glm-higgs", "dense", n=11_010_048, d=28,
                          bucket=8, chunks=4, feature_shard=False),
    # epsilon: 400k examples, 2000 dense features — wide: TP over 'model'
    "glm-epsilon": GLMScale("glm-epsilon", "dense", n=409_600, d=2_000,
                            bucket=16, chunks=8, feature_shard=True),
    # webspam-trigram: 350k examples, 16.6M features, ~3727 nnz — d is
    # ~8x over the replicated-v VMEM budget, so this is THE
    # feature-sharded sparse workload: model lanes each hold a d/M
    # slice of v and run the sharded bucket kernel (DESIGN.md S12)
    "glm-webspam": GLMScale("glm-webspam", "sparse", n=360_448,
                            d=16_609_280, nnz=3_728, bucket=16,
                            chunks=4, feature_shard=True),
    # beyond-paper optimized variant (SPerf glm iteration): int8
    # two-phase chunk reductions + 25% partial re-deal
    "glm-criteo-opt": GLMScale("glm-criteo-opt", "sparse", n=45_088_768,
                               d=1_048_576, nnz=40, bucket=16, chunks=4,
                               compress_sync=True, redeal_frac=0.25),
}


def scale_for_dataset(name: str, **overrides) -> GLMScale:
    """Registry dataset -> a deployment-scale `GLMScale`.

    Sizes come from the dataset registry's REAL shapes (not the offline
    sub-samples): n is padded to a 32k multiple and d/nnz to mesh- and
    tile-friendly multiples, mirroring how the hand-written GLM_CONFIGS
    entries were derived from the paper's tables.  The data layout —
    and, under ``$REPRO_PLAN=search|probe``, the bucket/chunk geometry
    — resolves through the system-aware planner (`core.planner`,
    DESIGN.md S13): wide dense datasets (d >= 512) feature-shard over
    'model'; sparse datasets do exactly when the replicated shared
    vector cannot fit the kernel's VMEM budget (webspam-scale d) — the
    same boundary `kernels.ops.sparse_solver_plan` dispatches on, now
    written once in `planner.feature_shard_default`.  Explicit
    overrides always win, and any planner failure degrades
    warn-and-safe to that static layout rule.
    """
    from repro.core import planner
    from repro.data.registry import get_spec

    spec = get_spec(name)
    n = -(-spec.full_n // 32_768) * 32_768
    d = -(-spec.full_d // 4_096) * 4_096 if spec.full_d >= 4_096 \
        else spec.full_d
    kw = dict(name=f"glm-{name}", kind=spec.kind, n=n, d=d,
              lam=spec.lam)
    sparse = spec.kind == "sparse"
    if sparse:
        kw["nnz"] = -(-spec.nnz // 8) * 8
    sig = planner.WorkloadSignature(n=n, d=d, nnz=kw.get("nnz", 0),
                                    sparse=sparse, name=name)
    searching = planner.plan_mode() in ("search", "probe")
    plan = planner.resolve_plan(
        sig, planner.Topology.detect(),
        bucket=overrides.get("bucket", None if searching else 16),
        chunks=overrides.get("chunks", None if searching else 4))
    kw["feature_shard"] = plan.feature_shard
    if searching:
        kw["bucket"], kw["chunks"] = plan.bucket, plan.chunks
    kw.update(overrides)
    return GLMScale(**kw)


def scale_for_estimator(est, **overrides) -> GLMScale:
    """A FITTED `repro.api` estimator (or bare `Session`) -> `GLMScale`.

    The deployment-scale view is derived from the estimator's own
    solver state: data dims from its session, algorithm knobs from its
    `EngineConfig` — so the mesh program it lowers to runs the *same*
    epoch the estimator ran in the simulator."""
    ses = getattr(est, "session_", est)
    if not hasattr(ses, "spec") or not hasattr(ses, "n"):
        raise ValueError(
            "estimator_epoch needs a fitted estimator (or a Session): "
            "the mesh program is sized from its data and config")
    algo, dep = ses.spec.algo, ses.spec.deployment
    kind = "sparse" if ses.sparse else "dense"
    kw = dict(name=f"glm-{type(est).__name__.lower()}", kind=kind,
              n=ses.n, d=ses.d, bucket=ses.bplan.bucket,
              chunks=algo.chunks, lam=ses.lam,
              compress_pod=dep.compress_pod,
              compress_sync=algo.compress_sync,
              redeal_frac=algo.redeal_frac,
              local_solver=algo.local_solver,
              deterministic=dep.deterministic,
              # the mesh has two physical partition modes; every sim
              # re-dealing scheme maps onto the all-to-all re-deal
              partition=("static" if algo.partition == "static"
                         else "alltoall"),
              aggregation=algo.aggregation, seed=algo.seed)
    if kind == "sparse":
        if ses.cache is not None:
            kw["nnz"] = ses.cache.meta.nnz
        elif hasattr(ses, "idx"):
            kw["nnz"] = int(ses.idx.shape[1])
        elif "nnz" not in overrides:
            raise ValueError("sparse feed-backed session: pass nnz=...")
    else:
        kw["feature_shard"] = dep.feature_shard
    kw.update(overrides)
    return GLMScale(**kw)


def estimator_epoch(est, mesh, **overrides):
    """Lower an `repro.api` estimator onto a device mesh.

    Returns ``(epoch_fn, scale)``: `epoch_fn` is the shard_map'd epoch
    program over global arrays (same signature as `make_dense_epoch` /
    `make_sparse_epoch` products; jit/donate and feed it
    `glm_input_specs(scale, mesh)`-shaped arrays), `scale` the derived
    `GLMScale`.  The estimator's algorithm knobs (bucket, chunks,
    aggregation, seed, compression, determinism) carry over verbatim;
    its partition scheme maps onto the mesh's physical modes ("static"
    stays static, every re-dealing scheme becomes the TPU-native
    all-to-all re-deal).  With `deterministic=True` and a
    static/alltoall-partition estimator, the mesh program is
    bitwise-identical to the engine's stacked-sim epochs on P pods x K
    data-lane layouts (the S2 sim<->mesh contract); other sim schedule
    modes are convergence-equivalent, not bitwise.
    """
    from repro.core.objectives import get_objective

    scale = scale_for_estimator(est, **overrides)
    objective = getattr(est, "_objective", None)
    obj = get_objective(objective) if objective else getattr(
        getattr(est, "session_", est), "obj", LOGISTIC)
    make = make_sparse_epoch if scale.kind == "sparse" else make_dense_epoch
    return make(scale, mesh, obj=obj), scale


def _axes(mesh, scale: GLMScale):
    """-> (example_axes, sync_axes, has_pod, model_is_tp).

    feature_shard picks the model axis's ROLE.  Dense TP shards the v
    rows themselves (P("model") specs, tp=True).  Sparse feature
    sharding keeps v replicated at the XLA level, but each model lane's
    SOLVER only writes its contiguous d/M slice (sharded kernel /
    masked scan), so 'model' leaves the example axes and joins the
    SYNC axes: the ordered dv reduction reassembles the disjoint
    slices.  Without feature_shard the model axis is just more
    example-parallel workers.
    """
    names = mesh.axis_names
    has_pod = "pod" in names
    if scale.feature_shard:
        ex = tuple(a for a in ("pod", "data") if a in names)
        if scale.kind == "dense":
            sync = ("data",)
            tp = True
        else:
            sync = tuple(a for a in ("data", "model") if a in names)
            tp = False
    else:
        ex = tuple(a for a in ("pod", "data", "model") if a in names)
        sync = tuple(a for a in ("data", "model") if a in names)
        tp = False
    return ex, sync, has_pod, tp


def _worker_count(mesh, scale: GLMScale) -> int:
    ex, _, _, _ = _axes(mesh, scale)
    n = 1
    for a in ex:
        n *= mesh.shape[a]
    return n


def _collectives(mesh, scale: GLMScale) -> engine.MeshCollectives:
    ex_axes, sync_axes, has_pod, _ = _axes(mesh, scale)
    sizes = {a: mesh.shape.get(a, 1) for a in ("pod", "data", "model")}
    return engine.MeshCollectives(
        lane_axes=tuple(a for a in ex_axes if a != "pod"),
        sync_axes=sync_axes, axis_sizes=sizes,
        pod_axis="pod" if has_pod else None, redeal_axis="data",
        deterministic=scale.deterministic,
        compress_pod=scale.compress_pod)


def make_dense_epoch(scale: GLMScale, mesh, obj: Objective = LOGISTIC):
    """-> jit-ready epoch fn over global arrays (X, y, alpha, v, epoch)."""
    ex_axes, _, _, tp = _axes(mesh, scale)
    W = _worker_count(mesh, scale)
    spec = scale.engine_config(mesh)
    coll = _collectives(mesh, scale)
    model_axis = "model" if tp else None

    def epoch_fn(X, y, a, v, epoch):
        # X: (d_loc, n_local) f32; y/a: (n_local,); v: (d_loc,)
        blk, y, a, v = engine.sharded_epoch(
            obj, spec, coll, engine.DenseBlock(X), y, a, v, epoch,
            lam=scale.lam, n_total=scale.n, workers=W,
            model_axis=model_axis)
        return blk.X, y, a, v

    x_spec = P("model" if tp else None, ex_axes)
    e_spec = P(ex_axes)
    v_spec = P("model") if tp else P(None)
    return shard_map(
        epoch_fn, mesh,
        in_specs=(x_spec, e_spec, e_spec, v_spec, P()),
        out_specs=(x_spec, e_spec, e_spec, v_spec))


def make_sparse_epoch(scale: GLMScale, mesh, obj: Objective = LOGISTIC,
                      *, interpret: bool | None = None):
    """`interpret` forces the Pallas kernels' interpret mode (tests
    drive TPU-targeted solver selection on CPU hosts with it); None =
    backend default."""
    ex_axes, _, _, _ = _axes(mesh, scale)
    W = _worker_count(mesh, scale)
    spec = scale.engine_config(mesh)
    coll = _collectives(mesh, scale)
    sparse_tp = scale.feature_shard and "model" in mesh.axis_names
    model_axis = "model" if sparse_tp else None
    model_lanes = mesh.shape["model"] if sparse_tp else None

    def epoch_fn(idx, val, y, a, v, epoch):
        # idx/val: (n_local, nnz); v: (d,) replicated at the XLA level
        # even when feature-sharded — each lane's solver writes only
        # its own d/M slice and the model-axis sync reassembles them
        blk, y, a, v = engine.sharded_epoch(
            obj, spec, coll, engine.SparseBlock(idx, val), y, a, v,
            epoch, lam=scale.lam, n_total=scale.n, workers=W,
            model_axis=model_axis, model_lanes=model_lanes,
            interpret=interpret)
        return blk.idx, blk.val, y, a, v

    r_spec = P(ex_axes, None)
    e_spec = P(ex_axes)
    return shard_map(
        epoch_fn, mesh,
        in_specs=(r_spec, r_spec, e_spec, e_spec, P(None), P()),
        out_specs=(r_spec, r_spec, e_spec, e_spec, P(None)))


def glm_input_specs(scale: GLMScale, mesh):
    ex_axes, _, _, tp = _axes(mesh, scale)

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))

    e_spec = P(ex_axes)
    if scale.kind == "sparse":
        return (sds((scale.n, scale.nnz), jnp.int32, P(ex_axes, None)),
                sds((scale.n, scale.nnz), jnp.float32, P(ex_axes, None)),
                sds((scale.n,), jnp.float32, e_spec),
                sds((scale.n,), jnp.float32, e_spec),
                sds((scale.d,), jnp.float32, P(None)),
                jax.ShapeDtypeStruct((), jnp.int32))
    x_spec = P("model" if tp else None, ex_axes)
    v_spec = P("model") if tp else P(None)
    return (sds((scale.d, scale.n), jnp.float32, x_spec),
            sds((scale.n,), jnp.float32, e_spec),
            sds((scale.n,), jnp.float32, e_spec),
            sds((scale.d,), jnp.float32, v_spec),
            jax.ShapeDtypeStruct((), jnp.int32))


def lower_glm(arch: str, mesh):
    """Lower a GLM epoch program: named config or registry dataset.

    `arch` is a GLM_CONFIGS key ("glm-criteo", ...) or a dataset
    registry name ("higgs", "criteo-kaggle-sub", ...), which is sized
    via `scale_for_dataset`."""
    scale = (GLM_CONFIGS[arch] if arch in GLM_CONFIGS
             else scale_for_dataset(arch))
    make = make_sparse_epoch if scale.kind == "sparse" else make_dense_epoch
    epoch = make(scale, mesh)
    inputs = glm_input_specs(scale, mesh)
    return jax.jit(epoch, donate_argnums=tuple(range(len(inputs) - 1))) \
        .lower(*inputs)


# ---------------------------------------------------------------------------
# Streamed epochs on the mesh (DESIGN.md S16)
# ---------------------------------------------------------------------------


def _as_mesh_feed(source, mesh, *, ex_axes, tp, model_axis, model_lanes,
                  d_loc, verify, width) -> engine.MeshChunkFeed:
    """Coerce any streamable source into a mesh-sharded chunk feed.

    Accepts a `TileCache`, a `TileFeed` (its verify flag carries over),
    an `ArrayFeed`-style host-array holder, a ready `MeshChunkFeed`, or
    a `ResilientChunkFeed` wrapping any of those — in the resilient
    case the INNER feed is upgraded in place, so retry/quarantine/
    rebuild semantics keep guarding the mesh path (`rebind` keeps the
    sharded feed alive across a cache rebuild).
    """
    from repro.data.cache import TileCache, TileFeed
    from repro.resilience.feed import ResilientChunkFeed

    def wrap(src, v):
        return engine.MeshChunkFeed(
            src, mesh, ex_axes=ex_axes, tp=tp, model_axis=model_axis,
            model_lanes=model_lanes, d_loc=d_loc, verify=v, width=width)

    if isinstance(source, engine.MeshChunkFeed):
        return source
    if isinstance(source, ResilientChunkFeed):
        inner = source.feed
        if not isinstance(inner, engine.MeshChunkFeed):
            if isinstance(inner, TileFeed):
                source.feed = wrap(inner.cache, verify or inner.verify)
            else:
                source.feed = wrap(inner, verify)
        return source
    if isinstance(source, TileCache):
        return wrap(source, verify)
    if isinstance(source, TileFeed):
        return wrap(source.cache, verify or source.verify)
    if hasattr(source, "y") and (hasattr(source, "X")
                                 or hasattr(source, "idx")):
        return wrap(source, verify)
    raise TypeError(
        f"cannot stream a {type(source).__name__} onto a mesh — pass a "
        f"TileCache, TileFeed, ArrayFeed, MeshChunkFeed, or a "
        f"ResilientChunkFeed wrapping one")


def make_streamed_epoch_mesh(scale: GLMScale, mesh, source,
                             obj: Objective = LOGISTIC, *,
                             interpret: bool | None = None,
                             journal=None, verify: bool = False,
                             width: int | None = None,
                             damp: float = 1.0, stats: dict | None = None,
                             jit_step: bool = True):
    """-> epoch_fn(alpha, v, epoch) streaming `source` onto the mesh.

    The mesh twin of `engine.make_streamed_epoch`: the SAME chunk loop
    (`run_epoch_streamed` — double buffering, journal hooks, stats)
    drives a shard_map'd chunk step, with `engine.MeshSchedule`
    mirroring the resident mesh's re-deal + visit PRNG streams on the
    host and `engine.MeshChunkFeed` landing each chunk pre-sharded.
    Under ``deterministic=True`` the result is bitwise-identical to
    resident mesh training (`make_dense_epoch`/`make_sparse_epoch`) on
    the same (seed, epoch) — pinned by tests/test_mesh_stream.py —
    while only ever holding `chunks`-th of the examples on device.

    Feature-sharded sparse scales stream slice-compacted per-lane
    feeds through `TileCache.slice_gather` (each model lane transfers
    only its d/M feature slice, ~M-fold fewer per-lane H2D bytes; the
    step reassembles exact rows on device).  `alpha` and `v` follow
    the global-array convention of the streamed sim path: alpha (n,)
    replicated, v (d,) — P('model')-sharded for dense TP.

    ``journal`` threads an `EpochJournal` (chunk-cursor crash resume,
    bitwise replay); ``stats`` a dict collecting the epoch's ingest
    overlap metrics; ``damp`` the health guard's dv_scale multiplier;
    ``verify``/``width`` forward to the feed.  The returned closure
    exposes ``.feed`` and ``.schedule``.
    """
    ex_axes, _, _, tp = _axes(mesh, scale)
    W = _worker_count(mesh, scale)
    spec = scale.engine_config(mesh)
    coll = _collectives(mesh, scale)
    sparse = scale.kind == "sparse"
    sparse_tp = sparse and scale.feature_shard \
        and "model" in mesh.axis_names
    model_axis = "model" if (tp or sparse_tp) else None
    model_lanes = mesh.shape["model"] if sparse_tp else None
    d_loc = None
    if sparse_tp:
        from repro.kernels import ops as kops
        d_loc = kops.sparse_slice_width(scale.d, model_lanes)
    feed = _as_mesh_feed(source, mesh, ex_axes=ex_axes, tp=tp,
                         model_axis=model_axis, model_lanes=model_lanes,
                         d_loc=d_loc, verify=verify, width=width)
    if feed.n != scale.n or feed.bucket != scale.bucket:
        raise ValueError(
            f"feed shape mismatch: feed has n={feed.n} bucket="
            f"{feed.bucket}, scale wants n={scale.n} bucket="
            f"{scale.bucket}")
    cache_backed = getattr(feed, "cache", None) is not None
    solver = engine.make_local_solver(
        scale.local_solver, obj, scale.lam * scale.n,
        spec.sigma_prime(W), bucket=scale.bucket, sparse=sparse,
        model_axis=model_axis,
        model_lanes=model_lanes, interpret=interpret,
        source=("tile cache (mesh-streamed)" if cache_backed
                else "array feed (mesh-streamed)"))
    dv_scale = (1.0 / W if scale.aggregation == "averaging"
                else 1.0) * damp
    step = engine.make_mesh_streamed_step(
        mesh, coll, solver, spec.algo, ex_axes=ex_axes, sparse=sparse,
        tp=tp, slice_lanes=model_lanes, model_axis="model",
        nnz=(feed.nnz if sparse else None), dv_scale=dv_scale,
        jit=jit_step)
    sched = engine.MeshSchedule(
        scale.n // scale.bucket, pods=mesh.shape.get("pod", 1),
        data=mesh.shape.get("data", 1),
        model=mesh.shape.get("model", 1),
        model_in_lanes=("model" in ex_axes), seed=scale.seed,
        redeal=(scale.partition != "static"),
        redeal_frac=scale.redeal_frac)
    driver = engine.MeshStreamDriver(mesh, coll, tp=tp)

    def epoch_fn(alpha, v, epoch):
        return engine.run_epoch_streamed(
            driver, feed, step, sched, spec.algo, alpha, v, epoch,
            journal=journal, stats=stats)

    epoch_fn.feed = feed
    epoch_fn.schedule = sched
    return epoch_fn


# ---------------------------------------------------------------------------
# Analytic per-epoch cost (GLM epochs scan coordinates inside while loops,
# which XLA:CPU's cost_analysis counts once — see counting.py; the closed
# form below is exact for this algorithm and is used for the roofline)
# ---------------------------------------------------------------------------

_BISECT_FLOPS = 40 * 12       # logistic delta: 40 bisection iters


def glm_analytic(scale: GLMScale, mesh, *, streamed: bool = False) -> dict:
    """Per-device per-epoch {flops, bytes accessed, coll} estimates.

    ``streamed=True`` adds an "h2d bytes" entry — the host->device
    ingest bytes a `MeshChunkFeed` ships per device-epoch, taken from
    `core.planner.streamed_transfer_bytes` (the one h2d model) and
    reported SEPARATELY from HBM traffic: the host link is ~50x slower
    than HBM, so folding ingest into "bytes accessed" would corrupt
    the roofline's memory-bound term."""
    W = _worker_count(mesh, scale)
    ex_axes, sync_axes, has_pod, tp = _axes(mesh, scale)
    n_local = scale.n // W
    B = scale.bucket
    nb = n_local // B
    d_loc = scale.d // mesh.shape["model"] if tp else scale.d

    if scale.kind == "dense":
        # per bucket: margins 2*d_loc*B + Gram d_loc*B^2 + v-update
        # 2*d_loc*B + recursion B * (B axpy + bisection)
        per_bucket = (2 * d_loc * B + d_loc * B * B + 2 * d_loc * B
                      + B * (2 * B + _BISECT_FLOPS))
        flops = nb * per_bucket
        x_bytes = d_loc * n_local * 4
        # X streamed once per chunked pass + rotated once (read+write)
        bytes_acc = x_bytes * 3 + scale.chunks * d_loc * 4 * 2
    else:
        per_coord = (2 * scale.nnz * 3 + _BISECT_FLOPS)
        flops = n_local * per_coord
        x_bytes = n_local * scale.nnz * 8
        bytes_acc = x_bytes * 3 + n_local * scale.nnz * 4 * 2  # v gather/scatter
    # collectives (result-shape convention, per device):
    #   chunk reductions of dv over sync axes (f32 all-reduce: 4 B/elem;
    #   int8 two-phase: ~2 B/elem) + the bucket re-deal (all-to-all of
    #   redeal_frac of the local shard) + cross-pod int8 all-gather
    sync_bytes = 2 if scale.compress_sync else 4
    dv_len = scale.d if scale.kind == "sparse" else d_loc
    coll = scale.chunks * dv_len * sync_bytes * len(sync_axes)
    coll += (x_bytes + n_local * 4 * 2) * scale.redeal_frac
    if scale.kind == "sparse" and scale.feature_shard:
        # sharded-v solver: one working-set all-gather per bucket over
        # 'model' — (M, B, nnz) f32 landing on every lane
        M = mesh.shape.get("model", 1)
        coll += (n_local // B) * M * B * scale.nnz * 4
    if has_pod:
        coll += (scale.d if scale.kind == "sparse" else d_loc) * 1 * \
            mesh.shape.get("pod", 1)               # int8 payload gather
    out = {"flops": float(flops), "bytes accessed": float(bytes_acc),
           "coll": float(coll), "method": "analytic-closed-form"}
    if streamed:
        from repro.core import planner
        pods = mesh.shape.get("pod", 1)
        topo = planner.Topology(
            backend="tpu", device_count=mesh.size, pods=pods,
            lanes=W // pods,
            model_lanes=(mesh.shape.get("model", 1)
                         if scale.feature_shard else 1))
        sig = planner.WorkloadSignature(
            n=scale.n, d=scale.d, nnz=scale.nnz,
            sparse=scale.kind == "sparse", streamed=True)
        plan = planner.SolverPlan(
            solver="xla", route="xla", bucket=scale.bucket,
            chunks=scale.chunks, nnz_multiple=8,
            feature_shard=scale.feature_shard)
        out["h2d bytes"] = planner.streamed_transfer_bytes(
            sig, topo, plan)
    return out


def glm_model_flops(scale: GLMScale, mesh) -> float:
    """Useful work per device-epoch: one pass of coordinate updates.

    For SDCA the 'model flops' are the margin + v-update inner products:
    4*d*nnz-equivalents per coordinate — the irreducible work of one
    epoch of the sequential algorithm, divided over chips.
    """
    W = _worker_count(mesh, scale)
    n_local = scale.n // W
    if scale.kind == "sparse":
        return float(n_local * 4 * scale.nnz)
    d_loc = scale.d // mesh.shape["model"] \
        if scale.feature_shard else scale.d
    return float(n_local * 4 * d_loc)
