"""Production mesh construction.

The target is a TPU v5e deployment: one pod = a 16x16 mesh of 256 chips
(axes ("data","model")); the multi-pod config stacks 2 pods on a leading
"pod" axis (512 chips) connected by the slower pod-to-pod interconnect.
The paper's hierarchy maps onto these axes (DESIGN.md S2):

    pod   — static example partition (NUMA-node analogue, slowest link)
    data  — dynamic example partition within a pod (thread analogue)
    model — feature / tensor-parallel sharding (new axis at this scale)

Everything is a FUNCTION (no module-level device touching) so importing
this module never locks jax's device count; only the dry-run entrypoint
sets XLA_FLAGS for 512 host devices.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import AbstractMesh

PEAK_FLOPS = 197e12          # bf16 FLOP/s per v5e chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~ICI); pod-to-pod is slower

# host->device ingest link (streamed feeds) — defined ONCE in
# core.planner so the plan score and the roofline agree; re-exported
# here next to its sibling bandwidths
from repro.core.planner import H2D_BW  # noqa: E402,F401


def abstract_mesh(shape, axis_names) -> AbstractMesh:
    """Version-compatible AbstractMesh constructor.

    Newer jax takes `AbstractMesh(shape, axis_names)`; jax <= 0.4.x
    takes a single `shape_tuple` of (name, size) pairs.  Tests and
    spec-checking code should use this instead of the raw class so the
    production 256/512-chip shardings can be validated without device
    allocation on any supported jax.
    """
    try:
        return AbstractMesh(tuple(shape), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for {shape}, have {len(devs)}; the dry-run "
            "entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_host_mesh(*, data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    shape = tuple(s for s in (pod, data, model))
    axes = ("pod", "data", "model")
    keep = [i for i, s in enumerate(shape)]
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def mesh_chips(mesh) -> int:
    return math.prod(mesh.devices.shape)
