"""Batched serving driver: prefill a prompt batch, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --smoke --batch 4 --prompt-len 32 --gen 16

Exercises the prefill -> decode cache hand-off used by the decode_32k /
long_500k dry-run cells, at CPU scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke, list_archs
from repro.launch import steps as steps_lib
from repro.models import lm


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          mesh=None, verbose: bool = True):
    params = steps_lib.init_params(cfg, jax.random.PRNGKey(seed), mesh)
    rng = np.random.default_rng(seed)
    max_seq = prompt_len + gen

    enc_out = None
    if cfg.frontend == "audio":
        frames = jnp.asarray(rng.standard_normal(
            (batch, cfg.enc_seq, cfg.d_model), np.float32))
        enc_out = lm.encoder_fwd(params, frames, cfg)

    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                         jnp.int32)

    # prefill into a max_seq-sized cache: run prefill, then widen the
    # kv caches to max_seq (real deployments allocate at max_seq)
    t0 = time.perf_counter()
    logits, cache = lm.forward(params, tokens, cfg, mode="prefill",
                               enc_out=enc_out)
    shapes = lm.cache_shapes(cfg, batch, max_seq)

    def widen(c, s):
        if c.shape == s.shape:
            return c.astype(s.dtype)
        pad = [(0, ds - dc) for dc, ds in zip(c.shape, s.shape)]
        return jnp.pad(c, pad).astype(s.dtype)

    def widen_tree(ct, st):
        return jax.tree.map(widen, ct, st)

    cache = {"head": [widen_tree(c, s) for c, s in
                      zip(cache["head"], shapes["head"])],
             "blocks": (widen_tree(cache["blocks"], shapes["blocks"])
                        if shapes["blocks"] else {}),
             "tail": [widen_tree(c, s) for c, s in
                      zip(cache["tail"], shapes["tail"])]}
    t_prefill = time.perf_counter() - t0

    raw_decode = steps_lib.make_decode_step(cfg)
    decode = jax.jit(
        lambda params, tokens, cache, pos: raw_decode(
            params, {"tokens": tokens, "cache": cache, "pos": pos}),
        donate_argnums=(2,))               # donate only the cache
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        tok, cache = decode(params, tok, cache,
                            jnp.int32(prompt_len + i))
        tok = tok[:, None]
        out.append(tok)
    t_decode = time.perf_counter() - t0
    gen_tokens = jnp.concatenate(out, axis=1)
    if verbose:
        print(f"prefill {prompt_len} toks x{batch}: {t_prefill:.2f}s; "
              f"decode {gen - 1} steps: {t_decode:.2f}s "
              f"({(gen - 1) * batch / max(t_decode, 1e-9):.1f} tok/s)")
    return gen_tokens


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    toks = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                 gen=args.gen)
    print("generated token ids:\n", np.asarray(toks))


if __name__ == "__main__":
    main()
