"""Batched serving drivers: LM decode AND GLM batch prediction.

LM path (prefill a prompt batch, decode greedily):

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --smoke --batch 4 --prompt-len 32 --gen 16

GLM path (batch predict through an `repro.api` estimator — dense or
CSR, in-memory or streamed from the bucket-tile cache for out-of-core
inference):

    PYTHONPATH=src python -m repro.launch.serve --glm higgs \
        --glm-epochs 10 --glm-batch 4096
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke, list_archs
from repro.launch import steps as steps_lib
from repro.models import lm


# ---------------------------------------------------------------------------
# GLM batch prediction (DESIGN.md S10: the estimator IS the serving unit)
# ---------------------------------------------------------------------------


def glm_predict_batch(est, X, *, batch: int = 8192,
                      proba: bool = False) -> np.ndarray:
    """Predict in fixed-size batches through a fitted estimator.

    ``X`` is sklearn-layout dense ``(n, d)``, a scipy sparse matrix, or
    an engine padded-CSR ``(idx, val)`` pair.  Batching bounds peak
    device memory at `batch` rows regardless of request size — the
    serving analogue of the trainer's chunked epochs.
    """
    pair = isinstance(X, (tuple, list))
    n = X[0].shape[0] if pair else X.shape[0]
    fn = est.predict_proba if proba else est.predict
    outs = []
    for s in range(0, n, batch):
        sl = ((X[0][s:s + batch], X[1][s:s + batch]) if pair
              else X[s:s + batch])
        outs.append(np.asarray(fn(sl)))
    return np.concatenate(outs) if outs else np.empty((0,))


def glm_predict_streamed(est, cache, *, gbuckets: int = 512,
                         return_margins: bool = False,
                         verify_tiles: bool = False) -> np.ndarray:
    """Out-of-core inference: stream bucket tiles straight off the
    mmap'd cache, never holding more than `gbuckets` tiles in memory.

    Returns predictions (or raw margins) for the TRUE examples — the
    cache's inert padding rows are trimmed via ``meta.n_examples``.
    ``verify_tiles`` crc-checks each tile group against the cache's
    per-tile sidecar before serving from it (raising
    `data.cache.TileCorruptionError` rather than emitting predictions
    from corrupt bytes); default off — the fast path pays nothing.
    """
    from repro.api import margins as _margins

    est._check_fitted()
    m = cache.meta
    out = []
    for start in range(0, m.n_buckets, gbuckets):
        bids = np.arange(start, min(start + gbuckets, m.n_buckets))
        if verify_tiles:
            cache.verify_tiles(bids)
        data, _y = cache.gather_buckets(bids)
        data = tuple(data) if m.kind == "sparse" else data
        out.append(np.asarray(_margins(est.coef_, data)))
    mg = np.concatenate(out)[:m.n_examples]
    if return_margins or not getattr(est, "_classifier", False):
        return mg
    return np.asarray(est.classes_)[(mg > 0).astype(int)]


def serve_glm(dataset: str, *, ckpt=None, epochs: int = 10,
              batch: int = 8192, cache_dir=None, bucket: int = 8,
              verbose: bool = True):
    """Registry dataset -> (load or fit) estimator -> streamed predict.

    The one-command GLM serving demo: materializes the bucket-tile
    cache, restores an `est.save` checkpoint when given (else runs a
    quick fit), then serves the whole dataset out of core and reports
    throughput + training-set accuracy.
    """
    from repro.api import LogisticRegression, load as load_estimator
    from repro.api.session import _pad_multiple
    from repro.data import registry

    if ckpt is not None:
        est = load_estimator(ckpt)
    else:
        est = LogisticRegression(max_epochs=epochs, bucket=bucket,
                                 lanes=4, partition="dynamic")
    # pad to the estimator's training topology so est.fit(cache) divides
    # for any raw-file n (the cache path cannot re-pad)
    cache = registry.materialize(
        dataset, cache_dir, bucket=est.bucket,
        pad_multiple=_pad_multiple(est.engine_config(), est.bucket))
    if ckpt is None:
        est.fit(cache)
    t0 = time.perf_counter()
    preds = glm_predict_streamed(est, cache, gbuckets=max(batch // bucket,
                                                          1))
    dt = time.perf_counter() - t0
    y = np.ascontiguousarray(
        cache.arrays["y"]).reshape(-1)[:cache.meta.n_examples]
    labels = np.asarray(est.classes_)[(y > 0).astype(int)]
    acc = float(np.mean(preds == labels))
    if verbose:
        print(f"glm-serve {dataset}: {preds.shape[0]} rows in {dt:.3f}s "
              f"({preds.shape[0] / max(dt, 1e-9):,.0f} rows/s), "
              f"train-acc {acc:.4f}")
    return preds, acc


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          mesh=None, verbose: bool = True):
    params = steps_lib.init_params(cfg, jax.random.PRNGKey(seed), mesh)
    rng = np.random.default_rng(seed)
    max_seq = prompt_len + gen

    enc_out = None
    if cfg.frontend == "audio":
        frames = jnp.asarray(rng.standard_normal(
            (batch, cfg.enc_seq, cfg.d_model), np.float32))
        enc_out = lm.encoder_fwd(params, frames, cfg)

    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                         jnp.int32)

    # prefill into a max_seq-sized cache: run prefill, then widen the
    # kv caches to max_seq (real deployments allocate at max_seq)
    t0 = time.perf_counter()
    logits, cache = lm.forward(params, tokens, cfg, mode="prefill",
                               enc_out=enc_out)
    shapes = lm.cache_shapes(cfg, batch, max_seq)

    def widen(c, s):
        if c.shape == s.shape:
            return c.astype(s.dtype)
        pad = [(0, ds - dc) for dc, ds in zip(c.shape, s.shape)]
        return jnp.pad(c, pad).astype(s.dtype)

    def widen_tree(ct, st):
        return jax.tree.map(widen, ct, st)

    cache = {"head": [widen_tree(c, s) for c, s in
                      zip(cache["head"], shapes["head"])],
             "blocks": (widen_tree(cache["blocks"], shapes["blocks"])
                        if shapes["blocks"] else {}),
             "tail": [widen_tree(c, s) for c, s in
                      zip(cache["tail"], shapes["tail"])]}
    t_prefill = time.perf_counter() - t0

    raw_decode = steps_lib.make_decode_step(cfg)
    decode = jax.jit(
        lambda params, tokens, cache, pos: raw_decode(
            params, {"tokens": tokens, "cache": cache, "pos": pos}),
        donate_argnums=(2,))               # donate only the cache
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        tok, cache = decode(params, tok, cache,
                            jnp.int32(prompt_len + i))
        tok = tok[:, None]
        out.append(tok)
    t_decode = time.perf_counter() - t0
    gen_tokens = jnp.concatenate(out, axis=1)
    if verbose:
        print(f"prefill {prompt_len} toks x{batch}: {t_prefill:.2f}s; "
              f"decode {gen - 1} steps: {t_decode:.2f}s "
              f"({(gen - 1) * batch / max(t_decode, 1e-9):.1f} tok/s)")
    return gen_tokens


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--glm", default=None, metavar="DATASET",
                    help="serve GLM predictions for a registry dataset "
                         "(streamed from the tile cache) instead of the "
                         "LM decode path")
    ap.add_argument("--glm-ckpt", default=None,
                    help="estimator checkpoint dir (from est.save); "
                         "without it a quick fit runs first")
    ap.add_argument("--glm-epochs", type=int, default=10)
    ap.add_argument("--glm-batch", type=int, default=8192)
    ap.add_argument("--glm-cache-dir", default=None)
    args = ap.parse_args()
    if args.glm:
        serve_glm(args.glm, ckpt=args.glm_ckpt, epochs=args.glm_epochs,
                  batch=args.glm_batch, cache_dir=args.glm_cache_dir)
        return
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    toks = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                 gen=args.gen)
    print("generated token ids:\n", np.asarray(toks))


if __name__ == "__main__":
    main()
