"""Launch layer: production mesh, input specs, step builders, dry-run."""
