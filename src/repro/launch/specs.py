"""Input shapes, applicability rules, and ShapeDtypeStruct stand-ins.

Every dry-run cell is (architecture x input shape x mesh).  This module
owns the four assigned LM shapes, the skip rules (DESIGN.md S4), and the
construction of weak-type-correct, shardable ShapeDtypeStructs for every
model input — no device allocation ever happens for the full configs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import lm

BATCH = ("pod", "data")      # batch-sharding axes (pod absent on 1-pod mesh)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq: int
    batch: int
    kind: str                # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}

# Architectures whose every token attends over the full context have no
# sub-quadratic path; the 524k-decode cell is skipped for them per the
# assignment ("run for SSM/hybrid/linear-attn").
_SUBQUADRATIC_FAMILIES = ("hybrid", "ssm")


def applicable(cfg, shape: ShapeCfg) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in _SUBQUADRATIC_FAMILIES:
        return False, ("pure full-attention arch: no sub-quadratic path at "
                       "524k context (skip noted in DESIGN.md S4)")
    return True, ""


def clean_pspec(mesh, spec: P) -> P:
    """Drop axis names absent from `mesh` (so BATCH works on both meshes)."""
    names = set(mesh.axis_names)

    def keep(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in names)
            return kept if kept else None
        return e if e in names else None

    return P(*(keep(e) for e in spec))


def _sds(mesh, shape, dtype, spec: P):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, clean_pspec(mesh, spec)))


def cache_pspec(shape: tuple, mdiv: int, bdiv: int,
                stacked: bool = False) -> P:
    """Sharding rule for one decode-cache tensor.

    Batch (dim 0 after any stacking dim) shards over ('pod','data') when
    divisible.  One feature-ish dim shards over 'model': prefer the
    heads/latent dim (index 2+) over the last dim; never shard the
    sequence dim of a (B, S, ...) cache; 2-D (B, feat) caches shard feat.
    """
    lead = (None,) if stacked else ()
    shp = shape[1:] if stacked else shape
    entries = [BATCH if shp[0] % bdiv == 0 else None] + \
        [None] * (len(shp) - 1)
    candidates = list(range(2, len(shp))) if len(shp) > 2 else \
        ([1] if len(shp) == 2 else [])
    for i in candidates:
        if shp[i] % mdiv == 0 and shp[i] >= mdiv:
            entries[i] = "model"
            break
    return P(*(lead + tuple(entries)))


def cache_specs(cfg, mesh, batch: int, max_seq: int):
    """ShapeDtypeStructs (with shardings) for the decode cache."""
    shapes = lm.cache_shapes(cfg, batch, max_seq)
    mdiv = mesh.shape.get("model", 1)
    bdiv = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)

    def map_tree(tree, stacked):
        return jax.tree.map(
            lambda s: _sds(mesh, s.shape, s.dtype,
                           cache_pspec(s.shape, mdiv, bdiv, stacked)),
            tree)

    return {
        "head": [map_tree(c, False) for c in shapes["head"]],
        "blocks": map_tree(shapes["blocks"], True),
        "tail": [map_tree(c, False) for c in shapes["tail"]],
    }


def input_specs(cfg, shape: ShapeCfg, mesh):
    """-> dict of ShapeDtypeStructs for one (arch x shape) cell.

    train:   {tokens, labels [, frames | patches]}
    prefill: {tokens [, frames | patches]}
    decode:  {tokens(B,1), cache, pos}   (cross caches hold encoder state)
    """
    B, S = shape.batch, shape.seq
    baxes = cfg.batch_axes if shape.kind == "train" else BATCH
    bdiv = 1
    for a in (baxes if isinstance(baxes, tuple) else (baxes,)):
        bdiv *= mesh.shape.get(a, 1)
    bspec = baxes if B % bdiv == 0 else None   # batch=1 cells replicate
    def tok(b, s):
        return _sds(mesh, (b, s), jnp.int32, P(bspec, None))

    def frames():
        return _sds(mesh, (B, cfg.enc_seq, cfg.d_model),
                    jnp.float32, P(bspec, None, None))

    def patches(s_tok):
        return _sds(mesh, (B, cfg.n_patches, cfg.d_model),
                    jnp.float32, P(bspec, None, None))

    if shape.kind == "train":
        out = {"tokens": tok(B, S), "labels": tok(B, S)}
        if cfg.frontend == "vision":
            s_tok = S - cfg.n_patches
            out = {"tokens": tok(B, s_tok), "labels": tok(B, s_tok),
                   "patches": patches(s_tok)}
        if cfg.frontend == "audio":
            out["frames"] = frames()
        return out

    if shape.kind == "prefill":
        out = {"tokens": tok(B, S)}
        if cfg.frontend == "vision":
            out = {"tokens": tok(B, S - cfg.n_patches),
                   "patches": patches(S - cfg.n_patches)}
        if cfg.frontend == "audio":
            out["frames"] = frames()
        return out

    # decode: one new token against a seq_len-sized cache.  Encoder
    # output lives in the cross caches, so no frames input.
    return {"tokens": tok(B, 1),
            "cache": cache_specs(cfg, mesh, B, S),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
