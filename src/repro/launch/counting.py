"""Correct HLO cost counting on XLA:CPU (which counts loop bodies once).

The record artifact (scan-over-layers + remat + chunked attention) is
what proves compile/fit, but XLA:CPU's cost_analysis counts a while-loop
body ONCE, not x trip-count — so scanned layers and the attention
KV-chunk scan under-report flops/bytes/collective-bytes.

Fix: lower UNROLLED counting variants at two depths and two attention
chunk sizes and solve the linear system (everything else is constant):

    F(L, c) = base + n_rep(L) * (g + b_pat(c)) + b_ht(c)
    b(2c) = 2 b(c)            (attention one-trip body is linear in c)

    pat_b = [F(L2,2c) - F(L1,2c)] - [F(L2,c) - F(L1,c)]
    ht_b  = [F(L1,2c) - F(L1,c)] - pat_b
    D_L   = F(L2,c) - F(L1,c)
    F_full = F(L1,c) + (n_rep-1) * D_L
             + (n_chunks-1) * (ht_b + n_rep * pat_b)

Applied uniformly to flops, bytes-accessed, and per-collective bytes.
Exceptions (documented per-cell in the JSON):
  * decode cells have no attention scan -> 2 lowers, no chunk term;
  * xlstm's chunkwise mLSTM body is quadratic in c (linearity breaks)
    and sLSTM scans time -> analytic model (flops_model.py) instead;
  * GLM cells (fori over sync chunks, scan over coordinates) -> analytic.
"""
from __future__ import annotations

import dataclasses
from typing import Callable


from repro.models.lm import layer_layout
from .hlo_analysis import collective_bytes

_COUNT_KEYS = ("flops", "bytes accessed")


def _measure(lowered) -> dict:
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    out = {k: float(cost.get(k, 0.0)) for k in _COUNT_KEYS}
    out["coll"] = float(sum(v for k, v in coll.items() if k != "count"))
    for k, v in coll.items():
        out[f"coll.{k}"] = float(v)
    return out


def _combine(ms: dict, n_rep: int, n_chunks: int) -> dict:
    """Solve the linear system per metric; ms keys: l1c, l2c, l1c2, l2c2.

    Also emits `attn_term.<metric>` — the total attention-scan
    contribution at full depth — so variant analyses (e.g. the flash
    kernel substitution) can subtract exactly what they replace.
    """
    out = {}
    for key in ms["l1c"]:
        f11, f21 = ms["l1c"][key], ms["l2c"][key]
        d_l = f21 - f11
        if "l1c2" in ms:
            f12, f22 = ms["l1c2"][key], ms["l2c2"][key]
            pat_b = (f22 - f12) - (f21 - f11)
            ht_b = (f12 - f11) - pat_b
            extra = (n_chunks - 1) * (ht_b + n_rep * pat_b)
            out[f"attn_term.{key}"] = n_chunks * (ht_b + n_rep * pat_b)
        else:
            extra = 0.0
        out[key] = f11 + (n_rep - 1) * d_l + extra
    return out


def counting_cost(cfg, lower_fn: Callable, *, seq: int, kind: str,
                  per_dev_batch: int = 1) -> dict:
    """-> corrected {flops, bytes accessed, coll, coll.<kind>} for one cell.

    lower_fn(cfg_variant) must lower the SAME step with a modified config.
    per_dev_batch scales the analytic ssm correction (which is per-row).
    """
    head, pat, n_rep, tail = layer_layout(cfg)
    pat_len = len(pat)
    base_layers = len(head) + len(tail)
    l1 = base_layers + pat_len
    l2 = base_layers + 2 * pat_len
    c = cfg.attn_chunk
    n_chunks = max(seq // c, 1)

    def variant(n_layers, chunk):
        return dataclasses.replace(
            cfg, n_layers=n_layers, unroll_layers=True, attn_chunk=chunk)

    ms = {"l1c": _measure(lower_fn(variant(l1, c))),
          "l2c": _measure(lower_fn(variant(l2, c)))}
    chunkable = kind in ("train", "prefill") and n_chunks > 1 \
        and cfg.family != "ssm"      # mlstm body is quadratic in c
    if chunkable:
        ms["l1c2"] = _measure(lower_fn(variant(l1, 2 * c)))
        ms["l2c2"] = _measure(lower_fn(variant(l2, 2 * c)))
    out = _combine(ms, n_rep, n_chunks)
    out["method"] = ("unroll-extrapolate-4pt" if "l1c2" in ms
                     else "unroll-extrapolate-2pt")
    if cfg.family == "ssm" and kind in ("train", "prefill"):
        out["flops"] += per_dev_batch * _ssm_scan_flops_correction(
            cfg, seq, kind)
        out["method"] += "+ssm-analytic"
    return out


def _ssm_scan_flops_correction(cfg, seq: int, kind: str) -> float:
    """Per-batch-row flop correction for xLSTM's internal scans.

    In the unrolled counting lowers the mLSTM chunk scan and the sLSTM
    time scan are still while loops (counted once); add the missing
    (trips-1) * body analytically.  Train counts fwd+bwd+remat ~ 3x fwd.
    """
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    c = min(cfg.attn_chunk or 256, seq)
    nc = max(seq // c, 1)
    n_mlstm = sum(k == "mlstm" for k in cfg.block_pattern) \
        * (cfg.n_layers // max(len(cfg.block_pattern), 1))
    n_slstm = sum(k == "slstm" for k in cfg.block_pattern) \
        * (cfg.n_layers // max(len(cfg.block_pattern), 1))
    # one mLSTM chunk body (B=1): intra scores+values 4c^2*H*hd,
    # gate maps ~8c^2*H, state update + inter 8c*H*hd^2
    body_m = 4 * c * c * H * hd + 8 * c * c * H + 8 * c * H * hd * hd
    # one sLSTM time step (B=1): recurrent matmul + elementwise
    body_s = 2 * d * d + 16 * d
    fwdbwd = 3.0 if kind == "train" else 1.0
    return fwdbwd * (n_mlstm * (nc - 1) * body_m
                     + n_slstm * (seq - 1) * body_s)
