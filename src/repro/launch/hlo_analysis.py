"""Static analysis of lowered/compiled HLO: collective bytes + roofline.

collective_bytes is not in cost_analysis(), so we parse the
post-partitioning HLO text and sum the result-shape bytes of every
collective op.  Shapes in the partitioned module are PER-DEVICE, so the
sums here are per-device quantities; the roofline terms below divide by
per-chip bandwidths, which is algebraically identical to the brief's
global_bytes / (chips * bw).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one result shape: dtype[d0,d1,...] — or a tuple of them
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\((?:[^()]|\([^)]*\))*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(-start)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes (per device) from HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for m in _OP_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shapes)
        out["count"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one compiled (arch x shape x mesh) cell.

    All terms are seconds-per-step for ONE device executing the
    partitioned module — identical to global work / (chips * rate).
    """
    flops: float              # per-device HLO flops
    hbm_bytes: float          # per-device bytes accessed
    coll_bytes: float         # per-device collective bytes
    peak_flops: float
    hbm_bw: float
    link_bw: float

    @property
    def t_compute(self) -> float:
        return self.flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Lower bound assuming perfect overlap: max of the three."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_lb_s": self.step_time,
        }


def analyze(compiled, *, peak_flops: float, hbm_bw: float,
            link_bw: float) -> tuple[Roofline, dict]:
    """-> (Roofline, raw dict) from a compiled executable."""
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = collective_bytes(text)
    total_coll = sum(v for k, v in coll.items() if k != "count")
    rl = Roofline(
        flops=float(cost.get("flops", 0.0)),
        hbm_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(total_coll),
        peak_flops=peak_flops, hbm_bw=hbm_bw, link_bw=link_bw)
    return rl, {"cost_analysis": {k: float(v) for k, v in cost.items()
                                  if isinstance(v, (int, float))},
                "collectives": coll}


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    # audit: except-ok backends without memory_analysis report nothing
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "serialized_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out
