import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: a successful .lower().compile() for the production meshes,
plus memory_analysis (fits) and cost_analysis + collective-bytes (feeds
EXPERIMENTS.md SRoofline).

Two artifacts per cell (see counting.py for why):
  * RECORD — scan-over-layers + remat + chunked attention: the deployed
    program; compile success + memory_analysis are taken from it.
  * COUNTING (single-pod cells only) — unrolled variants whose HLO cost
    analysis is extrapolated to full depth; feeds the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k --mesh pod,multipod

Results are written incrementally to experiments/dryrun/*.json.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import get_config, list_archs
from repro.launch import glm as glm_launch
from repro.launch import steps as steps_lib
from repro.launch.counting import counting_cost
from repro.launch.hlo_analysis import (Roofline, analyze,
                                       memory_analysis_dict)
from repro.launch.mesh import (H2D_BW, HBM_BW, ICI_BW, PEAK_FLOPS,
                               make_production_mesh, mesh_chips)
from repro.launch.specs import SHAPES, applicable, input_specs

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

MESHES = {"pod": False, "multipod": True}


def lower_lm_cell(cfg, shape_name: str, mesh):
    import math
    import dataclasses as _dc
    shape = SHAPES[shape_name]
    if cfg.layout != "tp":
        chips = math.prod(mesh.devices.shape)
        if shape.kind != "train" or shape.batch % chips:
            # fsdp layout is train-only AND needs batch >= all chips
            # (at 512 chips with batch 256 the TP layout is retained;
            # deployment would raise global batch instead)
            cfg = _dc.replace(cfg, layout="tp")
    step = steps_lib.step_for(cfg, shape.kind)
    inputs = input_specs(cfg, shape, mesh)
    if shape.kind == "train":
        opt_cfg = steps_lib.make_opt_cfg(cfg)
        p_abs = steps_lib.abstract_params(cfg, mesh)
        o_abs = steps_lib.abstract_opt_state(cfg, mesh, opt_cfg)
        out_sh = (jax.tree.map(lambda s: s.sharding, p_abs),
                  jax.tree.map(lambda s: s.sharding, o_abs),
                  None)
        fn = jax.jit(steps_lib.make_train_step(cfg, opt_cfg),
                     out_shardings=out_sh, donate_argnums=(0, 1))
        return fn.lower(p_abs, o_abs, inputs)
    p_abs = steps_lib.abstract_params(cfg, mesh)
    if shape.kind == "decode":
        out_sh = (None, jax.tree.map(lambda s: s.sharding,
                                     inputs["cache"]))
        fn = jax.jit(step, out_shardings=out_sh, donate_argnums=(1,))
        return fn.lower(p_abs, inputs)
    return jax.jit(step).lower(p_abs, inputs)


def lower_cell(arch: str, shape_name: str, mesh):
    if arch.startswith("glm-"):
        return glm_launch.lower_glm(arch, mesh)
    return lower_lm_cell(get_config(arch), shape_name, mesh)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N_active*tokens (train) / 2*N_active*tokens (infer),
    N_active excluding the embedding table (lm_head matmul is counted)."""
    n_act = cfg.active_param_count() - cfg.vocab * cfg.d_model
    if shape.kind == "train":
        return 6.0 * n_act * shape.batch * shape.seq
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.batch * shape.seq
    return 2.0 * n_act * shape.batch          # decode: one token per row


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: pathlib.Path, skip_existing: bool = False,
             counting: bool = True) -> dict:
    tag = f"{arch}__{shape_name}__{mesh_name}"
    path = out_dir / f"{tag}.json"
    if skip_existing and path.exists():
        rec = json.loads(path.read_text())
        print(f"[skip] {tag}: cached ({rec['status']})", flush=True)
        return rec
    mesh = make_production_mesh(multi_pod=MESHES[mesh_name])
    chips = mesh_chips(mesh)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": chips}
    if not arch.startswith("glm-"):
        ok, why = applicable(get_config(arch), SHAPES[shape_name])
        if not ok:
            rec.update(status="skipped", reason=why)
            path.write_text(json.dumps(rec, indent=1))
            print(f"[skip] {tag}: {why}", flush=True)
            return rec
    t0 = time.perf_counter()
    try:
        with mesh:
            lowered = lower_cell(arch, shape_name, mesh)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
            rl_raw, raw = analyze(compiled, peak_flops=PEAK_FLOPS,
                                  hbm_bw=HBM_BW, link_bw=ICI_BW)
            mem = memory_analysis_dict(compiled)
            rec.update(status="ok", t_lower_s=t_lower,
                       t_compile_s=t_compile, memory_analysis=mem,
                       raw_roofline=rl_raw.as_dict(), **raw)

            # counting pass (roofline of record): single-pod mesh only
            if counting and mesh_name == "pod":
                if arch.startswith("glm-"):
                    # streamed=True adds the "h2d bytes" entry: ingest
                    # over the slow host link, reported as its own
                    # t_h2d_s term below — NOT folded into hbm_bytes,
                    # which would corrupt the memory-bound roofline
                    cnt = glm_launch.glm_analytic(
                        glm_launch.GLM_CONFIGS[arch], mesh,
                        streamed=True)
                else:
                    cfg = get_config(arch)
                    shape = SHAPES[shape_name]
                    bdiv = mesh.shape.get("pod", 1) * \
                        mesh.shape.get("data", 1)
                    pdb = max(shape.batch // bdiv, 1)
                    cnt = counting_cost(
                        cfg, lambda c: lower_lm_cell(c, shape_name, mesh),
                        seq=shape.seq, kind=shape.kind, per_dev_batch=pdb)
                rl = Roofline(
                    flops=cnt["flops"], hbm_bytes=cnt["bytes accessed"],
                    coll_bytes=cnt["coll"], peak_flops=PEAK_FLOPS,
                    hbm_bw=HBM_BW, link_bw=ICI_BW)
                mf = (glm_launch.glm_model_flops(
                          glm_launch.GLM_CONFIGS[arch], mesh)
                      if arch.startswith("glm-")
                      else model_flops(get_config(arch),
                                       SHAPES[shape_name]) / chips)
                rec["roofline"] = rl.as_dict()
                if "h2d bytes" in cnt:
                    rec["roofline"]["t_h2d_s"] = (
                        cnt["h2d bytes"] / H2D_BW)
                rec["roofline"]["model_flops_per_dev"] = mf
                rec["roofline"]["model_over_hlo"] = (
                    mf / rl.flops if rl.flops else float("nan"))
                rec["counting"] = cnt
        rl_show = rec.get("roofline", rec["raw_roofline"])
        print(f"[ ok ] {tag}: lower {rec['t_lower_s']:.0f}s compile "
              f"{rec['t_compile_s']:.0f}s bottleneck="
              f"{rl_show['bottleneck']} t=({rl_show['t_compute_s']:.2e},"
              f"{rl_show['t_memory_s']:.2e},{rl_show['t_collective_s']:.2e})s",
              flush=True)
    # audit: except-ok the sweep records the failure row and moves on
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:300]}",
              flush=True)
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id or glm-criteo|glm-higgs|"
                         "glm-epsilon (default: all)")
    ap.add_argument("--shape", default=None,
                    help="shape name (default: all four)")
    ap.add_argument("--mesh", default="pod,multipod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-counting", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = (args.arch.split(",") if args.arch else
             list_archs() + list(glm_launch.GLM_CONFIGS))
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = args.mesh.split(",")

    results = []
    for arch in archs:
        cell_shapes = (["epoch"] if arch.startswith("glm-") else shapes)
        for shape in cell_shapes:
            for mesh_name in meshes:
                results.append(run_cell(
                    arch, shape, mesh_name, out_dir,
                    args.skip_existing, counting=not args.no_counting))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_err} failed "
          f"of {len(results)} cells")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
