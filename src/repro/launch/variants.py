"""Variant roofline analysis: measured-minus-measured-plus-analytic.

The flash-attention kernel cannot be HLO-counted on this CPU container
(Pallas TPU kernels compile only for TPU; interpret mode re-introduces
the loop-undercount).  Its cost IS exact by construction, though: the
kernel reads q/k/v once, writes o once, and computes only unmasked
tiles.  So the optimized cell's roofline =

    measured_baseline  -  measured_attention_term  +  analytic_flash

where measured_attention_term is isolated by the 4-point counting solve
(counting.py `attn_term.*`).  Everything except the kernel stays
measured HLO.

    PYTHONPATH=src python -m repro.launch.variants --arch minicpm3-4b \
        --shape train_4k
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.configs import get_config
from repro.launch.hlo_analysis import Roofline
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.launch.specs import SHAPES

DRYRUN = pathlib.Path(__file__).resolve().parents[3] / "experiments"

# train = fwd + bwd(~2.5x fwd, flash recomputes p internally) for flops;
# bytes: fwd reads q,k,v writes o; bwd reads q,k,v,o,do writes dq,dk,dv
_TRAIN_FLOP_MULT = 3.5
_TRAIN_BYTE_MULT = 3.0


def flash_analytic(cfg, shape, chips: int) -> dict:
    """Per-device analytic flops/bytes of ALL flash-attention instances
    in one step (self-attention of every layer; cross-attn excluded —
    whisper keeps the jnp path for its padded cross length)."""
    B, S = shape.batch, shape.seq
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    if cfg.attention == "mla":
        hd_qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        hd_v = cfg.v_head_dim
    else:
        hd_qk = hd_v = cfg.head_dim
    n_attn = cfg.n_layers
    if cfg.block_pattern:
        per = sum(k in ("attn", "attn_local")
                  for k in cfg.block_pattern)
        n_attn = cfg.n_layers * per // len(cfg.block_pattern)

    # effective kv length per query: causal -> S/2; local -> window
    if cfg.attention == "local":
        s_eff = min(cfg.window, S)
    else:
        s_eff = S / 2
    flops_fwd = 2.0 * B * S * s_eff * H * (hd_qk + hd_v) * n_attn
    bytes_fwd = (B * S * H * hd_qk + 2 * B * S * Hkv * hd_qk
                 + B * S * H * hd_v) * 2.0 * n_attn
    mult_f = _TRAIN_FLOP_MULT if shape.kind == "train" else 1.0
    mult_b = _TRAIN_BYTE_MULT if shape.kind == "train" else 1.0
    return {"flops": flops_fwd * mult_f / chips,
            "bytes accessed": bytes_fwd * mult_b / chips}


def flash_variant(arch: str, shape_name: str,
                  base_dir: str = "dryrun_opt") -> dict:
    rec = json.loads(
        (DRYRUN / base_dir / f"{arch}__{shape_name}__pod.json"
         ).read_text())
    cnt = rec["counting"]
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    fa = flash_analytic(cfg, shape, rec["chips"])

    out = {}
    for key in ("flops", "bytes accessed"):
        attn = cnt.get(f"attn_term.{key}", 0.0)
        out[key] = cnt[key] - attn + fa[key]
        out[f"attn_measured.{key}"] = attn
        out[f"attn_flash.{key}"] = fa[key]
    rl = Roofline(flops=out["flops"], hbm_bytes=out["bytes accessed"],
                  coll_bytes=cnt["coll"], peak_flops=PEAK_FLOPS,
                  hbm_bw=HBM_BW, link_bw=ICI_BW)
    result = dict(rec, roofline_flash=rl.as_dict(),
                  flash_substitution=out)
    out_path = DRYRUN / "dryrun_opt" / \
        f"{arch}__{shape_name}__pod__flash.json"
    out_path.write_text(json.dumps(result, indent=1))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--base-dir", default="dryrun_opt")
    args = ap.parse_args()
    r = flash_variant(args.arch, args.shape, args.base_dir)
    base = r.get("roofline", r["raw_roofline"])
    opt = r["roofline_flash"]
    print(f"{args.arch} {args.shape}:")
    for k in ("t_compute_s", "t_memory_s", "t_collective_s",
              "bottleneck"):
        print(f"  {k:16s} base={base[k]!s:>10} flash={opt[k]!s:>10}")


if __name__ == "__main__":
    main()
