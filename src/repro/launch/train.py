"""End-to-end LM training driver (CPU-runnable; production mesh via pjit).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20

Features exercised here (and relied on by examples/tests):
  * registry configs (--arch, --smoke for the reduced config)
  * sharded params via ParamSpec pspecs on whatever mesh exists
  * Markov-chain token stream (learnable structure, loss decreases)
  * checkpoint/restart: auto-resume from the latest step in --ckpt-dir,
    bit-exact because the data stream is indexed by step
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke, list_archs
from repro.data.loader import markov_batch
from repro.launch import steps as steps_lib
from repro.optim import adamw


def batch_at(cfg, batch: int, seq: int, step: int, seed: int = 0):
    """Deterministic batch for a given step (restartable stream)."""
    b = markov_batch(cfg.vocab, batch, seq, table_seed=seed, step=step)
    out = {"tokens": jnp.asarray(b["tokens"]),
           "labels": jnp.asarray(b["labels"])}
    if cfg.frontend == "audio":
        rng = np.random.default_rng(seed + step)
        out["frames"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.enc_seq, cfg.d_model), np.float32))
    if cfg.frontend == "vision":
        rng = np.random.default_rng(seed + step)
        out["patches"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_patches, cfg.d_model), np.float32))
    return out


def train(cfg, *, steps: int, batch: int, seq: int, lr: float = 3e-4,
          ckpt_dir: str | None = None, ckpt_every: int = 0,
          seed: int = 0, verbose: bool = True, mesh=None):
    opt_cfg = dataclasses.replace(steps_lib.make_opt_cfg(cfg), lr=lr)
    params = steps_lib.init_params(cfg, jax.random.PRNGKey(seed), mesh)
    opt_state = adamw.init(params, opt_cfg)
    step_fn = jax.jit(steps_lib.make_train_step(cfg, opt_cfg),
                      donate_argnums=(0, 1))

    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir)
        latest = mgr.latest_step()
        if latest is not None:
            (params, opt_state), meta = mgr.restore(
                (params, opt_state))
            start = int(meta["step"])
            if verbose:
                print(f"resumed from step {start}")

    losses = []
    t0 = time.perf_counter()
    for s in range(start, steps):
        b = batch_at(cfg, batch, seq, s, seed)
        params, opt_state, metrics = step_fn(params, opt_state, b)
        losses.append(float(metrics["loss"]))
        if verbose and (s % max(1, steps // 10) == 0 or s == steps - 1):
            print(f"step {s:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.perf_counter() - t0:.1f}s)")
        if mgr and ckpt_every and (s + 1) % ckpt_every == 0:
            mgr.save(s + 1, (params, opt_state), meta={"step": s + 1})
    if mgr:
        mgr.wait()
    return params, opt_state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    _, _, losses = train(cfg, steps=args.steps, batch=args.batch,
                         seq=args.seq, lr=args.lr,
                         ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, seed=args.seed)
    k = max(len(losses) // 5, 1)
    print(f"first-{k} mean loss {np.mean(losses[:k]):.4f} -> "
          f"last-{k} mean loss {np.mean(losses[-k:]):.4f}")


if __name__ == "__main__":
    main()
