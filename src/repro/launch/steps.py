"""jit-able train / prefill / decode steps for every architecture.

These are the functions the launcher runs and the dry-run lowers: pure
(params, opt_state, batch) -> (params, opt_state, metrics) and the
serving equivalents.  Sharding comes from ParamSpec pspecs (+ the FSDP
transform for the big archs) on the inputs; out_shardings pin outputs to
the same layout so steps chain without resharding.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models.layers import ParamSpec, materialize
from repro.optim import adamw
from .specs import clean_pspec

Array = jax.Array

# params below this size are never FSDP-sharded (norms, biases, routers)
_FSDP_MIN_SIZE = 1 << 22


def fsdp_spec(s: ParamSpec, data_div: int,
              axes: tuple = ("data",)) -> ParamSpec:
    """Additionally shard the largest replicated dim over `axes`.

    Skips specs that already use any of `axes` (EP expert weights) and
    small params (norms, routers)."""
    import numpy as np
    if int(np.prod(s.shape)) < _FSDP_MIN_SIZE or len(s.shape) < 2:
        return s
    flat_axes = [a for e in s.pspec if e is not None
                 for a in (e if isinstance(e, tuple) else (e,))]
    if any(a in flat_axes for a in axes):
        return s
    entries = list(s.pspec) + [None] * (len(s.shape) - len(s.pspec))
    cands = [i for i, (e, dim) in enumerate(zip(entries, s.shape))
             if e is None and dim % data_div == 0 and dim >= data_div]
    if not cands:
        return s
    # largest replicated dim.  (A prefer-the-output-dim variant was
    # tried and REFUTED: under the fsdp layout it pushed GSPMD into
    # "involuntary full rematerialization" — f32 all-gathers of GLOBAL
    # activations, 441 s/step of collective time on granite/internlm2.
    # See EXPERIMENTS.md SPerf iteration 3.)
    best = max(cands, key=lambda i: s.shape[i])
    entries[best] = axes if len(axes) > 1 else axes[0]
    return dataclasses.replace(s, pspec=P(*entries))


def _strip_model(s: ParamSpec) -> ParamSpec:
    """fsdp layout: drop 'model' from param pspecs (no TP — the model
    axis becomes extra batch parallelism)."""
    def keep(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a != "model")
            return kept if kept else None
        return None if e == "model" else e

    return dataclasses.replace(s, pspec=P(*(keep(e) for e in s.pspec)))


def model_param_specs(cfg, mesh=None):
    """Param specs with the arch's ZeRO policy applied.

    zero3: shard big params over 'data' too (XLA re-gathers per layer —
           lowest memory, highest collective volume).
    zero1: params stay TP-only; ONLY the optimizer states shard over
           'data' (see opt_state_specs) — one grad all-reduce + one
           update all-gather per STEP instead of per-layer gathers.
           This is the measured winner for the 20B dense models
           (EXPERIMENTS.md SPerf iteration 1).
    """
    specs = lm.param_specs(cfg)
    if mesh is None:
        return specs
    if cfg.layout == "fsdp":
        div = mesh.shape.get("data", 1) * mesh.shape.get("model", 1)
        specs = jax.tree.map(_strip_model, specs,
                             is_leaf=lambda x: isinstance(x, ParamSpec))
        return jax.tree.map(
            lambda s: fsdp_spec(s, div, axes=("data", "model")), specs,
            is_leaf=lambda x: isinstance(x, ParamSpec))
    if cfg.zero_stage == "zero3":
        data_div = mesh.shape.get("data", 1)
        if data_div > 1:
            specs = jax.tree.map(
                lambda s: fsdp_spec(s, data_div), specs,
                is_leaf=lambda x: isinstance(x, ParamSpec))
    return specs


def opt_state_specs(cfg, mesh):
    """ParamSpecs for optimizer moments (ZeRO-1: extra 'data' sharding)."""
    specs = model_param_specs(cfg, mesh)
    if cfg.zero_stage == "zero1" and cfg.layout != "fsdp":
        data_div = mesh.shape.get("data", 1) if mesh is not None else 1
        if data_div > 1:
            specs = jax.tree.map(
                lambda s: fsdp_spec(s, data_div), specs,
                is_leaf=lambda x: isinstance(x, ParamSpec))
    return specs


def abstract_params(cfg, mesh):
    specs = model_param_specs(cfg, mesh)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(mesh, clean_pspec(mesh, s.pspec))),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def abstract_opt_state(cfg, mesh, opt_cfg: adamw.AdamWConfig):
    """AdamW moments with the ZeRO-1/3 sharding policy applied."""
    o_specs = opt_state_specs(cfg, mesh)

    def mom(s: ParamSpec):
        sh = NamedSharding(mesh, clean_pspec(mesh, s.pspec))
        if opt_cfg.state_dtype == "int8":
            return adamw.QMoment(
                q=jax.ShapeDtypeStruct(s.shape, jnp.int8, sharding=sh),
                scale=jax.ShapeDtypeStruct(
                    s.shape[:-1] + (1,), jnp.float32,
                    sharding=NamedSharding(
                        mesh, clean_pspec(
                            mesh, P(*(list(s.pspec)[:len(s.shape) - 1]
                                      + [None]))))))
        return jax.ShapeDtypeStruct(s.shape, opt_cfg.state_dtype,
                                    sharding=sh)

    m = jax.tree.map(mom, o_specs,
                     is_leaf=lambda x: isinstance(x, ParamSpec))
    return adamw.AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=m,
        nu=jax.tree.map(lambda s: s, m,
                        is_leaf=lambda x: isinstance(x, adamw.QMoment)))


def make_opt_cfg(cfg) -> adamw.AdamWConfig:
    state_dtype = {"bf16": jnp.bfloat16, "int8": "int8"}.get(
        cfg.opt_dtype, jnp.float32)
    return adamw.AdamWConfig(state_dtype=state_dtype)


# ---------------------------------------------------------------------------
# Forward wrappers (modality stubs resolved here)
# ---------------------------------------------------------------------------

def _full_forward(params, batch, cfg, mode):
    enc_out = None
    extra = None
    if cfg.frontend == "audio":
        enc_out = lm.encoder_fwd(params, batch["frames"], cfg)
    if cfg.frontend == "vision":
        extra = batch["patches"]
    logits, cache = lm.forward(params, batch["tokens"], cfg, mode=mode,
                               enc_out=enc_out, extra_embeds=extra)
    return logits, cache


def loss_fn(params, batch, cfg):
    logits, _ = _full_forward(params, batch, cfg, "train")
    if cfg.frontend == "vision":
        npch = cfg.n_patches
        logits = logits[:, npch - 1:-1] if npch else logits
    return lm.lm_loss(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg, opt_cfg: adamw.AdamWConfig | None = None):
    opt_cfg = opt_cfg or make_opt_cfg(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        params, opt_state, metrics = adamw.apply(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        logits, cache = _full_forward(params, batch, cfg, "prefill")
        return logits[:, -1:], cache

    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, batch):
        logits, cache = lm.forward(
            params, batch["tokens"], cfg, mode="decode",
            cache=batch["cache"], pos=batch["pos"])
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return decode_step


def step_for(cfg, kind: str):
    return {"train": make_train_step, "prefill": make_prefill_step,
            "decode": make_decode_step}[kind](cfg)


def init_params(cfg, key, mesh=None):
    """Materialize real (small/smoke) params, optionally sharded."""
    specs = model_param_specs(cfg, mesh)
    params = materialize(specs, key)
    if mesh is not None:
        params = jax.tree.map(
            lambda x, s: jax.device_put(
                x, NamedSharding(mesh, clean_pspec(mesh, s.pspec))),
            params, specs)
    return params
