"""Mesh-agnostic checkpointing with atomic commits and async writes.

Design points for the 1000+ node story (DESIGN.md S5):

  * MESH-AGNOSTIC: leaves are saved as logical (unsharded) arrays keyed
    by their tree path, so a checkpoint written on a (16,16) mesh
    restores onto (2,16,16) — or onto 8 CPU devices — by re-sharding at
    load time (`shardings` argument).  This is what makes restart
    ELASTIC: the mesh shape is a property of the run, not the data.
  * ATOMIC: writes go to <dir>/.tmp.<step> and are renamed into place;
    a crash mid-write never corrupts the latest checkpoint (rename is
    atomic on POSIX).
  * KEEP-N: old steps are garbage-collected after a successful commit.
  * ASYNC: device_get happens on the caller thread (cheap, and required
    for consistency with the donated buffers of the next step), the
    file write happens on a background thread so the train loop does
    not block on I/O — the standard overlap trick.
  * SELF-DESCRIBING: meta.json records step + user metadata (partition
    seed, data position) so a restart resumes the exact schedule.

At datacenter scale the .npz body would be sharded per-host object
storage writes; the manager's commit protocol is unchanged.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        flat[key] = arr
    return flat


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def save_tree(path: pathlib.Path, tree, *, meta: Optional[dict] = None
              ) -> None:
    """Atomic single-file save of a pytree (+ meta.json).

    Leaves are stored as raw bytes with (dtype, shape) metadata so
    non-native dtypes (bfloat16, fp8) round-trip through .npz.

    Overwrite protocol: stage into .tmp.<name>, swap the live dir to
    .old.<name>, rename tmp into place, then drop .old — so at every
    instant either <name> or .old.<name> holds a COMPLETE checkpoint
    and `restore_tree` can always find one (torn-write safety; the old
    rmtree-then-rename left a window with neither)."""
    path = pathlib.Path(path)
    tmp = path.with_name(f".tmp.{path.name}")
    old = path.with_name(f".old.{path.name}")
    shutil.rmtree(tmp, ignore_errors=True)    # stale tmp from a crash
    tmp.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    manifest = [{"key": k, "dtype": str(v.dtype), "shape": list(v.shape)}
                for k, v in flat.items()]
    np.savez(tmp / "arrays.npz",
             **{f"a{i}": np.frombuffer(v.tobytes(), np.uint8)
                for i, v in enumerate(flat.values())})
    (tmp / "keys.json").write_text(json.dumps(manifest))
    (tmp / "meta.json").write_text(json.dumps(meta or {}))
    if path.exists():
        shutil.rmtree(old, ignore_errors=True)
        path.rename(old)
    tmp.rename(path)
    shutil.rmtree(old, ignore_errors=True)


def restore_tree(path: pathlib.Path, target, *, shardings=None
                 ) -> tuple[Any, dict]:
    """Restore into the structure of `target` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of
    NamedSharding to place leaves onto a (possibly different) mesh.

    Falls back to the .old.<name> sibling when <name> is missing or
    torn (no keys.json) — the save_tree swap protocol guarantees one
    of the two is complete after any crash."""
    path = pathlib.Path(path)
    if not (path / "keys.json").exists():
        old = path.with_name(f".old.{path.name}")
        if (old / "keys.json").exists():
            path = old
    manifest = json.loads((path / "keys.json").read_text())
    with np.load(path / "arrays.npz") as z:
        flat = {m["key"]: np.frombuffer(
                    z[f"a{i}"].tobytes(), _np_dtype(m["dtype"])
                ).reshape(m["shape"])
                for i, m in enumerate(manifest)}
    meta = json.loads((path / "meta.json").read_text())

    leaves_t, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    for path_t, leaf in leaves_t:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_t)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: checkpoint "
                             f"{arr.shape} vs target {leaf.shape}")
        out.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), out)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, meta


class CheckpointManager:
    """step-numbered checkpoints under a root dir; keep_n GC; async."""

    def __init__(self, root: str | pathlib.Path, *, keep_n: int = 3,
                 async_write: bool = True):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> pathlib.Path:
        return self.root / f"step_{step:012d}"

    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1])
                      for p in self.root.glob("step_*"))

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def save(self, step: int, tree, *, meta: Optional[dict] = None
             ) -> None:
        self.wait()
        meta = dict(meta or {}, step=step)
        flat_now = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                tree)           # snapshot before donation

        def _write():
            save_tree(self._step_dir(step), flat_now, meta=meta)
            self._gc()

        if self.async_write:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()

    def restore(self, target, *, step: Optional[int] = None,
                shardings=None) -> tuple[Any, dict]:
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return restore_tree(self._step_dir(step), target,
                            shardings=shardings)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
