"""Checkpointing: mesh-agnostic, atomic, keep-N, async-write."""
from .manager import CheckpointManager, restore_tree, save_tree

__all__ = ["CheckpointManager", "restore_tree", "save_tree"]
