"""Static analysis of the determinism + VMEM contracts (DESIGN.md S14).

Two layers, one report:

* **Layer 1 — jaxpr auditor** (`jaxpr_audit`, `matrix`): abstract-trace
  the real epoch programs (the same `launch/glm.py` shard_map builds
  and `engine.make_streamed_step` steps that training runs) for every
  registry workload x solver route, then walk the ClosedJaxprs for
  contract violations — sum-reordering collectives on exchanges the
  determinism contract requires to be ordered, and the shard_map
  loop-invariant-replicated closure hazard (rule IDs in `rules`).
* **Layer 2 — repo lint + budget audit** (`lint`, `budget`): AST rules
  ruff cannot express (kernel-contract registration, collective
  allowlist markers, unseeded RNG, CSR-invariant altitudes) plus an
  offline sweep proving no plan the planner can emit busts the
  kernels' VMEM budgets.

`runner.run_audit` orchestrates both and emits the machine-readable
report; `selftest.run_selftests` mutates each invariant and proves the
matching detector fires.  Front door: ``tools/audit.py``.

This ``__init__`` stays import-light (no jax): `rules`, `config`, and
`lint` are stdlib-only so docs tooling can read the rule registry
without an accelerator stack.
"""
from . import config, rules           # noqa: F401  (stdlib-only)
from .rules import RULES, Finding, Rule  # noqa: F401
