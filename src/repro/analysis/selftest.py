"""Mutation self-tests: prove every detector actually fires.

Each check injects one synthetic bug — a probe program traced for the
jaxpr layer, a synthesized source file for the lint layer, a forged
plan for the budget layer — runs it through the EXACT production
checker, and asserts the expected rule ID (and, where meaningful, that
the corrected twin passes: a detector that fires on everything is as
useless as one that fires on nothing).  `tools/audit.py --selftest`
runs these in CI next to the clean-tree audit, so a refactor that
silently lobotomizes a detector fails the build instead of shipping a
green-but-blind auditor.
"""
from __future__ import annotations

import textwrap
from typing import Callable

from . import budget, jaxpr_audit, lint, rules

__all__ = ["run_selftests", "SELFTESTS"]


class SelfTestError(AssertionError):
    """One mutation was not detected (or a clean twin was flagged)."""


def _expect(findings, rule: str, ctx: str) -> None:
    got = [f.rule for f in findings]
    if rule not in got:
        raise SelfTestError(
            f"{ctx}: expected {rule} to fire, got {got or 'nothing'}")


def _expect_clean(findings, ctx: str) -> None:
    if findings:
        raise SelfTestError(
            f"{ctx}: expected no findings, got "
            f"{[str(f) for f in findings]}")


# --- jaxpr layer ----------------------------------------------------------


def _mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(pod=1, data=2, model=1)


def _shmap(inner, out_spec=None):
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = _mesh()
    f = shard_map(inner, mesh=mesh, in_specs=P("data"),
                  out_specs=out_spec if out_spec is not None
                  else P("data"))
    return jax.make_jaxpr(f)(jnp.zeros(8))


def check_psum_exchange() -> None:
    """Injected psum exchange -> JAX-PSUM-EXCHANGE (det only)."""
    import jax
    from jax.sharding import PartitionSpec as P
    j = _shmap(lambda x: jax.lax.psum(x, "data"), out_spec=P(None))
    _expect(jaxpr_audit.audit_jaxpr(j, deterministic=True),
            rules.JAX_PSUM_EXCHANGE, "psum under deterministic=True")
    _expect_clean(jaxpr_audit.audit_jaxpr(j, deterministic=False),
                  "psum under deterministic=False")


def check_loop_closure() -> None:
    """Un-threaded tainted int in a fori body -> JAX-LOOP-CLOSURE; the
    carry-threaded twin of the same program must pass (this pair is the
    PR 1 / PR 6 bug class reconstructed minimally — the regression
    test pins it too)."""
    import jax

    def buggy(x):
        lane = jax.lax.axis_index("data")
        lo = lane * 4                       # tainted int32 ...
        def body(i, acc):
            return acc + x[lo + i]          # ... closed over: replicated
        return jax.lax.fori_loop(0, 4, body, 0.0)[None]

    def threaded(x):
        lane = jax.lax.axis_index("data")
        lo = lane * 4
        def body(i, carry):
            acc, lo = carry
            return acc + x[lo + i], lo      # threaded through the carry
        return jax.lax.fori_loop(0, 4, body, (0.0, lo))[0][None]

    _expect(jaxpr_audit.audit_jaxpr(_shmap(buggy), deterministic=True),
            rules.JAX_LOOP_CLOSURE, "closed-over axis-derived offset")
    _expect_clean(
        jaxpr_audit.audit_jaxpr(_shmap(threaded), deterministic=True),
        "carry-threaded twin")


def check_nondet_prim() -> None:
    """Injected pmax reduction -> JAX-NONDET-PRIM (det only)."""
    import jax
    from jax.sharding import PartitionSpec as P
    j = _shmap(lambda x: jax.lax.pmax(x, "data"), out_spec=P(None))
    _expect(jaxpr_audit.audit_jaxpr(j, deterministic=True),
            rules.JAX_NONDET_PRIM, "pmax under deterministic=True")
    _expect_clean(jaxpr_audit.audit_jaxpr(j, deterministic=False),
                  "pmax under deterministic=False")


# --- budget layer ---------------------------------------------------------


def check_plan_budget() -> None:
    """Forged over-budget pallas plan -> VMEM-PLAN-BUDGET; the same
    geometry routed honestly (through candidate enumeration) passes."""
    from repro.core.planner import (SolverPlan, Topology,
                                    WorkloadSignature, static_plan)
    # (B=16, nnz=512): match tensor alone is 16*512*512*5 B ~ 20 MiB
    sig = WorkloadSignature(n=4096, d=64, nnz=512, sparse=True,
                            name="selftest-forged")
    topo = Topology(backend="tpu")
    forged = SolverPlan(solver="pallas", route="pallas-replicated",
                        bucket=16, chunks=1, nnz_multiple=0,
                        feature_shard=False)
    _expect(budget.audit_plan(sig, topo, forged),
            rules.VMEM_PLAN_BUDGET, "forged over-budget plan")
    honest = static_plan(sig, topo, bucket=16)
    _expect_clean(budget.audit_plan(sig, topo, honest),
                  f"honestly routed plan ({honest.route})")


# --- lint layer -----------------------------------------------------------


_UNREGISTERED_KERNEL = textwrap.dedent("""\
    from jax.experimental import pallas as pl

    def rogue_kernel(x):
        return pl.pallas_call(lambda r, o: None, out_shape=x)(x)
    """)

_UNMARKED_COLLECTIVE = textwrap.dedent("""\
    import jax

    def exchange(dv, ax):
        bad = jax.lax.psum(dv, ax)
        good = jax.lax.all_gather(dv, ax)  # audit: collective-ok test
        return bad + good
    """)

_UNSEEDED_RNG = textwrap.dedent("""\
    import numpy as np

    def jitter(shape):
        good = np.random.default_rng(0).normal(size=shape)
        return good + np.random.rand(*shape)
    """)

_SWALLOWED_EXCEPT = textwrap.dedent("""\
    def load(path):
        try:
            return open(path).read()
        except Exception:
            return None
    """)

_MARKED_EXCEPT = textwrap.dedent("""\
    def load(path):
        try:
            return open(path).read()
        # audit: except-ok missing file means empty payload, by design
        except Exception:
            return None

    def narrow(path):
        try:
            return open(path).read()
        except Exception as e:
            raise RuntimeError(path) from e
    """)


def check_kernel_contract() -> None:
    """Synthesized pallas_call entry point that is not in
    KERNEL_CONTRACTS -> LINT-KERNEL-CONTRACT; the real registered
    kernel files stay clean."""
    from repro.analysis import config
    from repro.kernels.contracts import KERNEL_CONTRACTS
    path = "src/repro/kernels/rogue.py"
    got = lint.check_kernel_contracts(path, _UNREGISTERED_KERNEL,
                                      KERNEL_CONTRACTS)
    _expect(got, rules.LINT_KERNEL_CONTRACT, "unregistered pallas_call")
    for real in config.LIVE_KERNEL_FILES:
        src = (config.REPO_ROOT / real).read_text()
        _expect_clean(
            lint.check_kernel_contracts(real, src, KERNEL_CONTRACTS),
            f"registered kernels in {real}")


def check_raw_collective() -> None:
    """Unmarked lax.psum in a collective-scoped file ->
    LINT-RAW-COLLECTIVE; the marked all_gather beside it passes."""
    path = "src/repro/core/engine.py"     # scoped path, injected source
    got = lint.check_collective_markers(path, _UNMARKED_COLLECTIVE)
    _expect(got, rules.LINT_RAW_COLLECTIVE, "unmarked lax.psum")
    if len(got) != 1:
        raise SelfTestError(
            f"marked all_gather must NOT be flagged; got "
            f"{[str(f) for f in got]}")


def check_unseeded_rng() -> None:
    """np.random.rand global-state draw -> LINT-UNSEEDED-RNG; the
    seeded default_rng draw beside it passes."""
    got = lint.check_unseeded_rng("src/repro/x.py", _UNSEEDED_RNG)
    _expect(got, rules.LINT_UNSEEDED_RNG, "np.random.rand")
    if len(got) != 1:
        raise SelfTestError(
            f"seeded default_rng must NOT be flagged; got "
            f"{[str(f) for f in got]}")


def check_bare_except() -> None:
    """Error-swallowing `except Exception` -> LINT-BARE-EXCEPT; the
    marked twin and the re-raising handler both pass, and a bare
    `except:` fires regardless of markers."""
    path = "src/repro/x.py"
    _expect(lint.check_bare_except(path, _SWALLOWED_EXCEPT),
            rules.LINT_BARE_EXCEPT, "swallowing except Exception")
    _expect_clean(lint.check_bare_except(path, _MARKED_EXCEPT),
                  "marked swallow + re-raising handler")
    bare = _SWALLOWED_EXCEPT.replace("except Exception:", "except:")
    _expect(lint.check_bare_except(path, bare),
            rules.LINT_BARE_EXCEPT, "bare except")


def check_csr_entry() -> None:
    """CSR altitude file stripped of raise_on_duplicate_nonzeros ->
    LINT-CSR-ENTRY."""
    from repro.analysis import config
    stripped = {p: "def nothing():\n    pass\n"
                for p in config.CSR_ENTRY_FILES}
    _expect(lint.check_csr_entries(stripped), rules.LINT_CSR_ENTRY,
            "stripped CSR check")
    live = {p: (config.REPO_ROOT / p).read_text()
            for p in config.CSR_ENTRY_FILES}
    _expect_clean(lint.check_csr_entries(live), "live CSR altitudes")


#: name -> check, one per rule ID (closure check covers the
#: regression-pinned pair).
SELFTESTS: dict[str, Callable[[], None]] = {
    rules.JAX_PSUM_EXCHANGE: check_psum_exchange,
    rules.JAX_LOOP_CLOSURE: check_loop_closure,
    rules.JAX_NONDET_PRIM: check_nondet_prim,
    rules.VMEM_PLAN_BUDGET: check_plan_budget,
    rules.LINT_KERNEL_CONTRACT: check_kernel_contract,
    rules.LINT_RAW_COLLECTIVE: check_raw_collective,
    rules.LINT_UNSEEDED_RNG: check_unseeded_rng,
    rules.LINT_CSR_ENTRY: check_csr_entry,
    rules.LINT_BARE_EXCEPT: check_bare_except,
}


def run_selftests(log=None) -> list[str]:
    """Run every mutation self-test; returns failure messages
    (empty = all detectors proved live)."""
    failures: list[str] = []
    for rule_id, check in SELFTESTS.items():
        try:
            check()
            if log:
                log(f"  selftest {rule_id}: detector fired")
        except SelfTestError as e:
            failures.append(f"{rule_id}: {e}")
            if log:
                log(f"  selftest {rule_id}: FAILED ({e})")
    return failures
