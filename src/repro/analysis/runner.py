"""Audit orchestrator: jaxpr matrix + repo lint + VMEM budget sweep.

`run_audit` is what `tools/audit.py` (and the CI static-analysis job)
calls: it runs all three layers, returns an `AuditReport` whose `ok`
is the CI gate, and serializes to the JSON artifact schema
(`report.to_json()`).  Layers can be restricted for fast partial runs
(`layers={"lint"}` needs no jax import at all).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

from .rules import RULES, Finding

__all__ = ["AuditReport", "run_audit", "REPORT_VERSION", "LAYERS"]

REPORT_VERSION = 1
LAYERS = ("jaxpr", "lint", "budget")


@dataclasses.dataclass
class AuditReport:
    """Everything one audit run determined."""
    findings: list[Finding]
    cases: list[str]                  # jaxpr matrix case names traced
    layers: tuple[str, ...]
    plans_swept: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> dict:
        return {
            "version": REPORT_VERSION,
            "ok": self.ok,
            "layers": list(self.layers),
            "cases": list(self.cases),
            "plans_swept": self.plans_swept,
            "findings": [f.to_json() for f in self.findings],
            "rules": {rid: dataclasses.asdict(r)
                      for rid, r in RULES.items()},
        }


def run_audit(*, layers: Optional[Iterable[str]] = None,
              workloads: Optional[list[str]] = None,
              log=None) -> AuditReport:
    """Run the requested layers (default: all) over the live tree.

    ``workloads`` restricts the jaxpr matrix to named registry entries
    (tests use one small workload for speed); ``log`` gets per-case
    progress lines.
    """
    want = tuple(layers) if layers is not None else LAYERS
    unknown = set(want) - set(LAYERS)
    if unknown:
        raise ValueError(f"unknown audit layers: {sorted(unknown)}; "
                         f"choose from {LAYERS}")

    findings: list[Finding] = []
    cases: list[str] = []
    plans_swept = 0

    if "jaxpr" in want:
        from . import matrix
        if log:
            log("[jaxpr] tracing workload x route matrix")
        built = matrix.build_cases(workloads)
        cases = [c.name for c in built]
        for case in built:
            got = matrix.trace_case(case)
            if log:
                log(f"  jaxpr {case.name}: "
                    f"{'clean' if not got else f'{len(got)} finding(s)'}")
            findings += got

    if "lint" in want:
        from . import lint
        if log:
            log("[lint] AST rules over live sources")
        # resolve=True also import-checks the contract registry's
        # dotted refs whenever the jaxpr layer runs (jax is loaded
        # anyway); lint-only runs stay stdlib-importable.
        findings += lint.run_lint(resolve="jaxpr" in want)

    if "budget" in want:
        from . import budget
        if log:
            log("[budget] VMEM sweep over registry x topologies")
        got, plans_swept = budget.run_budget_audit(log=log)
        findings += got

    return AuditReport(findings=findings, cases=cases, layers=want,
                       plans_swept=plans_swept)
