"""Layer-1 jaxpr auditor: walk traced epoch programs for contract bugs.

`audit_jaxpr` takes a ClosedJaxpr (from `jax.make_jaxpr` over one of
the real epoch builders — see `analysis.matrix`) and returns findings
for three rules:

* JAX-PSUM-EXCHANGE — psum / psum_scatter ("reduce_scatter") anywhere
  in a deterministic=True trace.  The determinism contract's only
  reductions are ordered gather-sums; sum-reordering collectives have
  no legal site.
* JAX-LOOP-CLOSURE — the shard_map loop-invariant-replicated closure
  hazard (the PR 1 / PR 6 bug class): inside a shard_map region, a
  scan/while const (a value the loop CLOSES OVER, as opposed to its
  carry or scanned xs) that is integer-typed and tainted by
  lax.axis_index.  shard_map treats such closures as replicated, so
  every lane silently runs lane 0's value.
* JAX-NONDET-PRIM — other unordered cross-lane reductions (pmax/pmin)
  in a deterministic=True trace.

Taint analysis: `axis_index` outputs seed the taint set; taint
propagates through every equation (any tainted input taints all
outputs) and flows structurally into sub-jaxprs (pjit bodies, scan
carries/xs, cond branches), with loop carries iterated to a fixed
point.  Two deliberate scope cuts, both load-bearing for a
zero-false-positive clean tree:

* only INTEGER-dtype consts are flagged — the hazard class is
  index/offset values (visit perms, slice offsets); float data tiles
  gathered with tainted indices legitimately appear as inner-loop
  consts in the bucket recursion (`sdca.bucket_solve` closes over its
  Gram matrix) and are not scheduling state;
* `pallas_call` bodies are opaque (taint crosses them input->output
  but the walker does not descend): Mosaic kernels have their own
  semantics and no shard_map closures.
"""
from __future__ import annotations

from typing import Any, Optional

from . import config, rules
from .rules import Finding

__all__ = ["audit_jaxpr"]


def _summ(eqn) -> str:
    """file:line anchor for an eqn, best-effort."""
    try:
        from jax._src import source_info_util
        return source_info_util.summarize(eqn.source_info)
    # audit: except-ok best-effort anchor; empty string is the fallback
    except Exception:                     # pragma: no cover - jax-version
        return ""


def _is_int(var) -> bool:
    import jax.numpy as jnp
    dtype = getattr(getattr(var, "aval", None), "dtype", None)
    if dtype is None:
        return False
    try:
        return bool(jnp.issubdtype(dtype, jnp.integer))
    # audit: except-ok extension dtypes simply aren't ints
    except Exception:                     # pragma: no cover - ext dtypes
        return False


def _sub_jaxprs(eqn) -> list[tuple[str, Any]]:
    """(param-name, Jaxpr-or-ClosedJaxpr) pairs reachable from eqn."""
    out = []
    for k, v in eqn.params.items():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for j in vals:
            if hasattr(j, "eqns") or hasattr(j, "jaxpr"):
                out.append((k, j))
    return out


def _open(j):
    """ClosedJaxpr | Jaxpr -> Jaxpr."""
    return j.jaxpr if hasattr(j, "jaxpr") else j


def _literal_cls():
    try:
        from jax._src.core import Literal
    except ImportError:                   # pragma: no cover - jax-version
        from jax.core import Literal
    return Literal


class _Walker:
    def __init__(self, deterministic: bool, case: str):
        self.deterministic = deterministic
        self.case = case
        self.findings: list[Finding] = []
        self._seen: set[tuple] = set()    # dedupe across fixpoint passes

    def _emit(self, rule: str, eqn, message: str) -> None:
        where = _summ(eqn)
        key = (rule, where, message)
        if key in self._seen:
            return
        self._seen.add(key)
        self.findings.append(Finding(rule, message, where=where,
                                     case=self.case))

    # -- taint plumbing ----------------------------------------------------

    def _run_body(self, j, in_taint: list[bool], in_shard: bool,
                  ) -> list[bool]:
        """Walk one (Closed)Jaxpr body; returns outvar taint flags."""
        jaxpr = _open(j)
        tainted: set = set()
        for var, t in zip(jaxpr.invars, in_taint):
            if t:
                tainted.add(var)
        return self._walk(jaxpr, tainted, in_shard)

    def _loop_fixpoint(self, body, n_consts: int, n_carry: int,
                       in_taint: list[bool], in_shard: bool,
                       ) -> list[bool]:
        """Iterate a scan/while body until carry taint stabilizes."""
        carry = list(in_taint[n_consts:n_consts + n_carry])
        for _ in range(max(n_carry, 1) + 1):
            flags = (in_taint[:n_consts] + carry
                     + in_taint[n_consts + n_carry:])
            out = self._run_body(body, flags, in_shard)
            new_carry = [a or b for a, b in zip(carry, out[:n_carry])]
            if new_carry == carry:
                break
            carry = new_carry
        return out

    # -- the walk ----------------------------------------------------------

    def _walk(self, jaxpr, tainted: set, in_shard: bool) -> list[bool]:
        Literal = _literal_cls()

        def tin(eqn) -> list[bool]:
            return [not isinstance(v, Literal) and v in tainted
                    for v in eqn.invars]

        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            flags = tin(eqn)
            out_taint = any(flags)

            if name == "axis_index":
                out_taint = True
            elif name in config.PSUM_PRIMS:
                if self.deterministic:
                    self._emit(
                        rules.JAX_PSUM_EXCHANGE, eqn,
                        f"sum-reordering collective '{name}' in a "
                        f"deterministic=True trace; the contract "
                        f"requires all-gather + ordered jnp.sum")
            elif name in config.NONDET_PRIMS:
                if self.deterministic:
                    self._emit(
                        rules.JAX_NONDET_PRIM, eqn,
                        f"unordered cross-lane reduction '{name}' in "
                        f"a deterministic=True trace")

            if name == "pallas_call":
                pass                       # opaque: propagate, no descent
            elif name == "scan":
                nc = eqn.params["num_consts"]
                ncar = eqn.params["num_carry"]
                if in_shard:
                    self._check_consts(eqn, flags[:nc], "scan")
                out = self._loop_fixpoint(eqn.params["jaxpr"], nc, ncar,
                                          flags, in_shard)
                out_taint = None           # per-outvar flags below
                outs = out
            elif name == "while":
                bn = eqn.params["body_nconsts"]
                cn = eqn.params["cond_nconsts"]
                if in_shard:
                    self._check_consts(
                        eqn, flags[cn:cn + bn], "while/fori_loop",
                        offset=cn)
                ncar = len(flags) - cn - bn
                outs = self._loop_fixpoint(
                    eqn.params["body_jaxpr"], bn, ncar,
                    flags[cn:], in_shard)
                self._run_body(eqn.params["cond_jaxpr"],
                               flags[:cn] + outs, in_shard)
                out_taint = None
            elif name == "cond":
                outs = [False] * len(eqn.outvars)
                for br in eqn.params["branches"]:
                    o = self._run_body(br, flags[1:], in_shard)
                    outs = [a or b for a, b in zip(outs, o)]
                out_taint = None
            else:
                # generic descent: pjit / remat / custom_* / anything
                # else carrying sub-jaxprs.  shard_map marks the region
                # the closure rule applies to.
                descend_shard = in_shard or name == "shard_map"
                outs = None
                for _, j in _sub_jaxprs(eqn):
                    body = _open(j)
                    if len(body.invars) == len(flags):
                        o = self._run_body(j, flags, descend_shard)
                    else:
                        # arity mismatch (custom_jvp residuals etc.):
                        # conservatively taint every body input if any
                        # eqn input is tainted
                        o = self._run_body(
                            j, [any(flags)] * len(body.invars),
                            descend_shard)
                    if len(o) == len(eqn.outvars):
                        outs = ([a or b for a, b in zip(outs, o)]
                                if outs is not None else o)
                if outs is not None:
                    out_taint = None

            if out_taint is None:
                for var, t in zip(eqn.outvars, outs):
                    if t:
                        tainted.add(var)
            elif out_taint:
                for var in eqn.outvars:
                    tainted.add(var)

        return [not isinstance(v, Literal) and v in tainted
                for v in jaxpr.outvars]

    def _check_consts(self, eqn, const_flags: list[bool], kind: str,
                      offset: int = 0) -> None:
        for i, t in enumerate(const_flags):
            var = eqn.invars[offset + i]
            if t and _is_int(var):
                self._emit(
                    rules.JAX_LOOP_CLOSURE, eqn,
                    f"{kind} inside shard_map closes over a "
                    f"loop-invariant integer value derived from "
                    f"axis_index (const #{i}, "
                    f"{getattr(var, 'aval', '?')}); thread it through "
                    f"the carry or the scanned xs — shard_map "
                    f"replicates closed-over values across lanes")


def audit_jaxpr(closed, *, deterministic: bool, case: str = "",
                only: Optional[set] = None) -> list[Finding]:
    """Audit one ClosedJaxpr; returns rule findings (empty = clean).

    ``deterministic`` states whether the traced program ran under the
    determinism contract (enables the reduction rules; the closure
    rule applies either way).  ``only`` optionally restricts to a
    subset of rule IDs.
    """
    w = _Walker(deterministic, case)
    w._walk(closed.jaxpr, set(), in_shard=False)
    found = w.findings
    if only is not None:
        found = [f for f in found if f.rule in only]
    return found
