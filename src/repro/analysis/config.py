"""Audit scope + repo-specific constants (stdlib-only).

The quarantine list is the single place that says which packages are
inert seed scaffolding vs live solver code: the lint layer and ruff
(pyproject.toml ``extend-exclude`` — kept in sync by
tests/test_analysis.py) both skip quarantined paths so findings are
signal, not seed noise.  README.md documents the split.
"""
from __future__ import annotations

import pathlib

#: Repo root (…/src/repro/analysis/config.py -> repo).
REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]

#: Inert seed scaffolding, excluded from the audit AND from ruff
#: (pyproject.toml mirrors this list).  `optim/compression.py` is NOT
#: here — the engine's int8 wire compression imports it — so only the
#: unused optimizers are quarantined, not the package.
QUARANTINE = (
    "src/repro/models",
    "src/repro/configs",
    "src/repro/optim/adamw.py",
    "src/repro/optim/lbfgs.py",
    "src/repro/kernels/flash_attention.py",
    "src/repro/kernels/rglru.py",
    "src/repro/kernels/ref.py",
)

#: Where live python sources are discovered for the repo-wide lint
#: rules (unseeded RNG).  Tests/benchmarks/examples are out of scope:
#: they are allowed ad-hoc randomness and are not shipped solver code.
LINT_ROOTS = ("src/repro",)

#: Files whose collective calls must carry the allowlist marker
#: (LINT-RAW-COLLECTIVE).  These are the only modules allowed to issue
#: raw lax collectives at all; everything else under src/repro goes
#: through them.
COLLECTIVE_SCOPED_FILES = (
    "src/repro/core/engine.py",
    "src/repro/kernels/ops.py",
)

#: The allowlist marker a collective call line (or the line above it)
#: must carry, with a short justification after it:
#:     dv = jax.lax.psum(dv, ax)  # audit: collective-ok unordered ...
ALLOWLIST_MARKER = "audit: collective-ok"

#: lax attribute names that count as collectives for the marker rule.
#: axis_index is included deliberately: it is the taint seed of the
#: loop-closure hazard, so every site must be an enumerated one.
COLLECTIVE_CALL_NAMES = frozenset({
    "psum", "psum_scatter", "pmax", "pmin", "pmean", "all_gather",
    "all_to_all", "ppermute", "pshuffle", "axis_index",
})

#: The marker an error-swallowing broad except handler must carry
#: (LINT-BARE-EXCEPT), on the ``except`` line or the line above, with
#: a short justification after it:
#:     except Exception:   # audit: except-ok stale plan cache entry
EXCEPT_MARKER = "audit: except-ok"

#: Files that must each contain a raise_on_duplicate_nonzeros call —
#: the CSR no-duplicate-nonzero invariant's entry altitudes
#: (LINT-CSR-ENTRY).
CSR_ENTRY_FILES = (
    "src/repro/kernels/ops.py",
    "src/repro/api/session.py",
)
CSR_CHECK_NAME = "raise_on_duplicate_nonzeros"

#: Live kernel modules whose pallas_call entry points must be
#: registered in kernels/contracts.py (LINT-KERNEL-CONTRACT).
LIVE_KERNEL_FILES = (
    "src/repro/kernels/sdca_bucket.py",
    "src/repro/kernels/sdca_sparse_bucket.py",
)

# --- jaxpr-layer primitive sets ------------------------------------------

#: Sum-reordering cross-lane reductions: banned anywhere in a
#: deterministic=True trace (JAX-PSUM-EXCHANGE).  lax.psum_scatter
#: binds the "reduce_scatter" primitive; under shard_map's
#: check_rep=True rewrite, lax.psum binds "psum2".
PSUM_PRIMS = frozenset({"psum", "psum2", "reduce_scatter"})

#: Other unordered cross-lane reductions with no ordered twin in the
#: contract (JAX-NONDET-PRIM under deterministic=True).
NONDET_PRIMS = frozenset({"pmax", "pmin"})

#: Pure data-movement collectives, always allowed (documented here so
#: the walker's allow-list is explicit): all_gather, all_to_all,
#: ppermute, pshuffle, axis_index.


def is_quarantined(path) -> bool:
    """True when `path` (absolute or repo-relative) is seed scaffolding."""
    p = pathlib.Path(path)
    if p.is_absolute():
        try:
            p = p.relative_to(REPO_ROOT)
        except ValueError:
            return False
    s = str(p)
    return any(s == q or s.startswith(q + "/") for q in QUARANTINE)
