"""The audit matrix: registry workloads x solver routes, traced.

Each case abstract-traces a REAL epoch program — the same
`launch/glm.py` shard_map builds (`make_dense_epoch` /
`make_sparse_epoch`, which resolve solvers through
`engine.make_local_solver` and run `engine.sharded_epoch`) and the
same `engine.make_streamed_step` chunk step the out-of-core trainer
jits — with `jax.make_jaxpr` over ShapeDtypeStructs.  No data is
materialized and nothing executes: Pallas kernels trace in interpret
mode on CPU, so the full matrix runs on a bare CI host with forced
host devices (tools/audit.py sets XLA_FLAGS before importing jax).

Shapes are the registry's OFFLINE sub shapes (`DatasetSpec.sub_*`) —
the shapes CI can actually exercise — with the mesh fixed at
data=2 (x model=2 for the sharded route).  Dense workloads audit
feature_shard=False only: dense TP psums Gram/margin partials inside
the sub-epoch by design and is documented as non-bitwise (DESIGN.md
S12), so it is not on the determinism-contract path this layer
checks.

Every case traces under deterministic=True (all rules) and again
under deterministic=False (closure rule only — psum is the legal
exchange there).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from . import jaxpr_audit, rules
from .rules import Finding

__all__ = ["AuditCase", "build_cases", "trace_case", "run_matrix",
           "ROUTES_SPARSE", "ROUTES_DENSE"]

ROUTES_SPARSE = ("xla", "pallas-replicated", "pallas-sharded")
ROUTES_DENSE = ("xla", "pallas-replicated")

#: rules active per determinism flag: deterministic traces check
#: everything; non-deterministic traces only the closure hazard.
_DET_RULES = None                                    # None = all
_NONDET_RULES = {rules.JAX_LOOP_CLOSURE}


@dataclasses.dataclass(frozen=True)
class AuditCase:
    """One traceable program + the rule scope it is audited under."""
    name: str
    deterministic: bool
    trace: Callable[[], object]           # -> ClosedJaxpr
    only: Optional[frozenset] = None      # rule-ID restriction


def _mesh(model: int = 1):
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(pod=1, data=2, model=model)


def _glm_case(spec, route: str, deterministic: bool) -> AuditCase:
    from repro.launch import glm

    def trace():
        import jax
        sharded = route == "pallas-sharded"
        mesh = _mesh(model=2 if sharded else 1)
        nnz = -(-(spec.sub_nnz or spec.nnz or 8) // 8) * 8 \
            if spec.kind == "sparse" else 0
        scale = glm.GLMScale(
            name=f"audit-{spec.name}", kind=spec.kind, n=spec.sub_n,
            d=spec.sub_d, nnz=nnz, bucket=16, chunks=2,
            feature_shard=sharded,
            local_solver="xla" if route == "xla" else "pallas",
            deterministic=deterministic, compress_pod=False)
        if spec.kind == "sparse":
            ep = glm.make_sparse_epoch(scale, mesh, interpret=True)
        else:
            ep = glm.make_dense_epoch(scale, mesh)
        return jax.make_jaxpr(ep)(*glm.glm_input_specs(scale, mesh))

    tag = "det" if deterministic else "nondet"
    return AuditCase(f"{spec.name}/{route}/{tag}", deterministic, trace,
                     only=None if deterministic
                     else frozenset(_NONDET_RULES))


def _streamed_case(sparse: bool) -> AuditCase:
    """The out-of-core chunk step (`engine.make_streamed_step`) under
    the deterministic contract, on the sim collectives backend."""

    def trace():
        import jax
        import jax.numpy as jnp
        from repro.core import engine
        from repro.core.config import EngineConfig, AlgoConfig, \
            DeploymentConfig
        from repro.core.objectives import LOGISTIC
        spec = EngineConfig(
            algo=AlgoConfig(bucket=16, chunks=2, local_solver="xla"),
            deployment=DeploymentConfig(pods=1, lanes=2,
                                        deterministic=True))
        coll = engine.SimCollectives(pods=1, lanes=2, deterministic=True)
        solver = engine.make_local_solver(
            "xla", LOGISTIC, 2048 * 1e-3, 2.0, bucket=16, sparse=sparse)
        step = engine.make_streamed_step(coll, solver, spec.algo,
                                         jit=False)
        S = jax.ShapeDtypeStruct
        nb, d, nnz = 512, 64, 8
        if sparse:
            data = (S((1, 2, nb, nnz), jnp.int32),
                    S((1, 2, nb, nnz), jnp.float32))
        else:
            data = S((1, 2, d, nb), jnp.float32)
        # v_c is the pod-replicated (pods, d) view run_epoch_streamed
        # maintains across chunks (coll.pod_replicate)
        return jax.make_jaxpr(step)(
            data, S((1, 2, nb), jnp.float32),
            S((1, 2, nb), jnp.int32), S((2048,), jnp.float32),
            S((1, d if not sparse else 256), jnp.float32))

    kind = "sparse" if sparse else "dense"
    return AuditCase(f"streamed-{kind}/xla/det", True, trace)


def build_cases(workloads: Optional[list[str]] = None,
                ) -> list[AuditCase]:
    """The full matrix: every registry workload x its routes x both
    determinism flags, plus the streamed chunk steps."""
    from repro.data.registry import REGISTRY
    names = workloads if workloads is not None else sorted(REGISTRY)
    cases: list[AuditCase] = []
    for name in names:
        spec = REGISTRY[name]
        routes = ROUTES_SPARSE if spec.kind == "sparse" else ROUTES_DENSE
        for route in routes:
            cases.append(_glm_case(spec, route, deterministic=True))
            cases.append(_glm_case(spec, route, deterministic=False))
    if workloads is None:
        cases.append(_streamed_case(sparse=False))
        cases.append(_streamed_case(sparse=True))
    return cases


def trace_case(case: AuditCase) -> list[Finding]:
    """Trace + audit one case.  A trace failure is itself a finding
    (the auditor must never silently skip a case)."""
    try:
        closed = case.trace()
    # audit: except-ok a trace failure is converted into a finding
    except Exception as e:
        return [Finding(
            rules.JAX_LOOP_CLOSURE,
            f"case failed to trace ({type(e).__name__}: {e}); the "
            f"audit matrix must cover it", case=case.name)]
    return jaxpr_audit.audit_jaxpr(
        closed, deterministic=case.deterministic, case=case.name,
        only=set(case.only) if case.only is not None else None)


def run_matrix(workloads: Optional[list[str]] = None,
               log=None) -> list[Finding]:
    """Trace + audit every case; returns the combined findings."""
    found: list[Finding] = []
    for case in build_cases(workloads):
        got = trace_case(case)
        if log is not None:
            log(f"  jaxpr {case.name}: "
                f"{'clean' if not got else f'{len(got)} finding(s)'}")
        found += got
    return found
