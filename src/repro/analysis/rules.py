"""The rule registry: every machine-checked invariant, with its ID.

One `Rule` per invariant the auditor enforces.  The registry is the
single source of truth for rule IDs: DESIGN.md S14 and
docs/analysis.md carry a table of these IDs which
``tools/docs_check.py`` keeps in sync, and every mutation self-test
(`analysis.selftest`) names the rule it proves fires.  Stdlib-only on
purpose — docs tooling imports this without jax installed.
"""
from __future__ import annotations

import dataclasses

__all__ = [
    "Rule", "Finding", "RULES",
    "JAX_PSUM_EXCHANGE", "JAX_LOOP_CLOSURE", "JAX_NONDET_PRIM",
    "LINT_KERNEL_CONTRACT", "LINT_RAW_COLLECTIVE", "LINT_UNSEEDED_RNG",
    "LINT_CSR_ENTRY", "LINT_BARE_EXCEPT", "VMEM_PLAN_BUDGET",
]

JAX_PSUM_EXCHANGE = "JAX-PSUM-EXCHANGE"
JAX_LOOP_CLOSURE = "JAX-LOOP-CLOSURE"
JAX_NONDET_PRIM = "JAX-NONDET-PRIM"
LINT_KERNEL_CONTRACT = "LINT-KERNEL-CONTRACT"
LINT_RAW_COLLECTIVE = "LINT-RAW-COLLECTIVE"
LINT_UNSEEDED_RNG = "LINT-UNSEEDED-RNG"
LINT_CSR_ENTRY = "LINT-CSR-ENTRY"
LINT_BARE_EXCEPT = "LINT-BARE-EXCEPT"
VMEM_PLAN_BUDGET = "VMEM-PLAN-BUDGET"


@dataclasses.dataclass(frozen=True)
class Rule:
    """One machine-checked invariant.

    ``layer`` is where the checker lives ("jaxpr" | "lint" | "budget");
    ``invariant`` states the contract being enforced; ``history`` names
    the concrete bug (or bug class) it guards against — the rule table
    in DESIGN.md S14 renders these three columns verbatim.
    """
    id: str
    layer: str
    invariant: str
    history: str


RULES: dict[str, Rule] = {r.id: r for r in (
    Rule(
        JAX_PSUM_EXCHANGE, "jaxpr",
        "Under deterministic=True no cross-lane sum-reordering "
        "reduction (psum / psum_scatter) may appear anywhere in a "
        "traced epoch program: every exchange on the contract path is "
        "all-gather + an ordered jnp.sum (or pure data movement).",
        "The sharded sparse working-set exchange (DESIGN.md S12) was "
        "designed as all-gather + owner-select precisely because a "
        "psum of partial margins reorders float sums and silently "
        "breaks the bitwise sim<->mesh contract."),
    Rule(
        JAX_LOOP_CLOSURE, "jaxpr",
        "Inside a shard_map region, no scan/while/fori_loop may close "
        "over a loop-invariant integer value derived from "
        "lax.axis_index (it must ride in the carry or the scanned "
        "xs): shard_map treats such closures as replicated and every "
        "lane runs lane 0's value.",
        "PR 1: a fori_loop chunk loop replicated lane 0's visit perm "
        "to every lane (now statically unrolled in engine.run_epoch); "
        "PR 6: the sharded sparse solver threads its slice offset "
        "`lo` through the scan carry for the same reason."),
    Rule(
        JAX_NONDET_PRIM, "jaxpr",
        "Under deterministic=True no other unordered cross-lane "
        "reduction primitive (pmax / pmin / reduce_scatter) may be "
        "reachable: the contract's reductions are all enumerated, "
        "ordered gather-sums.",
        "Guards the same bug class as JAX-PSUM-EXCHANGE for the "
        "collectives that do not spell 'psum' — a reduce_scatter "
        "sneaking into a sync path would reorder sums identically."),
    Rule(
        LINT_KERNEL_CONTRACT, "lint",
        "Every Pallas kernel entry point in the live kernels must be "
        "registered in kernels/contracts.py with a misfit predicate "
        "and a vmem_bytes_estimate* model, so trace-time routing and "
        "the planner can never meet an unbudgeted kernel.",
        "PR 4's review rounds: kernels without misfit predicates "
        "failed at epoch build (or as opaque Mosaic OOMs) instead of "
        "routing to the XLA path at trace time."),
    Rule(
        LINT_RAW_COLLECTIVE, "lint",
        "core/engine.py and kernels/ops.py may call lax collectives "
        "(psum, all_gather, all_to_all, psum_scatter, ppermute, "
        "axis_index) only on lines carrying an explicit "
        "'# audit: collective-ok' marker: every cross-lane exchange "
        "is an enumerated, reviewed site.",
        "The determinism contract is a property of a closed set of "
        "exchange sites; an unmarked collective added in review is "
        "exactly how an unordered reduction slips onto the contract "
        "path."),
    Rule(
        LINT_UNSEEDED_RNG, "lint",
        "No live module may use numpy's global-state RNG "
        "(np.random.rand & co.) or the stdlib random module: all "
        "randomness flows from explicit seeds "
        "(np.random.default_rng(seed), jax.random keys).",
        "The repro's schedules, synthetic datasets and re-deals are "
        "all replayable from (seed, epoch); one unseeded draw makes "
        "a training run unreproducible."),
    Rule(
        LINT_CSR_ENTRY, "lint",
        "Each CSR entry altitude (kernels/ops.py, api/session.py) "
        "must call data.formats.raise_on_duplicate_nonzeros: rows "
        "with duplicate nonzero feature ids silently break the "
        "sparse kernel's bitwise-vs-XLA contract.",
        "PR 4 review rounds added the check at both altitudes after "
        "duplicate synthetic rows broke the bitwise contract; losing "
        "either call reopens the hole for ad-hoc arrays."),
    Rule(
        LINT_BARE_EXCEPT, "lint",
        "No live module may contain a bare `except:` or an `except "
        "Exception/BaseException` handler that swallows the error "
        "(no re-raise) without an explicit '# audit: except-ok' "
        "marker: every swallow site is an enumerated, reviewed "
        "recovery decision, and injected faults must surface through "
        "the typed resilience layer instead of dying silently.",
        "PR 9's fault-injection campaign: recovery machinery is built "
        "on typed errors (TileCorruptionError, FaultInjectedIOError) "
        "and a BaseException crash sentinel; one anonymous "
        "`except Exception: pass` between the fault site and the "
        "resilience layer turns a recoverable fault into silent "
        "state corruption."),
    Rule(
        VMEM_PLAN_BUDGET, "budget",
        "No plan the planner can emit (any candidate geometry over "
        "any registry workload x topology) may claim a pallas route "
        "whose kernel VMEM estimate exceeds TOTAL_VMEM_BUDGET_BYTES "
        "or whose resident vector busts V_VMEM_BUDGET_BYTES.",
        "Pre-PR-4 wide tiles (e.g. B=16, nnz=512) surfaced as opaque "
        "Mosaic OOMs at run time; the budget sweep fails the same "
        "geometry offline, before a TPU ever sees it."),
)}


@dataclasses.dataclass
class Finding:
    """One rule violation: rule ID + anchor + human message.

    ``where`` is a file:line anchor when the checker has one (lint
    rules always do; jaxpr rules carry the eqn's source_info summary);
    ``case`` labels the audit-matrix case or self-test that produced
    it (e.g. "webspam/pallas-sharded/det").
    """
    rule: str
    message: str
    where: str = ""
    case: str = ""

    def to_json(self) -> dict:
        """JSON-safe dict for the machine-readable report."""
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        case = f" ({self.case})" if self.case else ""
        return f"{self.rule}{case}{loc}: {self.message}"
