"""Static VMEM budget audit: plans vs the kernels' own estimators.

`audit_plan` is an INDEPENDENT re-derivation of the feasibility
arithmetic: given a plan that claims a Pallas route, it recomputes the
registered VMEM estimator for the plan's geometry and checks it
against the topology's budgets directly — it does not trust
`planner._plan_feasible` or the route verdict baked into the plan.
On a clean tree the sweep finds nothing, because `candidate_plans`
attaches routes through `ops.sparse_solver_plan`/`dense_kernel_misfit`
and those share the estimators; the audit exists to catch DRIFT — an
estimator change that the routing predicates stopped mirroring, a
hand-edited plan cache, or a forged plan (the mutation self-test).

`run_budget_audit` sweeps every registry workload (sub AND real
shapes) x TPU topologies (model_lanes 1/2/8) x the planner's full
candidate geometry enumeration.
"""
from __future__ import annotations

from typing import Optional

from . import rules
from .rules import Finding

__all__ = ["audit_plan", "run_budget_audit"]

#: model-lane counts swept per workload (1 = no model axis; 2 and 8
#: bracket the v5e configurations the launch scripts target).
MODEL_LANES = (1, 2, 8)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def audit_plan(sig, topo, plan) -> list[Finding]:
    """VMEM-PLAN-BUDGET for one (workload, topology, plan) triple.

    Re-evaluates the claiming kernel's estimator for the plan's
    geometry against the topology budgets.  xla-routed plans are
    always fine (HBM-resident v scan has no VMEM contract).
    """
    from repro.kernels import ops, sdca_bucket, sdca_sparse_bucket

    if plan.solver != "pallas" or plan.route == "xla":
        return []
    found: list[Finding] = []
    where = "src/repro/core/planner.py:1"
    case = (f"{sig.name or 'workload'}(n={sig.n},d={sig.d},"
            f"nnz={sig.nnz})/M={topo.model_lanes}")

    def emit(msg: str) -> None:
        found.append(Finding(rules.VMEM_PLAN_BUDGET, msg, where=where,
                             case=case))

    B = plan.bucket
    if sig.sparse:
        nnz = _round_up(max(sig.nnz, 1), plan.nnz_multiple) \
            if plan.nnz_multiple else sig.nnz
        d_pad = _round_up(max(sig.d, 8), 8)
        if plan.route == "pallas-sharded":
            if not plan.feature_shard or topo.model_lanes <= 1:
                emit(f"plan claims route=pallas-sharded without a "
                     f"model axis (feature_shard={plan.feature_shard}, "
                     f"model_lanes={topo.model_lanes})")
                return found
            d_eff = ops.sparse_slice_width(sig.d, topo.model_lanes)
            need = sdca_sparse_bucket.vmem_bytes_estimate_sharded(
                B, nnz, d_eff)
            label = f"sharded slice d_loc={d_eff}"
        else:
            d_eff = d_pad
            need = sdca_sparse_bucket.vmem_bytes_estimate(B, nnz, d_pad)
            label = f"replicated d_pad={d_pad}"
        if d_eff * 4 > topo.v_budget():
            emit(f"{plan.route} plan's resident v ({label}, "
                 f"{d_eff * 4} B) exceeds the {topo.v_budget()}-byte "
                 f"resident-v budget")
        if need > topo.total_budget():
            emit(f"{plan.route} plan needs ~{need} B of VMEM for "
                 f"(B={B}, nnz={nnz}, {label}); budget is "
                 f"{topo.total_budget()} B")
    else:
        B_pad = _round_up(max(B, 8), 8)
        if B_pad > sdca_bucket.MAX_BUCKET:
            emit(f"dense plan bucket={B} exceeds the kernel recursion "
                 f"cap B <= {sdca_bucket.MAX_BUCKET}")
        d_pad = _round_up(max(sig.d, 8), 8)
        need = sdca_bucket.vmem_bytes_estimate(B_pad, d_pad)
        if need > topo.total_budget():
            emit(f"dense plan needs ~{need} B of VMEM for (B={B_pad}, "
                 f"d_pad={d_pad}); budget is {topo.total_budget()} B")
    return found


def _signatures():
    from repro.core.planner import WorkloadSignature
    from repro.data.registry import REGISTRY
    sigs = []
    for name in sorted(REGISTRY):
        spec = REGISTRY[name]
        sparse = spec.kind == "sparse"
        sigs.append(WorkloadSignature(
            n=spec.sub_n, d=spec.sub_d, nnz=spec.sub_nnz or 0,
            sparse=sparse, name=f"{name}-sub"))
        if (spec.full_n, spec.full_d) != (spec.sub_n, spec.sub_d):
            sigs.append(WorkloadSignature(
                n=spec.full_n, d=spec.full_d, nnz=spec.nnz or 0,
                sparse=sparse, name=name))
    return sigs


def run_budget_audit(log=None) -> tuple[list[Finding], int]:
    """Sweep registry workloads x TPU topologies x candidate plans.

    -> (findings, plans_swept).
    """
    from repro.core.planner import Topology, candidate_plans

    found: list[Finding] = []
    n_plans = 0
    for sig in _signatures():
        for lanes in MODEL_LANES:
            topo = Topology(backend="tpu", device_count=max(lanes, 1),
                            model_lanes=lanes)
            plans = candidate_plans(sig, topo)
            n_plans += len(plans)
            for plan in plans:
                found += audit_plan(sig, topo, plan)
    if log is not None:
        log(f"  budget: {n_plans} candidate plans swept, "
            f"{len(found)} finding(s)")
    return found, n_plans
