"""Layer-2 AST lint: repo-specific rules ruff cannot express.

Every checker operates on ``(repo-relative path, source)`` pairs, so
the mutation self-tests can feed synthesized module sources through
the exact production code paths without touching the tree.  Stdlib
only — no jax import — which keeps the lint layer runnable in a bare
CI container and importable by docs tooling.

Rules (registry: `analysis.rules`):

* LINT-KERNEL-CONTRACT — every pallas_call entry point in the live
  kernel files is registered in `kernels.contracts.KERNEL_CONTRACTS`
  with a misfit predicate and a VMEM estimator.
* LINT-RAW-COLLECTIVE — lax collective calls in the collective-scoped
  files carry the ``# audit: collective-ok`` marker.
* LINT-UNSEEDED-RNG — no numpy global-state RNG / stdlib ``random``
  in live modules.
* LINT-CSR-ENTRY — each CSR entry altitude still calls
  ``raise_on_duplicate_nonzeros``.
* LINT-BARE-EXCEPT — no bare ``except:`` and no error-swallowing
  ``except Exception`` without the ``# audit: except-ok`` marker in
  live modules.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Iterable, Mapping, Optional

from . import config, rules
from .rules import Finding

__all__ = ["run_lint", "default_sources", "check_kernel_contracts",
           "check_collective_markers", "check_unseeded_rng",
           "check_csr_entries", "check_bare_except"]

#: numpy.random attributes that are explicitly seeded constructors
#: (everything else on np.random is the legacy global-state API).
_SEEDED_RNG_OK = frozenset({
    "default_rng", "Generator", "SeedSequence", "PCG64", "Philox",
    "MT19937", "BitGenerator",
})


def default_sources() -> dict[str, str]:
    """Discover the live sources: every .py under `config.LINT_ROOTS`
    that is not quarantined, as {repo-relative path: source}."""
    out: dict[str, str] = {}
    for root in config.LINT_ROOTS:
        base = config.REPO_ROOT / root
        for p in sorted(base.rglob("*.py")):
            rel = str(p.relative_to(config.REPO_ROOT))
            if config.is_quarantined(rel):
                continue
            out[rel] = p.read_text()
    return out


def _parse(path: str, source: str) -> Optional[ast.Module]:
    try:
        return ast.parse(source, filename=path)
    except SyntaxError:                       # pragma: no cover - defensive
        return None


def _attr_chain(node: ast.AST) -> list[str]:
    """x.y.z -> ["x", "y", "z"] (empty when not a plain name chain)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def check_kernel_contracts(path: str, source: str,
                           contracts: Mapping[str, Mapping[str, str]],
                           ) -> list[Finding]:
    """LINT-KERNEL-CONTRACT over one live kernel file.

    A "kernel entry point" is any module-level function whose body
    contains a ``pallas_call`` invocation; its registry key is
    ``<module-stem>.<function-name>`` and the entry must name both a
    ``misfit`` predicate and a ``vmem_estimate`` model.
    """
    tree = _parse(path, source)
    if tree is None:
        return []
    stem = pathlib.Path(path).stem
    found: list[Finding] = []
    for node in tree.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        call_lines = [
            sub.lineno for sub in ast.walk(node)
            if isinstance(sub, ast.Call)
            and (_attr_chain(sub.func)[-1:] == ["pallas_call"])]
        if not call_lines:
            continue
        key = f"{stem}.{node.name}"
        entry = contracts.get(key)
        if entry is None:
            found.append(Finding(
                rules.LINT_KERNEL_CONTRACT,
                f"pallas kernel entry point {key!r} (pallas_call at "
                f"line {call_lines[0]}) is not registered in "
                f"kernels/contracts.py KERNEL_CONTRACTS",
                where=f"{path}:{node.lineno}"))
            continue
        for field in ("misfit", "vmem_estimate"):
            if not entry.get(field):
                found.append(Finding(
                    rules.LINT_KERNEL_CONTRACT,
                    f"KERNEL_CONTRACTS[{key!r}] is missing the "
                    f"{field!r} reference",
                    where=f"{path}:{node.lineno}"))
    return found


def check_collective_markers(path: str, source: str) -> list[Finding]:
    """LINT-RAW-COLLECTIVE over one collective-scoped file: every
    ``[jax.]lax.<collective>(...)`` call line (or the line above it)
    must carry the ``# audit: collective-ok`` marker."""
    tree = _parse(path, source)
    if tree is None:
        return []
    lines = source.splitlines()
    found: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if (len(chain) < 2 or chain[-1] not in config.COLLECTIVE_CALL_NAMES
                or chain[-2] != "lax"):
            continue
        ln = node.func.lineno if hasattr(node.func, "lineno") \
            else node.lineno
        window = lines[max(ln - 2, 0):ln]
        if not any(config.ALLOWLIST_MARKER in s for s in window):
            found.append(Finding(
                rules.LINT_RAW_COLLECTIVE,
                f"raw collective lax.{chain[-1]} without a "
                f"'# {config.ALLOWLIST_MARKER}' marker on the call "
                f"line or the line above",
                where=f"{path}:{ln}"))
    return found


def check_unseeded_rng(path: str, source: str) -> list[Finding]:
    """LINT-UNSEEDED-RNG over one live file: no ``np.random.<legacy>``
    global-state draws, no stdlib ``random`` import."""
    tree = _parse(path, source)
    if tree is None:
        return []
    found: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "random":
                    found.append(Finding(
                        rules.LINT_UNSEEDED_RNG,
                        "stdlib `import random` in live solver code; "
                        "use np.random.default_rng(seed) or a jax key",
                        where=f"{path}:{node.lineno}"))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "random":
                found.append(Finding(
                    rules.LINT_UNSEEDED_RNG,
                    "stdlib `from random import ...` in live solver "
                    "code; use np.random.default_rng(seed)",
                    where=f"{path}:{node.lineno}"))
        elif isinstance(node, ast.Attribute):
            chain = _attr_chain(node)
            if (len(chain) == 3 and chain[0] in ("np", "numpy")
                    and chain[1] == "random"
                    and chain[2] not in _SEEDED_RNG_OK):
                found.append(Finding(
                    rules.LINT_UNSEEDED_RNG,
                    f"global-state numpy RNG np.random.{chain[2]}; "
                    f"use np.random.default_rng(seed)",
                    where=f"{path}:{node.lineno}"))
    return found


#: exception names in an `except` clause that count as "broad".
_BROAD_EXC = frozenset({"Exception", "BaseException"})


def _broad_names(node: ast.AST) -> list[str]:
    """Broad exception-class names in an except clause's type
    expression (handles bare names, module attributes, and tuples)."""
    if node is None:
        return []
    if isinstance(node, ast.Tuple):
        return [n for elt in node.elts for n in _broad_names(elt)]
    chain = _attr_chain(node)
    if chain and chain[-1] in _BROAD_EXC:
        return [chain[-1]]
    return []


def check_bare_except(path: str, source: str) -> list[Finding]:
    """LINT-BARE-EXCEPT over one live file.

    Bare ``except:`` is always a finding.  ``except Exception`` /
    ``except BaseException`` (alone or inside a tuple) is a finding
    when the handler body contains no ``raise`` — i.e. it swallows the
    error — unless the except line (or the line above) carries the
    ``# audit: except-ok`` marker.  Handlers that re-raise are fine:
    they narrow or annotate, they don't swallow.
    """
    tree = _parse(path, source)
    if tree is None:
        return []
    lines = source.splitlines()
    found: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        ln = node.lineno
        if node.type is None:
            found.append(Finding(
                rules.LINT_BARE_EXCEPT,
                "bare `except:` catches SystemExit/KeyboardInterrupt "
                "and the SimulatedCrash fault sentinel; name the "
                "exceptions (or `except Exception` with a "
                f"'# {config.EXCEPT_MARKER}' marker)",
                where=f"{path}:{ln}"))
            continue
        broad = _broad_names(node.type)
        if not broad:
            continue
        swallows = not any(isinstance(sub, ast.Raise)
                           for sub in ast.walk(node))
        if not swallows:
            continue
        window = lines[max(ln - 2, 0):ln]
        if any(config.EXCEPT_MARKER in s for s in window):
            continue
        found.append(Finding(
            rules.LINT_BARE_EXCEPT,
            f"`except {broad[0]}` swallows the error (no raise in "
            f"the handler) without a '# {config.EXCEPT_MARKER}' "
            f"marker on the except line or the line above; swallow "
            f"sites must be enumerated, justified recovery decisions",
            where=f"{path}:{ln}"))
    return found


def check_csr_entries(sources: Mapping[str, str]) -> list[Finding]:
    """LINT-CSR-ENTRY: each configured altitude file must contain at
    least one call to `raise_on_duplicate_nonzeros`."""
    found: list[Finding] = []
    for path in config.CSR_ENTRY_FILES:
        src = sources.get(path)
        if src is None:
            continue                      # partial source sets (tests)
        tree = _parse(path, src)
        calls = [
            n for n in ast.walk(tree)
            if isinstance(n, ast.Call)
            and _attr_chain(n.func)[-1:] == [config.CSR_CHECK_NAME]
        ] if tree else []
        if not calls:
            found.append(Finding(
                rules.LINT_CSR_ENTRY,
                f"CSR entry altitude no longer calls "
                f"{config.CSR_CHECK_NAME}; the no-duplicate-nonzero "
                f"invariant is unenforced at this boundary",
                where=f"{path}:1"))
    return found


def _load_contracts() -> Mapping[str, Mapping[str, str]]:
    from repro.kernels.contracts import KERNEL_CONTRACTS
    return KERNEL_CONTRACTS


def resolve_contract_refs(contracts: Optional[Mapping] = None,
                          ) -> list[Finding]:
    """Import-check every dotted ``module:attr`` reference in the
    kernel-contract registry (needs the full dependency stack; the
    pure-AST checks above do not)."""
    import importlib
    contracts = _load_contracts() if contracts is None else contracts
    found: list[Finding] = []
    for key, entry in contracts.items():
        for field in ("misfit", "vmem_estimate"):
            ref = entry.get(field, "")
            mod, _, attr = ref.partition(":")
            try:
                fn = getattr(importlib.import_module(mod), attr)
                if not callable(fn):
                    raise TypeError(f"{ref} is not callable")
            # audit: except-ok a broken ref IS the reported finding
            except Exception as e:
                found.append(Finding(
                    rules.LINT_KERNEL_CONTRACT,
                    f"KERNEL_CONTRACTS[{key!r}].{field} = {ref!r} "
                    f"does not resolve: {type(e).__name__}: {e}",
                    where="src/repro/kernels/contracts.py:1"))
    return found


def run_lint(sources: Optional[Mapping[str, str]] = None, *,
             contracts: Optional[Mapping] = None,
             resolve: bool = False,
             only: Optional[Iterable[str]] = None) -> list[Finding]:
    """Run every lint rule over the live tree (or injected sources).

    ``sources`` maps repo-relative paths to source text (default: the
    live tree per `config`); ``only`` restricts to a subset of rule
    IDs; ``resolve=True`` additionally import-checks the contract
    registry's dotted references (requires jax).
    """
    sources = default_sources() if sources is None else dict(sources)
    contracts = _load_contracts() if contracts is None else contracts
    want = set(only) if only is not None else None

    def on(rule: str) -> bool:
        return want is None or rule in want

    found: list[Finding] = []
    if on(rules.LINT_KERNEL_CONTRACT):
        for path in config.LIVE_KERNEL_FILES:
            if path in sources:
                found += check_kernel_contracts(path, sources[path],
                                                contracts)
        if resolve:
            found += resolve_contract_refs(contracts)
    if on(rules.LINT_RAW_COLLECTIVE):
        for path in config.COLLECTIVE_SCOPED_FILES:
            if path in sources:
                found += check_collective_markers(path, sources[path])
    if on(rules.LINT_UNSEEDED_RNG):
        for path, src in sources.items():
            found += check_unseeded_rng(path, src)
    if on(rules.LINT_BARE_EXCEPT):
        for path, src in sources.items():
            found += check_bare_except(path, src)
    if on(rules.LINT_CSR_ENTRY):
        found += check_csr_entries(sources)
    return found
