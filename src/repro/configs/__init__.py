"""Architecture registry: one module per assigned architecture."""
from .base import ArchConfig, get_config, get_smoke, list_archs, register

__all__ = ["ArchConfig", "get_config", "get_smoke", "list_archs",
           "register"]
