"""whisper-base [audio]: enc-dec, conv frontend stubbed (precomputed
frame embeddings), 6 encoder + 6 decoder layers.  [arXiv:2212.04356]

Assignment line: 6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865.
Whisper uses learned positions, LayerNorm, GELU, non-gated MLP.
"""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51865,
    is_encoder_decoder=True, n_enc_layers=6, enc_seq=1500,
    frontend="audio",
    norm="layernorm", act="gelu", gated_mlp=False,
    use_rope=False, learned_pos=True, max_seq=32768 + 8,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="whisper-base-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=128,
        is_encoder_decoder=True, n_enc_layers=2, enc_seq=24,
        frontend="audio",
        norm="layernorm", act="gelu", gated_mlp=False,
        use_rope=False, learned_pos=True, max_seq=64, remat=False,
    )


register(__name__, CONFIG, smoke)
