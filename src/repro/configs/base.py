"""ArchConfig: one dataclass describes every supported architecture.

Exact full-size configs live in one file per architecture; each exposes
CONFIG (full size, dry-run only) and smoke() (reduced same-family config
that trains a step on CPU).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense|moe|hybrid|ssm|encdec|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention
    attention: str = "full"      # full | mla | local
    head_dim: int = 0            # 0 -> d_model // n_heads
    rope_theta: float = 1e4
    use_rope: bool = True
    window: int = 2048           # local attention window

    # MLA
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    # expert-capacity factor: tokens beyond capacity drop to the
    # residual path during TRAINING (standard); decode never drops
    # (T=batch << capacity), so train/decode outputs differ for
    # dropped tokens — tests use a dropless factor to compare paths.
    moe_capacity: float = 1.25

    # hybrid / ssm
    block_pattern: Tuple[str, ...] = ()
    rglru_dim: int = 0

    # encoder-decoder
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1536          # encoder length (stub frames)

    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None
    n_patches: int = 576         # vision stub patch count

    # misc
    act: str = "silu"
    norm: str = "rmsnorm"
    gated_mlp: bool = True
    learned_pos: bool = False
    max_seq: int = 8192          # positional table size (learned_pos only)
    dtype: object = jnp.bfloat16
    remat: bool = True
    fsdp: bool = False           # deprecated alias for zero="zero3"
    zero: str = ""               # "" | "zero1" | "zero3" (see launch/steps)
    opt_dtype: str = "f32"       # AdamW moment dtype: f32 | bf16 | int8
    shard_resid: bool = False    # shard residual d over 'model' (SP-style)
                                 # to fit remat'd activations of big archs
    layout: str = "tp"           # "tp": TP over 'model' + DP over rest;
                                 # "fsdp": batch over ALL axes, weights
                                 # ZeRO-3-gathered per layer (measured
                                 # winner for 20B dense at batch 1M tok)

    @property
    def batch_axes(self) -> tuple:
        return ("pod", "data", "model") if self.layout == "fsdp" \
            else ("pod", "data")

    @property
    def zero_stage(self) -> str:
        if self.zero:
            return self.zero
        return "zero3" if self.fsdp else "none"
    attn_chunk: int = 512        # KV-chunk of the online-softmax attention
    unroll_layers: bool = False  # python-loop layers (HLO counting mode)

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding/lm_head rows padded to 512 so the vocab dim shards
        over the 'model' axis AND the combined ('data','model') fsdp
        axis (labels never hit the pad)."""
        return -(-self.vocab // 512) * 512

    # -- bookkeeping used by roofline ------------------------------------
    def param_count(self) -> int:
        """Total parameters (embedding + blocks + head)."""
        from repro.models import lm
        from repro.models.layers import ParamSpec
        import numpy as np
        specs = lm.param_specs(self)
        leaves = [l for l in
                  __import__("jax").tree.flatten(
                      specs, is_leaf=lambda x: isinstance(x, ParamSpec))[0]]
        return int(sum(int(np.prod(l.shape)) for l in leaves))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared)."""
        full = self.param_count()
        if not self.n_experts:
            return full
        expert_params = (self.n_layers - self.first_dense_layers) * \
            self.n_experts * 3 * self.d_model * self.moe_d_ff
        active_expert = expert_params * self.top_k / self.n_experts
        return int(full - expert_params + active_expert)


_REGISTRY: dict = {}


def register(cfg_module_name: str, cfg: ArchConfig, smoke_fn) -> None:
    _REGISTRY[cfg.name] = (cfg, smoke_fn)


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name][0]


def get_smoke(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name][1]()


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    import importlib
    for m in ("whisper_base", "kimi_k2", "deepseek_v2_lite", "smollm_360m",
              "minicpm3_4b", "granite_20b", "internlm2_20b",
              "recurrentgemma_2b", "phi3_vision", "xlstm_1_3b"):
        importlib.import_module(f"repro.configs.{m}")
