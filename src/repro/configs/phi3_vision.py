"""phi-3-vision-4.2b [vlm]: phi-3-mini backbone + CLIP frontend (stub).
[hf:microsoft/Phi-3-vision-128k-instruct]

Assignment line: 32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.
The CLIP vision tower is a STUB: input_specs() provides precomputed
patch embeddings (batch, n_patches, d_model) that are prepended to the
token embeddings; loss is masked to text positions.
"""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32064,
    frontend="vision", n_patches=576,

)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="phi-3-vision-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256,
        frontend="vision", n_patches=16, remat=False,
    )


register(__name__, CONFIG, smoke)
