"""smollm-360m [dense]: llama-arch small model.  [hf:HuggingFaceTB/SmolLM]

Assignment line: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
"""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab=49152,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="smollm-360m-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=3, n_kv_heads=1, d_ff=256,
        vocab=256, remat=False,
    )


register(__name__, CONFIG, smoke)
