"""deepseek-v2-lite-16b [moe]: MLA + fine-grained MoE.  [arXiv:2405.04434]

Assignment line: 27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE 64e top-6 — MLA kv_lora=512, 2 shared + routed top-6.  We implement
64 routed + 2 shared experts, top-6 (the "160 routed" fragment in the
line contradicts the primary "64e" clause and the HF config; see
DESIGN.md S4).  MLA dims from the HF config: qk_nope=128, qk_rope=64,
v_head=128, kv_lora=512, no q-LoRA.  First layer is dense (ff=10944).
"""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
    vocab=102400,
    attention="mla", kv_lora_rank=512, q_lora_rank=0,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    first_dense_layers=1,
    zero="zero1", shard_resid=True,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256,
        attention="mla", kv_lora_rank=32, q_lora_rank=0,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        n_experts=8, n_shared_experts=2, top_k=2, moe_d_ff=48,
        first_dense_layers=1, remat=False,
    )


register(__name__, CONFIG, smoke)
