"""xlstm-1.3b [ssm]: alternating mLSTM / sLSTM blocks.  [arXiv:2405.04517]

Assignment line: 48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.
d_ff=0 => no separate MLP (the xLSTM block's projections are the FFN).
Sub-quadratic decode: runs the long_500k cell.
"""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304,
    block_pattern=("mlstm", "slstm"),
    use_rope=False,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="xlstm-1.3b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=0,
        vocab=256,
        block_pattern=("mlstm", "slstm"),
        use_rope=False, remat=False,
    )


register(__name__, CONFIG, smoke)
