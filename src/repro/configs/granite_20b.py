"""granite-20b [dense]: code model, MQA (kv=1).  [arXiv:2405.04324]

Assignment line: 52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152
— "llama-arch" per the assignment, so RoPE + RMSNorm + gated SiLU MLP
(the HF granite-20b-code is gpt_bigcode-style; the assignment overrides
to llama-arch and we follow the assignment).
"""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab=49152,
    zero="zero1", layout="fsdp",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="granite-20b-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=1, d_ff=384,
        vocab=256, remat=False,
    )


register(__name__, CONFIG, smoke)
