"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1 attn : 2 rec.
[arXiv:2402.19427 (Griffin)]

Assignment line: 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
Pattern ("rec","rec","attn") x 8 + 2 trailing rec layers (26 = 3*8+2).
Local attention window 2048; RG-LRU width = d_model.  Sub-quadratic:
runs the long_500k cell.
"""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000,
    attention="local", window=2048,
    block_pattern=("rec", "rec", "attn"), rglru_dim=2560,
    act="gelu",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-2b-smoke", family="hybrid",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab=256,
        attention="local", window=16,
        block_pattern=("rec", "rec", "attn"), rglru_dim=64,
        act="gelu", remat=False,
    )


register(__name__, CONFIG, smoke)
