"""minicpm3-4b [dense]: dense transformer with MLA.  [hf:openbmb/MiniCPM3-4B]

Assignment line: 62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448 — MLA.
MLA dims from the HF config: qk_nope=64, qk_rope=32, v_head=64,
kv_lora=256, q_lora=768.
"""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73448,
    attention="mla", kv_lora_rank=256, q_lora_rank=768,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    shard_resid=True,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256,
        attention="mla", kv_lora_rank=32, q_lora_rank=48,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, remat=False,
    )


register(__name__, CONFIG, smoke)
