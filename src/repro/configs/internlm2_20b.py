"""internlm2-20b [dense]: GQA llama-arch.  [arXiv:2403.17297]

Assignment line: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
"""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92544,
    rope_theta=1e6,
    zero="zero1", shard_resid=True,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="internlm2-20b-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256,
        vocab=256, remat=False,
    )


register(__name__, CONFIG, smoke)
