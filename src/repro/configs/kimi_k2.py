"""kimi-k2-1t-a32b [moe]: trillion-parameter MoE.  [arXiv:2501.kimi2]

Assignment line: 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840,
MoE 384e top-8.  d_ff=2048 is the per-expert intermediate size
(61 x 384 x 3 x 7168 x 2048 ~= 1.03T expert params — the "1T"), top-8 of
384 ~= 32B active.  We follow the line as written (GQA kv=8; the public
model uses MLA — noted in DESIGN.md S4) with 1 shared expert and a dense
first layer per the public config.
"""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840,
    n_experts=384, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=1,
    rope_theta=5e4, zero="zero1", opt_dtype="int8", shard_resid=True,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab=256,
        n_experts=8, n_shared_experts=1, top_k=2, moe_d_ff=96,
        first_dense_layers=1, remat=False,
    )


register(__name__, CONFIG, smoke)
