"""Sharding context: mesh registry + guarded sharding constraints.

Model code calls `constrain(x, axis, axis, ...)` unconditionally; the
constraint is a no-op unless a mesh has been registered (smoke tests run
mesh-less on one CPU device, the launcher registers the production mesh).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def constrain(x, *spec):
    """with_sharding_constraint(x, P(*spec)) iff a mesh is registered.

    Axis names absent from the registered mesh are dropped from the spec,
    so the same model code works on a ("data","model") mesh and a
    ("pod","data","model") mesh.
    """
    if _MESH is None:
        return x
    names = set(_MESH.axis_names)

    def keep(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in names)
            return kept if kept else None
        return e if e in names else None

    cleaned = P(*(keep(e) for e in spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, cleaned))


def clean_pspec(spec: P) -> P:
    """Drop axis names not present in the registered mesh from a spec."""
    if _MESH is None:
        return spec
    names = set(_MESH.axis_names)

    def keep(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in names)
            return kept if kept else None
        return e if e in names else None

    return P(*(keep(e) for e in spec))
