"""SDCA primitives: bucket recursion and per-worker local sub-epochs.

The TPU formulation of the paper's bucket (DESIGN.md S2): a bucket of B
consecutive coordinates is processed through its Gram matrix

    m0 = X_b^T v          (B,)    margins at bucket entry
    G  = X_b^T X_b        (B,B)

after which the sequential SDCA recursion over the bucket only touches
(m, G, alpha_b, y_b) — O(B^2) scalar work — and the shared vector is
updated once per bucket:  v += (sigma'/lam_n) X_b @ delta.  This is
EXACTLY sequential SDCA in the same visiting order (the in-bucket margin
evolution is fully determined by G), but it
  * streams the (d x B) tile from HBM once,
  * turns the dot/axpy stream into two MXU matmuls + one small recursion,
  * needs one model-axis psum per bucket instead of one per coordinate
    when features are sharded (TP).

sigma' is the CoCoA(+) subproblem scaling: 1 for a truly sequential
solver, K (#independent workers whose updates are summed) for safe
additive aggregation, and deliberately 1-with-summing for the "wild"
simulator (which is what makes it diverge on dense data, as in Fig 1a).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .objectives import Objective

Array = jax.Array


def bucket_solve(obj: Objective, G: Array, m0: Array, a0: Array, y: Array,
                 lam_n: Array, sigma_p: Array) -> Array:
    """Sequential SDCA over one bucket via its Gram matrix.

    Returns delta (B,) such that alpha_bucket += delta reproduces the
    sequential visiting order 0..B-1 exactly.
    """
    B = m0.shape[0]

    def body(i, carry):
        m, deltas = carry
        q = sigma_p * jnp.diag(G)[i] / lam_n
        d = obj.delta(m[i], a0[i], y[i], q)
        m = m + (sigma_p * d / lam_n) * G[i]
        deltas = deltas.at[i].set(d)
        return m, deltas

    _, deltas = jax.lax.fori_loop(
        0, B, body, (m0, jnp.zeros_like(m0)))
    return deltas


def dense_local_subepoch(
    obj: Objective,
    Xl: Array,            # (d_shard, n_local) columns in visiting order
    yl: Array,            # (n_local,)
    al: Array,            # (n_local,)
    v0: Array,            # (d_shard,) worker-local replica (model shard)
    lam_n: Array,
    sigma_p: Array,
    bucket: int,
    model_axis: Optional[str] = None,
) -> tuple[Array, Array]:
    """One worker's pass over its buckets.  Returns (al_new, dv).

    When features are sharded over a mesh axis (TP), pass model_axis: the
    per-bucket Gram/margin partials are psum'd so every shard runs the
    identical recursion; v stays shard-local.
    """
    d, n_local = Xl.shape
    nb = n_local // bucket
    Xb = Xl.reshape(d, nb, bucket).transpose(1, 0, 2)   # (nb, d, B)
    ab = al.reshape(nb, bucket)
    yb = yl.reshape(nb, bucket)

    def step(v, inp):
        Xt, a_b, y_b = inp
        m0 = Xt.T @ v                     # (B,)
        G = Xt.T @ Xt                     # (B,B)
        if model_axis is not None:
            # one fused psum per bucket amortizes the TP collective over B
            # coordinates (vs one per coordinate without bucketing)
            packed = jnp.concatenate([m0[:, None], G], axis=1)
            packed = jax.lax.psum(packed, model_axis)
            m0, G = packed[:, 0], packed[:, 1:]
        deltas = bucket_solve(obj, G, m0, a_b, y_b, lam_n, sigma_p)
        v = v + (sigma_p / lam_n) * (Xt @ deltas)
        return v, a_b + deltas

    v1, a_new = jax.lax.scan(step, v0, (Xb, ab, yb))
    # CoCoA+: the local replica evolves with the sigma'-scaled updates, but
    # the aggregated global delta is the UNSCALED (1/lam_n) A_k @ dalpha_k.
    return a_new.reshape(-1), (v1 - v0) / sigma_p


def sparse_local_subepoch(
    obj: Objective,
    idx: Array,           # (n_local, nnz) int32 feature ids (padded)
    val: Array,           # (n_local, nnz) values (0 where padded)
    yl: Array,
    al: Array,
    v0: Array,            # (d,) replicated feature vector
    lam_n: Array,
    sigma_p: Array,
) -> tuple[Array, Array]:
    """Sparse (padded-CSR) sequential pass: gather/scatter per coordinate.

    No Gram trick (sparse-sparse Gram is not worth it on the VPU); the
    bucket optimization still applies upstream as shuffle granularity.
    This is the XLA reference path; on TPU the engine routes sparse
    sub-epochs through `kernels.ops.sdca_sparse_bucket_subepoch`, which
    keeps v VMEM-resident and is bitwise-identical to this scan for
    rows obeying the CSR no-duplicate-nonzero invariant (DESIGN.md S11).
    """
    qii = jnp.sum(val * val, axis=1)                    # (n_local,)

    def step(v, inp):
        ii, vv, y, a, q = inp
        m = jnp.sum(v[ii] * vv)
        d = obj.delta(m, a, y, sigma_p * q / lam_n)
        v = v.at[ii].add((sigma_p * d / lam_n) * vv)
        return v, a + d

    v1, a_new = jax.lax.scan(step, v0, (idx, val, yl, al, qii))
    return a_new, (v1 - v0) / sigma_p


def sequential_epoch(
    obj: Objective,
    X: Array,             # (d, n)
    y: Array,
    alpha: Array,
    v: Array,
    lam: float,
    perm: Array,          # (n,) visiting order
    bucket: int = 1,
    sigma_p: float = 1.0,
) -> tuple[Array, Array]:
    """Single-worker epoch (the paper's sequential baseline).

    bucket=1 reproduces classic per-coordinate SDCA; bucket>1 uses the
    Gram recursion (identical updates for the same perm).
    """
    n = y.shape[0]
    lam_n = jnp.asarray(lam * n, X.dtype)
    Xp = X[:, perm]
    a_new, dv = dense_local_subepoch(
        obj, Xp, y[perm], alpha[perm], v, lam_n,
        jnp.asarray(sigma_p, X.dtype), bucket)
    alpha = alpha.at[perm].set(a_new)
    return alpha, v + dv
