"""GLM objectives for SDCA.

Primal:  min_w  P(w) = (1/n) sum_i phi(x_i^T w, y_i) + (lam/2) ||w||^2
Dual:    max_a  D(a) = -(1/n) sum_i phi*(-a_i, y_i) - (lam/2) ||v||^2
with the shared vector v = (1/(lam*n)) * A @ a  (A = [x_1 ... x_n], d x n)
and w = v at optimality.

Each objective provides the scalar dual coordinate update

    delta(m, a, y, q) = argmin_d  phi*(-(a+d), y) + m*d + (q/2) d^2

where m = x_i^T v_local is the current margin and q = sigma' * ||x_i||^2
/ (lam*n) is the (CoCoA-scaled) curvature.  All functions are
elementwise/vectorized and jit/vmap/scan-safe.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-12
_BISECT_ITERS = 40


@dataclasses.dataclass(frozen=True)
class Objective:
    """A GLM loss, its conjugate, and its SDCA coordinate update."""

    name: str
    # phi(z, y): per-example primal loss
    loss: Callable[[Array, Array], Array]
    # phi*(-a, y): per-example dual (conjugate) penalty, +inf outside domain
    conj_neg: Callable[[Array, Array], Array]
    # delta(m, a, y, q): scalar dual coordinate update
    delta: Callable[[Array, Array, Array, Array], Array]
    # whether labels live in {-1, +1} (classification) or R (regression)
    classification: bool


# ---------------------------------------------------------------------------
# Ridge regression (squared loss)
# ---------------------------------------------------------------------------

def _ridge_loss(z: Array, y: Array) -> Array:
    return 0.5 * (z - y) ** 2


def _ridge_conj_neg(a: Array, y: Array) -> Array:
    # phi*(u) = u^2/2 + u*y  =>  phi*(-a) = a^2/2 - a*y
    return 0.5 * a ** 2 - a * y


def _ridge_delta(m: Array, a: Array, y: Array, q: Array) -> Array:
    return (y - m - a) / (1.0 + q)


# ---------------------------------------------------------------------------
# Smooth-hinge-free SVM (hinge loss, box-constrained dual)
# ---------------------------------------------------------------------------

def _hinge_loss(z: Array, y: Array) -> Array:
    return jnp.maximum(0.0, 1.0 - y * z)


def _hinge_conj_neg(a: Array, y: Array) -> Array:
    # phi*(-a) = -a*y on the domain a*y in [0, 1]; +inf outside (callers keep
    # iterates feasible so we do not materialize the +inf branch).
    return -a * y


def _hinge_delta(m: Array, a: Array, y: Array, q: Array) -> Array:
    q = jnp.maximum(q, _EPS)
    b_new = jnp.clip(a * y + (1.0 - y * m) / q, 0.0, 1.0)
    return y * b_new - a


# ---------------------------------------------------------------------------
# Logistic regression
# ---------------------------------------------------------------------------

def _log_loss(z: Array, y: Array) -> Array:
    # log(1 + exp(-y z)), numerically stable
    return jnp.logaddexp(0.0, -y * z)


def _xlogx(b: Array) -> Array:
    return jnp.where(b > _EPS, b * jnp.log(jnp.maximum(b, _EPS)), 0.0)


def _log_conj_neg(a: Array, y: Array) -> Array:
    # phi*(-a) = b log b + (1-b) log(1-b) with b = a*y in [0, 1]
    b = a * y
    return _xlogx(b) + _xlogx(1.0 - b)


def _log_delta(m: Array, a: Array, y: Array, q: Array) -> Array:
    """Guarded bisection on the monotone derivative.

    g(d)  = phi*(-(a+d)) + m d + q d^2 / 2,   b = (a+d) y in (0, 1)
    g'(d) = y log(b / (1-b)) + m + q d        (strictly increasing in d)
    """
    b0 = a * y
    # feasible b in [lo, hi]; keep strictly inside for the log (f32-safe)
    blo = jnp.full_like(b0, 1e-6)
    bhi = jnp.full_like(b0, 1.0 - 1e-6)

    def gprime(b):
        d = (b - b0) * y  # since b = (a+d) y and y^2 = 1
        return y * (jnp.log(b) - jnp.log1p(-b)) + m + q * d

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        gp = gprime(mid)
        # g' increasing in d; d increasing in b iff y > 0.  Bisect on b with
        # the sign flip folded in: moving b by +y moves d by +1.
        go_up = (gp * y) < 0.0
        lo = jnp.where(go_up, mid, lo)
        hi = jnp.where(go_up, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, _BISECT_ITERS, body, (blo, bhi))
    b = 0.5 * (lo + hi)
    return (b - b0) * y


RIDGE = Objective("ridge", _ridge_loss, _ridge_conj_neg, _ridge_delta,
                  classification=False)
HINGE = Objective("hinge", _hinge_loss, _hinge_conj_neg, _hinge_delta,
                  classification=True)
LOGISTIC = Objective("logistic", _log_loss, _log_conj_neg, _log_delta,
                     classification=True)

OBJECTIVES = {o.name: o for o in (RIDGE, HINGE, LOGISTIC)}


def get_objective(name: str) -> Objective:
    try:
        return OBJECTIVES[name]
    except KeyError:
        raise ValueError(f"unknown objective {name!r}; have {list(OBJECTIVES)}")


# ---------------------------------------------------------------------------
# Diagnostics
# ---------------------------------------------------------------------------

def primal_value(obj: Objective, v: Array, X: Array, y: Array,
                 lam: float) -> Array:
    """P(v) for dense X of shape (d, n)."""
    margins = X.T @ v
    n = y.shape[0]
    return jnp.sum(obj.loss(margins, y)) / n + 0.5 * lam * jnp.sum(v * v)


def dual_value(obj: Objective, alpha: Array, v: Array, y: Array,
               lam: float) -> Array:
    n = y.shape[0]
    return -jnp.sum(obj.conj_neg(alpha, y)) / n - 0.5 * lam * jnp.sum(v * v)


def duality_gap(obj: Objective, alpha: Array, v: Array, X: Array, y: Array,
                lam: float) -> Array:
    """P(v) - D(alpha); -> 0 at the optimum.  v must equal A@alpha/(lam n)."""
    return (primal_value(obj, v, X, y, lam)
            - dual_value(obj, alpha, v, y, lam))
