"""GLM training drivers: epochs, convergence detection, metrics,
checkpoint/restart — for in-memory arrays AND out-of-core caches.

Convergence is declared the way the paper does it: when the relative
change of the learned model between consecutive epochs drops below a
threshold.  The duality gap (a certificate, not available to the paper's
stopping rule) is also tracked for tests and benchmarks.

Two drivers share one fit loop (`_TrainerBase`):

  * `GLMTrainer`     — device-resident arrays, whole-epoch jit (the
                       simulator path every benchmark uses);
  * `StreamedGLMTrainer` — examples live in a `repro.data.cache`
                       bucket-tile cache and stream through the
                       engine's `ChunkFeed` loop, so n can exceed
                       device memory.  With `deterministic=True` the
                       two are bitwise-identical on the same data
                       (pinned by tests/test_pipeline.py).

`fit_dataset` is the one-call entry point: registry name -> cache ->
trainer -> `FitResult`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import engine, objectives
from .bucketing import BucketPlan, make_plan
from .cocoa import SolverConfig
from .config import EngineConfig, as_engine_config
from .objectives import Objective, get_objective
from .partition import PartitionPlan

Array = jax.Array


@dataclasses.dataclass
class FitResult:
    epochs: int
    converged: bool
    diverged: bool
    v: np.ndarray
    alpha: np.ndarray
    history: list[dict[str, float]]
    wall_time: float

    @property
    def final_gap(self) -> float:
        return self.history[-1]["gap"] if self.history else float("nan")


class _TrainerBase:
    """The shared fit loop.  Subclasses provide `_epoch_fn(alpha, v,
    epoch)`, `gap()`, and the `alpha`/`v`/`epoch` state fields."""

    obj: Objective
    lam: float
    alpha: Array
    v: Array
    epoch: int

    def gap(self) -> float:
        raise NotImplementedError

    def fit(self, max_epochs: int = 100, tol: float = 1e-3,
            gap_every: int = 0, verbose: bool = False,
            diverge_above: float = 1e8) -> FitResult:
        history: list[dict[str, float]] = []
        t0 = time.perf_counter()
        converged = diverged = False
        for _ in range(max_epochs):
            v_prev = self.v
            self.alpha, self.v = self._epoch_fn(
                self.alpha, self.v, jnp.int32(self.epoch))
            self.epoch += 1
            rel = float(jnp.linalg.norm(self.v - v_prev)
                        / jnp.maximum(jnp.linalg.norm(self.v), 1e-30))
            rec = {"epoch": self.epoch, "rel_change": rel,
                   "t": time.perf_counter() - t0}
            if gap_every and self.epoch % gap_every == 0:
                rec["gap"] = self.gap()
            history.append(rec)
            if verbose:
                print(f"epoch {self.epoch:4d} rel={rel:.3e} "
                      + (f"gap={rec['gap']:.3e}" if "gap" in rec else ""))
            vmax = float(jnp.max(jnp.abs(self.v)))
            if not np.isfinite(vmax) or vmax > diverge_above:
                diverged = True
                break
            if rel < tol:
                converged = True
                break
        if history and "gap" not in history[-1]:
            history[-1]["gap"] = self.gap() if not diverged else float("inf")
        return FitResult(
            epochs=self.epoch, converged=converged, diverged=diverged,
            v=np.asarray(self.v), alpha=np.asarray(self.alpha),
            history=history, wall_time=time.perf_counter() - t0)

    # -- checkpoint/restart ------------------------------------------------
    def state_dict(self) -> dict[str, Any]:
        return {"alpha": np.asarray(self.alpha), "v": np.asarray(self.v),
                "epoch": np.int64(self.epoch)}

    def load_state_dict(self, st: dict[str, Any]) -> None:
        self.alpha = jnp.asarray(st["alpha"])
        self.v = jnp.asarray(st["v"])
        self.epoch = int(st["epoch"])


class GLMTrainer(_TrainerBase):
    """Paper's solver: bucketed, dynamically partitioned, hierarchical SDCA.

    dense:  X (d, n);  sparse: (idx, val) padded CSR, plus d.
    """

    def __init__(self, X, y, *, objective: str | Objective = "logistic",
                 lam: float = 1e-3,
                 cfg: SolverConfig | EngineConfig = SolverConfig(),
                 sparse: bool = False, d: Optional[int] = None,
                 bucket_force: Optional[int] = None):
        self.obj = (objective if isinstance(objective, Objective)
                    else get_objective(objective))
        self.lam = float(lam)
        self.cfg = cfg
        self.spec = as_engine_config(cfg)
        self.sparse = sparse
        if sparse:
            idx, val = X
            self.idx = jnp.asarray(idx, jnp.int32)
            self.val = jnp.asarray(val, jnp.float32)
            self.n = self.val.shape[0]
            self.d = int(d)
        else:
            self.X = jnp.asarray(X)
            self.d, self.n = self.X.shape
        self.y = jnp.asarray(y)

        algo, dep = self.spec.algo, self.spec.deployment
        force = bucket_force if bucket_force is not None else algo.bucket
        self.bplan = make_plan(self.n, self.d, force=force or 1)
        if self.bplan.bucket != algo.bucket:
            # run_epoch chunks columns by algo.bucket while the gather/
            # solver use the plan's bucket — keep the single source of
            # truth (bucket_force / the plan heuristic) authoritative.
            algo = dataclasses.replace(algo, bucket=self.bplan.bucket)
            self.spec = dataclasses.replace(self.spec, algo=algo)
        self.plan = PartitionPlan(
            n_buckets=self.bplan.n_buckets, pods=dep.pods, lanes=dep.lanes,
            mode=algo.partition, seed=algo.seed,
            redeal_frac=algo.redeal_frac)

        self.alpha = jnp.zeros(self.n, jnp.float32)
        self.v = jnp.zeros(self.d, jnp.float32)
        self.epoch = 0

        if sparse:
            self._epoch_fn = jax.jit(
                lambda a, v, e: engine.sim_epoch_sparse(
                    self.obj, self.idx, self.val, self.y, a, v, self.lam,
                    self.plan, self.bplan, self.spec, e))
        else:
            self._epoch_fn = jax.jit(
                lambda a, v, e: engine.sim_epoch_dense(
                    self.obj, self.X, self.y, a, v, self.lam,
                    self.plan, self.bplan, self.spec, e))

    # -- diagnostics ------------------------------------------------------
    def gap(self) -> float:
        if self.sparse:
            m = jnp.sum(self.v[self.idx] * self.val, axis=1)
            n = self.n
            p = (jnp.sum(self.obj.loss(m, self.y)) / n
                 + 0.5 * self.lam * jnp.sum(self.v ** 2))
            dval = objectives.dual_value(self.obj, self.alpha, self.v,
                                         self.y, self.lam)
            return float(p - dval)
        return float(objectives.duality_gap(
            self.obj, self.alpha, self.v, self.X, self.y, self.lam))

    def primal(self) -> float:
        if self.sparse:
            m = jnp.sum(self.v[self.idx] * self.val, axis=1)
            return float(jnp.sum(self.obj.loss(m, self.y)) / self.n
                         + 0.5 * self.lam * jnp.sum(self.v ** 2))
        return float(objectives.primal_value(
            self.obj, self.v, self.X, self.y, self.lam))


class StreamedGLMTrainer(_TrainerBase):
    """Out-of-core twin of `GLMTrainer` over a bucket-tile cache.

    Only alpha (n,) and v (d,) live on device between chunks; X/y
    stream through the cache's `TileFeed` one chunk at a time with
    double-buffered host->device transfer, so datasets larger than
    device memory train at full algorithmic fidelity (same schedule,
    same solver, same sigma').
    """

    def __init__(self, cache, *, objective: str | Objective | None = None,
                 lam: float = 1e-3,
                 cfg: SolverConfig | EngineConfig = SolverConfig(),
                 jit_step: bool = True):
        meta = cache.meta
        objective = objective or meta.objective
        self.obj = (objective if isinstance(objective, Objective)
                    else get_objective(objective))
        self.lam = float(lam)
        self.cfg = cfg
        self.spec = as_engine_config(cfg)
        self.cache = cache
        self.sparse = meta.kind == "sparse"
        self.n, self.d = meta.n, meta.d

        algo, dep = self.spec.algo, self.spec.deployment
        if algo.bucket not in (0, 1, meta.bucket):
            raise ValueError(
                f"cfg bucket={algo.bucket} != cache bucket={meta.bucket}; "
                f"rebuild the cache at the training bucket size")
        self.bplan = BucketPlan(n=self.n, bucket=meta.bucket,
                                n_buckets=meta.n_buckets)
        self.plan = PartitionPlan(
            n_buckets=meta.n_buckets, pods=dep.pods, lanes=dep.lanes,
            mode=algo.partition, seed=algo.seed,
            redeal_frac=algo.redeal_frac)
        self.feed = cache.feed()

        self.alpha = jnp.zeros(self.n, jnp.float32)
        self.v = jnp.zeros(self.d, jnp.float32)
        self.epoch = 0
        self._epoch_fn = engine.make_streamed_epoch(
            self.obj, self.spec, self.plan, self.feed, lam=self.lam,
            jit_step=jit_step)

    # -- diagnostics (streamed over the cache) ----------------------------
    def _primal_dual(self, gbuckets: int = 256) -> tuple[float, float]:
        """One streaming pass: primal loss sum + dual conjugate sum."""
        nb = self.cache.meta.n_buckets
        B = self.cache.meta.bucket
        loss_sum = conj_sum = 0.0
        alpha = np.asarray(self.alpha)
        v = self.v
        for start in range(0, nb, gbuckets):
            bids = np.arange(start, min(start + gbuckets, nb))
            data, y = self.cache.gather_buckets(bids)
            if self.sparse:
                idx, val = data
                m = jnp.sum(v[jnp.asarray(idx)] * jnp.asarray(val), axis=1)
            else:
                m = jnp.asarray(data).T @ v
            y = jnp.asarray(y)
            loss_sum += float(jnp.sum(self.obj.loss(m, y)))
            a = jnp.asarray(alpha[start * B:start * B + y.shape[0]])
            conj_sum += float(jnp.sum(self.obj.conj_neg(a, y)))
        reg = 0.5 * self.lam * float(jnp.sum(v ** 2))
        primal = loss_sum / self.n + reg
        dual = -conj_sum / self.n - reg
        return primal, dual

    def primal(self) -> float:
        return self._primal_dual()[0]

    def gap(self) -> float:
        p, dv = self._primal_dual()
        return p - dv


def fit_dataset(name: str, *,
                cfg: SolverConfig | EngineConfig | None = None,
                objective: Optional[str] = None,
                lam: Optional[float] = None,
                n: Optional[int] = None, d: Optional[int] = None,
                streamed: bool = False, cache_dir=None, data_dir=None,
                bucket: Optional[int] = None,
                max_epochs: int = 100, tol: float = 1e-3,
                gap_every: int = 0, verbose: bool = False,
                return_trainer: bool = False):
    """Train on a registry dataset end to end: name -> (cache) -> fit.

    * ``streamed=False`` loads the dataset (through the tile cache when
      ``cache_dir`` is set, else directly) and runs `GLMTrainer`;
    * ``streamed=True`` builds/opens the bucket-tile cache and runs
      `StreamedGLMTrainer` out of core.

    The cache is padded so every partition mode divides the chosen
    (pods, lanes, chunks, bucket) topology; with
    ``deterministic=True`` the two modes produce bitwise-identical
    models on the same cache.
    """
    from repro.data import registry

    spec = registry.get_spec(name)
    ecfg = as_engine_config(cfg) if cfg is not None else EngineConfig()
    algo, dep = ecfg.algo, ecfg.deployment
    objective = objective or spec.objective
    lam = spec.lam if lam is None else lam
    B = bucket or max(algo.bucket, 1)
    use_cache = streamed or cache_dir is not None

    if use_cache:
        # every partition mode divides: pods*lanes*lanes*chunks buckets
        mult = dep.pods * dep.lanes * dep.lanes * algo.chunks * B
        cache = registry.materialize(
            name, cache_dir, bucket=B, pods=dep.pods, n=n, d=d,
            pad_multiple=mult, data_dir=data_dir)
        if streamed:
            tr = StreamedGLMTrainer(cache, objective=objective, lam=lam,
                                    cfg=ecfg)
        else:
            arrays, y = cache.load_arrays()
            if cache.meta.kind == "sparse":
                tr = GLMTrainer(arrays, y, objective=objective, lam=lam,
                                cfg=ecfg, sparse=True, d=cache.meta.d,
                                bucket_force=cache.meta.bucket)
            else:
                tr = GLMTrainer(arrays, y, objective=objective, lam=lam,
                                cfg=ecfg, bucket_force=cache.meta.bucket)
    else:
        ds = registry.get_dataset(name, n=n, d=d, data_dir=data_dir)
        if ds.sparse:
            tr = GLMTrainer((ds.idx, ds.val), ds.y, objective=objective,
                            lam=lam, cfg=ecfg, sparse=True, d=ds.d,
                            bucket_force=B)
        else:
            tr = GLMTrainer(ds.X, ds.y, objective=objective, lam=lam,
                            cfg=ecfg, bucket_force=B)

    res = tr.fit(max_epochs=max_epochs, tol=tol, gap_every=gap_every,
                 verbose=verbose)
    return (res, tr) if return_trainer else res
