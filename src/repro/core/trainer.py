"""Legacy GLM training drivers — deprecation shims over `repro.api`.

The drivers that used to live here (`GLMTrainer` for resident arrays,
`StreamedGLMTrainer` for out-of-core caches, `fit_dataset` for registry
names) are now thin facades over ONE owner of solver state:
`repro.api.Session` (DESIGN.md S10).  Each shim keeps its exact legacy
constructor/`fit` signature and attributes (`alpha`, `v`, `epoch`,
`plan`, `bplan`, `_epoch_fn`, `gap()`, `primal()`, `state_dict()`), so
existing code and tests keep passing, and emits a
`ReproDeprecationWarning` pointing at the replacement.

New code should use `repro.api` directly:

    Session((X, y), ...)          instead of  GLMTrainer(X, y, ...)
    Session(cache, streamed=True) instead of  StreamedGLMTrainer(cache)
    Session("higgs").fit(...)     instead of  fit_dataset("higgs")
    api.LogisticRegression(...)   for the sklearn-shaped front door
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from .cocoa import SolverConfig
from .config import EngineConfig
from .objectives import Objective


@dataclasses.dataclass
class FitResult:
    epochs: int
    converged: bool
    diverged: bool
    v: np.ndarray
    alpha: np.ndarray
    history: list[dict[str, float]]
    wall_time: float

    @property
    def final_gap(self) -> float:
        return self.history[-1]["gap"] if self.history else float("nan")


class _TrainerBase:
    """Shared shim plumbing: every attribute the legacy trainers exposed
    resolves against the wrapped `repro.api.Session`."""

    _session: Any

    # legacy state fields, proxied so reads AND writes hit the session
    @property
    def alpha(self):
        return self._session.alpha

    @alpha.setter
    def alpha(self, value):
        self._session.alpha = value

    @property
    def v(self):
        return self._session.v

    @v.setter
    def v(self, value):
        self._session.v = value

    @property
    def epoch(self) -> int:
        return self._session.epochs_done

    @epoch.setter
    def epoch(self, value: int):
        self._session.epochs_done = int(value)

    def __getattr__(self, name):
        # anything else (obj, lam, plan, bplan, spec, cfg, X, y, idx,
        # val, n, d, sparse, cache, feed, _epoch_fn, ...) lives on the
        # session; __getattr__ only fires when normal lookup misses.
        if name == "_session":
            raise AttributeError(name)
        return getattr(self._session, name)

    def fit(self, max_epochs: int = 100, tol: float = 1e-3,
            gap_every: int = 0, verbose: bool = False,
            diverge_above: float = 1e8) -> FitResult:
        return self._session.fit(
            max_epochs=max_epochs, tol=tol, gap_every=gap_every,
            verbose=verbose, diverge_above=diverge_above)

    def gap(self) -> float:
        return self._session.gap()

    def primal(self) -> float:
        return self._session.primal()

    # -- checkpoint/restart ------------------------------------------------
    def state_dict(self) -> dict[str, Any]:
        return self._session.state_dict()

    def load_state_dict(self, st: dict[str, Any]) -> None:
        self._session.load_state_dict(st)


class GLMTrainer(_TrainerBase):
    """Deprecated: use `repro.api.Session((X, y), ...)` (or an
    `repro.api` estimator).  dense: X (d, n); sparse: (idx, val) padded
    CSR plus d."""

    def __init__(self, X, y, *, objective: str | Objective = "logistic",
                 lam: float = 1e-3,
                 cfg: SolverConfig | EngineConfig = SolverConfig(),
                 sparse: bool = False, d: Optional[int] = None,
                 bucket_force: Optional[int] = None):
        from repro.api import Session, warn_deprecated
        warn_deprecated("repro.core.GLMTrainer",
                        "repro.api.Session (or an repro.api estimator)")
        data = tuple(X) if sparse else X
        self._session = Session(data, y, objective=objective, lam=lam,
                                cfg=cfg, d=d, bucket=bucket_force,
                                pad=False)


class StreamedGLMTrainer(_TrainerBase):
    """Deprecated: use `repro.api.Session(cache, streamed=True)`."""

    def __init__(self, cache, *, objective: str | Objective | None = None,
                 lam: float = 1e-3,
                 cfg: SolverConfig | EngineConfig = SolverConfig(),
                 jit_step: bool = True, journal_dir=None, health=None):
        from repro.api import Session, warn_deprecated
        warn_deprecated("repro.core.StreamedGLMTrainer",
                        "repro.api.Session(cache, streamed=True)")
        self._session = Session(cache, objective=objective, lam=lam,
                                cfg=cfg, streamed=True, jit_step=jit_step,
                                journal_dir=journal_dir, health=health)


def fit_dataset(name: str, *,
                cfg: SolverConfig | EngineConfig | None = None,
                objective: Optional[str] = None,
                lam: Optional[float] = None,
                n: Optional[int] = None, d: Optional[int] = None,
                streamed: bool = False, cache_dir=None, data_dir=None,
                bucket: Optional[int] = None,
                nnz_multiple: Optional[int] = None,
                max_epochs: int = 100, tol: float = 1e-3,
                gap_every: int = 0, verbose: bool = False,
                return_trainer: bool = False):
    """Deprecated: use `repro.api.Session(name, ...).fit(...)`.

    Train on a registry dataset end to end: name -> (cache) -> fit.
    With ``return_trainer=True`` the second element is now the
    underlying `Session` (it exposes everything the old trainer did:
    `gap()`, `primal()`, `alpha`, `v`, `plan`, ...).
    """
    from repro.api import Session, warn_deprecated
    warn_deprecated("repro.core.fit_dataset",
                    "repro.api.Session(name, ...).fit(...)")
    session = Session(name, objective=objective, lam=lam, cfg=cfg,
                      n=n, d=d, streamed=streamed, cache_dir=cache_dir,
                      data_dir=data_dir, bucket=bucket,
                      nnz_multiple=nnz_multiple)
    res = session.fit(max_epochs=max_epochs, tol=tol,
                      gap_every=gap_every, verbose=verbose)
    return (res, session) if return_trainer else res
