"""GLMTrainer: epochs, convergence detection, metrics, checkpoint/restart.

Convergence is declared the way the paper does it: when the relative
change of the learned model between consecutive epochs drops below a
threshold.  The duality gap (a certificate, not available to the paper's
stopping rule) is also tracked for tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import engine, objectives
from .bucketing import BucketPlan, make_plan
from .cocoa import SolverConfig
from .config import EngineConfig, as_engine_config
from .objectives import Objective, get_objective
from .partition import PartitionPlan

Array = jax.Array


@dataclasses.dataclass
class FitResult:
    epochs: int
    converged: bool
    diverged: bool
    v: np.ndarray
    alpha: np.ndarray
    history: list[dict[str, float]]
    wall_time: float

    @property
    def final_gap(self) -> float:
        return self.history[-1]["gap"] if self.history else float("nan")


class GLMTrainer:
    """Paper's solver: bucketed, dynamically partitioned, hierarchical SDCA.

    dense:  X (d, n);  sparse: (idx, val) padded CSR, plus d.
    """

    def __init__(self, X, y, *, objective: str | Objective = "logistic",
                 lam: float = 1e-3,
                 cfg: SolverConfig | EngineConfig = SolverConfig(),
                 sparse: bool = False, d: Optional[int] = None,
                 bucket_force: Optional[int] = None):
        self.obj = (objective if isinstance(objective, Objective)
                    else get_objective(objective))
        self.lam = float(lam)
        self.cfg = cfg
        self.spec = as_engine_config(cfg)
        self.sparse = sparse
        if sparse:
            idx, val = X
            self.idx = jnp.asarray(idx, jnp.int32)
            self.val = jnp.asarray(val, jnp.float32)
            self.n = self.val.shape[0]
            self.d = int(d)
        else:
            self.X = jnp.asarray(X)
            self.d, self.n = self.X.shape
        self.y = jnp.asarray(y)

        algo, dep = self.spec.algo, self.spec.deployment
        force = bucket_force if bucket_force is not None else algo.bucket
        self.bplan = make_plan(self.n, self.d, force=force or 1)
        self.plan = PartitionPlan(
            n_buckets=self.bplan.n_buckets, pods=dep.pods, lanes=dep.lanes,
            mode=algo.partition, seed=algo.seed,
            redeal_frac=algo.redeal_frac)

        self.alpha = jnp.zeros(self.n, jnp.float32)
        self.v = jnp.zeros(self.d, jnp.float32)
        self.epoch = 0

        if sparse:
            self._epoch_fn = jax.jit(
                lambda a, v, e: engine.sim_epoch_sparse(
                    self.obj, self.idx, self.val, self.y, a, v, self.lam,
                    self.plan, self.bplan, self.spec, e))
        else:
            self._epoch_fn = jax.jit(
                lambda a, v, e: engine.sim_epoch_dense(
                    self.obj, self.X, self.y, a, v, self.lam,
                    self.plan, self.bplan, self.spec, e))

    # -- diagnostics ------------------------------------------------------
    def gap(self) -> float:
        if self.sparse:
            m = jnp.sum(self.v[self.idx] * self.val, axis=1)
            n = self.n
            p = (jnp.sum(self.obj.loss(m, self.y)) / n
                 + 0.5 * self.lam * jnp.sum(self.v ** 2))
            dval = objectives.dual_value(self.obj, self.alpha, self.v,
                                         self.y, self.lam)
            return float(p - dval)
        return float(objectives.duality_gap(
            self.obj, self.alpha, self.v, self.X, self.y, self.lam))

    def primal(self) -> float:
        if self.sparse:
            m = jnp.sum(self.v[self.idx] * self.val, axis=1)
            return float(jnp.sum(self.obj.loss(m, self.y)) / self.n
                         + 0.5 * self.lam * jnp.sum(self.v ** 2))
        return float(objectives.primal_value(
            self.obj, self.v, self.X, self.y, self.lam))

    # -- training ---------------------------------------------------------
    def fit(self, max_epochs: int = 100, tol: float = 1e-3,
            gap_every: int = 0, verbose: bool = False,
            diverge_above: float = 1e8) -> FitResult:
        history: list[dict[str, float]] = []
        t0 = time.perf_counter()
        converged = diverged = False
        for _ in range(max_epochs):
            v_prev = self.v
            self.alpha, self.v = self._epoch_fn(
                self.alpha, self.v, jnp.int32(self.epoch))
            self.epoch += 1
            rel = float(jnp.linalg.norm(self.v - v_prev)
                        / jnp.maximum(jnp.linalg.norm(self.v), 1e-30))
            rec = {"epoch": self.epoch, "rel_change": rel,
                   "t": time.perf_counter() - t0}
            if gap_every and self.epoch % gap_every == 0:
                rec["gap"] = self.gap()
            history.append(rec)
            if verbose:
                print(f"epoch {self.epoch:4d} rel={rel:.3e} "
                      + (f"gap={rec['gap']:.3e}" if "gap" in rec else ""))
            vmax = float(jnp.max(jnp.abs(self.v)))
            if not np.isfinite(vmax) or vmax > diverge_above:
                diverged = True
                break
            if rel < tol:
                converged = True
                break
        if history and "gap" not in history[-1]:
            history[-1]["gap"] = self.gap() if not diverged else float("inf")
        return FitResult(
            epochs=self.epoch, converged=converged, diverged=diverged,
            v=np.asarray(self.v), alpha=np.asarray(self.alpha),
            history=history, wall_time=time.perf_counter() - t0)

    # -- checkpoint/restart ------------------------------------------------
    def state_dict(self) -> dict[str, Any]:
        return {"alpha": np.asarray(self.alpha), "v": np.asarray(self.v),
                "epoch": np.int64(self.epoch)}

    def load_state_dict(self, st: dict[str, Any]) -> None:
        self.alpha = jnp.asarray(st["alpha"])
        self.v = jnp.asarray(st["v"])
        self.epoch = int(st["epoch"])
