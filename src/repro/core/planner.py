"""System-aware auto-tuning of bucket/tile geometry (DESIGN.md S13).

The source paper shows that per-epoch speed and convergence trade off
through the bucket/partition geometry; its follow-up **SySCD: A
System-Aware Parallel Coordinate Descent Algorithm** (PAPERS.md) closes
that gap by making bucket size, worker count, and data layout functions
of the *machine* instead of config constants.  This module is that
planner for the TPU re-derivation: given a workload signature
(n, d, nnz, sparsity, dtype) and a topology (backend, device count,
model lanes, VMEM budgets), it

  1. enumerates candidate geometries — (bucket B, chunks,
     nnz_multiple, replicated-vs-feature-sharded layout) — and filters
     them through the EXISTING feasibility predicates
     (`kernels.ops.sparse_solver_plan` / `dense_kernel_misfit`, i.e.
     the kernels' own VMEM/alignment models; the planner can never
     loosen them);
  2. scores survivors with an analytic bytes-per-effective-epoch model
     (HBM traffic per epoch x a convergence multiplier for shuffle
     granularity and sync interval — the SySCD trade-off made
     explicit);
  3. optionally refines the top candidates with a few *timed probe
     epochs* (`probe_plans`) when the caller can provide a
     `probe_fn(plan) -> seconds`;
  4. emits a `SolverPlan`, cached on disk per (dataset fingerprint,
     topology fingerprint, PLAN_VERSION) alongside the tile cache
     (`data.registry.cache_root()/plans`), so the search is paid once
     per workload x machine.

Never-regress contract (the PR-4 rule, extended): every plan the
planner emits must pass the same misfit pre-checks the engine's
backend-picked "auto" path applies, and any planner failure — bad
cache file, version skew, search exception — falls back WARN-AND-SAFE
to today's static resolution.  ``$REPRO_PLAN`` is the escape hatch:

    $REPRO_PLAN=off      bypass the planner everywhere (static rules)
    $REPRO_PLAN=on       validate/route/cache; keep static geometry
                         unless it is infeasible (default)
    $REPRO_PLAN=search   let the analytic model pick the geometry
    $REPRO_PLAN=probe    search + timed probe epochs (needs a probe_fn)

Under the default ``on`` mode the planner's geometry is BITWISE
identical to the static rules on every previously-working config
(pinned by tests/test_planner.py): it only repairs geometries the
static rules would reject, and it owns the layout boundary decisions
that used to be hardcoded (`launch/glm.py scale_for_dataset`'s
feature-shard flip).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import pathlib
import warnings
from typing import Callable, Optional

__all__ = [
    "PLAN_VERSION", "H2D_BW", "WorkloadSignature", "Topology",
    "SolverPlan", "plan_mode", "static_plan", "candidate_plans",
    "plan_cost", "search_plans", "probe_plans", "resolve_plan",
    "plan_cache_dir", "load_cached_plan", "store_plan", "route_sparse",
    "route_dense", "feature_shard_default", "streamed_transfer_bytes",
]

#: Bump when the plan schema, the search space, or the cost model
#: changes meaning: cached plans from older versions are ignored (the
#: key embeds the version, and `load_cached_plan` re-checks the stored
#: field), so a bump invalidates cleanly — same discipline as
#: `data.cache.CACHE_VERSION`.
PLAN_VERSION = 1

#: Candidate bucket sizes (f32 sublane multiples; the dense kernel caps
#: at MAX_BUCKET=512 and the misfit predicates enforce it).
BUCKET_CANDIDATES = (8, 16, 32, 64, 128)
#: Candidate sync intervals (v reductions per epoch).
CHUNK_CANDIDATES = (1, 2, 4, 8)

# -- convergence-multiplier constants (the SySCD trade-off, made
# explicit so docs/tuning.md can cite them).  Larger buckets coarsen
# the per-epoch shuffle (the paper's only residual bucketing cost);
# fewer chunks mean staler v replicas between syncs when several
# workers add deltas.  Both are mild, so the multipliers are mild —
# the analytic score is a RANKING device, refined by probe epochs when
# available, not a convergence proof.
CONV_BUCKET_COST = 0.02       # per doubling of B above 8
CONV_SYNC_COST = 0.10         # x (workers-1)/workers / chunks

#: Host->device link bandwidth (bytes/s) used to weigh streamed-ingest
#: transfer bytes against HBM traffic in `plan_cost` and to turn
#: `streamed_transfer_bytes` into seconds in the roofline table.  A
#: PCIe-class figure, deliberately conservative: TPU hosts feed chips
#: over PCIe, ~50x slower than HBM, which is exactly why streamed plans
#: must score ingest bytes separately from on-chip traffic.  The ONE
#: definition — `launch/mesh.py` and the benchmarks re-export it.
H2D_BW = 16e9

#: HBM bandwidth assumed by the cost model's streamed-ingest weighting
#: (matches `launch/mesh.py`'s roofline constant for TPU v5p-class
#: chips; only the RATIO to H2D_BW enters the score).
_HBM_BW = 819e9


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Inputs: workload signature + machine topology
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorkloadSignature:
    """Everything about the DATA that shapes the plan.

    ``nnz`` is the padded-CSR row width (0 for dense), ``density`` an
    optional observed nonzero fraction (informational — feasibility
    only depends on the padded width).  ``name`` carries the registry
    name when known so cached plans are human-findable on disk.
    ``streamed`` marks out-of-core workloads whose chunks arrive over
    the host link each epoch: `plan_cost` then weighs the per-epoch
    H2D bytes (HBM-equivalent via the bandwidth ratio) so geometry
    choices see the ingest cost; resident workloads score unchanged.
    """
    n: int
    d: int
    nnz: int = 0
    sparse: bool = False
    dtype_bytes: int = 4
    name: str = ""
    density: float = 0.0
    streamed: bool = False

    def fingerprint(self) -> str:
        """Stable hash of the plan-relevant fields (n/d/nnz/kind).

        ``streamed`` joins the key only when set, so every resident
        fingerprint (and its cached plans) is byte-identical to
        pre-streaming versions.
        """
        key = (f"{self.name}|n{self.n}|d{self.d}|z{self.nnz}"
               f"|s{int(self.sparse)}|b{self.dtype_bytes}"
               + ("|st1" if self.streamed else ""))
        return hashlib.sha1(key.encode()).hexdigest()[:12]


@dataclasses.dataclass(frozen=True)
class Topology:
    """Everything about the MACHINE that shapes the plan.

    VMEM budgets default to the kernels' own constants so the planner
    and the kernels can never disagree about feasibility; they are
    fields (not imports at use sites) so tests can probe exact
    boundaries.
    """
    backend: str                  # "tpu" | "cpu" | "gpu"
    device_count: int = 1
    pods: int = 1
    lanes: int = 1
    model_lanes: int = 1
    vmem_v_budget: int = 0        # 0 = kernel default
    vmem_total_budget: int = 0

    @classmethod
    def detect(cls, spec=None, *, model_lanes: int = 1) -> "Topology":
        """Topology from the live jax backend (+ an EngineConfig's
        deployment layer when given)."""
        import jax
        pods = lanes = 1
        if spec is not None:
            dep = getattr(spec, "deployment", spec)
            pods = getattr(dep, "pods", 1)
            lanes = getattr(dep, "lanes", 1)
        return cls(backend=jax.default_backend(),
                   device_count=jax.device_count(),
                   pods=pods, lanes=lanes, model_lanes=model_lanes)

    @property
    def workers(self) -> int:
        return max(self.pods * self.lanes, 1)

    def v_budget(self) -> int:
        if self.vmem_v_budget:
            return self.vmem_v_budget
        from repro.kernels.sdca_sparse_bucket import V_VMEM_BUDGET_BYTES
        return V_VMEM_BUDGET_BYTES

    def total_budget(self) -> int:
        if self.vmem_total_budget:
            return self.vmem_total_budget
        from repro.kernels.sdca_sparse_bucket import TOTAL_VMEM_BUDGET_BYTES
        return TOTAL_VMEM_BUDGET_BYTES

    def fingerprint(self) -> str:
        """Stable hash of the plan-relevant machine facts."""
        key = (f"{self.backend}|c{self.device_count}|p{self.pods}"
               f"|l{self.lanes}|m{self.model_lanes}"
               f"|v{self.v_budget()}|t{self.total_budget()}")
        return hashlib.sha1(key.encode()).hexdigest()[:12]


# ---------------------------------------------------------------------------
# Output: the plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SolverPlan:
    """One resolved geometry + route for a (workload, topology) pair.

    ``solver`` is what ``local_solver="auto"`` should resolve to
    ("pallas" | "xla"); ``route`` the kernel variant
    ("pallas-replicated" | "pallas-sharded" | "xla"); ``origin`` how
    the plan was produced ("static" | "search" | "probe" | "cache").
    ``score`` is the analytic bytes-per-effective-epoch (lower is
    better; comparable only within one workload x topology).
    ``reason`` carries the misfit string for "xla" routes and the
    decision rationale otherwise; ``reason_code`` its stable
    `kernels.ops.MisfitCode` ("" when the geometry fits) so tools can
    key on the verdict without parsing prose.
    """
    solver: str
    route: str
    bucket: int
    chunks: int
    nnz_multiple: int             # 0 = no row-width padding needed
    feature_shard: bool
    reason: str = ""
    reason_code: str = ""
    origin: str = "static"
    score: float = 0.0
    probe_s: float = -1.0         # timed probe epoch seconds (-1 = none)
    version: int = PLAN_VERSION

    def to_json(self) -> dict:
        """JSON-safe dict (the on-disk + BENCH-json record shape)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, doc: dict) -> "SolverPlan":
        """Inverse of `to_json`; unknown keys are ignored so the schema
        can grow without breaking older readers."""
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in doc.items() if k in names})


# ---------------------------------------------------------------------------
# Mode (the $REPRO_PLAN escape hatch)
# ---------------------------------------------------------------------------

_MODES = ("on", "off", "search", "probe")


def plan_mode() -> str:
    """Parse ``$REPRO_PLAN`` -> "on" | "off" | "search" | "probe".

    The ONE parser of the env hatch (mirrors
    `engine._resolve_auto` for $REPRO_LOCAL_SOLVER).  Unset/empty means
    "on"; anything unrecognized raises so typos cannot silently change
    solver behavior.
    """
    env = os.environ.get("REPRO_PLAN", "").strip().lower()
    if not env:
        return "on"
    if env not in _MODES:
        raise ValueError(
            f"$REPRO_PLAN={env!r}: must be one of {', '.join(_MODES)}")
    return env


# ---------------------------------------------------------------------------
# Feasibility + routing (delegates to the kernels' own predicates)
# ---------------------------------------------------------------------------


def _sparse_route(nnz: int, d: int, bucket: int,
                  model_lanes: int) -> tuple[str, Optional[str]]:
    """Route a sparse geometry through `ops.sparse_solver_plan` with
    n_local=bucket (Session/cache padding guarantees divisibility, so
    only the alignment/VMEM misfits matter at plan time)."""
    from repro.kernels import ops as kops
    return kops.sparse_solver_plan(bucket, nnz, d, bucket,
                                   model_lanes=model_lanes)


def _dense_route(d: int, bucket: int) -> tuple[str, Optional[str]]:
    from repro.kernels import ops as kops
    why = kops.dense_kernel_misfit(d, bucket, bucket)
    return ("xla", why) if why else ("pallas-replicated", None)


def route_sparse(n_local: int, nnz: int, d: int, bucket: int, *,
                 model_lanes: int = 1) -> tuple[str, Optional[str]]:
    """Trace-time sparse route for the engine's backend-picked "auto".

    A pure delegation to `kernels.ops.sparse_solver_plan`,
    deliberately: the planner ranks among feasible geometries but can
    NEVER loosen the kernels' own predicates, so the engine's
    never-regress fallback verdicts are byte-identical with the
    planner on, off, or broken — $REPRO_PLAN does not (and must not)
    change what this function returns.
    """
    from repro.kernels import ops as kops
    return kops.sparse_solver_plan(n_local, nnz, d, bucket,
                                   model_lanes=model_lanes)


def route_dense(d: int, n_local: int, bucket: int) -> Optional[str]:
    """Trace-time dense misfit for the engine's backend-picked "auto"
    (reason string or None) — see `route_sparse` for why this is a
    delegation, not a policy point."""
    from repro.kernels import ops as kops
    return kops.dense_kernel_misfit(d, n_local, bucket)


def _plan_feasible(sig: WorkloadSignature, topo: Topology,
                   plan: SolverPlan) -> bool:
    """The never-regress pre-check: a pallas plan must still pass the
    kernels' misfit predicates; an xla plan is always safe."""
    if plan.solver != "pallas":
        return True
    nnz = _effective_nnz(sig, plan.nnz_multiple)
    if sig.sparse:
        lanes = topo.model_lanes if plan.feature_shard else 1
        route, _ = _sparse_route(nnz, sig.d, plan.bucket, lanes)
        return route == plan.route
    route, _ = _dense_route(sig.d, plan.bucket)
    return route == "pallas-replicated"


def _effective_nnz(sig: WorkloadSignature, nnz_multiple: int) -> int:
    if not sig.sparse:
        return 0
    if nnz_multiple:
        return _round_up(max(sig.nnz, 1), nnz_multiple)
    return sig.nnz


def feature_shard_default(sig: WorkloadSignature,
                          topo: Optional[Topology] = None) -> bool:
    """The layout boundary `launch/glm.py scale_for_dataset` used to
    hardcode: shard features over 'model' exactly when the replicated
    shared vector cannot fit the sparse kernel's resident-v VMEM
    budget (sparse), or when d is TP-wide (dense, d >= 512).

    Owned by the planner so the boundary is written ONCE; with
    ``$REPRO_PLAN=off`` the same expressions run inline (they ARE the
    static rule — this function never disagrees with it).
    """
    if topo is None:
        topo = Topology(backend="tpu")
    if sig.sparse:
        d_pad = _round_up(max(sig.d, 8), 8)
        return d_pad * 4 > topo.v_budget()
    return sig.d >= 512


# ---------------------------------------------------------------------------
# Static resolution (today's rules, as one function)
# ---------------------------------------------------------------------------


def static_plan(sig: WorkloadSignature, topo: Topology, *,
                bucket: Optional[int] = None,
                chunks: Optional[int] = None,
                nnz_multiple: Optional[int] = None) -> SolverPlan:
    """Today's fixed-default resolution, expressed as a `SolverPlan`.

    This is both the ``$REPRO_PLAN=off`` behavior and the warn-and-safe
    fallback for every planner failure: bucket from the caller (else
    `bucketing.choose_bucket_size`), chunks from the caller (else 1),
    feature_shard from `feature_shard_default`, solver route from the
    kernels' own predicates on the resulting geometry.
    """
    from repro.core.bucketing import choose_bucket_size
    B = bucket if bucket else choose_bucket_size(sig.n, sig.d)
    C = chunks if chunks else 1
    zmult = nnz_multiple or 0
    shard = feature_shard_default(sig, topo)
    plan = _routed_plan(sig, topo, B, C, zmult, shard, origin="static")
    return plan


def _routed_plan(sig: WorkloadSignature, topo: Topology, bucket: int,
                 chunks: int, nnz_multiple: int, feature_shard: bool,
                 origin: str) -> SolverPlan:
    """Attach the kernels' route verdict + analytic score to a
    candidate geometry."""
    nnz = _effective_nnz(sig, nnz_multiple)
    if sig.sparse:
        lanes = topo.model_lanes if feature_shard else 1
        route, reason = _sparse_route(nnz, sig.d, bucket, lanes)
    else:
        route, reason = _dense_route(sig.d, bucket)
    solver = "xla" if route == "xla" else "pallas"
    if topo.backend != "tpu":
        # backend-picked "auto" resolves to xla off-TPU; the plan
        # records what WOULD run on TPU in `route` but scores/solves
        # for the machine at hand
        solver = "xla"
    plan = SolverPlan(
        solver=solver, route=route, bucket=bucket, chunks=chunks,
        nnz_multiple=nnz_multiple, feature_shard=feature_shard,
        reason=str(reason or "fits"),
        reason_code=getattr(reason, "code", ""), origin=origin)
    return dataclasses.replace(plan, score=plan_cost(sig, topo, plan))


# ---------------------------------------------------------------------------
# The search: candidates -> analytic score -> (optional) probe epochs
# ---------------------------------------------------------------------------


def candidate_plans(sig: WorkloadSignature, topo: Topology, *,
                    bucket: Optional[int] = None,
                    chunks: Optional[int] = None,
                    nnz_multiple: Optional[int] = None
                    ) -> list[SolverPlan]:
    """Enumerate the search space, respecting caller-fixed knobs.

    Dimensions: bucket (sublane multiples up to the dense cap), chunks
    (sync intervals that divide the bucket count), nnz_multiple (0 =
    keep the raw row width, 8 = pad to the sparse kernels' lane
    alignment — only offered when the width is unaligned), and
    replicated vs feature-sharded layout (sharded only when the
    topology HAS model lanes).  Every candidate carries the kernels'
    route verdict; infeasible-for-pallas candidates are kept with
    route="xla" (the scan is always a legal geometry).
    """
    buckets = (bucket,) if bucket else BUCKET_CANDIDATES
    chunk_opts = (chunks,) if chunks else CHUNK_CANDIDATES
    if nnz_multiple is not None:
        zmults: tuple[int, ...] = (nnz_multiple,)
    elif sig.sparse and sig.nnz % 8:
        zmults = (0, 8)
    else:
        zmults = (0,)
    layouts = [False]
    if topo.model_lanes > 1 or feature_shard_default(sig, topo):
        layouts.append(True)
    out = []
    for B in buckets:
        for C in chunk_opts:
            nb = max(sig.n // max(B, 1), 1)
            if nb % C:
                continue
            for z in zmults:
                for shard in layouts:
                    out.append(_routed_plan(sig, topo, B, C, z, shard,
                                            origin="search"))
    return out


def streamed_transfer_bytes(sig: WorkloadSignature, topo: Topology,
                            plan: SolverPlan) -> float:
    """Modeled host->device bytes per device per streamed epoch.

    The ONE h2d byte model (DESIGN.md S16): `plan_cost`'s streamed
    score term, `launch/glm.py glm_analytic(streamed=True)`, and the
    fig4/roofline benchmark figures all report this quantity, so the
    planner and the bench artifacts can never disagree about what
    "ingest bytes" means.  Mirrors what `engine.MeshChunkFeed`
    actually ships:

      dense replicated   n_loc * d * 4            (each worker's X cols)
      dense TP           n_loc * d_loc * 4        (device_put slices rows)
      sparse replicated  n_loc * nnz * 8          (idx + val, full rows)
      sparse sharded     n_loc * w * 12           (slice-compacted
                         idx/val/pos, w ~= the per-lane share of the
                         row width ceiled to the lane multiple — the
                         ~M-fold per-lane saving; the real feed's w is
                         data-dependent, this is the uniform estimate)

    plus 4 bytes/example of labels everywhere.
    """
    n_loc = max(sig.n // max(topo.workers, 1), 1)
    y_bytes = n_loc * 4
    if sig.sparse:
        nnz = max(_effective_nnz(sig, plan.nnz_multiple), 1)
        if plan.feature_shard and topo.model_lanes > 1:
            mult = plan.nnz_multiple or 8
            w = min(_round_up(-(-nnz // topo.model_lanes), mult), nnz)
            return float(n_loc * w * 12 + y_bytes)
        return float(n_loc * nnz * 8 + y_bytes)
    d_loc = sig.d
    if plan.feature_shard and topo.model_lanes > 1:
        d_loc = -(-sig.d // topo.model_lanes)
    return float(n_loc * d_loc * sig.dtype_bytes + y_bytes)


def plan_cost(sig: WorkloadSignature, topo: Topology,
              plan: SolverPlan) -> float:
    """Analytic score: modeled HBM bytes per EFFECTIVE epoch, per device.

    Per-epoch traffic mirrors the fig6 throughput models (DESIGN.md
    S11/S12): every route streams the data once; the XLA scan also
    pays an HBM gather + read-modify-write scatter against v per
    coordinate; the replicated kernel pays v only at chunk syncs; the
    sharded kernel round-trips its d/M slice per bucket and receives
    the all-gathered (M, B, nnz) working set.  The result is then
    multiplied by a mild convergence factor penalizing coarse shuffles
    (large B) and stale replicas (few chunks with many workers) — the
    SySCD speed/convergence trade-off.  A ranking device, not a
    simulator: probe epochs (`probe_plans`) are the ground truth.
    """
    n_loc = max(sig.n // topo.workers, 1)
    B, C = plan.bucket, max(plan.chunks, 1)
    nnz = _effective_nnz(sig, plan.nnz_multiple)
    if sig.sparse:
        data = n_loc * nnz * (4 + sig.dtype_bytes)
        sync = C * sig.d * sig.dtype_bytes * 2
        if plan.route == "pallas-replicated":
            traffic = data + sync
        elif plan.route == "pallas-sharded":
            from repro.kernels.ops import sparse_slice_width
            M = max(topo.model_lanes, 1)
            d_loc = sparse_slice_width(sig.d, M)
            nb = max(n_loc // B, 1)
            traffic = (data + nb * d_loc * sig.dtype_bytes * 2
                       + nb * M * B * nnz * sig.dtype_bytes + sync)
        else:
            traffic = data + n_loc * nnz * sig.dtype_bytes * 3 + sync
    else:
        d_loc = sig.d
        data = n_loc * d_loc * sig.dtype_bytes
        sync = C * d_loc * sig.dtype_bytes * 2
        if plan.route == "pallas-replicated" and plan.solver == "pallas":
            traffic = data + sync
        else:
            # the scan re-touches v per bucket (Gram + margin carry)
            traffic = data + max(n_loc // B, 1) * d_loc \
                * sig.dtype_bytes * 2 + sync
    if sig.streamed:
        # out-of-core: every epoch re-ships the chunks over the host
        # link — score those bytes at their HBM-equivalent weight so a
        # streamed plan's geometry sees the ~50x slower ingest lane
        traffic += streamed_transfer_bytes(sig, topo, plan) \
            * (_HBM_BW / H2D_BW)
    conv = 1.0 + CONV_BUCKET_COST * max(math.log2(max(B, 8) / 8), 0.0)
    W = topo.workers
    if W > 1:
        conv *= 1.0 + CONV_SYNC_COST * (W - 1) / W / C
    return float(traffic) * conv


def search_plans(sig: WorkloadSignature, topo: Topology, *,
                 bucket: Optional[int] = None,
                 chunks: Optional[int] = None,
                 nnz_multiple: Optional[int] = None,
                 top_k: int = 3) -> list[SolverPlan]:
    """Ranked (best-first) feasible plans under the analytic model.

    Ties break toward the static layout (`feature_shard_default`) and
    then the smaller bucket: when the model cannot tell two candidates
    apart, the planner must not drift from today's resolution — the
    never-regress contract applies to score ties too.
    """
    cands = candidate_plans(sig, topo, bucket=bucket, chunks=chunks,
                            nnz_multiple=nnz_multiple)
    cands = [c for c in cands if _plan_feasible(sig, topo, c)]
    shard0 = feature_shard_default(sig, topo)
    cands.sort(key=lambda p: (p.score, p.feature_shard != shard0,
                              p.bucket, p.chunks, p.nnz_multiple))
    return cands[:max(top_k, 1)]


def probe_plans(cands: list[SolverPlan],
                probe_fn: Callable[[SolverPlan], float]) -> SolverPlan:
    """Refine a ranked candidate list with timed probe epochs.

    ``probe_fn(plan) -> seconds`` runs a few real epochs of the
    workload under the candidate geometry (the fig6 planner arm builds
    one from a Session; operators can pass their own).  The fastest
    measured candidate wins; a probe that raises disqualifies its
    candidate rather than the whole search.  Returns the winner with
    ``origin="probe"`` and its measured seconds in ``probe_s``.
    """
    best: Optional[SolverPlan] = None
    for cand in cands:
        try:
            dt = float(probe_fn(cand))
        # audit: except-ok a failed probe is warned about and skipped
        except Exception as e:            # pragma: no cover - probe-dep
            warnings.warn(f"plan probe failed for bucket={cand.bucket} "
                          f"chunks={cand.chunks}: {e}", stacklevel=2)
            continue
        timed = dataclasses.replace(cand, probe_s=dt, origin="probe")
        if best is None or dt < best.probe_s:
            best = timed
    if best is None:
        raise RuntimeError("every probe candidate failed")
    return best


# ---------------------------------------------------------------------------
# Disk cache (alongside the tile cache)
# ---------------------------------------------------------------------------


def plan_cache_dir(cache_dir=None) -> pathlib.Path:
    """Where plans live: ``<tile-cache root>/plans`` (so one
    $REPRO_CACHE_DIR move relocates both)."""
    from repro.data.registry import cache_root
    return cache_root(cache_dir) / "plans"


def _plan_path(sig: WorkloadSignature, topo: Topology,
               cache_dir=None) -> pathlib.Path:
    name = f"{sig.name}-" if sig.name else ""
    return plan_cache_dir(cache_dir) / (
        f"{name}{sig.fingerprint()}-{topo.fingerprint()}"
        f"-v{PLAN_VERSION}.json")


def store_plan(sig: WorkloadSignature, topo: Topology, plan: SolverPlan,
               cache_dir=None) -> pathlib.Path:
    """Persist a plan (atomic rename, sorted keys — byte-stable like
    the tile cache's meta.json)."""
    path = _plan_path(sig, topo, cache_dir)
    path.parent.mkdir(parents=True, exist_ok=True)
    doc = {"magic": "repro-solver-plan", "version": PLAN_VERSION,
           "signature": dataclasses.asdict(sig),
           "topology": dataclasses.asdict(topo),
           "plan": plan.to_json()}
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(doc, sort_keys=True, indent=1) + "\n")
    os.replace(tmp, path)
    return path


def load_cached_plan(sig: WorkloadSignature, topo: Topology,
                     cache_dir=None) -> Optional[SolverPlan]:
    """Load + validate a cached plan; None on miss/skew/corruption.

    Validation is the never-regress gate: version must match
    PLAN_VERSION (the filename key AND the stored field — a bump
    invalidates even a hand-renamed file) and the plan must still pass
    the kernels' misfit predicates (budgets can tighten between
    versions).
    """
    path = _plan_path(sig, topo, cache_dir)
    try:
        if not path.exists():
            return None
        doc = json.loads(path.read_text())
        if (doc.get("magic") != "repro-solver-plan"
                or doc.get("version") != PLAN_VERSION):
            return None
        plan = SolverPlan.from_json(doc["plan"])
        if plan.version != PLAN_VERSION:
            return None
        if not _plan_feasible(sig, topo, plan):
            return None
        return dataclasses.replace(plan, origin="cache")
    # audit: except-ok unreadable/stale cache entry -> plan from scratch
    except Exception:
        return None


# ---------------------------------------------------------------------------
# The front door
# ---------------------------------------------------------------------------


def resolve_plan(sig: WorkloadSignature, topo: Optional[Topology] = None,
                 *, bucket: Optional[int] = None,
                 chunks: Optional[int] = None,
                 nnz_multiple: Optional[int] = None,
                 cache_dir=None,
                 probe_fn: Optional[Callable[[SolverPlan], float]] = None,
                 use_cache: bool = True) -> SolverPlan:
    """Workload + topology -> `SolverPlan`, honoring ``$REPRO_PLAN``.

    Caller-fixed knobs (bucket/chunks/nnz_multiple given explicitly)
    are never overridden — the planner only decides what was left
    open.  Resolution ladder:

      off    -> `static_plan` (today's rules), nothing cached;
      cache  -> a stored plan for this (fingerprint, topology,
                version) that still passes the misfit pre-checks;
      on     -> static geometry if feasible, else the best feasible
                search candidate (the "repair" case);
      search -> best candidate under the analytic cost model;
      probe  -> search, then timed probe epochs over the top
                candidates when ``probe_fn`` is given.

    Any exception inside the planner degrades warn-and-safe to
    `static_plan` — a broken plan cache can never take down training.
    """
    if topo is None:
        topo = Topology.detect()
    mode = plan_mode()
    fixed = dict(bucket=bucket, chunks=chunks, nnz_multiple=nnz_multiple)
    if mode == "off":
        return static_plan(sig, topo, **fixed)
    try:
        if use_cache:
            cached = load_cached_plan(sig, topo, cache_dir)
            if cached is not None and _respects_fixed(cached, fixed):
                return cached
        static = static_plan(sig, topo, **fixed)
        if mode == "on":
            plan = static if _plan_feasible(sig, topo, static) else None
            if plan is None:
                ranked = search_plans(sig, topo, **fixed)
                plan = ranked[0] if ranked else static
        else:
            ranked = search_plans(sig, topo, **fixed)
            if not ranked:
                plan = static
            elif mode == "probe" and probe_fn is not None:
                plan = probe_plans(ranked, probe_fn)
            else:
                plan = ranked[0]
        if not _plan_feasible(sig, topo, plan):
            warnings.warn(
                "planner produced an infeasible plan "
                f"(bucket={plan.bucket}, route={plan.route}); using the "
                "static resolution instead", stacklevel=2)
            return static
        if use_cache and plan.origin != "static":
            store_plan(sig, topo, plan, cache_dir)
        return plan
    # audit: except-ok planner failure degrades to the static plan + warn
    except Exception as e:
        warnings.warn(
            f"solver planner failed ({type(e).__name__}: {e}); falling "
            f"back to static resolution ($REPRO_PLAN=off silences this)",
            stacklevel=2)
        return static_plan(sig, topo, **fixed)


def _respects_fixed(plan: SolverPlan, fixed: dict) -> bool:
    """A cached plan only applies when it agrees with every knob the
    caller pinned explicitly."""
    if fixed["bucket"] is not None and plan.bucket != fixed["bucket"]:
        return False
    if fixed["chunks"] is not None and plan.chunks != fixed["chunks"]:
        return False
    if (fixed["nnz_multiple"] is not None
            and plan.nnz_multiple != fixed["nnz_multiple"]):
        return False
    return True
