"""Bucketing: the paper's cache-line locality optimization, re-derived for TPU.

On CPU the paper groups consecutive training examples into buckets sized
by the cache line (8-16 examples) so that the model vector alpha is
accessed with cache-line locality and the per-epoch shuffle permutes
n/B bucket ids instead of n example ids.

On TPU the analogous fast memory is VMEM, and the analogous win is
threefold (see DESIGN.md S2/S6):
  * the (d_pad x B) data tile for one bucket is streamed HBM->VMEM once
    and reused for margins, Gram matrix, and the shared-vector update;
  * the per-epoch shuffle is over n/B bucket ids (device-side);
  * processing a bucket through its Gram matrix turns the memory-bound
    dot/axpy stream into MXU matmuls and (for feature-sharded runs)
    amortizes one model-axis psum over B coordinates instead of one per
    coordinate.

The bucket recursion is EXACTLY equivalent to sequential SDCA over the
bucket's coordinates (the margin evolution within a bucket only depends
on the bucket Gram matrix), so unlike the paper's CPU variant the TPU
bucket costs no extra epochs relative to an unbucketed pass with the
same visiting order; the residual convergence cost is only the reduced
shuffle granularity, identical to the paper's.
"""
from __future__ import annotations

import dataclasses

# The paper: bucket size = cacheline/8B (8 or 16).  TPU: bucket size is
# bounded by VMEM (the (d_pad x B) tile + B x B Gram must fit) and should
# be a multiple of the 8-sublane register shape for the VPU.
DEFAULT_BUCKET = 16
# The paper disables bucketing when the model vector (n entries) fits the
# last-level cache (~500k entries).  TPU analogue: alpha lives in HBM and
# the kernel keeps v resident in VMEM; the shuffle-granularity cost is only
# worth paying when alpha is big enough that random single-coordinate
# access patterns dominate.  Same cut-off, same spirit.
LLC_ENTRIES = 500_000
# VMEM budget we allow one bucket tile to claim (bytes).  v5e VMEM is
# ~128 MiB/core; we stay far below so double-buffering + v + Gram fit.
VMEM_TILE_BUDGET = 4 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    n: int                  # number of examples (padded)
    bucket: int             # examples per bucket (1 = bucketing off)
    n_buckets: int

    @property
    def enabled(self) -> bool:
        return self.bucket > 1


def choose_bucket_size(n: int, d: int, *, dtype_bytes: int = 4,
                       force: int | None = None,
                       llc_entries: int = LLC_ENTRIES) -> int:
    """Run-time bucket-size heuristic (paper S3, adapted to VMEM).

    force=B overrides; force=1 disables.  Otherwise: disabled when alpha
    fits the 'LLC' threshold, else the largest B in {8, 16, 32, 64} whose
    (d x B) tile fits the VMEM tile budget.
    """
    if force is not None:
        return max(1, force)
    if n <= llc_entries:
        return 1
    for b in (64, 32, 16, 8):
        if d * b * dtype_bytes <= VMEM_TILE_BUDGET:
            return b
    return 8


def make_plan(n: int, d: int, **kw) -> BucketPlan:
    b = choose_bucket_size(n, d, **kw)
    if n % b:
        raise ValueError(f"n={n} not divisible by bucket={b}; pad the data")
    return BucketPlan(n=n, bucket=b, n_buckets=n // b)
