"""Core: the paper's contribution — system-aware parallel SDCA."""
from .bucketing import BucketPlan, choose_bucket_size, make_plan
from .cocoa import SolverConfig, epoch_sim, epoch_sim_sparse
from .config import (AlgoConfig, DeploymentConfig, EngineConfig,
                     as_engine_config)
from .engine import (ChunkFeed, Collectives, DenseBlock, LocalSolver,
                     MeshCollectives, SimCollectives, SparseBlock,
                     make_local_solver, make_streamed_epoch, run_epoch,
                     run_epoch_streamed, sharded_epoch)
from .objectives import (HINGE, LOGISTIC, OBJECTIVES, RIDGE, Objective,
                         duality_gap, dual_value, get_objective,
                         primal_value)
from .partition import PartitionPlan
from .sdca import (bucket_solve, dense_local_subepoch, sequential_epoch,
                   sparse_local_subepoch)
from .trainer import (FitResult, GLMTrainer, StreamedGLMTrainer,
                      fit_dataset)

__all__ = [
    "BucketPlan", "choose_bucket_size", "make_plan",
    "SolverConfig", "epoch_sim", "epoch_sim_sparse",
    "AlgoConfig", "DeploymentConfig", "EngineConfig", "as_engine_config",
    "ChunkFeed", "Collectives", "DenseBlock", "LocalSolver",
    "MeshCollectives", "SimCollectives", "SparseBlock",
    "make_local_solver", "make_streamed_epoch", "run_epoch",
    "run_epoch_streamed", "sharded_epoch",
    "HINGE", "LOGISTIC", "OBJECTIVES", "RIDGE", "Objective",
    "duality_gap", "dual_value", "get_objective", "primal_value",
    "PartitionPlan",
    "bucket_solve", "dense_local_subepoch", "sequential_epoch",
    "sparse_local_subepoch",
    "FitResult", "GLMTrainer", "StreamedGLMTrainer", "fit_dataset",
]
