"""Legacy simulator API: thin wrappers over the unified solver engine.

Historically this module held a full vmap epoch driver that duplicated
the distributed program in `launch/glm.py` (its own re-deal, chunk
loop, quantized sync and pod reduce).  Both now run
`core.engine.run_epoch`; what remains here is the flat `SolverConfig`
(still accepted everywhere) and the `epoch_sim{,_sparse}` signatures,
kept for compatibility.  New code should use `core.config.EngineConfig`
and `core.engine` directly.

Aggregation modes (paper S3 / DESIGN.md S2):
  wild       sigma'=1, plain sum of worker deltas.  This is the
             deterministic proxy for Hogwild's stale lock-free updates:
             fine for sparse / few workers, divergent for dense / many.
  adding     sigma'=#workers, sum (CoCoA+ safe aggregation; default).
  averaging  sigma'=1, mean (CoCoA v1; safe but slow).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from . import engine
from .config import Aggregation, EngineConfig
from .objectives import Objective

Array = jax.Array

__all__ = ["Aggregation", "SolverConfig", "epoch_sim", "epoch_sim_sparse"]


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Flat knobs of the multi-worker solver (paper S3).

    Deprecated in favour of the layered `EngineConfig` (algo x
    deployment); `.to_engine()` converts, and every entry point accepts
    either form.
    """
    pods: int = 1                   # NUMA nodes -> TPU pods (static outer)
    lanes: int = 1                  # threads -> chips (dynamic inner)
    partition: str = "hierarchical"  # static|dynamic|hierarchical|alltoall
    aggregation: Aggregation = "adding"
    bucket: int = 1                 # examples per bucket (1 = off)
    chunks: int = 1                 # v syncs per epoch (within pods)
    seed: int = 0
    use_kernel: bool = False        # route buckets through Pallas kernels
    compress_sync: bool = False     # int8-quantize dv before the sync
    redeal_frac: float = 1.0        # alltoall: bucket fraction exchanged

    @property
    def workers(self) -> int:
        return self.pods * self.lanes

    def sigma_prime(self) -> float:
        if self.aggregation == "adding":
            return float(self.workers)
        return 1.0

    def to_engine(self) -> EngineConfig:
        return EngineConfig.make(
            pods=self.pods, lanes=self.lanes, partition=self.partition,
            aggregation=self.aggregation, bucket=self.bucket,
            chunks=self.chunks, seed=self.seed,
            local_solver="pallas" if self.use_kernel else "auto",
            compress_sync=self.compress_sync,
            redeal_frac=self.redeal_frac)


def epoch_sim(
    obj: Objective,
    X: Array,                  # (d, n) dense
    y: Array,
    alpha: Array,
    v: Array,
    lam: float,
    plan,                      # PartitionPlan
    bplan,                     # BucketPlan
    cfg,                       # SolverConfig | EngineConfig
    epoch: Array,
    straggler_mask: Optional[Array] = None,   # (P, K) True = worker alive
) -> tuple[Array, Array]:
    """One bulk-synchronous epoch over P*K virtual workers (dense path).

    Deprecated shim: forwards to `engine.sim_epoch_dense`.
    """
    from repro.api import warn_deprecated
    warn_deprecated("repro.core.cocoa.epoch_sim",
                    "repro.core.engine.sim_epoch_dense (or repro.api."
                    "Session for training loops)")
    return engine.sim_epoch_dense(obj, X, y, alpha, v, lam, plan, bplan,
                                  cfg, epoch, straggler_mask)


def epoch_sim_sparse(
    obj: Objective,
    idx: Array,                # (n, nnz) int32
    val: Array,                # (n, nnz)
    y: Array,
    alpha: Array,
    v: Array,                  # (d,)
    lam: float,
    plan,
    bplan,
    cfg,
    epoch: Array,
) -> tuple[Array, Array]:
    """Sparse-path epoch (padded CSR).  Deprecated shim over
    `engine.sim_epoch_sparse`; unlike the pre-engine driver this now
    honours `chunks` (v syncs per epoch) on the sparse path too."""
    from repro.api import warn_deprecated
    warn_deprecated("repro.core.cocoa.epoch_sim_sparse",
                    "repro.core.engine.sim_epoch_sparse (or repro.api."
                    "Session for training loops)")
    return engine.sim_epoch_sparse(obj, idx, val, y, alpha, v, lam, plan,
                                   bplan, cfg, epoch)
