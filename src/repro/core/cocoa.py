"""Parallel epoch drivers: wild simulator + domesticated hierarchical CoCoA.

Two interchangeable drivers run the same per-worker local solver
(`dense_local_subepoch` / `sparse_local_subepoch`):

  * `epoch_sim`   — vmap over (pods x lanes) virtual workers on however
                    many real devices exist.  Used for convergence studies
                    and benchmarks on CPU; semantics are bit-identical to
                    the distributed driver because both are bulk-
                    synchronous with the same schedules and aggregation.
  * `make_distributed_epoch` (in repro/launch/glm.py) — shard_map over the
    real ("pod","data","model") mesh; the vmap axes become mesh axes and
    the aggregation sums become psums (data axis per sync interval, pod
    axis per epoch).

Aggregation modes:
  wild       sigma'=1, plain sum of worker deltas.  This is the
             deterministic proxy for Hogwild's stale lock-free updates
             (DESIGN.md S2): it reproduces wild's behaviour — fine for
             sparse / few workers, divergent for dense / many workers.
  adding     sigma'=#workers, sum (CoCoA+ safe aggregation; default).
  averaging  sigma'=1, mean (CoCoA v1; safe but slow).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from . import sdca
from .bucketing import BucketPlan
from .objectives import Objective
from .partition import PartitionPlan

Array = jax.Array
Aggregation = Literal["wild", "adding", "averaging"]


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Knobs of the multi-worker solver (paper S3)."""
    pods: int = 1                   # NUMA nodes -> TPU pods (static outer)
    lanes: int = 1                  # threads -> chips (dynamic inner)
    partition: str = "hierarchical"  # static|dynamic|hierarchical|alltoall
    aggregation: Aggregation = "adding"
    bucket: int = 1                 # examples per bucket (1 = off)
    chunks: int = 1                 # v syncs per epoch (within pods)
    seed: int = 0
    use_kernel: bool = False        # route dense buckets through Pallas
    compress_sync: bool = False     # int8-quantize dv before the sync
    redeal_frac: float = 1.0        # alltoall: bucket fraction exchanged

    @property
    def workers(self) -> int:
        return self.pods * self.lanes

    def sigma_prime(self) -> float:
        if self.aggregation == "adding":
            return float(self.workers)
        return 1.0


def _combine(v0: Array, dv: Array, agg: Aggregation,
             compress: bool = False) -> Array:
    """dv: (P, K, d) worker deltas -> new shared vector."""
    if compress:
        # model the int8 wire reduction: per-worker quantize/dequantize
        from repro.optim.compression import compress as q8, dequantize
        qz, _ = q8(dv, axis=dv.ndim - 1)
        dv = dequantize(qz)
    if agg == "averaging":
        return v0 + dv.mean(axis=(0, 1))
    # wild and adding both sum; they differ in sigma' used by the workers
    return v0 + dv.sum(axis=(0, 1))


def epoch_sim(
    obj: Objective,
    X: Array,                  # (d, n) dense
    y: Array,
    alpha: Array,
    v: Array,
    lam: float,
    plan: PartitionPlan,
    bplan: BucketPlan,
    cfg: SolverConfig,
    epoch: Array,
    straggler_mask: Optional[Array] = None,   # (P, K) True = worker alive
) -> tuple[Array, Array]:
    """One bulk-synchronous epoch over P*K virtual workers (dense path)."""
    d, n = X.shape
    P, K, B = plan.pods, plan.lanes, bplan.bucket
    lam_n = jnp.asarray(lam * n, X.dtype)
    sig = jnp.asarray(cfg.sigma_prime(), X.dtype)

    sched = plan.schedule(epoch)                       # (P, K, per_lane)
    ex = (sched[..., None] * B
          + jnp.arange(B, dtype=jnp.int32)).reshape(P, K, -1)

    chunks = cfg.chunks
    per_chunk = ex.shape[-1] // chunks
    if straggler_mask is None:
        straggler_mask = jnp.ones((P, K), dtype=bool)

    if cfg.use_kernel:
        from repro.kernels import ops as kops
        local = functools.partial(kops.sdca_bucket_subepoch, obj,
                                  bucket=B)
    else:
        local = functools.partial(sdca.dense_local_subepoch, obj, bucket=B)

    def run_chunk(c, state):
        alpha, v = state
        ids = jax.lax.dynamic_slice_in_dim(ex, c * per_chunk, per_chunk, 2)
        Xg = X[:, ids]                                  # (d, P, K, nc)
        Xg = jnp.moveaxis(Xg, 0, 2)                     # (P, K, d, nc)
        ag, yg = alpha[ids], y[ids]

        def worker(Xw, yw, aw):
            return local(Xw, yw, aw, v, lam_n, sig)

        a_new, dv = jax.vmap(jax.vmap(worker))(Xg, yg, ag)
        mask = straggler_mask
        a_new = jnp.where(mask[..., None], a_new, ag)
        dv = dv * mask[..., None].astype(dv.dtype)
        alpha = alpha.at[ids].set(a_new)
        v = _combine(v, dv, cfg.aggregation, cfg.compress_sync)
        return alpha, v

    return jax.lax.fori_loop(0, chunks, run_chunk, (alpha, v))


def epoch_sim_sparse(
    obj: Objective,
    idx: Array,                # (n, nnz) int32
    val: Array,                # (n, nnz)
    y: Array,
    alpha: Array,
    v: Array,                  # (d,)
    lam: float,
    plan: PartitionPlan,
    bplan: BucketPlan,
    cfg: SolverConfig,
    epoch: Array,
) -> tuple[Array, Array]:
    """Sparse-path epoch (padded CSR); bucketing affects shuffle granularity."""
    n = y.shape[0]
    P, K, B = plan.pods, plan.lanes, bplan.bucket
    lam_n = jnp.asarray(lam * n, val.dtype)
    sig = jnp.asarray(cfg.sigma_prime(), val.dtype)

    sched = plan.schedule(epoch)
    ex = (sched[..., None] * B
          + jnp.arange(B, dtype=jnp.int32)).reshape(P, K, -1)

    def worker(ii, vv, yw, aw):
        return sdca.sparse_local_subepoch(obj, ii, vv, yw, aw, v, lam_n, sig)

    a_new, dv = jax.vmap(jax.vmap(worker))(idx[ex], val[ex], y[ex], alpha[ex])
    alpha = alpha.at[ex].set(a_new)
    v = _combine(v, dv, cfg.aggregation, cfg.compress_sync)
    return alpha, v
