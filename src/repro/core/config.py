"""Layered solver configuration: algorithm knobs x deployment knobs.

The paper's solver has two kinds of parameters that used to be tangled
in one flat `SolverConfig` (simulator) and duplicated in `GLMScale`
(distributed launcher):

  * `AlgoConfig` — properties of the *algorithm*: bucket size, sync
    interval, aggregation rule, partition scheme, wire compression.
    These determine convergence and are backend-independent.
  * `DeploymentConfig` — properties of *where it runs*: how many pods
    and lanes (virtual workers in the simulator, mesh axes on TPU),
    feature sharding, cross-pod compression, and whether collectives
    must be bit-deterministic.

`EngineConfig` composes the two and is what `core.engine` consumes on
every path (simulated and distributed).  The legacy flat
`core.cocoa.SolverConfig` converts via `.to_engine()` and keeps working
everywhere an `EngineConfig` is accepted.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Aggregation = Literal["wild", "adding", "averaging"]

#: local-solver implementations the engine can dispatch to, on BOTH the
#: dense and sparse paths.  "auto" resolves to "pallas" on TPU backends
#: and "xla" elsewhere ($REPRO_LOCAL_SOLVER overrides either way — see
#: engine.resolve_auto_solver).
LocalSolverKind = Literal["auto", "xla", "pallas"]


@dataclasses.dataclass(frozen=True)
class AlgoConfig:
    """Algorithm knobs (paper S3) — identical across backends."""
    bucket: int = 1                 # examples per bucket (1 = off)
    chunks: int = 1                 # v syncs per epoch (within pods)
    aggregation: Aggregation = "adding"
    partition: str = "hierarchical"  # static|dynamic|hierarchical|alltoall
    redeal_frac: float = 1.0        # alltoall: bucket fraction exchanged
    local_solver: LocalSolverKind = "auto"
    compress_sync: bool = False     # int8-quantize dv on the chunk sync
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class DeploymentConfig:
    """Where the solver runs: worker topology + wire/compute policies."""
    pods: int = 1                   # NUMA nodes -> TPU pods (static outer)
    lanes: int = 1                  # threads -> chips (dynamic inner)
    feature_shard: bool = False     # dense TP: shard d over 'model'
    compress_pod: bool = False      # int8 cross-pod epoch reduce
    # Bit-deterministic collectives: workers run unbatched (lax.map in
    # the simulator) and reductions are ordered gather-sums, so the sim
    # and mesh backends produce bitwise-identical results.  Costs some
    # throughput; off by default.
    deterministic: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """The one config both entry points consume (engine.run_epoch)."""
    algo: AlgoConfig = AlgoConfig()
    deployment: DeploymentConfig = DeploymentConfig()

    @classmethod
    def make(cls, **kw) -> "EngineConfig":
        """Build from flat kwargs, routing each to its layer."""
        af = {f.name for f in dataclasses.fields(AlgoConfig)}
        df = {f.name for f in dataclasses.fields(DeploymentConfig)}
        unknown = set(kw) - af - df
        if unknown:
            raise TypeError(f"unknown EngineConfig fields: {sorted(unknown)}")
        return cls(
            algo=AlgoConfig(**{k: v for k, v in kw.items() if k in af}),
            deployment=DeploymentConfig(
                **{k: v for k, v in kw.items() if k in df}))

    @property
    def workers(self) -> int:
        return self.deployment.pods * self.deployment.lanes

    def sigma_prime(self, workers: int | None = None) -> float:
        """CoCoA(+) subproblem scaling for `workers` independent solvers."""
        if self.algo.aggregation == "adding":
            return float(workers if workers is not None else self.workers)
        return 1.0


def as_engine_config(cfg) -> EngineConfig:
    """Accept an EngineConfig or anything exposing `.to_engine()`."""
    if isinstance(cfg, EngineConfig):
        return cfg
    to_engine = getattr(cfg, "to_engine", None)
    if to_engine is None:
        raise TypeError(f"cannot convert {type(cfg).__name__} to EngineConfig")
    return to_engine()
