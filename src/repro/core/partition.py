"""Bucket-to-worker partitioning schedules.

Schemes (first three from the paper, 'rotation' is our TPU-native form):
  * static      — bucket b is owned by lane (b * K) // nb forever.  Cheap,
                  but convergence degrades with K (paper Fig 2b).
  * dynamic     — a fresh permutation of bucket ids every epoch; lane k
                  takes the k-th slice.  The paper's novel contribution
                  (affordable inside a node / pod, not across).
  * hierarchical— static split across pods (outer axis, slow interconnect)
                  x dynamic within each pod (paper's NUMA scheme).
  * rotation    — lane k takes the block of lane (k + epoch) % K,
                  shuffled locally.  KEPT AS A REFUTED HYPOTHESIS: it
                  was our first TPU mapping (one collective_permute per
                  epoch), but rotating ownership of FIXED blocks leaves
                  the subproblem sets unchanged — workers are symmetric,
                  so it is convergence-EQUIVALENT TO STATIC (measured in
                  fig5a; hypothesis log in EXPERIMENTS.md SPerf).
  * alltoall    — the TPU-native dynamic scheme the distributed launcher
                  actually uses (launch/glm.py): every epoch each lane
                  shuffles its buckets locally, splits them K ways, and
                  exchanges via ONE balanced all-to-all, so every new
                  block mixes buckets from every old block.  Same wire
                  bytes as rotation, convergence parity with 'dynamic'
                  (fig5a).

Schedules are pure functions of (seed, epoch), so checkpoint/restart and
elastic re-runs reproduce the exact visiting order without host state.

Straggler mitigation: with over_decompose=c, each lane is dealt c*
`chunks` chunks per epoch and a lane that completes only some of them
simply contributes fewer buckets to that sync interval; the next epoch's
re-deal (dynamic) naturally rebalances.  The simulation driver exposes a
`straggler_mask` to exercise this path.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

Mode = Literal["static", "dynamic", "hierarchical", "rotation",
               "alltoall"]


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    n_buckets: int          # global bucket count (divisible by pods*lanes)
    pods: int               # outer (static) axis, paper's NUMA nodes
    lanes: int              # inner (dynamic) axis, paper's threads
    mode: Mode = "hierarchical"
    seed: int = 0
    # alltoall only: fraction of each lane's buckets exchanged per epoch
    # (1.0 = full re-deal; smaller = less wire for nearly the same
    # convergence — see fig5a / EXPERIMENTS.md SPerf glm iteration)
    redeal_frac: float = 1.0

    def __post_init__(self):
        if self.n_buckets % (self.pods * self.lanes):
            raise ValueError(
                f"n_buckets={self.n_buckets} must divide by pods*lanes="
                f"{self.pods * self.lanes}")

    @property
    def per_lane(self) -> int:
        return self.n_buckets // (self.pods * self.lanes)

    def schedule(self, epoch) -> jax.Array:
        """Bucket ids per worker for one epoch: (pods, lanes, per_lane).

        jit-safe: `epoch` may be a traced int32 scalar.
        """
        nb, P, K = self.n_buckets, self.pods, self.lanes
        per_pod = nb // P
        base = jnp.arange(nb, dtype=jnp.int32).reshape(P, per_pod)
        if self.mode == "static":
            return base.reshape(P, K, self.per_lane)
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                 jnp.asarray(epoch, jnp.int32))
        if self.mode == "dynamic":
            # one global shuffle: buckets may migrate across pods too
            # (single-node view: pods=1 gives the paper's in-node scheme)
            perm = jax.random.permutation(key, nb).astype(jnp.int32)
            return perm.reshape(P, K, self.per_lane)
        if self.mode == "rotation":
            # ring-rotate lane blocks within each pod + local shuffle
            blocks = base.reshape(P, K, self.per_lane)
            shift = jnp.asarray(epoch, jnp.int32) % K
            blocks = jnp.roll(blocks, -shift, axis=1)
            keys = jax.random.split(key, P * K).reshape(P, K, -1)
            perms = jax.vmap(jax.vmap(
                lambda k: jax.random.permutation(k, self.per_lane)))(keys)
            return jnp.take_along_axis(
                blocks, perms.astype(jnp.int32), axis=2)
        if self.mode == "alltoall":
            # iterate the (local shuffle -> balanced transpose) re-deal
            # `epoch+1` times; pure function of (seed, epoch) as required
            if self.per_lane % K:
                raise ValueError(f"alltoall needs per_lane % lanes == 0,"
                                 f" got {self.per_lane} % {K}")
            blocks0 = base.reshape(P, K, self.per_lane)
            exch = int(self.per_lane * self.redeal_frac) // K * K
            exch = max(exch, K) if self.redeal_frac > 0 else 0

            def round_(r, blocks):
                rk = jax.random.fold_in(jax.random.PRNGKey(self.seed), r)
                keys = jax.random.split(rk, P * K).reshape(P, K, 2)
                perms = jax.vmap(jax.vmap(lambda k: jax.random.permutation(
                    k, self.per_lane)))(keys)
                sh = jnp.take_along_axis(blocks, perms.astype(jnp.int32),
                                         axis=2)
                if exch == 0:
                    return sh
                # exchange only the first `exch` buckets of each lane:
                # split K ways, transpose across lanes (= all_to_all)
                head = sh[:, :, :exch].reshape(P, K, K, exch // K)
                head = head.swapaxes(1, 2).reshape(P, K, exch)
                return jnp.concatenate([head, sh[:, :, exch:]], axis=2)

            return jax.lax.fori_loop(
                0, jnp.asarray(epoch, jnp.int32) + 1, round_, blocks0)
        # hierarchical: shuffle independently inside each pod's static range
        keys = jax.random.split(key, P)
        perms = jax.vmap(
            lambda k: jax.random.permutation(k, per_pod))(keys)
        ids = jnp.take_along_axis(base, perms.astype(jnp.int32), axis=1)
        return ids.reshape(P, K, self.per_lane)
