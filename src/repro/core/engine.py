"""The solver engine: ONE epoch program for every backend (DESIGN.md S2).

The paper's algorithm — bucketed SDCA + dynamic bucket re-dealing +
hierarchical aggregation — is a single bulk-synchronous program:

    schedule -> re-deal -> (chunked local sub-epoch) -> sync -> pod-reduce

This module implements that program exactly once (`run_epoch`),
parametrized by two seams:

  * `Collectives` — how worker axes are realized and how workers talk.
      - `SimCollectives`: pods x lanes are *virtual* workers stacked on
        leading array axes of one process (vmap / lax.map lifting,
        stacked-axis reductions).  Used by `GLMTrainer`, `cocoa.epoch_sim`
        and every benchmark.
      - `MeshCollectives`: workers are shards of a ("pod","data","model")
        device mesh; the same calls become all_to_all / all_gather / psum
        (used from inside shard_map by `launch/glm.py`).
  * `LocalSolver` — how one worker solves its chunk: dense XLA
    (`sdca.dense_local_subepoch`), dense Pallas
    (`kernels.ops.sdca_bucket_subepoch`), sparse XLA
    (`sdca.sparse_local_subepoch`), or sparse Pallas
    (`kernels.ops.sdca_sparse_bucket_subepoch` — the VMEM-resident
    shared-vector kernel over cached CSR tiles, DESIGN.md S11).
    "auto" picks Pallas on TPU backends and XLA elsewhere; the
    `$REPRO_LOCAL_SOLVER` env var overrides either way.

Bit-determinism: with `DeploymentConfig.deterministic=True` both
backends run each worker's sub-epoch UNBATCHED (lax.map in the sim;
shard programs are unbatched by construction) and reduce with ordered
gather-sums instead of psum, so `SimCollectives` and `MeshCollectives`
produce bitwise-identical (alpha, v) for the same (seed, epoch) — the
property the sim<->mesh equivalence test in tests/test_engine.py pins.
The contract holds when the simulator's lane axis mirrors the mesh's
example-parallel layout, i.e. P pods x K data lanes with model=1 (or a
feature-sharded model axis, which carries no examples).  When workers
also span 'model' (sparse / narrow-dense meshes with model>1), the
mesh re-deals only over 'data' within each model group and reduces
data-then-model, which the flat sim lane axis does not mirror — sim
runs there are convergence-equivalent, not bitwise.

Worker PRNG streams are derived identically on both backends:

    worker_key = fold(fold(fold(PRNGKey(seed), epoch), pod), lane)
    re-deal perm   <- fold(worker_key, 0)
    visit-order    <- fold(worker_key, 1)

with `lane` counted data-major over the example-parallel axes.
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Mapping, Optional, Protocol, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import sdca
from .config import AlgoConfig, EngineConfig, as_engine_config
from .objectives import Objective

Array = jax.Array

# check_vma=False: v is *mathematically* invariant over unmentioned axes
# (every lane adds the same reduced delta to the same replica), but the
# static VMA tracker cannot see through the chunked carry + the int8
# all-gather pod reduce, so we assert replication via out_specs instead.
# Lives here (not launch/glm.py) since the mesh-streamed step below
# needs it too; launch/glm.py re-imports it.
try:
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except (ImportError, TypeError):                        # older jax
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f, mesh, in_specs, out_specs):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)

# ---------------------------------------------------------------------------
# Worker-local data blocks
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DenseBlock:
    """Dense worker-local examples: X (*w, d_shard, n_local)."""
    X: Array

    @property
    def n_local(self) -> int:
        return self.X.shape[-1]

    def take(self, cols: Array):
        return jnp.take_along_axis(self.X, cols[..., None, :], axis=-1)

    def arrs(self):
        return ((self.X, -1),)

    def rebuild(self, arrs) -> "DenseBlock":
        return DenseBlock(arrs[0])


@dataclasses.dataclass(frozen=True)
class SparseBlock:
    """Padded-CSR worker-local examples: idx/val (*w, n_local, nnz)."""
    idx: Array
    val: Array

    @property
    def n_local(self) -> int:
        return self.idx.shape[-2]

    def take(self, cols: Array):
        return (jnp.take_along_axis(self.idx, cols[..., :, None], axis=-2),
                jnp.take_along_axis(self.val, cols[..., :, None], axis=-2))

    def arrs(self):
        return ((self.idx, -2), (self.val, -2))

    def rebuild(self, arrs) -> "SparseBlock":
        return SparseBlock(arrs[0], arrs[1])


Block = Union[DenseBlock, SparseBlock]

# ---------------------------------------------------------------------------
# Local solvers (the per-worker sub-epoch)
# ---------------------------------------------------------------------------


class LocalSolver(Protocol):
    """One worker's pass over its chunk: (data, y, a, v) -> (a_new, dv).

    `data` is an X tile (d_shard, nc) for dense solvers or an
    (idx, val) row pair for sparse ones; `dv` is the UNSCALED global
    delta (CoCoA+ convention).
    """

    def __call__(self, data, y: Array, a: Array, v: Array
                 ) -> tuple[Array, Array]: ...


def dense_xla_solver(obj: Objective, lam_n, sig, bucket: int,
                     model_axis: Optional[str] = None) -> LocalSolver:
    def solve(X, y, a, v):
        return sdca.dense_local_subepoch(
            obj, X, y, a, v, jnp.asarray(lam_n, X.dtype),
            jnp.asarray(sig, X.dtype), bucket, model_axis=model_axis)
    return solve


def dense_pallas_solver(obj: Objective, lam_n, sig, bucket: int,
                        interpret: Optional[bool] = None,
                        source: str = "ad-hoc arrays") -> LocalSolver:
    from repro.kernels import ops as kops

    def solve(X, y, a, v):
        return kops.sdca_bucket_subepoch(
            obj, X, y, a, v, jnp.asarray(lam_n, X.dtype),
            jnp.asarray(sig, X.dtype), bucket=bucket, interpret=interpret,
            source=source)
    return solve


def sparse_solver(obj: Objective, lam_n, sig) -> LocalSolver:
    def solve(data, y, a, v):
        idx, val = data
        return sdca.sparse_local_subepoch(
            obj, idx, val, y, a, v, jnp.asarray(lam_n, val.dtype),
            jnp.asarray(sig, val.dtype))
    return solve


def sparse_pallas_solver(obj: Objective, lam_n, sig, bucket: int,
                         interpret: Optional[bool] = None,
                         source: str = "ad-hoc arrays") -> LocalSolver:
    from repro.kernels import ops as kops

    def solve(data, y, a, v):
        idx, val = data
        return kops.sdca_sparse_bucket_subepoch(
            obj, idx, val, y, a, v, jnp.asarray(lam_n, val.dtype),
            jnp.asarray(sig, val.dtype), bucket=bucket,
            interpret=interpret, source=source)
    return solve


def sparse_sharded_pallas_solver(obj: Objective, lam_n, sig, bucket: int,
                                 model_axis: str, model_lanes: int,
                                 interpret: Optional[bool] = None,
                                 source: str = "ad-hoc arrays"
                                 ) -> LocalSolver:
    """Feature-sharded sparse kernel: each `model_axis` lane owns a
    d/model_lanes slice of v and the per-bucket working-set exchange
    happens inside the sub-epoch (kernels/ops.py, DESIGN.md S12).  dv
    has support only on the lane's slice, so the engine's ordered sync
    over the model axis reassembles the serial dv bitwise."""
    from repro.kernels import ops as kops

    def solve(data, y, a, v):
        idx, val = data
        return kops.sdca_sparse_sharded_subepoch(
            obj, idx, val, y, a, v, jnp.asarray(lam_n, val.dtype),
            jnp.asarray(sig, val.dtype), bucket=bucket,
            model_axis=model_axis, model_lanes=model_lanes,
            interpret=interpret, source=source)
    return solve


def sparse_sharded_xla_solver(obj: Objective, lam_n, sig,
                              model_axis: str, model_lanes: int
                              ) -> LocalSolver:
    """The sharded kernel's XLA twin on the SAME feature-sharded
    layout: run the full HBM-resident scan, then zero dv outside this
    lane's slice (`kops.sparse_slice_width` — the kernel's exact
    partition).  Masking is bitwise-free (kept entries are untouched,
    dropped entries are exact zeros), and without it every lane would
    contribute the FULL dv and the model-axis sync would count it
    `model_lanes` times."""
    from repro.kernels import ops as kops

    def solve(data, y, a, v):
        idx, val = data
        a_new, dv = sdca.sparse_local_subepoch(
            obj, idx, val, y, a, v, jnp.asarray(lam_n, val.dtype),
            jnp.asarray(sig, val.dtype))
        d_loc = kops.sparse_slice_width(v.shape[-1], model_lanes)
        # audit: collective-ok owner-slice offset for the masked update
        lo = jax.lax.axis_index(model_axis).astype(jnp.int32) \
            * jnp.int32(d_loc)
        j = jnp.arange(v.shape[-1], dtype=jnp.int32)
        own = jnp.logical_and(j >= lo, j < lo + d_loc)
        return a_new, jnp.where(own, dv, jnp.zeros((), dv.dtype))
    return solve


def _resolve_auto() -> tuple[str, bool]:
    """("xla"|"pallas", explicit?) for `local_solver="auto"` — explicit
    when the `$REPRO_LOCAL_SOLVER` hatch forced the choice.  The ONLY
    parser of the env hatch."""
    env = os.environ.get("REPRO_LOCAL_SOLVER", "").strip().lower()
    if env:
        if env not in ("xla", "pallas"):
            raise ValueError(
                f"$REPRO_LOCAL_SOLVER={env!r}: must be 'xla' or 'pallas'")
        return env, True
    return ("pallas" if jax.default_backend() == "tpu" else "xla"), False


def resolve_auto_solver() -> str:
    """What `local_solver="auto"` means here: "pallas" on TPU backends
    (dense AND sparse — both kernels exist), "xla" everywhere else.
    `$REPRO_LOCAL_SOLVER=xla|pallas` overrides in either direction
    (the escape hatch for unprofiled TPU topologies / forcing the
    interpret-mode kernel on CPU)."""
    return _resolve_auto()[0]


def _auto_fallback(pallas_solve: LocalSolver, xla_solve: LocalSolver,
                   misfit: Callable, warn_path: str) -> LocalSolver:
    """Backend-auto pallas: pre-check the workload's static shapes
    against the kernel contract at trace time (`misfit(data, v) ->
    reason | None`) and route misfits to the XLA path instead of
    raising mid-trace.  Explicit `local_solver="pallas"` (config or
    $REPRO_LOCAL_SOLVER) skips this and keeps the kernel's actionable
    errors."""
    def solve(data, y, a, v):
        why = misfit(data, v)
        if why is None:
            return pallas_solve(data, y, a, v)
        warnings.warn(
            f"local_solver='auto': the {warn_path} Pallas kernel "
            f"cannot run this workload ({why}); using the XLA path "
            f"instead.  Set $REPRO_LOCAL_SOLVER=pallas to force the "
            f"kernel and get the full error.", stacklevel=2)
        return xla_solve(data, y, a, v)
    return solve


def _sparse_auto_fallback(obj: Objective, lam_n, sig, bucket: int,
                          pallas_solve: LocalSolver) -> LocalSolver:
    from repro.core import planner

    def misfit(data, v):
        idx, _ = data
        _, why = planner.route_sparse(
            idx.shape[-2], idx.shape[-1], v.shape[-1], bucket)
        return why
    return _auto_fallback(pallas_solve, sparse_solver(obj, lam_n, sig),
                          misfit, "sparse")


def _sparse_sharded_auto_fallback(obj: Objective, lam_n, sig, bucket: int,
                                  model_axis: str, model_lanes: int,
                                  pallas_solve: LocalSolver) -> LocalSolver:
    """Sharded-layout twin of `_sparse_auto_fallback`: the misfit check
    carries `model_lanes` (sharded feasibility) and the fallback is the
    slice-MASKED scan — the layout already commits every lane to owning
    only its dv slice."""
    from repro.core import planner

    def misfit(data, v):
        idx, _ = data
        _, why = planner.route_sparse(
            idx.shape[-2], idx.shape[-1], v.shape[-1], bucket,
            model_lanes=model_lanes)
        return why
    return _auto_fallback(
        pallas_solve,
        sparse_sharded_xla_solver(obj, lam_n, sig, model_axis,
                                  model_lanes),
        misfit, "feature-sharded sparse")


def _dense_auto_fallback(obj: Objective, lam_n, sig, bucket: int,
                         pallas_solve: LocalSolver) -> LocalSolver:
    from repro.core import planner

    def misfit(X, v):
        return planner.route_dense(X.shape[-2], X.shape[-1], bucket)
    return _auto_fallback(pallas_solve,
                          dense_xla_solver(obj, lam_n, sig, bucket),
                          misfit, "dense")


def make_local_solver(kind: str, obj: Objective, lam_n, sig, *,
                      bucket: int = 1, sparse: bool = False,
                      model_axis: Optional[str] = None,
                      model_lanes: Optional[int] = None,
                      interpret: Optional[bool] = None,
                      source: str = "ad-hoc arrays") -> LocalSolver:
    """Resolve an `AlgoConfig.local_solver` name to a LocalSolver.

    "auto" resolves via `resolve_auto_solver`: "pallas" on TPU backends
    for BOTH the dense and sparse paths, "xla" elsewhere, with
    `$REPRO_LOCAL_SOLVER` as the override.  Unknown kinds are rejected
    everywhere.  Backend-picked auto's per-workload misfit pre-checks
    route through `core.planner.route_sparse`/`route_dense` (DESIGN.md
    S13) — pure delegations to the kernels' own predicates, so plans
    can never loosen feasibility and `$REPRO_PLAN` never changes the
    fallback verdicts here.

    Feature sharding: `model_axis` + `model_lanes` on the SPARSE path
    select the sharded-v layout (DESIGN.md S12) — "pallas" runs the
    model-axis sharded kernel, "xla" the slice-masked scan, and a
    backend-picked "auto" wraps the kernel with a sharded-feasibility
    check (`kops.sparse_kernel_misfit(..., model_lanes=...)`) that
    falls back to the masked scan.  Dense feature sharding (model-axis
    psum inside the sub-epoch) still has no kernel, as does the legacy
    sparse layout that passes `model_axis` WITHOUT `model_lanes` (the
    model axis as an example axis): a backend-picked "auto" quietly
    keeps the previously-working "xla" route there, while an explicit
    pallas request (config or env var) raises.  A backend-picked
    "auto" likewise falls back to "xla" per-workload (dense AND
    sparse) when the shapes violate the kernel contract (alignment,
    bucket cap, VMEM budgets) instead of failing at epoch build.
    `source` labels the data provenance (tile cache vs ad-hoc arrays)
    in kernel alignment errors.
    """
    auto_pick = False
    if kind == "auto":
        # backend-picked only if the env hatch is unset: a user-forced
        # $REPRO_LOCAL_SOLVER=pallas is an explicit request and keeps
        # the loud failure modes below.
        kind, explicit = _resolve_auto()
        auto_pick = not explicit
    if kind not in ("xla", "pallas"):
        raise ValueError(f"unknown local_solver {kind!r}")
    sharded_sparse = (sparse and model_axis is not None
                      and model_lanes is not None)
    if kind == "pallas" and model_axis is not None and not sharded_sparse:
        if auto_pick:
            kind = "xla"
        else:
            raise ValueError(
                "local_solver='pallas' does not support feature "
                "sharding (model-axis psum) on this path yet"
                + ("; pass model_lanes=... to route the sparse path "
                   "through the sharded-v kernel" if sparse else ""))
    if sparse:
        if sharded_sparse:
            if kind == "pallas":
                pallas = sparse_sharded_pallas_solver(
                    obj, lam_n, sig, bucket, model_axis, model_lanes,
                    interpret=interpret, source=source)
                if auto_pick:
                    return _sparse_sharded_auto_fallback(
                        obj, lam_n, sig, bucket, model_axis,
                        model_lanes, pallas)
                return pallas
            return sparse_sharded_xla_solver(obj, lam_n, sig,
                                             model_axis, model_lanes)
        if kind == "pallas":
            pallas = sparse_pallas_solver(obj, lam_n, sig, bucket,
                                          interpret=interpret,
                                          source=source)
            if auto_pick:
                return _sparse_auto_fallback(obj, lam_n, sig, bucket,
                                             pallas)
            return pallas
        return sparse_solver(obj, lam_n, sig)
    if kind == "pallas":
        pallas = dense_pallas_solver(obj, lam_n, sig, bucket,
                                     interpret=interpret, source=source)
        if auto_pick:
            return _dense_auto_fallback(obj, lam_n, sig, bucket, pallas)
        return pallas
    return dense_xla_solver(obj, lam_n, sig, bucket, model_axis=model_axis)


# ---------------------------------------------------------------------------
# Wire compression helpers (the ONLY home of this logic)
# ---------------------------------------------------------------------------


def q_psum(x: Array, axis_name: str, size: int) -> Array:
    """int8 two-phase reduction over `axis_name` (quantized
    reduce-scatter then quantized all-gather): ~2 bytes/element on the
    wire instead of all-reduce's ~8 — the glm-criteo SPerf iteration.
    """
    from repro.optim.compression import compress
    if size <= 1:
        return x
    n = x.shape[0]
    pad = (-n) % size
    if pad:
        x = jnp.pad(x, (0, pad))
    qz, _ = compress(x)
    # phase 1: exchange int8 shards, sum locally in f32
    # audit: collective-ok pure data movement; the sum is ordered jnp.sum
    shards = jax.lax.all_to_all(
        qz.q.reshape(size, -1), axis_name, split_axis=0, concat_axis=0,
        tiled=False)                                  # (size, n/size)
    scales = jax.lax.all_gather(qz.scale, axis_name)  # audit: collective-ok
    part = jnp.sum(shards.astype(jnp.float32)
                   * scales.reshape(size, 1), axis=0)  # my shard, reduced
    # phase 2: int8 all-gather of the reduced shards
    qz2, _ = compress(part)
    q_all = jax.lax.all_gather(qz2.q, axis_name)  # audit: collective-ok
    s_all = jax.lax.all_gather(qz2.scale, axis_name)  # audit: collective-ok
    out = (q_all.astype(jnp.float32)
           * s_all.reshape(size, 1)).reshape(x.shape)
    return out[:n] if pad else out


def _quantize_roundtrip(x: Array, axis: int) -> Array:
    """Model the int8 wire: per-worker quantize/dequantize along `axis`."""
    from repro.optim.compression import compress, dequantize
    qz, _ = compress(x, axis=axis)
    return dequantize(qz)


# ---------------------------------------------------------------------------
# Collectives backends
# ---------------------------------------------------------------------------


class Collectives(Protocol):
    """How worker axes are realized and how workers communicate.

    `wshape` is the leading stacked worker shape of every array the
    engine touches: (pods, lanes) for the simulator, () inside a
    shard_map where each program instance IS one worker.
    """
    wshape: tuple[int, ...]

    def worker_keys(self, seed: int, epoch): ...
    def map_workers(self, fn: Callable, args: tuple): ...
    def visit_perms(self, keys, nb_local: int): ...
    def broadcast_ids(self, ids: Array): ...
    def redeal(self, arrs, nb_local: int, keys, frac: float): ...
    def pod_replicate(self, v: Array): ...
    def worker_view(self, v: Array): ...
    def lane_sum(self, dv: Array, compress: bool = False): ...
    def pod_reduce(self, v_new: Array, v_in: Array): ...


@dataclasses.dataclass(frozen=True)
class SimCollectives:
    """pods x lanes virtual workers stacked on leading array axes.

    deterministic=True runs each worker's sub-epoch unbatched via
    lax.map (identical HLO to a mesh shard program) instead of vmap;
    reductions are ordered sums either way.
    """
    pods: int = 1
    lanes: int = 1
    deterministic: bool = False
    compress_pod: bool = False

    @property
    def wshape(self) -> tuple[int, ...]:
        return (self.pods, self.lanes)

    def worker_keys(self, seed, epoch):
        base = jax.random.fold_in(jax.random.PRNGKey(seed),
                                  jnp.asarray(epoch, jnp.int32))
        pods = jnp.arange(self.pods, dtype=jnp.int32)
        lanes = jnp.arange(self.lanes, dtype=jnp.int32)
        per_pod = jax.vmap(lambda p: jax.random.fold_in(base, p))(pods)
        return jax.vmap(lambda kp: jax.vmap(
            lambda l: jax.random.fold_in(kp, l))(lanes))(per_pod)

    def _flat(self, tree):
        W = self.pods * self.lanes
        return jax.tree.map(lambda x: x.reshape((W,) + x.shape[2:]), tree)

    def _unflat(self, tree):
        return jax.tree.map(
            lambda x: x.reshape((self.pods, self.lanes) + x.shape[1:]),
            tree)

    def map_workers(self, fn, args):
        flat = self._flat(args)
        if self.deterministic:
            out = jax.lax.map(lambda xs: fn(*xs), flat)
        else:
            out = jax.vmap(fn)(*flat)
        return self._unflat(out)

    def visit_perms(self, keys, nb_local):
        def one(k):
            return jax.random.permutation(
                jax.random.fold_in(k, 1), nb_local).astype(jnp.int32)
        return self._unflat(jax.vmap(one)(self._flat(keys)))

    def broadcast_ids(self, ids):
        return jnp.broadcast_to(ids, self.wshape + ids.shape)

    def redeal(self, arrs, nb_local, keys, frac):
        """Stacked mirror of the mesh all-to-all bucket re-deal: each
        lane shuffles its buckets (per-worker key), the first `exch`
        buckets are split K ways and transposed across the lane axis —
        pure data movement, bitwise-identical to lax.all_to_all."""
        P, K = self.pods, self.lanes
        if K <= 1 or frac <= 0:
            return tuple(x for x, _ in arrs)
        exch = max(int(nb_local * frac) // K * K, K)

        def pkey(k):
            return jax.random.permutation(
                jax.random.fold_in(k, 0), nb_local).astype(jnp.int32)
        perms = self._unflat(jax.vmap(pkey)(self._flat(keys)))  # (P,K,nb)

        def one(x, ax):
            xb = jnp.moveaxis(x, ax, 2)            # (P, K, n_local, ...)
            shp = xb.shape
            rows = shp[2] // nb_local
            rest = shp[3:]
            xb = xb.reshape((P, K, nb_local, rows) + rest)
            idx = perms.reshape((P, K, nb_local)
                                + (1,) * (xb.ndim - 3))
            xb = jnp.take_along_axis(xb, idx, axis=2)
            head = xb[:, :, :exch]
            # lane j receives [split_j of lane 0, ..., split_j of lane
            # K-1] concatenated in lane order == tiled all_to_all
            head = head.reshape((P, K, K, exch // K, rows) + rest)
            head = head.swapaxes(1, 2)
            head = head.reshape((P, K, exch, rows) + rest)
            xb = jnp.concatenate([head, xb[:, :, exch:]], axis=2)
            return jnp.moveaxis(xb.reshape(shp), 2, ax)

        return tuple(one(x, ax) for x, ax in arrs)

    def pod_replicate(self, v):
        if v.ndim == 1:
            return jnp.broadcast_to(v, (self.pods,) + v.shape)
        return v

    def worker_view(self, v):
        # (P, d) pod replicas -> (P, K, d) per-worker replicas
        return jnp.broadcast_to(v[:, None, :],
                                (self.pods, self.lanes, v.shape[-1]))

    def lane_sum(self, dv, compress=False):
        """(P, K, d) worker deltas -> (P, d) per-pod ordered sums."""
        if compress:
            dv = _quantize_roundtrip(dv, axis=dv.ndim - 1)
        # per-pod sum over the lane axis: the same ordered reduction
        # the mesh backend performs on its all_gather'd stack
        # (bit-stable; pinned by the sim<->mesh equivalence tests).
        return jnp.sum(dv, axis=1)

    def pod_reduce(self, v_pods, v_in):
        if self.pods == 1:
            return v_pods[0]
        deltas = v_pods - v_in
        if self.compress_pod:
            deltas = _quantize_roundtrip(deltas, axis=deltas.ndim - 1)
        return v_in[0] + jnp.sum(deltas, axis=0)


@dataclasses.dataclass(frozen=True)
class MeshCollectives:
    """Real collectives over a ("pod","data","model") mesh; every
    method body runs INSIDE shard_map, where this program instance is
    one worker and its arrays are the local shards."""
    lane_axes: tuple[str, ...]            # example-parallel, data-major
    sync_axes: tuple[str, ...]            # chunk-sync reduction axes
    axis_sizes: Mapping[str, int]
    pod_axis: Optional[str] = None
    redeal_axis: Optional[str] = "data"
    deterministic: bool = False
    compress_pod: bool = False

    wshape: tuple[int, ...] = ()

    def _pod_size(self) -> int:
        return self.axis_sizes.get(self.pod_axis, 1) if self.pod_axis else 1

    def worker_keys(self, seed, epoch):
        base = jax.random.fold_in(jax.random.PRNGKey(seed),
                                  jnp.asarray(epoch, jnp.int32))
        # audit: collective-ok per-worker RNG key derivation
        pod = (jax.lax.axis_index(self.pod_axis).astype(jnp.int32)
               if self.pod_axis else jnp.int32(0))
        kp = jax.random.fold_in(base, pod)
        lane = jnp.int32(0)
        for ax in self.lane_axes:
            lane = lane * self.axis_sizes[ax] \
                + jax.lax.axis_index(ax).astype(jnp.int32)  # audit: collective-ok key derivation
        return jax.random.fold_in(kp, lane)

    def map_workers(self, fn, args):
        return fn(*args)

    def visit_perms(self, keys, nb_local):
        return jax.random.permutation(
            jax.random.fold_in(keys, 1), nb_local).astype(jnp.int32)

    def broadcast_ids(self, ids):
        return ids

    def redeal(self, arrs, nb_local, keys, frac):
        """Balanced all-to-all bucket re-deal over the data axis (the
        paper's dynamic partitioning, TPU-native; O(local data) ICI).
        A ring rotation of whole blocks was tried first and REFUTED —
        see core/partition.py."""
        ax_name = self.redeal_axis
        size = self.axis_sizes.get(ax_name, 1) if ax_name else 1
        if size <= 1 or frac <= 0:
            return tuple(x for x, _ in arrs)
        perm = jax.random.permutation(
            jax.random.fold_in(keys, 0), nb_local).astype(jnp.int32)
        exch = max(int(nb_local * frac) // size * size, size)

        def one(x, ax):
            xb = jnp.moveaxis(x, ax, 0)        # (n_local, ...)
            shp = xb.shape
            rows = shp[0] // nb_local
            rest = shp[1:]
            xb = xb.reshape((nb_local, rows) + rest)[perm]
            head = xb[:exch].reshape((exch * rows,) + rest)
            # audit: collective-ok bucket re-deal is pure data movement
            head = jax.lax.all_to_all(head, ax_name, split_axis=0,
                                      concat_axis=0, tiled=True)
            xb = jnp.concatenate(
                [head.reshape((exch, rows) + rest), xb[exch:]], axis=0)
            return jnp.moveaxis(xb.reshape(shp), 0, ax)

        return tuple(one(x, ax) for x, ax in arrs)

    def pod_replicate(self, v):
        return v

    def worker_view(self, v):
        return v

    def lane_sum(self, dv, compress=False):
        for ax in self.sync_axes:
            size = self.axis_sizes.get(ax, 1)
            if size <= 1:
                continue
            if compress:
                dv = q_psum(dv, ax, size)
            elif self.deterministic:
                # ordered gather-sum: bit-stable and identical to the
                # simulator's stacked reduction
                # audit: collective-ok ordered gather-sum (bit-stable)
                dv = jnp.sum(jax.lax.all_gather(dv, ax), axis=0)
            else:
                # audit: collective-ok deterministic=False path only
                dv = jax.lax.psum(dv, ax)
        return dv

    def pod_reduce(self, v_new, v_in):
        """Cross-pod combine of per-pod v deltas (optionally int8)."""
        if self._pod_size() <= 1:
            return v_new
        dv = v_new - v_in
        if self.compress_pod:
            from repro.optim.compression import compress
            qz, _err = compress(dv)    # EF residual handled by caller state
            # audit: collective-ok int8 wire gather; sum is ordered
            q_all = jax.lax.all_gather(qz.q, self.pod_axis)
            s_all = jax.lax.all_gather(qz.scale, self.pod_axis)  # audit: collective-ok
            dv_sum = jnp.sum(q_all.astype(jnp.float32)
                             * s_all.reshape((-1,) + (1,) * dv.ndim),
                             axis=0)
        elif self.deterministic:
            # audit: collective-ok ordered gather-sum (bit-stable)
            dv_sum = jnp.sum(jax.lax.all_gather(dv, self.pod_axis), axis=0)
        else:
            # audit: collective-ok deterministic=False path only
            dv_sum = jax.lax.psum(dv, self.pod_axis)
        return v_in + dv_sum


# ---------------------------------------------------------------------------
# The epoch program (the only copy)
# ---------------------------------------------------------------------------


def _apply_chunk(coll: Collectives, solver: LocalSolver, algo: AlgoConfig,
                 data, yc: Array, ac: Array, v_c: Array, *,
                 straggler_mask: Optional[Array] = None,
                 dv_scale: float = 1.0) -> tuple[Array, Array]:
    """One chunk's solve/mask/sync — shared by the resident-block loop
    (`run_epoch`) and the out-of-core loop (`run_epoch_streamed`), so
    the two paths are the same program on the same inputs."""
    a_new, dv = coll.map_workers(solver,
                                 (data, yc, ac, coll.worker_view(v_c)))
    if straggler_mask is not None:
        a_new = jnp.where(straggler_mask[..., None], a_new, ac)
        dv = dv * straggler_mask[..., None].astype(dv.dtype)
    if dv_scale != 1.0:
        dv = dv * jnp.asarray(dv_scale, dv.dtype)
    return a_new, v_c + coll.lane_sum(dv, compress=algo.compress_sync)


def _put_cols(a: Array, cols: Array, vals: Array) -> Array:
    """alpha[..., cols] = vals with optional leading worker axes."""
    if a.ndim == 1:
        return a.at[cols].set(vals)
    lead = a.shape[:-1]
    fa = a.reshape((-1, a.shape[-1]))
    fc = cols.reshape((-1, cols.shape[-1]))
    fv = vals.reshape((-1, vals.shape[-1]))
    out = jax.vmap(lambda ai, ci, vi: ai.at[ci].set(vi))(fa, fc, fv)
    return out.reshape(lead + (a.shape[-1],))


def run_epoch(
    coll: Collectives,
    solver: LocalSolver,
    algo: AlgoConfig,
    block: Block,
    y: Array,
    a: Array,
    v: Array,
    epoch,
    *,
    straggler_mask: Optional[Array] = None,   # (*wshape) True = alive
    redeal: bool = True,
    visit_shuffle: bool = True,
    dv_scale: float = 1.0,
) -> tuple[Block, Array, Array, Array]:
    """One bulk-synchronous epoch over worker-local data.

    schedule/re-deal -> per-chunk: local sub-epoch, straggler mask,
    lane sync -> per-epoch: pod reduce.  Returns the (possibly
    re-dealt) block and labels so physical layouts persist across
    epochs, plus updated (alpha_local, v).
    """
    n_local = block.n_local
    B = algo.bucket
    if n_local % B:
        raise ValueError(f"n_local={n_local} not divisible by bucket={B}")
    nb_local = n_local // B
    chunks = algo.chunks
    if nb_local % chunks:
        raise ValueError(
            f"chunks={chunks} must divide local bucket count {nb_local}")
    per_chunk = nb_local // chunks

    keys = coll.worker_keys(algo.seed, epoch)
    if redeal:
        arrs = block.arrs() + ((y, -1), (a, -1))
        out = coll.redeal(arrs, nb_local, keys, algo.redeal_frac)
        nblk = len(block.arrs())
        block = block.rebuild(out[:nblk])
        y, a = out[nblk], out[nblk + 1]
    if visit_shuffle:
        perm = coll.visit_perms(keys, nb_local)
    else:
        perm = coll.broadcast_ids(jnp.arange(nb_local, dtype=jnp.int32))

    v = coll.pod_replicate(v)
    v_in = v
    barange = jnp.arange(B, dtype=jnp.int32)

    def chunk(c, carry):
        a_c, v_c = carry
        ids = jax.lax.slice_in_dim(
            perm, c * per_chunk, (c + 1) * per_chunk, axis=perm.ndim - 1)
        cols = (ids[..., None] * B + barange).reshape(
            ids.shape[:-1] + (per_chunk * B,))
        data = block.take(cols)
        yc = jnp.take_along_axis(y, cols, -1)
        ac = jnp.take_along_axis(a_c, cols, -1)
        a_new, v_c = _apply_chunk(
            coll, solver, algo, data, yc, ac, v_c,
            straggler_mask=straggler_mask, dv_scale=dv_scale)
        return _put_cols(a_c, cols, a_new), v_c

    # The chunk loop is unrolled (chunks is a small static count, <= ~8).
    # A lax.fori_loop here MISCOMPILES under shard_map on current jax:
    # closed-over values derived from axis_index (the per-lane visit
    # perm) are treated as loop-invariant-replicated and every lane
    # silently runs lane 0's visit order — the pre-engine distributed
    # driver had exactly this latent bug.  The sim<->mesh equivalence
    # test (tests/test_engine.py) pins the fixed behaviour.
    for c in range(chunks):
        a, v = chunk(c, (a, v))
    v = coll.pod_reduce(v, v_in)
    return block, y, a, v


def sharded_epoch(
    obj: Objective,
    spec: EngineConfig,
    coll: Collectives,
    block: Block,
    y: Array,
    a: Array,
    v: Array,
    epoch,
    *,
    lam: float,
    n_total: int,
    workers: int,
    model_axis: Optional[str] = None,
    model_lanes: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> tuple[Block, Array, Array, Array]:
    """Epoch over a *physically partitioned* workload (the distributed
    layout): partition != 'static' re-deals buckets across lanes, the
    visit order is a fresh per-worker shuffle.  Works with either
    collectives backend — this is the program the sim<->mesh
    equivalence test runs on both.  `model_axis` + `model_lanes` on a
    sparse block select the feature-sharded solver layout (the model
    axis carries v slices and joins the sync axes instead of the
    example axes — launch/glm.py wires both ends)."""
    algo = spec.algo
    lam_n = lam * n_total
    sig = spec.sigma_prime(workers)
    solver = make_local_solver(
        algo.local_solver, obj, lam_n, sig, bucket=algo.bucket,
        sparse=isinstance(block, SparseBlock), model_axis=model_axis,
        model_lanes=model_lanes, interpret=interpret,
        source="resident shard arrays")
    dv_scale = (1.0 / workers if algo.aggregation == "averaging" else 1.0)
    return run_epoch(
        coll, solver, algo, block, y, a, v, epoch,
        redeal=(algo.partition != "static"), visit_shuffle=True,
        dv_scale=dv_scale)


# ---------------------------------------------------------------------------
# Simulator entry points (global arrays, schedule-based partitioning)
# ---------------------------------------------------------------------------


def _sim_gather(plan, bucket: int, epoch):
    """(P, K, n_local) global example ids for this epoch's schedule."""
    sched = plan.schedule(epoch)                       # (P, K, per_lane)
    return (sched[..., None] * bucket
            + jnp.arange(bucket, dtype=jnp.int32)).reshape(
                plan.pods, plan.lanes, -1)


def _sim_coll(spec: EngineConfig) -> SimCollectives:
    dep = spec.deployment
    return SimCollectives(pods=dep.pods, lanes=dep.lanes,
                          deterministic=dep.deterministic,
                          compress_pod=dep.compress_pod)


def sim_epoch_dense(
    obj: Objective,
    X: Array,                  # (d, n) dense, global
    y: Array,
    alpha: Array,
    v: Array,
    lam: float,
    plan,                      # PartitionPlan
    bplan,                     # BucketPlan
    spec,                      # EngineConfig (or anything .to_engine())
    epoch,
    straggler_mask: Optional[Array] = None,
    *,
    dv_scale_mul: float = 1.0,
) -> tuple[Array, Array]:
    """One simulated epoch over P*K virtual workers (dense path).

    Partitioning comes from `plan.schedule` (static/dynamic/
    hierarchical/rotation/alltoall as index math on the global arrays);
    the engine then runs the exact same chunk/sync/pod-reduce program
    as the distributed launcher.

    Cost note: the epoch's schedule is gathered once up front, so the
    jitted epoch holds one extra X-sized permuted copy (the distributed
    path never does this — its layout is physical).  At simulator
    scale (CPU, n <= a few hundred k) this is the right trade for
    sharing the engine's chunk loop verbatim.
    """
    spec = as_engine_config(spec)
    d, n = X.shape
    B = bplan.bucket
    ex = _sim_gather(plan, B, epoch)                   # (P, K, n_local)
    Xl = jnp.transpose(X[:, ex], (1, 2, 0, 3))         # (P, K, d, n_local)
    coll = _sim_coll(spec)
    W = plan.pods * plan.lanes
    solver = make_local_solver(
        spec.algo.local_solver, obj, lam * n, spec.sigma_prime(W),
        bucket=B)
    # dv_scale_mul < 1 is the health guard's "damp" remedy: CoCoA
    # partial aggregation (gamma) applied uniformly on top of the
    # averaging/adding choice
    dv_scale = (1.0 / W if spec.algo.aggregation == "averaging"
                else 1.0) * dv_scale_mul
    _, _, a_new, v_new = run_epoch(
        coll, solver, spec.algo, DenseBlock(Xl), y[ex], alpha[ex], v,
        epoch, straggler_mask=straggler_mask, redeal=False,
        visit_shuffle=False, dv_scale=dv_scale)
    return alpha.at[ex].set(a_new), v_new


def sim_epoch_sparse(
    obj: Objective,
    idx: Array,                # (n, nnz) int32, global
    val: Array,                # (n, nnz)
    y: Array,
    alpha: Array,
    v: Array,                  # (d,)
    lam: float,
    plan,
    bplan,
    spec,
    epoch,
    straggler_mask: Optional[Array] = None,
    *,
    dv_scale_mul: float = 1.0,
) -> tuple[Array, Array]:
    """Sparse-path simulated epoch (padded CSR)."""
    spec = as_engine_config(spec)
    n = y.shape[0]
    B = bplan.bucket
    ex = _sim_gather(plan, B, epoch)
    coll = _sim_coll(spec)
    W = plan.pods * plan.lanes
    solver = make_local_solver(
        spec.algo.local_solver, obj, lam * n, spec.sigma_prime(W),
        bucket=B, sparse=True)
    dv_scale = (1.0 / W if spec.algo.aggregation == "averaging"
                else 1.0) * dv_scale_mul
    _, _, a_new, v_new = run_epoch(
        coll, solver, spec.algo, SparseBlock(idx[ex], val[ex]), y[ex],
        alpha[ex], v, epoch, straggler_mask=straggler_mask, redeal=False,
        visit_shuffle=False, dv_scale=dv_scale)
    return alpha.at[ex].set(a_new), v_new


# ---------------------------------------------------------------------------
# Out-of-core streaming: ChunkFeed + the streamed chunk loop (DESIGN.md S9)
# ---------------------------------------------------------------------------


class ChunkFeed(Protocol):
    """Host-side supplier of worker-shaped example chunks.

    The engine asks for GLOBAL bucket ids laid out (*wshape, nb_chunk)
    and gets back device-resident (data, y) covering those buckets'
    examples in schedule order:

        dense:   data (*wshape, d, nb_chunk*B)
        sparse:  data = (idx, val), each (*wshape, nb_chunk*B, nnz)
        labels:  y (*wshape, nb_chunk*B)

    `fetch` is called one chunk ahead from a worker thread (double
    buffering), so implementations must tolerate concurrent reads.
    Implementations live in `repro.data.cache` (`TileFeed` over the
    mmap'd bucket-tile cache, `ArrayFeed` over resident arrays).

    Contract on sparse rows: no feature id may repeat with a NONZERO
    value within a row (the CSR invariant the sparse Pallas kernel's
    bitwise guarantee rests on, DESIGN.md S11 — sanitize with
    `data.formats.zero_duplicates` when building a custom feed; chunks
    reach the solver inside the jitted step, where values can no
    longer be checked).
    """
    n: int          # global example count (padded)
    d: int
    bucket: int
    sparse: bool

    def fetch(self, bids: np.ndarray): ...


def make_streamed_step(coll: Collectives, solver: LocalSolver,
                       algo: AlgoConfig, *, dv_scale: float = 1.0,
                       jit: bool = True):
    """One streamed chunk: gather alpha rows, run `_apply_chunk` (the
    SAME body as `run_epoch`'s resident loop), scatter alpha back.

    Built once per trainer so the jitted step compiles once.  alpha is
    deliberately NOT donated: a mid-epoch failure (feed I/O error,
    interrupt) must leave the caller's pre-epoch alpha buffer alive so
    training state stays recoverable — donation would delete it on
    accelerator backends.
    """

    def step(data, yc, cols, a, v_c):
        ac = a[cols]
        a_new, v_c = _apply_chunk(coll, solver, algo, data, yc, ac, v_c,
                                  dv_scale=dv_scale)
        return a.at[cols].set(a_new), v_c

    return jax.jit(step) if jit else step


def run_epoch_streamed(
    coll: Collectives,
    feed: ChunkFeed,
    step,                      # from make_streamed_step
    plan,                      # PartitionPlan (host-evaluated schedule)
    algo: AlgoConfig,
    alpha: Array,              # (n,) global dual, device-resident
    v: Array,                  # (d,) shared vector, device-resident
    epoch: int,
    journal=None,              # optional resilience.EpochJournal
    stats: Optional[dict] = None,   # out: ingest-overlap metrics
) -> tuple[Array, Array]:
    """One epoch where `run_epoch`'s chunked sub-epoch loop consumes
    host-resident chunks instead of a device-resident block.

    The schedule is the same pure function of (seed, epoch) the
    in-memory simulator uses (`plan.schedule`), evaluated on host; the
    per-chunk compute is `_apply_chunk` — so with
    `deterministic=True` this path is bitwise-identical to
    `sim_epoch_dense`/`sim_epoch_sparse` on the same data (pinned by
    tests/test_pipeline.py) while only ever holding `chunks`-th of X on
    device.  Chunk c+1's host gather + H2D overlaps chunk c's compute
    (double buffering via a one-slot prefetch thread).

    With a `journal` (resilience.EpochJournal) the loop becomes
    crash-safe: state is snapshotted at chunk boundaries, and a
    re-entered epoch resumes from the journaled chunk cursor — because
    the schedule is pure in (seed, epoch), the resumed epoch replays
    exactly the not-yet-applied chunks and finishes bitwise-identical
    to an uninterrupted run (tests/test_resilience.py).  Without one,
    the loop body adds two ``is None`` checks per chunk and nothing
    else — no host sync, no checksum, zero overhead.

    A ``stats`` dict collects ingest-overlap metrics for the epoch
    (mutated in place): ``epoch_s`` wall time, ``ingest_wait_s`` the
    time the chunk loop spent BLOCKED on the prefetch thread (host
    gather + H2D not hidden behind compute), and
    ``transfer_hidden_frac = 1 - ingest_wait_s/epoch_s`` — the fig4
    streamed-mesh arm's headline number.  Passing one adds a
    `block_until_ready` at epoch end (an epoch boundary sync the
    benchmark wants anyway); None keeps the hot loop sync-free.
    """
    B = feed.bucket
    per_lane = plan.per_lane
    if per_lane % algo.chunks:
        raise ValueError(f"chunks={algo.chunks} must divide per-lane "
                         f"bucket count {per_lane}")
    per_chunk = per_lane // algo.chunks
    ep = int(epoch)
    sched = np.asarray(plan.schedule(ep))           # (P, K, per_lane)

    def fetch(c):
        bids = sched[..., c * per_chunk:(c + 1) * per_chunk]
        cols = (bids[..., None] * B
                + np.arange(B, dtype=np.int32)).reshape(
                    bids.shape[:-1] + (per_chunk * B,))
        data, yc = feed.fetch(bids)
        return jnp.asarray(cols), data, yc

    v = coll.pod_replicate(v)
    v_in = v
    start = 0
    if journal is not None:
        got = journal.load_inflight(ep, alpha, v, v_in)
        if got is not None:
            start, alpha, v, v_in = got
            alpha, v, v_in = (jnp.asarray(alpha), jnp.asarray(v),
                              jnp.asarray(v_in))
    t_start = time.perf_counter()
    wait_s = 0.0
    with ThreadPoolExecutor(max_workers=1) as ex:
        nxt = ex.submit(fetch, start)
        for c in range(start, algo.chunks):
            if journal is not None:
                journal.pre_chunk(ep, c)
            t0 = time.perf_counter()
            cols, data, yc = nxt.result()
            wait_s += time.perf_counter() - t0
            if c + 1 < algo.chunks:
                nxt = ex.submit(fetch, c + 1)
            alpha, v = step(data, yc, cols, alpha, v)
            if journal is not None:
                journal.post_chunk(ep, c, alpha, v, v_in, algo.chunks)
    v = coll.pod_reduce(v, v_in)
    if stats is not None:
        jax.block_until_ready((alpha, v))
        wall = time.perf_counter() - t_start
        stats.update(
            epoch_s=wall, ingest_wait_s=wait_s,
            chunks=algo.chunks - start,
            transfer_hidden_frac=(max(0.0, 1.0 - wait_s / wall)
                                  if wall > 0 else 0.0))
    return alpha, v


def make_streamed_epoch(obj: Objective, spec, plan, feed: ChunkFeed, *,
                        lam: float, jit_step: bool = True,
                        journal=None, damp: float = 1.0):
    """-> epoch_fn(alpha, v, epoch) for out-of-core training.

    The streamed twin of the jitted `sim_epoch_dense`/`sim_epoch_sparse`
    closure `GLMTrainer` builds: same solver, same sigma', same
    schedule, but examples arrive chunk-by-chunk through `feed`.
    ``journal`` threads an `EpochJournal` into the chunk loop (crash
    safety); ``damp`` is the health guard's aggressiveness multiplier
    on dv_scale (mirrors sim_epoch_*'s dv_scale_mul).
    """
    spec = as_engine_config(spec)
    coll = _sim_coll(spec)
    W = plan.pods * plan.lanes
    solver = make_local_solver(
        spec.algo.local_solver, obj, lam * feed.n, spec.sigma_prime(W),
        bucket=feed.bucket, sparse=feed.sparse,
        source=("tile cache" if getattr(feed, "cache", None) is not None
                else "array feed"))
    dv_scale = (1.0 / W if spec.algo.aggregation == "averaging"
                else 1.0) * damp
    step = make_streamed_step(coll, solver, spec.algo,
                              dv_scale=dv_scale, jit=jit_step)

    def epoch_fn(alpha, v, epoch):
        return run_epoch_streamed(coll, feed, step, plan, spec.algo,
                                  alpha, v, epoch, journal=journal)

    return epoch_fn


# ---------------------------------------------------------------------------
# Mesh streaming: per-host input pipeline for the real mesh (DESIGN.md S16)
# ---------------------------------------------------------------------------
#
# `run_epoch_streamed` above is deliberately backend-agnostic: it only
# needs a schedule, a feed, a jitted step, and pod_replicate/pod_reduce.
# The three classes below supply mesh-flavoured implementations of those
# seams so the SAME chunk loop (double buffering, journal hooks, stats)
# streams host-resident tiles onto a shard_map mesh:
#
#   MeshSchedule     — host mirror of the mesh's per-worker PRNG streams
#                      (re-deal + visit order), so the host knows which
#                      GLOBAL buckets each shard consumes each epoch.
#   MeshChunkFeed    — host gather + `device_put` with explicit
#                      NamedShardings (one transfer lands every shard's
#                      slice), optionally slice-compacted per model lane.
#   MeshStreamDriver — pod_replicate/pod_reduce over a pod-stacked v
#                      using real collectives inside shard_map.
#
# plus `make_mesh_streamed_step`, the mesh twin of `make_streamed_step`.


class MeshSchedule:
    """Host-side mirror of the mesh epoch's bucket schedule.

    The resident mesh path re-deals buckets ON DEVICE (`MeshCollectives.
    redeal`: per-worker shuffle + tiled all_to_all over 'data') and then
    visits them in a per-worker shuffled order.  To stream, the host
    must know which GLOBAL bucket ids land on which worker each epoch —
    so this class replays the exact same PRNG streams in numpy:

        worker_key = fold(fold(fold(PRNGKey(seed), epoch), pod), lane)
        re-deal perm <- fold(worker_key, 0);  visit <- fold(worker_key, 1)

    (threefry is bitwise-identical host/device, so the mirror is safe),
    applies the all_to_all index permutation to a persistent bucket
    LAYOUT — initialized contiguous, exactly how a flat global array
    shards under P(example_axes) — and composes re-deals epoch over
    epoch, because the physical layout persists across epochs on the
    resident path.  `schedule(e)` is therefore a pure function of
    (seed, e): re-entrant resume (EpochJournal) and the streamed loop
    replay the identical bucket order the resident mesh executes.

    `lane` is counted data-major over the example axes: for replicated
    model lanes (model carries examples) lane = data_idx * M + model_idx
    and the re-deal exchanges within each (pod, model) column over the
    D data lanes; for feature-sharded runs the model axis carries no
    examples and lane = data_idx.

    NOTE `core.partition.PartitionPlan` cannot be reused here: its
    "alltoall" schedule draws from a different key chain (fold(seed,
    round) + split), so it does NOT mirror the mesh re-deal.
    """

    def __init__(self, n_buckets: int, *, pods: int = 1, data: int = 1,
                 model: int = 1, model_in_lanes: bool = True,
                 seed: int = 0, redeal: bool = True,
                 redeal_frac: float = 1.0, visit_shuffle: bool = True):
        self.n_buckets = int(n_buckets)
        self.pods, self.data, self.model = int(pods), int(data), int(model)
        self.model_in_lanes = bool(model_in_lanes)
        self.lanes = self.data * self.model if model_in_lanes else self.data
        if self.n_buckets % (self.pods * self.lanes):
            raise ValueError(
                f"n_buckets={n_buckets} not divisible by "
                f"{self.pods} pods x {self.lanes} lanes")
        self.seed = int(seed)
        self.redeal = bool(redeal)
        self.redeal_frac = float(redeal_frac)
        self.visit_shuffle = bool(visit_shuffle)
        self._base = np.arange(self.n_buckets, dtype=np.int32).reshape(
            self.pods, self.lanes, self.per_lane)
        self._layouts: list[np.ndarray] = []   # post-redeal, per epoch

    @property
    def per_lane(self) -> int:
        return self.n_buckets // (self.pods * self.lanes)

    def _keys(self, epoch: int):
        base = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                  np.int32(epoch))
        out = np.empty((self.pods, self.lanes), dtype=object)
        for p in range(self.pods):
            kp = jax.random.fold_in(base, np.int32(p))
            for ln in range(self.lanes):
                out[p, ln] = jax.random.fold_in(kp, np.int32(ln))
        return out

    def _perm(self, key, stream: int) -> np.ndarray:
        return np.asarray(jax.random.permutation(
            jax.random.fold_in(key, np.int32(stream)), self.per_lane))

    def _redeal(self, layout: np.ndarray, keys) -> np.ndarray:
        """One epoch's re-deal: mirror of MeshCollectives.redeal over
        the 'data' axis (shuffle, exchange the first `exch` buckets via
        the tiled all_to_all's index permutation)."""
        D = self.data
        nb = self.per_lane
        if D <= 1 or self.redeal_frac <= 0:
            return layout
        exch = max(int(nb * self.redeal_frac) // D * D, D)
        g = exch // D
        out = layout.copy()
        cols = self.model if self.model_in_lanes else 1
        for p in range(self.pods):
            for m in range(cols):
                lanes = [i * cols + m for i in range(D)]
                shuf = [out[p, ln][self._perm(keys[p, ln], 0)]
                        for ln in lanes]
                for j, lnj in enumerate(lanes):
                    head = np.concatenate(
                        [shuf[i][j * g:(j + 1) * g] for i in range(D)])
                    out[p, lnj] = np.concatenate([head, shuf[j][exch:]])
        return out

    def layout(self, epoch: int) -> np.ndarray:
        """(pods, lanes, per_lane) GLOBAL bucket ids each worker holds
        AFTER epoch `epoch`'s re-deal — i.e. the physical layout the
        resident mesh trains on during that epoch.  Tests use it to map
        physically-permuted resident state back to global order."""
        if not self.redeal:
            return self._base
        while len(self._layouts) <= epoch:
            r = len(self._layouts)
            prev = self._layouts[r - 1] if r else self._base
            self._layouts.append(self._redeal(prev, self._keys(r)))
        return self._layouts[epoch]

    def schedule(self, epoch) -> np.ndarray:
        """(pods, lanes, per_lane) bucket ids in VISIT order — the
        `plan.schedule` contract `run_epoch_streamed` consumes."""
        e = int(epoch)
        lay = self.layout(e)
        if not self.visit_shuffle:
            return lay.copy()
        keys = self._keys(e)
        out = np.empty_like(lay)
        for p in range(self.pods):
            for ln in range(self.lanes):
                out[p, ln] = lay[p, ln][self._perm(keys[p, ln], 1)]
        return out


class MeshChunkFeed:
    """`ChunkFeed` that lands each chunk SHARDED across a mesh.

    The host gathers a chunk's buckets (from a `TileCache`'s mmap'd
    tiles or a resident host-array feed), lays the examples out in
    worker-major order — the order a flat global array shards under
    P(example_axes) — and `jax.device_put`s ONCE per array with an
    explicit NamedSharding, so each device receives exactly its slice
    (the `MpDeviceLoader`+`ShardingSpec` idiom).  Called from
    `run_epoch_streamed`'s prefetch thread, this overlaps host gather +
    H2D of chunk c+1 with chunk c's on-mesh compute.

    Feature-sharded sparse runs (`model_lanes`/`d_loc` set) use the
    slice-compacted feed: the host compacts each row to each model
    lane's [m*d_loc, (m+1)*d_loc) feature slice via
    `TileCache.slice_gather(positions=True)` and ships (M, nc, w)
    idx/val/pos stacks sharded P('model', example_axes, ...) — each
    lane transfers only its own slice's nonzeros (w ≈ nnz/M), cutting
    per-lane H2D bytes ~M-fold; the mesh step reassembles exact full
    rows on device from one model-axis all_gather (see
    `make_mesh_streamed_step`).  The compaction width `w` is fixed at
    construction (one scan over the nonzeros, or pass `width=`) so the
    jitted step compiles once.

    ``verify=True`` crc-checks the touched tiles per fetch (same
    contract as `TileFeed`); `rebind(cache)` swaps in a rebuilt
    `TileCache` after quarantine, which `ResilientChunkFeed` uses so
    its corruption recovery preserves the mesh feed (sharding + width)
    instead of downgrading to a plain `TileFeed`.  ``bytes_h2d`` /
    ``fetch_s`` accumulate host-side transfer bytes and gather+put
    seconds for the fig4 overlap metrics.
    """

    def __init__(self, source, mesh, *, ex_axes: tuple[str, ...],
                 tp: bool = False, model_axis: Optional[str] = None,
                 model_lanes: Optional[int] = None,
                 d_loc: Optional[int] = None, verify: bool = False,
                 width: Optional[int] = None, nnz_multiple: int = 8):
        from jax.sharding import NamedSharding, PartitionSpec
        if hasattr(source, "gather_buckets"):        # TileCache
            self.cache, self.host = source, None
            m = source.meta
            self.n, self.d, self.bucket = m.n, m.d, m.bucket
            self.sparse = m.kind == "sparse"
            self.nnz = m.nnz if self.sparse else 0
        else:                                        # ArrayFeed-like
            self.cache, self.host = None, source
            self.n, self.d = source.n, source.d
            self.bucket, self.sparse = source.bucket, source.sparse
            self.nnz = int(source.idx.shape[-1]) if self.sparse else 0
        self.mesh = mesh
        self.ex_axes = tuple(ex_axes)
        self.verify = bool(verify)
        self.nnz_multiple = int(nnz_multiple)
        self.sliced = model_lanes is not None and self.sparse
        self.model_lanes = model_lanes
        self.d_loc = d_loc
        if self.sliced and d_loc is None:
            raise ValueError("slice-compacted feed needs d_loc")
        ex = PartitionSpec(self.ex_axes)
        self._y_s = NamedSharding(mesh, ex)
        if self.sliced:
            self._r_s = NamedSharding(
                mesh, PartitionSpec(model_axis, self.ex_axes, None))
            self.width = int(width) if width else self._scan_width()
        elif self.sparse:
            self._r_s = NamedSharding(
                mesh, PartitionSpec(self.ex_axes, None))
            self.width = None
        else:
            self._x_s = NamedSharding(
                mesh, PartitionSpec(model_axis if tp else None,
                                    self.ex_axes))
            self.width = None
        self.bytes_h2d = 0
        self.fetch_s = 0.0
        self.fetches = 0

    def rebind(self, cache) -> None:
        """Swap in a rebuilt TileCache (post-quarantine recovery)."""
        if self.cache is None:
            raise ValueError("rebind() only applies to cache-backed feeds")
        self.cache = cache

    def reset_stats(self) -> None:
        self.bytes_h2d, self.fetch_s, self.fetches = 0, 0.0, 0

    # -- host-side gather ------------------------------------------------
    def _host_gather(self, bf: np.ndarray):
        h = self.host
        B = self.bucket
        cols = (bf[:, None] * B
                + np.arange(B, dtype=np.int64)).reshape(-1)
        y = h.y[cols]
        if self.sparse:
            return (h.idx[cols], h.val[cols]), y
        return np.ascontiguousarray(h.X[:, cols]), y

    def _gather(self, bf: np.ndarray):
        if self.cache is not None:
            if self.verify:
                self.cache.verify_tiles(bf)
            return self.cache.gather_buckets(bf)
        return self._host_gather(bf)

    def _scan_width(self) -> int:
        """Fixed compaction width: max in-slice nonzero count over the
        WHOLE dataset, ceiled to the kernel lane multiple — so every
        chunk's compacted arrays share one static shape."""
        M, dl = self.model_lanes, self.d_loc
        best = 1
        if self.cache is not None:
            idx_f = self.cache._flat("idx")
            val_f = self.cache._flat("val")
            nnz = idx_f.shape[-1]
            per_tile = int(np.prod(idx_f.shape[1:]))
            step = max(1, (1 << 22) // max(per_tile, 1))
            for s in range(0, idx_f.shape[0], step):
                idx = np.asarray(idx_f[s:s + step]).reshape(-1, nnz)
                val = np.asarray(val_f[s:s + step]).reshape(-1, nnz)
                best = max(best, self._max_count(idx, val))
        else:
            best = self._max_count(self.host.idx, self.host.val)
        mult = self.nnz_multiple
        return min(-(-best // mult) * mult, max(self.nnz, 1))

    def _max_count(self, idx: np.ndarray, val: np.ndarray) -> int:
        # keep-mask matches compact_slice_rows(positions=True): real
        # entries plus explicit (idx!=0, val==0) zeros; (0, 0) padding
        # is reproduced by the reassembly base and needn't travel
        keep = (val != 0) | (idx != 0)
        lane = idx // self.d_loc
        best = 0
        for m in range(self.model_lanes):
            c = ((lane == m) & keep).sum(axis=-1)
            best = max(best, int(c.max(initial=0)))
        return best

    def _fetch_sliced(self, bf: np.ndarray):
        from repro.data.cache import compact_slice_rows
        M, dl = self.model_lanes, self.d_loc
        rows, y = self._gather(bf)
        idx, val = rows
        parts = []
        for m in range(M):
            if self.cache is not None:
                # the per-lane slice compaction IS slice_gather
                # (gathered= skips re-reading the tiles per lane)
                (gi, gv, gp), _ = self.cache.slice_gather(
                    bf, m * dl, (m + 1) * dl,
                    nnz_multiple=self.nnz_multiple, positions=True,
                    width=self.width, gathered=(rows, y))
            else:
                gi, gv, gp = compact_slice_rows(
                    idx, val, m * dl, (m + 1) * dl,
                    nnz_multiple=self.nnz_multiple, positions=True,
                    width=self.width)
            parts.append((gi, gv, gp))
        gi = np.stack([p[0] for p in parts])
        gv = np.stack([p[1] for p in parts])
        gp = np.stack([p[2] for p in parts])
        return (gi, gv, gp), y

    # -- the ChunkFeed entry point ---------------------------------------
    def fetch(self, bids: np.ndarray):
        t0 = time.perf_counter()
        bf = np.asarray(bids).reshape(-1)
        nbytes = 0
        if self.sliced:
            (gi, gv, gp), y = self._fetch_sliced(bf)
            nbytes += gi.nbytes + gv.nbytes + gp.nbytes
            data = (jax.device_put(gi, self._r_s),
                    jax.device_put(gv, self._r_s),
                    jax.device_put(gp, self._r_s))
        elif self.sparse:
            (idx, val), y = self._gather(bf)
            nbytes += idx.nbytes + val.nbytes
            data = (jax.device_put(idx, self._r_s),
                    jax.device_put(val, self._r_s))
        else:
            X, y = self._gather(bf)
            X = np.ascontiguousarray(X)
            nbytes += X.nbytes
            data = jax.device_put(X, self._x_s)
        y = np.ascontiguousarray(y)
        nbytes += y.nbytes
        yd = jax.device_put(y, self._y_s)
        self.bytes_h2d += nbytes
        self.fetch_s += time.perf_counter() - t0
        self.fetches += 1
        return data, yd

    def host_fetch(self, bids: np.ndarray):
        """Raw host-resident rows ``(data, y)`` for the requested
        buckets — uncompacted, no device_put.  Diagnostics (the
        Session's streamed gap/primal pass) use this instead of
        `fetch`, whose sliced-feed output is a per-lane compaction
        that plain margin kernels cannot consume."""
        return self._gather(np.asarray(bids).reshape(-1))


class MeshStreamDriver:
    """The `Collectives` sliver `run_epoch_streamed` needs, for a mesh.

    The streamed loop holds v pod-STACKED — (pods, d) with the leading
    axis sharded over 'pod' — so each pod accumulates its own replica
    across chunks exactly like `SimCollectives` does, and the final
    cross-pod combine runs the REAL `MeshCollectives.pod_reduce`
    (ordered gather-sum / int8 EF) inside a tiny shard_map program.
    """

    def __init__(self, mesh, coll: MeshCollectives, *, tp: bool = False):
        from jax.sharding import NamedSharding, PartitionSpec
        self.mesh, self.coll = mesh, coll
        self.pods = coll._pod_size()
        self._vdim = "model" if tp else None
        self._vp = NamedSharding(
            mesh, PartitionSpec(coll.pod_axis, self._vdim))
        self._v1 = NamedSharding(mesh, PartitionSpec(self._vdim))
        self._finish = None

    def pod_replicate(self, v: Array) -> Array:
        stacked = jnp.broadcast_to(v, (self.pods,) + v.shape)
        return jax.device_put(stacked, self._vp)

    def pod_reduce(self, v_pods: Array, v_in: Array) -> Array:
        if self.pods == 1:
            return v_pods[0]
        if self._finish is None:
            from jax.sharding import PartitionSpec
            coll = self.coll
            vp_spec = PartitionSpec(coll.pod_axis, self._vdim)

            def finish(vp, vi):
                return coll.pod_reduce(vp[0], vi[0])

            self._finish = jax.jit(shard_map(
                finish, self.mesh, in_specs=(vp_spec, vp_spec),
                out_specs=PartitionSpec(self._vdim)))
        return self._finish(v_pods, v_in)


def make_mesh_streamed_step(mesh, coll: MeshCollectives,
                            solver: LocalSolver, algo: AlgoConfig, *,
                            ex_axes: tuple[str, ...], sparse: bool,
                            tp: bool = False,
                            slice_lanes: Optional[int] = None,
                            model_axis: str = "model",
                            nnz: Optional[int] = None,
                            dv_scale: float = 1.0, jit: bool = True):
    """Mesh twin of `make_streamed_step`: same (data, yc, cols, alpha,
    v) -> (alpha, v) contract, but the chunk solve runs inside
    shard_map with `MeshCollectives`, on chunk arrays `MeshChunkFeed`
    landed pre-sharded.  alpha stays a replicated global (n,) array —
    the gather/scatter at chunk edges reshards rows to/from the
    example axes — and is NOT donated (same crash-recoverability
    contract as the sim step).

    Slice-compacted sparse chunks (`slice_lanes` = M model lanes) are
    reassembled to exact full rows on device before the solver: one
    model-axis all_gather of the (n_loc, w) idx/val/pos triple, then a
    positional scatter into a zeros-(n_loc, nnz) base.  The compaction
    keep-mask retains every entry that is not (idx=0, val=0) padding —
    which is exactly what the zeros base reproduces — and kept entries
    scatter to their original (row, position) slots, so the
    reconstruction is bitwise-exact (explicit zero-value entries from
    `zero_duplicates` included) and the downstream solver sees the
    identical arrays the resident path replicates.  The redundant
    bytes move from the host link onto ICI, where the sharded solver
    already pays a per-bucket working-set exchange (DESIGN.md S12).
    """
    from jax.sharding import NamedSharding, PartitionSpec

    e_spec = PartitionSpec(ex_axes)
    vdim = "model" if tp else None
    vp_spec = PartitionSpec(coll.pod_axis, vdim)
    if sparse:
        if slice_lanes:
            if nnz is None:
                raise ValueError("slice-compacted step needs nnz")
            r_spec = PartitionSpec(model_axis, ex_axes, None)
            data_specs = (r_spec, r_spec, r_spec)
        else:
            r_spec = PartitionSpec(ex_axes, None)
            data_specs = (r_spec, r_spec)
    else:
        data_specs = PartitionSpec(vdim, ex_axes)

    def body(data, yc, ac, vp):
        v_c = vp[0]
        if sparse and slice_lanes:
            ci, cv, cp = (t[0] for t in data)     # (n_loc, w) own slice
            # audit: collective-ok pure data movement (slice reassembly)
            gi = jax.lax.all_gather(ci, model_axis)
            gv = jax.lax.all_gather(cv, model_axis)  # audit: collective-ok
            gp = jax.lax.all_gather(cp, model_axis)  # audit: collective-ok
            n_loc = ci.shape[0]
            rows = jnp.broadcast_to(
                jnp.arange(n_loc, dtype=jnp.int32)[None, :, None],
                gp.shape)
            # pad slots carry pos=nnz -> dropped; kept (row, pos) pairs
            # are unique, so the scatter is order-independent
            full_i = jnp.zeros((n_loc, nnz), jnp.int32) \
                .at[rows, gp].set(gi, mode="drop")
            full_v = jnp.zeros((n_loc, nnz), jnp.float32) \
                .at[rows, gp].set(gv, mode="drop")
            data = (full_i, full_v)
        a_new, v_new = _apply_chunk(coll, solver, algo, data, yc, ac,
                                    v_c, dv_scale=dv_scale)
        return a_new, v_new[None]

    inner = shard_map(body, mesh,
                      in_specs=(data_specs, e_spec, e_spec, vp_spec),
                      out_specs=(e_spec, vp_spec))
    a_rep = NamedSharding(mesh, PartitionSpec(None))
    a_ex = NamedSharding(mesh, e_spec)

    def step(data, yc, cols, a, v_c):
        colsf = cols.reshape(-1)
        ac = jax.lax.with_sharding_constraint(a[colsf], a_ex)
        a_new, v_c = inner(data, yc, ac, v_c)
        a = jax.lax.with_sharding_constraint(
            a.at[colsf].set(a_new), a_rep)
        return a, v_c

    return jax.jit(step) if jit else step


# ---------------------------------------------------------------------------
# Simulator entry points (physically partitioned layout)
# ---------------------------------------------------------------------------


def sim_sharded_dense_epoch(obj, spec, X, y, a, v, epoch, *,
                            lam: float, n_total: int):
    """Distributed-layout epoch on stacked sim workers: X (P, K, d,
    n_local).  Mirrors make_dense_epoch exactly (same keys, same
    re-deal, same sums) — the sim side of the equivalence test.
    Bitwise-identical to the mesh when K mirrors its data axis
    (model=1 or feature-sharded; see module docstring)."""
    spec = as_engine_config(spec)
    coll = _sim_coll(spec)
    blk, y, a, v = sharded_epoch(
        obj, spec, coll, DenseBlock(X), y, a, v, epoch, lam=lam,
        n_total=n_total, workers=spec.workers)
    return blk.X, y, a, v


def sim_sharded_sparse_epoch(obj, spec, idx, val, y, a, v, epoch, *,
                             lam: float, n_total: int):
    """Sparse twin of sim_sharded_dense_epoch: idx/val (P, K, nl, nnz)."""
    spec = as_engine_config(spec)
    coll = _sim_coll(spec)
    blk, y, a, v = sharded_epoch(
        obj, spec, coll, SparseBlock(idx, val), y, a, v, epoch, lam=lam,
        n_total=n_total, workers=spec.workers)
    return blk.idx, blk.val, y, a, v
