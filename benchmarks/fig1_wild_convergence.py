"""Paper Fig 1: 'wild' multi-threaded SDCA vs thread count, 1 vs 4 nodes.

Reproduces the qualitative claims: (a) dense data — wild degrades /
fails to converge as lanes grow, worse with more numa nodes (pods);
(b) sparse data — wild scales fine within one node.
"""
from __future__ import annotations


from repro.core import EngineConfig
from repro.data import (make_dense_classification,
                        make_sparse_classification)
from .common import emit, fit_timed

HEADER = ["bench", "dataset", "pods", "lanes", "epochs", "converged",
          "diverged", "gap", "wall_s"]


def run(quick: bool = False):
    rows = []
    n = 8192 if quick else 32768
    dense = make_dense_classification(n=n, d=100, seed=0)
    sparse = make_sparse_classification(n=n, d=1000, nnz=10, seed=0)
    lanes = [1, 4, 16] if quick else [1, 2, 4, 8, 16, 32]
    for name, data in (("dense", dense), ("sparse", sparse)):
        if name == "sparse":
            (idx, val), y, d = data
            dd = dict(X=(idx, val), y=y, d=d, sparse=True)
        else:
            X, y = data
            dd = dict(X=X, y=y, d=100, sparse=False)
        for pods in (1, 4):
            for k in lanes:
                if pods * k > 64:
                    continue
                cfg = EngineConfig.make(pods=pods, lanes=k, bucket=8,
                                   partition="dynamic",
                                   aggregation="wild")
                r = fit_timed(dd, cfg, max_epochs=40)
                rows.append(dict(bench="fig1", dataset=name, pods=pods,
                                 lanes=k, **r))
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
