"""Paper Fig 5 ablations: (a) static vs dynamic (vs our alltoall /
rotation) partitioning, (b) buckets on/off, (c) flat vs hierarchical
pod scheme."""
from __future__ import annotations

from repro.core import EngineConfig
from .common import emit, fit_timed, load

HEADER = ["bench", "dataset", "variant", "epochs", "converged", "wall_s",
          "gap"]


def _row(rows, bench, dataset, variant, r):
    rows.append(dict(bench=bench, dataset=dataset, variant=variant,
                     epochs=r["epochs"], converged=r["converged"],
                     wall_s=r["wall_s"], gap=r["gap"]))


def run(quick: bool = False):
    rows = []
    names = ["criteo"] if quick else ["criteo", "epsilon", "higgs"]
    for name in names:
        data = load(name)

        # (a) partitioning schemes, 16 lanes in one pod
        for mode in ("static", "dynamic", "alltoall", "rotation"):
            r = fit_timed(data, EngineConfig.make(
                pods=1, lanes=16, bucket=8, partition=mode),
                max_epochs=120)
            _row(rows, "fig5a", name, mode, r)

        # (b) buckets on/off (8 lanes, dynamic)
        for bucket, variant in ((1, "bucket_off"), (8, "bucket_8"),
                                (16, "bucket_16")):
            r = fit_timed(data, EngineConfig.make(
                pods=1, lanes=8, bucket=bucket, partition="dynamic"),
                max_epochs=120)
            _row(rows, "fig5b", name, variant, r)

        # (c) flat (1 pod x 16) vs hierarchical (4 pods x 4)
        for cfg, variant in (
            (EngineConfig.make(pods=1, lanes=16, bucket=8,
                          partition="dynamic"), "flat_16"),
            (EngineConfig.make(pods=4, lanes=4, bucket=8,
                          partition="hierarchical"), "hier_4x4"),
        ):
            r = fit_timed(data, cfg, max_epochs=120)
            _row(rows, "fig5c", name, variant, r)
    rows += run_wire_variants(quick)
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()


def run_wire_variants(quick: bool = False):
    """SPerf glm iteration evidence: epochs under int8 sync compression
    and partial re-deal (criteo-like).  Used by EXPERIMENTS.md SPerf-4."""
    rows = []
    data = load("criteo")
    for variant, kw in (
        ("dynamic", dict(partition="dynamic")),
        ("alltoall", dict(partition="alltoall")),
        ("alltoall_int8", dict(partition="alltoall",
                               compress_sync=True)),
        ("alltoall_frac25", dict(partition="alltoall",
                                 redeal_frac=0.25)),
        ("alltoall_frac25_int8", dict(partition="alltoall",
                                      redeal_frac=0.25,
                                      compress_sync=True)),
    ):
        r = fit_timed(data, EngineConfig.make(pods=1, lanes=16, bucket=8,
                                         chunks=4, **kw),
                      max_epochs=120)
        _row(rows, "fig5d", "criteo", variant, r)
    return rows
