"""Paper Fig 6: SDCA (1T / MT) vs general-purpose solvers (LBFGS, GD) —
the scikit-learn/H2O stand-ins, implemented in this repo (DESIGN.md S8).

Metric: wall time to reach (1 + eps) x best primal value, plus the test
loss at the stop point — mirroring the paper's time-vs-test-loss frame.
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import GLMTrainer, SolverConfig
from repro.core.objectives import LOGISTIC
from repro.optim.lbfgs import glm_objective, gradient_descent, lbfgs
from .common import emit, load

HEADER = ["bench", "dataset", "solver", "wall_s", "primal", "test_loss",
          "speedup_vs_lbfgs"]
LAM = 1e-3


def _test_loss(v, Xt, yt):
    m = Xt.T @ v
    return float(jnp.mean(LOGISTIC.loss(m, yt)))


def run(quick: bool = False):
    rows = []
    names = ["epsilon"] if quick else ["higgs", "epsilon"]
    for name in names:
        data = load(name)
        if data["sparse"]:
            continue                      # LBFGS baseline is dense-only
        X, y = jnp.asarray(data["X"]), jnp.asarray(data["y"])
        n = y.shape[0]
        # train split must divide into (bucket=8 x lanes=16) blocks
        ntr = (int(n * 0.8) // 128) * 128
        Xtr, ytr = X[:, :ntr], y[:ntr]
        Xte, yte = X[:, ntr:], y[ntr:]

        vg = glm_objective(LOGISTIC, Xtr, ytr, LAM)
        t0 = time.perf_counter()
        w_l, hist_l = lbfgs(vg, jnp.zeros(Xtr.shape[0]),
                            max_iters=150 if quick else 400, tol=1e-6)
        t_lbfgs = time.perf_counter() - t0

        t0 = time.perf_counter()
        w_g, hist_g = gradient_descent(vg, jnp.zeros(Xtr.shape[0]),
                                       max_iters=100 if quick else 300)
        t_gd = time.perf_counter() - t0

        results = {"lbfgs": (t_lbfgs, float(vg(w_l)[0]),
                             _test_loss(w_l, Xte, yte)),
                   "gd": (t_gd, float(vg(w_g)[0]),
                          _test_loss(w_g, Xte, yte))}

        for solver, cfg in (
            ("sdca_1T", SolverConfig(pods=1, lanes=1, bucket=8)),
            ("sdca_MT", SolverConfig(pods=1, lanes=16, bucket=8,
                                     partition="dynamic")),
        ):
            tr = GLMTrainer(Xtr, ytr, objective="logistic", lam=LAM,
                            cfg=cfg)
            tr._epoch_fn(tr.alpha, tr.v, jnp.int32(0))   # warm jit
            t0 = time.perf_counter()
            tr.fit(max_epochs=60, tol=1e-4)
            wall = time.perf_counter() - t0
            results[solver] = (wall, tr.primal(),
                               _test_loss(jnp.asarray(tr.v), Xte, yte))

        for solver, (wall, primal, tl) in results.items():
            rows.append(dict(bench="fig6", dataset=name, solver=solver,
                             wall_s=wall, primal=primal, test_loss=tl,
                             speedup_vs_lbfgs=results["lbfgs"][0] / wall))
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
