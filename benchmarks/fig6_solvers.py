"""Paper Fig 6: SDCA (1T / MT) vs general-purpose solvers (LBFGS, GD) —
the scikit-learn/H2O stand-ins, implemented in this repo (DESIGN.md S8)
— plus, when scikit-learn is installed, the REAL sklearn
LogisticRegression head-to-head through the estimator API (`--impl
sklearn` is implicit; the arm self-skips offline).

Metric: wall time to reach (1 + eps) x best primal value, plus the test
loss at the stop point — mirroring the paper's time-vs-test-loss frame.
The `estimator` row is `repro.api.LogisticRegression` (the paper's
solver behind the sklearn protocol), timed end-to-end like a user
would call it; its parity columns (train-score agreement with sklearn,
prediction agreement) are what CI uploads as the sklearn-parity
metrics.

The sparse arms (`sdca_sparse_xla` vs `sdca_sparse_pallas`, criteo-
shaped data) race the engine's two sparse local solvers head-to-head
at a FIXED epoch budget and emit per-solver throughput — examples/s
and a bytes-from-HBM-per-epoch model (the quantity the VMEM-resident
kernel exists to cut; DESIGN.md S11) — into the BENCH json.  On CPU
the Pallas arm runs in interpret mode, so treat its wall clock as a
smoke signal; the HBM-bytes column is the architecture-level claim.

The feature-sharded arm (`sdca_sharded_*`, webspam-shaped synthetic
with d past the replicated kernel's resident-v VMEM budget) races the
sharded-v kernel against the slice-masked XLA scan over a model-axis
mesh (DESIGN.md S12); it needs >= 2 devices and self-skips otherwise.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.api import LogisticRegression as ReproLogReg, Session
from repro.api.session import margins
from repro.core import EngineConfig, SolverConfig
from repro.core.objectives import LOGISTIC
from repro.optim.lbfgs import glm_objective, gradient_descent, lbfgs

from .common import emit, load, make_session, parity_metrics, sklearn_logreg

HEADER = ["bench", "dataset", "solver", "wall_s", "primal", "test_loss",
          "speedup_vs_lbfgs", "examples_per_s", "hbm_bytes_epoch",
          "score", "score_sklearn", "predict_agree"]
LAM = 1e-3


def _test_loss(v, Xt, yt):
    m = Xt.T @ v
    return float(jnp.mean(LOGISTIC.loss(m, yt)))


# -- sparse solver arms: XLA gather/scatter scan vs the Pallas kernel -------

SPARSE_CHUNKS = 2
SPARSE_LANES = 4
SPARSE_BUCKET = 8


def _sparse_hbm_bytes(n: int, nnz: int, d: int, solver: str,
                      chunks: int = SPARSE_CHUNKS) -> float:
    """Bytes each sparse solver moves through HBM per epoch (model).

    Both stream the (n, nnz) idx/val rows once (4+4 B/entry).  The XLA
    scan's carry is the full shared vector, so every coordinate also
    pays an nnz-wide gather + read-modify-write scatter against HBM-
    resident v (3 x 4 B/entry).  The Pallas kernel pins v in VMEM for
    the whole sub-epoch; v crosses HBM once per chunk sync (in + out).
    """
    data = n * nnz * 8
    if solver == "pallas":
        return float(data + chunks * d * 4 * 2)
    return float(data + n * nnz * 4 * 3)


def _sparse_rows(quick: bool) -> list[dict]:
    rows = []
    epochs = 2 if quick else 6
    data = load("criteo")                  # criteo-kaggle-sub subsample
    idx, val, y = data["X"][0], data["X"][1], data["y"]
    n, nnz = idx.shape
    blk = SPARSE_LANES * SPARSE_LANES * SPARSE_CHUNKS * SPARSE_BUCKET
    ntr = (int(n * 0.8) // blk) * blk
    tr = dict(X=(idx[:ntr], val[:ntr]), y=y[:ntr], d=data["d"],
              sparse=True)
    te = (jnp.asarray(idx[ntr:]), jnp.asarray(val[ntr:]))
    yte = jnp.asarray(y[ntr:])

    for solver in ("xla", "pallas"):
        cfg = EngineConfig.make(
            lanes=SPARSE_LANES, bucket=SPARSE_BUCKET, chunks=SPARSE_CHUNKS,
            partition="dynamic", deterministic=True, local_solver=solver)
        ses = Session(tr["X"], tr["y"], objective="logistic", lam=LAM,
                      cfg=cfg, d=tr["d"], pad=False)
        ses._epoch_fn(ses.alpha, ses.v, jnp.int32(0))    # warm the jit
        t0 = time.perf_counter()
        ses.fit(max_epochs=epochs, tol=0.0)
        wall = time.perf_counter() - t0
        rows.append(dict(
            bench="fig6", dataset="criteo-sparse",
            solver=f"sdca_sparse_{solver}", wall_s=wall,
            primal=ses.primal(),
            test_loss=float(jnp.mean(LOGISTIC.loss(
                margins(ses.v, te), yte))),
            examples_per_s=ntr * epochs / wall,
            hbm_bytes_epoch=_sparse_hbm_bytes(ntr, nnz, tr["d"], solver)))
    return rows


# -- planner arm: $REPRO_PLAN=probe geometry search on the criteo shape -----


def _planner_rows(quick: bool) -> list[dict]:
    """Race the system-aware planner's chosen geometry (DESIGN.md S13)
    on the criteo-shaped sparse subsample: a probe-mode search (timed
    1-epoch probes over the analytic top candidates, plan cached in a
    throwaway dir) picks (bucket, chunks), then the full fit runs under
    that geometry.  The row carries the chosen `SolverPlan` under the
    non-CSV "plan" key, which run.py lifts into the BENCH json next to
    examples/s — so CI tracks WHAT the planner picked, not just how
    fast it ran."""
    import os
    import tempfile

    from repro.core import planner

    epochs = 2 if quick else 6
    data = load("criteo")
    idx, val, y = data["X"][0], data["X"][1], data["y"]
    n, nnz = idx.shape
    d = data["d"]
    blk = SPARSE_LANES * SPARSE_LANES * SPARSE_CHUNKS * SPARSE_BUCKET
    ntr = (int(n * 0.8) // blk) * blk
    idx, val, y = idx[:ntr], val[:ntr], y[:ntr]

    def fit_timed(bucket, chunks, n_epochs):
        cfg = EngineConfig.make(
            lanes=SPARSE_LANES, bucket=bucket, chunks=chunks,
            partition="dynamic", deterministic=True, local_solver="auto")
        ses = Session((idx, val), y, objective="logistic", lam=LAM,
                      cfg=cfg, d=d)
        ses._epoch_fn(ses.alpha, ses.v, jnp.int32(0))    # warm the jit
        t0 = time.perf_counter()
        ses.fit(max_epochs=n_epochs, tol=0.0)
        return time.perf_counter() - t0, ses

    import jax
    sig = planner.WorkloadSignature(n=ntr, d=d, nnz=nnz, sparse=True,
                                    name="criteo-sub")
    topo = planner.Topology(backend=jax.default_backend(),
                            device_count=jax.device_count(),
                            lanes=SPARSE_LANES)
    with tempfile.TemporaryDirectory() as td:
        prev = os.environ.get("REPRO_PLAN")
        os.environ["REPRO_PLAN"] = "probe"
        try:
            plan = planner.resolve_plan(
                sig, topo, cache_dir=td,
                probe_fn=lambda p: fit_timed(p.bucket, p.chunks, 1)[0])
        finally:
            if prev is None:
                os.environ.pop("REPRO_PLAN", None)
            else:
                os.environ["REPRO_PLAN"] = prev
    wall, ses = fit_timed(plan.bucket, plan.chunks, epochs)
    return [dict(
        bench="fig6", dataset="criteo-sparse",
        solver="sdca_sparse_planner", wall_s=wall, primal=ses.primal(),
        examples_per_s=ntr * epochs / wall,
        hbm_bytes_epoch=_sparse_hbm_bytes(
            ntr, nnz, d, "pallas" if plan.solver == "pallas" else "xla",
            chunks=plan.chunks),
        plan=plan.to_json())]


# -- feature-sharded sparse arm: webspam-shape d on a model-axis mesh -------

SHARDED_D = 2_101_248        # past the replicated kernel's resident-v
                             # VMEM budget (2_097_152 f32 rows), so only
                             # the sharded kernel or the scan can run it
SHARDED_NNZ = 64             # webspam's offline fallback row width
SHARDED_N = 128
SHARDED_LANES = 2            # model-axis lanes


def _sharded_hbm_bytes(n: int, nnz: int, d: int, M: int,
                       solver: str) -> float:
    """Per-device HBM bytes/epoch for the feature-sharded arms (model).

    Every model lane streams the full (n, nnz) idx/val rows (the data
    is replicated over the model axis).  The sharded Pallas kernel
    keeps only its d/M slice resident: per bucket it round-trips the
    slice (in + out — the per-bucket pallas_call boundary forces a
    full-block DMA, unlike the replicated kernel's grid-resident v)
    and receives the all-gathered (M, B, nnz) f32 working set; the
    full v crosses HBM once per chunk sync.  The slice-masked XLA scan
    pays the HBM-resident-v gather/scatter exactly like the unsharded
    scan, plus the same syncs.  At bench scale the slice round-trip
    dominates, so the sharded kernel's bytes column exceeds the scan's
    — the column is here to make that cost structure visible, not to
    flatter the kernel; its win is VMEM-resident compute (examples/s
    on real TPUs) on shapes the replicated kernel cannot run AT ALL.
    """
    from repro.kernels.ops import sparse_slice_width
    data = n * nnz * 8
    sync = SPARSE_CHUNKS * d * 4 * 2
    if solver == "pallas":
        d_loc = sparse_slice_width(d, M)
        nb = n // SPARSE_BUCKET
        return float(data + nb * d_loc * 4 * 2
                     + nb * M * SPARSE_BUCKET * nnz * 4 + sync)
    return float(data + n * nnz * 4 * 3 + sync)


def _sharded_sparse_rows(quick: bool) -> list[dict]:
    """Race the feature-sharded sparse kernel vs the slice-masked XLA
    scan on a webspam-shaped synthetic (d past the replicated kernel's
    resident-v budget) over a (data=1, model=2) mesh.  Needs >= 2
    devices — the bench-smoke CI job forces host devices; runs with
    fewer skip the arm (compare.py's workload-version gate keeps such
    runs from being diffed against 2-device baselines)."""
    import jax
    from repro.data import make_sparse_classification
    from repro.launch.glm import GLMScale, make_sparse_epoch
    from repro.launch.mesh import make_host_mesh

    if jax.device_count() < SHARDED_LANES:
        print(f"# fig6 sharded arm skipped: "
              f"{jax.device_count()} device(s) < {SHARDED_LANES}")
        return []
    epochs = 1 if quick else 2
    n, d, nnz = SHARDED_N, SHARDED_D, SHARDED_NNZ
    (idx, val), y, _ = make_sparse_classification(n=n, d=d, nnz=nnz,
                                                  seed=6)
    idx, val, y = (jnp.asarray(t) for t in (idx, val, y))
    mesh = make_host_mesh(pod=1, data=1, model=SHARDED_LANES)
    rows = []
    for solver in ("xla", "pallas"):
        sc = GLMScale("webspam-sharded", "sparse", n=n, d=d, nnz=nnz,
                      bucket=SPARSE_BUCKET, chunks=SPARSE_CHUNKS,
                      lam=LAM, compress_pod=False, deterministic=True,
                      local_solver=solver, feature_shard=True)
        with mesh:
            ep = jax.jit(make_sparse_epoch(sc, mesh))
            jax.block_until_ready(                         # warm the jit
                ep(idx, val, y, jnp.zeros(n), jnp.zeros(d), jnp.int32(0)))
            st = (idx, val, y, jnp.zeros(n), jnp.zeros(d))
            t0 = time.perf_counter()
            for e in range(epochs):
                st = ep(*st, jnp.int32(e))
            jax.block_until_ready(st)
            wall = time.perf_counter() - t0
        v = st[4]
        rows.append(dict(
            bench="fig6", dataset="webspam-sharded",
            solver=f"sdca_sharded_{solver}", wall_s=wall,
            primal=float(jnp.mean(LOGISTIC.loss(margins(v, (idx, val)),
                                                y))
                         + LAM / 2 * jnp.vdot(v, v)),
            examples_per_s=n * epochs / wall,
            hbm_bytes_epoch=_sharded_hbm_bytes(n, nnz, d, SHARDED_LANES,
                                               solver)))
    return rows


def run(quick: bool = False):
    rows = []
    names = ["epsilon"] if quick else ["higgs", "epsilon"]
    for name in names:
        data = load(name)
        if data["sparse"]:
            continue                      # LBFGS baseline is dense-only
        X, y = jnp.asarray(data["X"]), jnp.asarray(data["y"])
        n = y.shape[0]
        # train split must divide into (bucket=8 x lanes=16) blocks
        ntr = (int(n * 0.8) // 128) * 128
        Xtr, ytr = X[:, :ntr], y[:ntr]
        Xte, yte = X[:, ntr:], y[ntr:]
        tr_data = dict(X=Xtr, y=ytr, d=int(Xtr.shape[0]), sparse=False)

        vg = glm_objective(LOGISTIC, Xtr, ytr, LAM)
        t0 = time.perf_counter()
        w_l, hist_l = lbfgs(vg, jnp.zeros(Xtr.shape[0]),
                            max_iters=150 if quick else 400, tol=1e-6)
        t_lbfgs = time.perf_counter() - t0

        t0 = time.perf_counter()
        w_g, hist_g = gradient_descent(vg, jnp.zeros(Xtr.shape[0]),
                                       max_iters=100 if quick else 300)
        t_gd = time.perf_counter() - t0

        results = {"lbfgs": (t_lbfgs, float(vg(w_l)[0]),
                             _test_loss(w_l, Xte, yte)),
                   "gd": (t_gd, float(vg(w_g)[0]),
                          _test_loss(w_g, Xte, yte))}

        for solver, cfg in (
            ("sdca_1T", SolverConfig(pods=1, lanes=1, bucket=8)),
            ("sdca_MT", SolverConfig(pods=1, lanes=16, bucket=8,
                                     partition="dynamic")),
        ):
            ses = make_session(tr_data, cfg, lam=LAM)
            ses._epoch_fn(ses.alpha, ses.v, jnp.int32(0))   # warm jit
            t0 = time.perf_counter()
            ses.fit(max_epochs=60, tol=1e-4)
            wall = time.perf_counter() - t0
            results[solver] = (wall, ses.primal(),
                               _test_loss(jnp.asarray(ses.v), Xte, yte))

        # the estimator arm: end-to-end through the public API (no jit
        # pre-warm — this is the latency a fresh sklearn user sees)
        est = ReproLogReg(lam=LAM, max_epochs=60, tol=1e-4, lanes=16,
                          bucket=8, partition="dynamic")
        t0 = time.perf_counter()
        est.fit(np.asarray(Xtr).T, np.asarray(ytr))
        wall_est = time.perf_counter() - t0
        # primal evaluated on the UNPADDED objective so rows compare
        results["estimator"] = (wall_est,
                                float(vg(jnp.asarray(est.coef_))[0]),
                                _test_loss(jnp.asarray(est.coef_),
                                           Xte, yte))

        sk = sklearn_logreg(tr_data, lam=LAM,
                            max_iter=100 if quick else 400)
        parity: dict[str, dict] = {}
        if sk is not None:
            w_sk = jnp.asarray(sk["clf"].coef_.ravel())
            results["sklearn"] = (sk["wall_s"], float(vg(w_sk)[0]),
                                  _test_loss(w_sk, Xte, yte))
            est_arm = dict(est=est,
                           score=float(est.score(sk["X"], sk["y"])),
                           inputs=(sk["X"], sk["y"]))
            # parity rides on the ESTIMATOR row (score = ours), keyed
            # the same way fig3 does, so CI's drift tracking compares
            # like-for-like records across figures
            parity["estimator"] = parity_metrics(est_arm, sk)

        for solver, (wall, primal, tl) in results.items():
            rows.append(dict(bench="fig6", dataset=name, solver=solver,
                             wall_s=wall, primal=primal, test_loss=tl,
                             speedup_vs_lbfgs=results["lbfgs"][0] / wall,
                             **parity.get(solver, {})))
    rows.extend(_sparse_rows(quick))
    rows.extend(_planner_rows(quick))
    rows.extend(_sharded_sparse_rows(quick))
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
