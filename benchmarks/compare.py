"""Diff two bench-summary JSONs and fail on perf regressions.

    python -m benchmarks.compare PREV.json NEW.json \
        [--runtime-tol 0.2] [--gap-tol 0.2] [--parity-floor 0.99]

CI's `bench-smoke` job downloads the previous run's `BENCH_*.json`
artifact and runs this against the fresh one (the ROADMAP
"perf trajectory" item): exit 1 when any figure got >20% slower or its
final duality gap got >20% worse, when a previously-passing figure now
fails, or when a figure disappeared.  A missing/unreadable PREV (first
run, expired artifact) is a clean pass — there is nothing to diff.

The fig3/fig6 sklearn-parity metrics ride in each summary's
`figures[*].parity` records and are part of the gate: any
`predict_agree` below the floor (default 0.99) fails the NEW run even
on a first run with no baseline, and a parity record that existed in
PREV but vanished from NEW is a regression (a silently-dropped parity
arm must not pass).

Quick-mode and full-mode summaries are never compared against each
other (sizes differ by design; the `quick` flag is checked first).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _load(path) -> dict | None:
    p = pathlib.Path(path)
    if not p.exists():
        return None
    try:
        doc = json.loads(p.read_text())
    except (OSError, json.JSONDecodeError):
        return None
    return doc if doc.get("schema", "").startswith("bench-summary") \
        else None


def _parity_key(rec: dict) -> tuple:
    return (rec.get("dataset"), rec.get("impl"), rec.get("solver"))


def parity_floor_problems(summary: dict, *, floor: float = 0.99
                          ) -> list[str]:
    """Absolute sklearn-parity gate on ONE summary (no baseline needed):
    every fig3/fig6 parity record must have predict_agree >= floor."""
    problems: list[str] = []
    for name, fig in summary.get("figures", {}).items():
        if fig.get("failed"):
            continue              # the figure failure already fails CI
        for rec in fig.get("parity", []):
            agree = rec.get("predict_agree")
            if agree is not None and agree < floor:
                problems.append(
                    f"{name}: sklearn parity predict_agree={agree:.4f} "
                    f"below the {floor:.2f} floor "
                    f"({rec.get('dataset')}/{rec.get('solver') or rec.get('impl')})")
    return problems


def compare(prev: dict, new: dict, *, runtime_tol: float = 0.2,
            gap_tol: float = 0.2) -> list[str]:
    """-> list of regression messages (empty = pass)."""
    problems: list[str] = []
    if prev.get("quick") != new.get("quick") \
            or prev.get("workload") != new.get("workload"):
        return []   # different scale or workload; nothing comparable
    pf, nf = prev.get("figures", {}), new.get("figures", {})
    for name, p in pf.items():
        n = nf.get(name)
        if n is None:
            problems.append(f"{name}: figure disappeared from the run")
            continue
        if n.get("failed") and not p.get("failed"):
            problems.append(f"{name}: now FAILING (previously passing)")
            continue
        if p.get("failed") or n.get("failed"):
            continue              # was already broken; tier-1 owns that
        rt_p, rt_n = p.get("runtime_s"), n.get("runtime_s")
        if rt_p and rt_n and rt_n > rt_p * (1 + runtime_tol):
            problems.append(
                f"{name}: runtime {rt_n:.1f}s vs {rt_p:.1f}s "
                f"(+{(rt_n / rt_p - 1) * 100:.0f}% > "
                f"{runtime_tol * 100:.0f}% budget)")
        g_p, g_n = p.get("final_gap"), n.get("final_gap")
        if g_p is not None and g_n is not None and g_p > 0 \
                and g_n > g_p * (1 + gap_tol):
            problems.append(
                f"{name}: final gap {g_n:.3e} vs {g_p:.3e} "
                f"(worse by {(g_n / g_p - 1) * 100:.0f}% > "
                f"{gap_tol * 100:.0f}% budget)")
        # parity trajectory: a record tracked last run must still exist
        # (its VALUE is gated by the absolute floor, not a relative diff
        # — agreement is already a ratio, and the floor is the contract)
        new_keys = {_parity_key(r) for r in n.get("parity", [])}
        for rec in p.get("parity", []):
            if _parity_key(rec) not in new_keys:
                problems.append(
                    f"{name}: sklearn-parity record "
                    f"{_parity_key(rec)} disappeared from the run")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("prev")
    ap.add_argument("new")
    ap.add_argument("--runtime-tol", type=float, default=0.2)
    ap.add_argument("--gap-tol", type=float, default=0.2)
    ap.add_argument("--parity-floor", type=float, default=0.99)
    args = ap.parse_args(argv)

    new = _load(args.new)
    if new is None:
        print(f"compare: cannot read new summary {args.new}")
        return 1
    # the absolute parity floor gates every run, baseline or not
    problems = parity_floor_problems(new, floor=args.parity_floor)
    prev = _load(args.prev)
    if prev is None:
        print(f"compare: no previous summary at {args.prev}; "
              "baseline accepted")
    else:
        problems += compare(prev, new, runtime_tol=args.runtime_tol,
                            gap_tol=args.gap_tol)
    if problems:
        print("perf/parity regressions:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print("compare: no perf or parity regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
