"""Paper Fig 3: wild vs domesticated time-to-convergence on the three
datasets x two 'machines' (2-pod and 4-pod mesh geometries).

Standalone it takes real dataset names from the registry:

    python -m benchmarks.fig3_convergence --dataset higgs \
        --dataset criteo-kaggle-sub

(any `repro.data.registry` name or benchmark alias works; a raw
svmlight/CSV file under $REPRO_DATA_DIR is ingested automatically).
"""
from __future__ import annotations

import argparse

from repro.core import SolverConfig
from .common import DATASETS, emit, fit_timed, load

HEADER = ["bench", "dataset", "machine", "impl", "lanes", "epochs",
          "converged", "gap", "wall_s", "speedup_vs_wild"]


def run(quick: bool = False, datasets: list[str] | None = None):
    rows = []
    names = datasets or (["higgs"] if quick else list(DATASETS))
    for name in names:
        data = load(name)
        for pods, machine in ((2, "2node"), (4, "4node")):
            lanes = 4
            wild = fit_timed(data, SolverConfig(
                pods=1, lanes=pods * lanes, bucket=8,
                partition="dynamic", aggregation="wild"))
            dom = fit_timed(data, SolverConfig(
                pods=pods, lanes=lanes, bucket=8,
                partition="hierarchical", aggregation="adding"))
            speed = (wild["wall_s"] / dom["wall_s"]
                     if dom["converged"] else float("nan"))
            rows.append(dict(bench="fig3", dataset=name, machine=machine,
                             impl="wild", lanes=pods * lanes,
                             epochs=wild["epochs"],
                             converged=wild["converged"],
                             gap=wild["gap"], wall_s=wild["wall_s"],
                             speedup_vs_wild=1.0))
            rows.append(dict(bench="fig3", dataset=name, machine=machine,
                             impl="domesticated", lanes=pods * lanes,
                             epochs=dom["epochs"],
                             converged=dom["converged"],
                             gap=dom["gap"], wall_s=dom["wall_s"],
                             speedup_vs_wild=speed))
    return emit(rows, HEADER)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", action="append", default=None,
                    help="registry dataset name or benchmark alias; "
                         "repeatable (default: the paper's three)")
    ap.add_argument("--full", action="store_true",
                    help="run all default datasets, not the quick subset")
    args = ap.parse_args()
    run(quick=not args.full, datasets=args.dataset)
