"""Paper Fig 3: wild vs domesticated time-to-convergence on the three
datasets x two 'machines' (2-pod and 4-pod mesh geometries), plus an
optional scikit-learn head-to-head arm through the estimator API.

Standalone it takes real dataset names from the registry:

    python -m benchmarks.fig3_convergence --dataset higgs \
        --dataset criteo-kaggle-sub --impl sklearn

(any `repro.data.registry` name or benchmark alias works; a raw
svmlight/CSV file under $REPRO_DATA_DIR is ingested automatically).
`--impl sklearn` adds two rows per dataset — `estimator`
(`repro.api.LogisticRegression`, the paper's solver behind the sklearn
protocol) and `sklearn` (the real `sklearn.linear_model`, identical
objective: C = 1/(lam n), no intercept) — with train-score parity and
prediction-agreement columns; skipped silently when sklearn is absent.
"""
from __future__ import annotations

import argparse

from repro.core import SolverConfig

from .common import (DATASETS, emit, estimator_arm, fit_timed, load,
                     parity_metrics, sklearn_logreg)

HEADER = ["bench", "dataset", "machine", "impl", "lanes", "epochs",
          "converged", "gap", "gap_est", "wall_s", "speedup_vs_wild",
          "score", "score_sklearn", "predict_agree"]


def _sklearn_rows(name: str, data, quick: bool) -> list[dict]:
    sk = sklearn_logreg(data, max_iter=100 if quick else 200)
    if sk is None:
        return []
    est = estimator_arm(data, max_epochs=40 if quick else 80)
    par = (parity_metrics(est, sk) if est["inputs"] is not None
           else dict(score=est["score"]))
    # the estimator arm's gap goes in its OWN column: run.py's
    # final_gap (what benchmarks/compare.py gates on) keeps tracking
    # the paper's domesticated arm, not this differently-configured one
    rows = [dict(bench="fig3", dataset=name, machine="-",
                 impl="estimator", lanes=8,
                 epochs=est["est"].n_iter_,
                 converged=est["est"].fit_result_.converged,
                 gap_est=est["est"].fit_result_.final_gap,
                 wall_s=est["wall_s"], **par),
            dict(bench="fig3", dataset=name, machine="-",
                 impl="sklearn", lanes=1, wall_s=sk["wall_s"],
                 score=par.get("score_sklearn"))]
    return rows


def run(quick: bool = False, datasets: list[str] | None = None,
        impls: list[str] | None = None):
    if impls is None:
        impls = ["sklearn"]       # auto-arm; _sklearn_rows no-ops when
                                  # sklearn is not installed
    rows = []
    names = datasets or (["higgs"] if quick else list(DATASETS))
    for name in names:
        data = load(name)
        for pods, machine in ((2, "2node"), (4, "4node")):
            lanes = 4
            wild = fit_timed(data, SolverConfig(
                pods=1, lanes=pods * lanes, bucket=8,
                partition="dynamic", aggregation="wild"))
            dom = fit_timed(data, SolverConfig(
                pods=pods, lanes=lanes, bucket=8,
                partition="hierarchical", aggregation="adding"))
            speed = (wild["wall_s"] / dom["wall_s"]
                     if dom["converged"] else float("nan"))
            rows.append(dict(bench="fig3", dataset=name, machine=machine,
                             impl="wild", lanes=pods * lanes,
                             epochs=wild["epochs"],
                             converged=wild["converged"],
                             gap=wild["gap"], wall_s=wild["wall_s"],
                             speedup_vs_wild=1.0))
            rows.append(dict(bench="fig3", dataset=name, machine=machine,
                             impl="domesticated", lanes=pods * lanes,
                             epochs=dom["epochs"],
                             converged=dom["converged"],
                             gap=dom["gap"], wall_s=dom["wall_s"],
                             speedup_vs_wild=speed))
        if impls and "sklearn" in impls:
            rows.extend(_sklearn_rows(name, data, quick))
    return emit(rows, HEADER)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", action="append", default=None,
                    help="registry dataset name or benchmark alias; "
                         "repeatable (default: the paper's three)")
    ap.add_argument("--impl", action="append", default=None,
                    help="extra head-to-head arms; currently: sklearn")
    ap.add_argument("--full", action="store_true",
                    help="run all default datasets, not the quick subset")
    args = ap.parse_args()
    run(quick=not args.full, datasets=args.dataset, impls=args.impl)
