"""Paper Fig 3: wild vs domesticated time-to-convergence on the three
datasets x two 'machines' (2-pod and 4-pod mesh geometries)."""
from __future__ import annotations

from repro.core import SolverConfig
from .common import DATASETS, emit, fit_timed, load

HEADER = ["bench", "dataset", "machine", "impl", "lanes", "epochs",
          "converged", "wall_s", "speedup_vs_wild"]


def run(quick: bool = False):
    rows = []
    names = ["higgs"] if quick else list(DATASETS)
    for name in names:
        data = load(name)
        for pods, machine in ((2, "2node"), (4, "4node")):
            lanes = 4
            wild = fit_timed(data, SolverConfig(
                pods=1, lanes=pods * lanes, bucket=8,
                partition="dynamic", aggregation="wild"))
            dom = fit_timed(data, SolverConfig(
                pods=pods, lanes=lanes, bucket=8,
                partition="hierarchical", aggregation="adding"))
            speed = (wild["wall_s"] / dom["wall_s"]
                     if dom["converged"] else float("nan"))
            rows.append(dict(bench="fig3", dataset=name, machine=machine,
                             impl="wild", lanes=pods * lanes,
                             epochs=wild["epochs"],
                             converged=wild["converged"],
                             wall_s=wild["wall_s"], speedup_vs_wild=1.0))
            rows.append(dict(bench="fig3", dataset=name, machine=machine,
                             impl="domesticated", lanes=pods * lanes,
                             epochs=dom["epochs"],
                             converged=dom["converged"],
                             wall_s=dom["wall_s"],
                             speedup_vs_wild=speed))
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
