"""Paper Fig 2: (a) per-epoch scalability bottleneck ablation;
(b) effect of the number of static (CoCoA) partitions on convergence.

Fig 2a ablation on TPU terms: 'wild' (shared-vector sum each chunk) vs
'adding' (one psum-equivalent per epoch) vs no-shuffle (static, no
permutation work).  Timings are CPU-simulator proxies; the structural
claim (shared updates and shuffling limit scaling) is what transfers.
"""
from __future__ import annotations

from repro.core import EngineConfig
from repro.data import make_dense_classification
from .common import emit, fit_timed

HEADER = ["bench", "variant", "lanes", "epochs", "s_per_epoch",
          "wall_s", "gap", "converged"]


def run(quick: bool = False):
    rows = []
    n = 8192 if quick else 32768
    X, y = make_dense_classification(n=n, d=100, seed=1)
    data = dict(X=X, y=y, d=100, sparse=False)
    lanes = [1, 4, 16] if quick else [1, 2, 4, 8, 16, 32]

    # (a) per-epoch-time ablations
    for k in lanes:
        for variant, cfg in (
            ("wild_shared", EngineConfig.make(lanes=k, bucket=8,
                                         partition="dynamic",
                                         aggregation="wild", chunks=4)),
            ("sync_per_epoch", EngineConfig.make(lanes=k, bucket=8,
                                            partition="dynamic",
                                            aggregation="adding")),
            ("no_shuffle", EngineConfig.make(lanes=k, bucket=8,
                                        partition="static",
                                        aggregation="adding")),
        ):
            r = fit_timed(data, cfg, max_epochs=5, tol=0.0)
            rows.append(dict(bench="fig2a", variant=variant, lanes=k,
                             **{h: r[h] for h in
                                ("epochs", "s_per_epoch", "wall_s",
                                 "gap", "converged")}))

    # (b) static partitions vs convergence (1 partition per lane)
    for k in ([1, 4, 16] if quick else [1, 2, 4, 8, 16, 32, 64]):
        cfg = EngineConfig.make(lanes=k, bucket=8, partition="static")
        r = fit_timed(data, cfg, max_epochs=120)
        rows.append(dict(bench="fig2b", variant="static_partitions",
                         lanes=k,
                         **{h: r[h] for h in
                            ("epochs", "s_per_epoch", "wall_s", "gap",
                             "converged")}))
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
