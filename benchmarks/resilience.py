"""Recovery-overhead microbench (DESIGN.md S15).

Three arms over one streamed cache, same geometry, deterministic=True:

* ``clean``      — the baseline: no journal, no injector, no monitor.
* ``journaled``  — mid-epoch journal armed (`journal_every=1`, the
  most paranoid setting); measures what crash safety costs per epoch.
* ``resumed``    — the journaled run killed mid-epoch, then resumed by
  a fresh Session; wall time is crash + resume TOGETHER, so
  ``overhead_vs_clean`` is the true price of the whole incident.

The fault-free contract says ``journaled``'s overhead comes only from
its snapshot writes (no extra host syncs), and ``clean`` pays nothing
at all — CI records ``overhead_vs_clean`` in the BENCH json so a
regression that sneaks per-chunk work into the hot loop shows up as a
ratio drift, not just a slow run.
"""
from __future__ import annotations

import tempfile
import time

import jax.numpy as jnp

from repro.api import Session
from repro.core import EngineConfig
from repro.data import registry
from repro.resilience import FaultInjector, SimulatedCrash

from .common import emit

HEADER = ["bench", "variant", "epochs", "wall_s", "s_per_epoch",
          "overhead_vs_clean"]


def _cfg() -> EngineConfig:
    return EngineConfig.make(pods=2, lanes=2, bucket=8, chunks=4,
                             partition="hierarchical",
                             deterministic=True, local_solver="xla")


def _session(cache, **kw) -> Session:
    s = Session(cache, cfg=_cfg(), lam=1e-3, objective="logistic",
                streamed=True, **kw)
    s._epoch_fn(s.alpha, s.v, jnp.int32(0))    # warm the jit
    return s


def run(quick: bool = True):
    epochs = 4 if quick else 12
    n = 2048 if quick else 16384
    root = tempfile.mkdtemp(prefix="resilience-bench-")

    def mk():
        return registry.materialize("synthetic-dense", root, bucket=8,
                                    pods=2, n=n, d=128, pad_multiple=256)

    rows = []

    def _row(variant, wall, done, clean_wall=None):
        rows.append(dict(
            bench="resilience", variant=variant, epochs=done,
            wall_s=wall, s_per_epoch=wall / max(done, 1),
            overhead_vs_clean=(wall / clean_wall if clean_wall else 1.0)))

    # one throwaway fit warms every per-epoch compilation process-wide
    # so the three timed arms compare steady-state epoch cost only
    _session(mk()).fit(until=epochs, tol=0)

    s = _session(mk())
    t0 = time.perf_counter()
    s.fit(until=epochs, tol=0)
    clean = time.perf_counter() - t0
    _row("clean", clean, epochs)

    s = _session(mk(), journal_dir=root + "/journal-steady")
    t0 = time.perf_counter()
    s.fit(until=epochs, tol=0)
    _row("journaled", time.perf_counter() - t0, epochs, clean)

    kill = FaultInjector(f"kill@e{epochs // 2}c2")
    jd = root + "/journal-crash"
    s = _session(mk(), journal_dir=jd, faults=kill)
    t0 = time.perf_counter()
    try:
        s.fit(until=epochs, tol=0)
    except SimulatedCrash:
        pass
    resumed = _session(mk(), journal_dir=jd)
    resumed.fit(until=epochs, tol=0)
    _row("resumed", time.perf_counter() - t0, epochs, clean)

    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
