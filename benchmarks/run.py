"""Run every paper-figure benchmark at reduced scale + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--full] [--json BENCH_2.json]

Each module prints its own CSV block; a machine-readable summary
(per-figure runtime, row count, final duality gap) is written as JSON
for CI artifacts / perf-trajectory tracking, and the process exits
non-zero when any figure module raises so a failing benchmark fails CI.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from . import (fig1_wild_convergence, fig2_scaling_partitions,
               fig3_convergence, fig4_strong_scaling, fig5_ablations,
               fig6_solvers, resilience, roofline)

# Bump when a figure's WORKLOAD changes (new arms, different sizes):
# benchmarks/compare.py only diffs runs with equal workload versions,
# so intentional changes reset the perf baseline instead of tripping
# the >20% regression gate.  v2: fig3/fig6 sklearn+estimator arms.
# v3: fig6 sparse xla-vs-pallas arms + deduped synthetic sparse rows.
# v4: fig6 feature-sharded sparse arm (webspam-shaped, model-axis mesh).
# v5: fig6 planner arm ($REPRO_PLAN=probe geometry search, chosen
#     SolverPlan emitted under figures[...]["plans"]).
# v6: resilience arm (journal + kill-and-resume recovery overhead,
#     emitted under figures[...]["recovery"]).
# v7: fig4 streamed-mesh arm (resident vs MeshChunkFeed-streamed epochs:
#     transfer-hidden fraction, ingest bytes measured + modeled) and
#     roofline t_h2d_s column.
WORKLOAD_VERSION = 7

BENCHES = [
    ("fig1_wild_convergence", fig1_wild_convergence),
    ("fig2_scaling_partitions", fig2_scaling_partitions),
    ("fig3_convergence", fig3_convergence),
    ("fig4_strong_scaling", fig4_strong_scaling),
    ("fig5_ablations", fig5_ablations),
    ("fig6_solvers", fig6_solvers),
    ("resilience", resilience),
    ("roofline", roofline),
]


def _final_gap(rows) -> float | None:
    gaps = [r["gap"] for r in rows
            if isinstance(r.get("gap"), float)]
    return gaps[-1] if gaps else None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-shaped sizes (slower)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default="BENCH_2.json",
                    help="summary output path ('' disables)")
    args = ap.parse_args(argv)

    total = 0
    figures: dict[str, dict] = {}
    failed: list[str] = []
    for name, mod in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        try:
            rows = mod.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            failed.append(name)
            figures[name] = {"failed": True,
                             "runtime_s": time.perf_counter() - t0}
            print(f"----- {name}: FAILED")
            continue
        dt = time.perf_counter() - t0
        total += len(rows)
        figures[name] = {"failed": False, "runtime_s": dt,
                         "rows": len(rows), "final_gap": _final_gap(rows)}
        # sklearn-parity metrics from the fig3/fig6 estimator arms go
        # into the artifact so CI can track drift across runs
        parity = [{k: r.get(k) for k in ("dataset", "impl", "solver",
                                         "score", "score_sklearn",
                                         "predict_agree")
                   if r.get(k) is not None}
                  for r in rows if r.get("predict_agree") is not None]
        if parity:
            figures[name]["parity"] = parity
        # per-solver throughput from the fig6 sparse xla/pallas arms
        # rides along too, so CI can watch examples/s + HBM bytes drift
        thr = [{k: r.get(k) for k in ("dataset", "solver",
                                      "examples_per_s", "hbm_bytes_epoch",
                                      "transfer_hidden_frac",
                                      "h2d_bytes_epoch", "h2d_bytes_model")
                if r.get(k) is not None}
               for r in rows if r.get("examples_per_s") is not None]
        if thr:
            figures[name]["throughput"] = thr
        # chosen SolverPlans from planner arms (fig6) land next to the
        # throughput records: CI tracks WHAT the planner picked (bucket,
        # chunks, route, probe seconds), not just how fast it ran
        plans = [{"dataset": r.get("dataset"), "solver": r.get("solver"),
                  "examples_per_s": r.get("examples_per_s"),
                  "plan": r["plan"]}
                 for r in rows if r.get("plan") is not None]
        if plans:
            figures[name]["plans"] = plans
        # recovery-overhead ratios from the resilience arm: CI watches
        # the fault-free hot loop stay free and resume stay ~one-epoch
        recovery = [{k: r.get(k) for k in ("variant", "wall_s",
                                           "overhead_vs_clean")}
                    for r in rows if r.get("overhead_vs_clean")
                    is not None]
        if recovery:
            figures[name]["recovery"] = recovery
        print(f"----- {name}: {len(rows)} rows in {dt:.1f}s")

    print(f"\nbenchmarks complete: {total} rows"
          + (f", {len(failed)} FAILED: {failed}" if failed else ""))
    if args.json:
        summary = {"schema": "bench-summary/v1",
                   "workload": WORKLOAD_VERSION,
                   "quick": not args.full,
                   "figures": figures, "total_rows": total,
                   "failed": failed}
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"summary JSON: {args.json}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
