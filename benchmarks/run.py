"""Run every paper-figure benchmark at reduced scale + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--full]

Each module prints its own CSV block; a summary line closes the run.
"""
from __future__ import annotations

import argparse
import time

from . import (fig1_wild_convergence, fig2_scaling_partitions,
               fig3_convergence, fig4_strong_scaling, fig5_ablations,
               fig6_solvers, roofline)

BENCHES = [
    ("fig1_wild_convergence", fig1_wild_convergence),
    ("fig2_scaling_partitions", fig2_scaling_partitions),
    ("fig3_convergence", fig3_convergence),
    ("fig4_strong_scaling", fig4_strong_scaling),
    ("fig5_ablations", fig5_ablations),
    ("fig6_solvers", fig6_solvers),
    ("roofline", roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-shaped sizes (slower)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    total = 0
    for name, mod in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        rows = mod.run(quick=not args.full)
        dt = time.perf_counter() - t0
        total += len(rows)
        print(f"----- {name}: {len(rows)} rows in {dt:.1f}s")
    print(f"\nbenchmarks complete: {total} rows")


if __name__ == "__main__":
    main()
