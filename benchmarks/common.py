"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints CSV rows (name,<fields...>) and returns them as a
list of dicts so run.py can aggregate.  Sizes are scaled down from the
paper's datasets to single-CPU budgets; the scale factor is recorded in
each row (DESIGN.md S7).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.api import Session
from repro.core import EngineConfig
from repro.data import registry

# reduced-scale materializations of registry datasets (paper: criteo
# 45M x 1M, higgs 11M x 28, epsilon 400k x 2k).  The registry records
# the real shapes; `scale` (fraction of the original n) is derived.
DATASETS = {
    "criteo": dict(registry="criteo-kaggle-sub", n=8192, d=4096),
    "higgs": dict(registry="higgs", n=16384),
    "epsilon": dict(registry="epsilon", n=4096),
}


def load(name):
    """Benchmark alias or any registry dataset name -> arrays dict."""
    opts = DATASETS.get(name, dict(registry=name))
    ds = registry.get_dataset(opts["registry"], n=opts.get("n"),
                              d=opts.get("d"))
    if ds.sparse:
        return dict(X=(ds.idx, ds.val), y=ds.y, d=ds.d, sparse=True,
                    scale=ds.scale)
    return dict(X=ds.X, y=ds.y, d=ds.d, sparse=False, scale=ds.scale)


def make_session(data, cfg: EngineConfig, *, lam=1e-3) -> Session:
    """Benchmark arrays dict -> `repro.api.Session` (the one driver)."""
    kw = dict(d=data["d"]) if data["sparse"] else {}
    return Session(data["X"], data["y"], objective="logistic", lam=lam,
                   cfg=cfg, pad=False, **kw)


def fit_timed(data, cfg: EngineConfig, *, lam=1e-3, max_epochs=80,
              tol=1e-3):
    """cfg: EngineConfig (or legacy SolverConfig; both are accepted)."""
    ses = make_session(data, cfg, lam=lam)
    # warm the jit so timings exclude compilation
    ses._epoch_fn(ses.alpha, ses.v, jnp.int32(0))
    t0 = time.perf_counter()
    res = ses.fit(max_epochs=max_epochs, tol=tol)
    wall = time.perf_counter() - t0
    return dict(epochs=res.epochs, converged=res.converged,
                diverged=res.diverged, gap=res.final_gap, wall_s=wall,
                s_per_epoch=wall / max(res.epochs, 1))


# -- sklearn head-to-head arm (fig3/fig6 `--impl sklearn`) ------------------


def to_sklearn_inputs(data):
    """Engine arrays -> sklearn layout: dense (n, d) or scipy CSR.

    Returns (X_sk, y) or None when scipy is needed but unavailable.
    """
    y = np.asarray(data["y"])
    if not data["sparse"]:
        return np.asarray(data["X"]).T, y
    try:
        from scipy import sparse as sp
    except ImportError:
        return None
    idx, val = (np.asarray(t) for t in data["X"])
    n, nnz = idx.shape
    rows = np.repeat(np.arange(n), nnz)
    mat = sp.csr_matrix((val.ravel(), (rows, idx.ravel())),
                        shape=(n, data["d"]))
    return mat, y


def sklearn_logreg(data, *, lam=1e-3, max_iter=200):
    """Fit sklearn's LogisticRegression at the EXACT same objective
    (C = 1/(lam*n), no intercept) — the paper's baseline.  Returns
    dict(wall_s, clf, X, y) or None when sklearn is not installed."""
    try:
        from sklearn.linear_model import LogisticRegression as SkLR
    except ImportError:
        return None
    inputs = to_sklearn_inputs(data)
    if inputs is None:
        return None
    X, y = inputs
    clf = SkLR(C=1.0 / (lam * y.shape[0]), fit_intercept=False,
               solver="lbfgs", max_iter=max_iter, tol=1e-6)
    t0 = time.perf_counter()
    clf.fit(X, y)
    return dict(wall_s=time.perf_counter() - t0, clf=clf, X=X, y=y)


def estimator_arm(data, *, lam=1e-3, max_epochs=80, tol=1e-4, lanes=8,
                  bucket=8):
    """Fit `repro.api.LogisticRegression` on the same workload; returns
    dict(wall_s, est, score)."""
    from repro.api import LogisticRegression

    inputs = to_sklearn_inputs(data)
    est = LogisticRegression(lam=lam, max_epochs=max_epochs, tol=tol,
                             lanes=lanes, bucket=bucket,
                             partition="dynamic",
                             n_features=data["d"])
    # sparse fits on the engine (idx, val) pair; dense reuses the
    # transpose to_sklearn_inputs already materialized
    Xfit = data["X"] if data["sparse"] else inputs[0]
    t0 = time.perf_counter()
    est.fit(Xfit, np.asarray(data["y"]))
    wall = time.perf_counter() - t0
    score = (est.score(*inputs) if inputs is not None else float("nan"))
    return dict(wall_s=wall, est=est, score=score, inputs=inputs)


def parity_metrics(est_arm, sk_arm) -> dict:
    """Agreement between our estimator and sklearn on the train set:
    the fig3/fig6 parity numbers CI uploads."""
    X, y = sk_arm["X"], sk_arm["y"]
    pr = est_arm["est"].predict(X)          # dense ndarray or scipy CSR
    ps = sk_arm["clf"].predict(X)
    return dict(score=est_arm["score"],
                score_sklearn=float(sk_arm["clf"].score(X, y)),
                predict_agree=float(np.mean(pr == ps)))


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(f"{r.get(h, ''):.6g}"
                       if isinstance(r.get(h), float)
                       else str(r.get(h, "")) for h in header))
    return rows
