"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints CSV rows (name,<fields...>) and returns them as a
list of dicts so run.py can aggregate.  Sizes are scaled down from the
paper's datasets to single-CPU budgets; the scale factor is recorded in
each row (DESIGN.md S7).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, GLMTrainer
from repro.data import (criteo_like, epsilon_like, higgs_like,
                        make_dense_classification,
                        make_sparse_classification)

# reduced-scale stand-ins (paper: criteo 45M x 1M, higgs 11M x 28,
# epsilon 400k x 2k).  scale = fraction of the original n.
DATASETS = {
    "criteo": dict(maker=lambda: criteo_like(n=8192, d=4096),
                   sparse=True, scale=8192 / 45e6),
    "higgs": dict(maker=lambda: higgs_like(n=16384),
                  sparse=False, scale=16384 / 11e6),
    "epsilon": dict(maker=lambda: epsilon_like(n=4096),
                    sparse=False, scale=4096 / 400e3),
}


def load(name):
    d = DATASETS[name]
    out = d["maker"]()
    if d["sparse"]:
        (idx, val), y, dim = out
        return dict(X=(idx, val), y=y, d=dim, sparse=True,
                    scale=d["scale"])
    X, y = out
    return dict(X=X, y=y, d=X.shape[0], sparse=False, scale=d["scale"])


def fit_timed(data, cfg: EngineConfig, *, lam=1e-3, max_epochs=80,
              tol=1e-3):
    """cfg: EngineConfig (or legacy SolverConfig; both are accepted)."""
    kw = dict(sparse=True, d=data["d"]) if data["sparse"] else {}
    tr = GLMTrainer(data["X"], data["y"], objective="logistic", lam=lam,
                    cfg=cfg, **kw)
    # warm the jit so timings exclude compilation
    tr._epoch_fn(tr.alpha, tr.v, jnp.int32(0))
    t0 = time.perf_counter()
    res = tr.fit(max_epochs=max_epochs, tol=tol)
    wall = time.perf_counter() - t0
    return dict(epochs=res.epochs, converged=res.converged,
                diverged=res.diverged, gap=res.final_gap, wall_s=wall,
                s_per_epoch=wall / max(res.epochs, 1))


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(f"{r.get(h, ''):.6g}"
                       if isinstance(r.get(h), float)
                       else str(r.get(h, "")) for h in header))
    return rows
