"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints CSV rows (name,<fields...>) and returns them as a
list of dicts so run.py can aggregate.  Sizes are scaled down from the
paper's datasets to single-CPU budgets; the scale factor is recorded in
each row (DESIGN.md S7).
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import EngineConfig, GLMTrainer
from repro.data import registry

# reduced-scale materializations of registry datasets (paper: criteo
# 45M x 1M, higgs 11M x 28, epsilon 400k x 2k).  The registry records
# the real shapes; `scale` (fraction of the original n) is derived.
DATASETS = {
    "criteo": dict(registry="criteo-kaggle-sub", n=8192, d=4096),
    "higgs": dict(registry="higgs", n=16384),
    "epsilon": dict(registry="epsilon", n=4096),
}


def load(name):
    """Benchmark alias or any registry dataset name -> arrays dict."""
    opts = DATASETS.get(name, dict(registry=name))
    ds = registry.get_dataset(opts["registry"], n=opts.get("n"),
                              d=opts.get("d"))
    if ds.sparse:
        return dict(X=(ds.idx, ds.val), y=ds.y, d=ds.d, sparse=True,
                    scale=ds.scale)
    return dict(X=ds.X, y=ds.y, d=ds.d, sparse=False, scale=ds.scale)


def fit_timed(data, cfg: EngineConfig, *, lam=1e-3, max_epochs=80,
              tol=1e-3):
    """cfg: EngineConfig (or legacy SolverConfig; both are accepted)."""
    kw = dict(sparse=True, d=data["d"]) if data["sparse"] else {}
    tr = GLMTrainer(data["X"], data["y"], objective="logistic", lam=lam,
                    cfg=cfg, **kw)
    # warm the jit so timings exclude compilation
    tr._epoch_fn(tr.alpha, tr.v, jnp.int32(0))
    t0 = time.perf_counter()
    res = tr.fit(max_epochs=max_epochs, tol=tol)
    wall = time.perf_counter() - t0
    return dict(epochs=res.epochs, converged=res.converged,
                diverged=res.diverged, gap=res.final_gap, wall_s=wall,
                s_per_epoch=wall / max(res.epochs, 1))


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(f"{r.get(h, ''):.6g}"
                       if isinstance(r.get(h), float)
                       else str(r.get(h, "")) for h in header))
    return rows
