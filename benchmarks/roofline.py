"""Roofline table: aggregates experiments/dryrun/*.json into the
EXPERIMENTS.md SRoofline table (single-pod cells; multipod rows only
prove the pod axis shards)."""
from __future__ import annotations

import json
import pathlib

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

HEADER = ["arch", "shape", "t_compute_s", "t_memory_s", "t_collective_s",
          "t_h2d_s", "bottleneck", "roofline_frac", "model_over_hlo",
          "method"]


def rows(mesh: str = "pod"):
    out = []
    for p in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok" or "roofline" not in rec:
            continue
        rl = rec["roofline"]
        out.append(dict(
            arch=rec["arch"], shape=rec["shape"],
            t_compute_s=rl["t_compute_s"], t_memory_s=rl["t_memory_s"],
            t_collective_s=rl["t_collective_s"],
            # streamed ingest time over the host link (DESIGN.md S16),
            # kept OUT of t_memory_s: the h2d link is ~50x slower than
            # HBM, so folding it in would corrupt the memory-bound
            # term.  Old dryrun records predate the field -> 0.
            t_h2d_s=rl.get("t_h2d_s", 0.0),
            bottleneck=rl["bottleneck"],
            roofline_frac=rl["t_compute_s"] / rl["step_time_lb_s"],
            model_over_hlo=rl.get("model_over_hlo", float("nan")),
            method=rec.get("counting", {}).get("method", "raw")))
    return out


def run(quick: bool = False):
    rs = rows()
    print(",".join(HEADER))
    for r in rs:
        print(",".join(f"{r[h]:.3e}" if isinstance(r[h], float)
                       else str(r[h]) for h in HEADER))
    return rs


if __name__ == "__main__":
    run()
