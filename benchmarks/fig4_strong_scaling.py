"""Paper Fig 4: strong scaling of per-epoch time with lane count.

Plus the streamed-mesh ingest arm (DESIGN.md S16): resident mesh
training vs the same epochs streamed chunk-by-chunk through
`MeshChunkFeed`'s double-buffered device_put pipeline, measuring how
much of the host->device transfer hides behind compute
(``transfer_hidden_frac``) and what it costs in examples/s.
"""
from __future__ import annotations

import time

from repro.core import SolverConfig
from .common import DATASETS, emit, fit_timed, load

HEADER = ["bench", "dataset", "lanes", "s_per_epoch", "speedup_vs_1",
          "solver", "examples_per_s", "transfer_hidden_frac",
          "ingest_wait_s", "h2d_bytes_epoch", "h2d_bytes_model"]

STREAM_LANES = 2          # data lanes for the streamed arm's mesh
STREAM_N, STREAM_D = 4096, 64
STREAM_BUCKET, STREAM_CHUNKS = 8, 4


def _streamed_mesh_rows(quick: bool) -> list[dict]:
    """Resident vs streamed epochs on a (data=2) host mesh.

    Needs >= 2 devices (the bench-smoke CI job forces host devices);
    fewer skip the arm, same convention as fig6's sharded arm.  The
    streamed row reports both the MEASURED per-epoch ingest bytes
    (`MeshChunkFeed.bytes_h2d`) and the planner's modeled quantity
    (`planner.streamed_transfer_bytes`, summed over workers) so CI
    can watch the model and the pipeline stay in agreement.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import planner
    from repro.data import make_dense_classification
    from repro.data.cache import ArrayFeed
    from repro.launch.glm import (GLMScale, make_dense_epoch,
                                  make_streamed_epoch_mesh)
    from repro.launch.mesh import make_host_mesh

    if jax.device_count() < STREAM_LANES:
        print(f"# fig4 streamed-mesh arm skipped: "
              f"{jax.device_count()} device(s) < {STREAM_LANES}")
        return []
    epochs = 2 if quick else 4
    n, d = (STREAM_N // 2, STREAM_D) if quick else (STREAM_N, STREAM_D)
    X, y = make_dense_classification(n=n, d=d, seed=4)
    X, y = np.asarray(X), np.asarray(y)
    scale = GLMScale("fig4-streamed", "dense", n=n, d=d,
                     bucket=STREAM_BUCKET, chunks=STREAM_CHUNKS,
                     lam=1e-3, compress_pod=False, deterministic=True,
                     local_solver="xla")
    mesh = make_host_mesh(pod=1, data=STREAM_LANES, model=1)
    rows = []

    # resident reference: whole dataset device-resident
    ep = jax.jit(make_dense_epoch(scale, mesh))
    st = (jnp.asarray(X), jnp.asarray(y), jnp.zeros(n), jnp.zeros(d))
    # warm epoch 0 and keep its OUTPUT state: epoch outputs carry the
    # mesh shardings, so timing from fresh inputs would pay one more
    # compile mid-loop; both arms then time epochs 1..epochs
    st = ep(*st, jnp.int32(0))
    jax.block_until_ready(st)
    t0 = time.perf_counter()
    for e in range(1, 1 + epochs):
        st = ep(*st, jnp.int32(e))
    jax.block_until_ready(st)
    wall = time.perf_counter() - t0
    rows.append(dict(bench="fig4", dataset="dense-streamed",
                     lanes=STREAM_LANES, solver="resident_mesh",
                     s_per_epoch=wall / epochs,
                     examples_per_s=n * epochs / wall))

    # streamed: chunks land through the double-buffered mesh feed
    feed = ArrayFeed(y, X=X, bucket=STREAM_BUCKET)
    stats: dict = {}
    epoch_fn = make_streamed_epoch_mesh(scale, mesh, feed, stats=stats)
    a, v = jnp.zeros(n), jnp.zeros(d)
    a, v = epoch_fn(a, v, 0)                               # warm the jit
    epoch_fn.feed.reset_stats()
    hidden, wait, t0 = [], 0.0, time.perf_counter()
    for e in range(1, 1 + epochs):
        a, v = epoch_fn(a, v, e)
        hidden.append(stats["transfer_hidden_frac"])
        wait += stats["ingest_wait_s"]
    wall = time.perf_counter() - t0
    sig = planner.WorkloadSignature(n=n, d=d, streamed=True)
    topo = planner.Topology(backend=jax.default_backend(),
                            device_count=mesh.size,
                            pods=1, lanes=STREAM_LANES)
    plan = planner.SolverPlan(solver="xla", route="xla",
                              bucket=STREAM_BUCKET, chunks=STREAM_CHUNKS,
                              nnz_multiple=8, feature_shard=False)
    rows.append(dict(
        bench="fig4", dataset="dense-streamed", lanes=STREAM_LANES,
        solver="streamed_mesh", s_per_epoch=wall / epochs,
        examples_per_s=n * epochs / wall,
        transfer_hidden_frac=float(np.mean(hidden)),
        ingest_wait_s=wait / epochs,
        h2d_bytes_epoch=epoch_fn.feed.bytes_h2d / epochs,
        h2d_bytes_model=planner.streamed_transfer_bytes(sig, topo, plan)
        * topo.workers))
    return rows


def run(quick: bool = False):
    rows = []
    names = ["higgs"] if quick else list(DATASETS)
    lanes = [1, 4, 16] if quick else [1, 2, 4, 8, 16, 32]
    for name in names:
        data = load(name)
        base = None
        for k in lanes:
            r = fit_timed(data, SolverConfig(
                pods=1, lanes=k, bucket=8, partition="dynamic"),
                max_epochs=4, tol=0.0)
            if base is None:
                base = r["s_per_epoch"]
            rows.append(dict(bench="fig4", dataset=name, lanes=k,
                             s_per_epoch=r["s_per_epoch"],
                             speedup_vs_1=base / r["s_per_epoch"]))
    rows += _streamed_mesh_rows(quick)
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
