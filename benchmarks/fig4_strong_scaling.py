"""Paper Fig 4: strong scaling of per-epoch time with lane count."""
from __future__ import annotations

from repro.core import SolverConfig
from .common import DATASETS, emit, fit_timed, load

HEADER = ["bench", "dataset", "lanes", "s_per_epoch", "speedup_vs_1"]


def run(quick: bool = False):
    rows = []
    names = ["higgs"] if quick else list(DATASETS)
    lanes = [1, 4, 16] if quick else [1, 2, 4, 8, 16, 32]
    for name in names:
        data = load(name)
        base = None
        for k in lanes:
            r = fit_timed(data, SolverConfig(
                pods=1, lanes=k, bucket=8, partition="dynamic"),
                max_epochs=4, tol=0.0)
            if base is None:
                base = r["s_per_epoch"]
            rows.append(dict(bench="fig4", dataset=name, lanes=k,
                             s_per_epoch=r["s_per_epoch"],
                             speedup_vs_1=base / r["s_per_epoch"]))
    return emit(rows, HEADER)


if __name__ == "__main__":
    run()
