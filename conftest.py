"""Make `repro` importable from this source checkout without PYTHONPATH.

src/ is prepended unconditionally, so when running pytest from the
checkout the checkout's code always wins over any installed `repro`
(tests should test the tree they sit in)."""
import pathlib
import sys

_SRC = str(pathlib.Path(__file__).resolve().parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
