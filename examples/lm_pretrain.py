"""End-to-end driver: pretrain a ~100M-param smollm-family model for a
few hundred steps on the synthetic Markov stream, with checkpointing.

    PYTHONPATH=src python examples/lm_pretrain.py [--steps 200]

This is the 'train ~100M model for a few hundred steps' deliverable at
CPU scale: real config, sharded-param init (single device here), AdamW,
deterministic restartable data, checkpoint/resume — the same train()
the production launcher uses on the 512-chip mesh.
"""
import argparse

import numpy as np

from repro.configs.base import ArchConfig
from repro.launch.train import train

# ~100M-param llama-family config.  vocab is kept small (2048) so the
# order-1 Markov stream is learnable within a few hundred CPU steps —
# with a 49k vocab the example would need far more tokens than a CPU
# session allows just to move off the uniform-loss plateau.
CFG_100M = ArchConfig(
    name="smollm-100m", family="dense",
    n_layers=16, d_model=640, n_heads=8, n_kv_heads=4, d_ff=2560,
    vocab=2048, remat=False,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_pretrain")
    args = ap.parse_args()

    n_params = CFG_100M.param_count()
    print(f"model: {CFG_100M.name}  params={n_params/1e6:.1f}M")
    _, _, losses = train(CFG_100M, steps=args.steps, batch=args.batch,
                         seq=args.seq, lr=1e-3, ckpt_dir=args.ckpt_dir,
                         ckpt_every=50)
    k = max(len(losses) // 10, 1)
    first, last = np.mean(losses[:k]), np.mean(losses[-k:])
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.05 else 'check lr/steps'})")


if __name__ == "__main__":
    main()
