"""Batched serving example: prefill + greedy decode on any registered
architecture (smoke-sized), including the enc-dec (whisper) and hybrid
(recurrentgemma) cache paths.

    PYTHONPATH=src python examples/serve_batched.py --arch xlstm-1.3b
"""
import argparse

import numpy as np

from repro.configs import get_smoke, list_archs
from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b",
                    choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    print(f"serving {cfg.name} (smoke config, batch={args.batch})")
    toks = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                 gen=args.gen)
    print("generated ids:")
    print(np.asarray(toks))


if __name__ == "__main__":
    main()
