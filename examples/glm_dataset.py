"""Train on a registry dataset through the real-data pipeline.

    PYTHONPATH=src python examples/glm_dataset.py --dataset higgs
    PYTHONPATH=src python examples/glm_dataset.py \
        --dataset criteo-kaggle-sub --streamed

Walks the pipeline end to end: registry name -> (svmlight/CSV file if
one sits under --data-dir / $REPRO_DATA_DIR, else the seeded synthetic
stand-in) -> packed bucket-tile cache (built once, mmap'd after) ->
in-memory or out-of-core streamed training.  With --verify both modes
run and the script checks they agree bitwise (deterministic engine).
"""
import argparse
import tempfile

import numpy as np

from repro.api import Session
from repro.core import EngineConfig
from repro.data import get_spec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="higgs",
                    help="registry name (higgs, epsilon, "
                         "criteo-kaggle-sub, webspam, synthetic-*)")
    ap.add_argument("--streamed", action="store_true",
                    help="train out of core through the tile cache")
    ap.add_argument("--verify", action="store_true",
                    help="run BOTH modes and check bitwise agreement")
    ap.add_argument("--cache-dir", default=None,
                    help="tile-cache directory (default: temp dir)")
    ap.add_argument("--data-dir", default=None,
                    help="directory with real <name>.svm/.csv files")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--epochs", type=int, default=30)
    args = ap.parse_args()

    spec = get_spec(args.dataset)
    print(f"dataset {spec.name}: {spec.kind}, real shape "
          f"{spec.full_n} x {spec.full_d}, objective {spec.objective}")
    print(f"  source: {spec.source}")

    cfg = EngineConfig.make(pods=2, lanes=4, bucket=8, chunks=2,
                            partition="hierarchical",
                            deterministic=args.verify)
    cache_dir = args.cache_dir or tempfile.mkdtemp(prefix="repro-cache-")
    ses_kw = dict(cfg=cfg, n=args.n, cache_dir=cache_dir,
                  data_dir=args.data_dir)
    fit_kw = dict(max_epochs=args.epochs, tol=1e-4, gap_every=10,
                  verbose=True)

    modes = [args.streamed] if not args.verify else [False, True]
    results = {}
    for streamed in modes:
        label = "streamed" if streamed else "in-memory"
        print(f"\n== {label} training ==")
        res = Session(args.dataset, streamed=streamed, **ses_kw).fit(
            **fit_kw)
        print(f"{label}: epochs={res.epochs} converged={res.converged} "
              f"gap={res.final_gap:.3e} wall={res.wall_time:.2f}s")
        results[streamed] = res

    if args.verify:
        same = (np.array_equal(results[False].v, results[True].v)
                and np.array_equal(results[False].alpha,
                                   results[True].alpha))
        print(f"\nstreamed == in-memory bitwise: {same}")


if __name__ == "__main__":
    main()
