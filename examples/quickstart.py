"""Quickstart: train the paper's solver through the sklearn-style API.

    PYTHONPATH=src python examples/quickstart.py

Walks the public `repro.api` surface end to end: estimator fit/predict/
score (drop-in sklearn shape), the Session underneath for epoch-level
control + callbacks, and the wild-vs-domesticated contrast the paper
is about.
"""

import numpy as np

from repro.api import (EarlyStopping, GapLogger, LogisticRegression,
                       Session)
from repro.core import EngineConfig
from repro.data import make_dense_classification


def main() -> None:
    # 16k examples x 100 dense features (the paper's Fig-1 shape).
    # Estimators speak sklearn layout: X (n_samples, n_features).
    Xcol, y = make_dense_classification(n=16_384, d=100, seed=0)
    X = np.asarray(Xcol).T

    print("== sklearn-style estimator (sequential baseline) ==")
    clf = LogisticRegression(lam=1e-3, bucket=8, max_epochs=40, tol=1e-4)
    clf.fit(X, y)
    print(f"epochs={clf.n_iter_} gap={clf.fit_result_.final_gap:.2e} "
          f"train-acc={clf.score(X, y):.4f}")
    print(f"proba[0]={clf.predict_proba(X[:1])[0]}")

    print("\n== domesticated parallel (2 pods x 8 lanes, dynamic) ==")
    par = LogisticRegression(lam=1e-3, bucket=8, pods=2, lanes=8,
                             partition="hierarchical",
                             aggregation="adding", max_epochs=60,
                             tol=1e-4)
    par.fit(X, y)
    print(f"epochs={par.n_iter_} gap={par.fit_result_.final_gap:.2e} "
          f"train-acc={par.score(X, y):.4f}")

    print("\n== Session: epoch-level control + callbacks ==")
    cfg = EngineConfig.make(pods=2, lanes=8, bucket=8,
                            partition="hierarchical")
    s = Session((Xcol, y), objective="logistic", lam=1e-3, cfg=cfg)
    rec = s.epoch()                       # run exactly ONE epoch
    print(f"one epoch: rel_change={rec['rel_change']:.3e}")
    res = s.fit(until=60, tol=0.0, callbacks=[
        GapLogger(every=10),
        EarlyStopping(monitor="gap", threshold=1e-4),   # certificate stop
    ])
    print(f"stopped at epoch {res.epochs} with gap={res.final_gap:.2e}")

    print("\n== 'wild' parallel (16 lock-free lanes) ==")
    wild = LogisticRegression(lam=1e-3, bucket=8, lanes=16,
                              partition="dynamic", aggregation="wild",
                              max_epochs=40, tol=1e-4)
    wild.fit(X, y)
    print(f"epochs={wild.n_iter_} "
          f"converged={wild.fit_result_.converged} "
          f"gap={wild.fit_result_.final_gap:.2e}"
          "  <- the paper's Fig-1 pathology")


if __name__ == "__main__":
    main()
