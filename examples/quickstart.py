"""Quickstart: train a logistic-regression GLM with the paper's solver.

    PYTHONPATH=src python examples/quickstart.py

Walks the public API end to end: synthetic data -> SolverConfig (the
paper's knobs) -> GLMTrainer -> duality-gap-certified solution, and
shows the wild-vs-domesticated contrast the paper is about.
"""

from repro.core import GLMTrainer, SolverConfig
from repro.data import make_dense_classification


def main() -> None:
    # 16k examples x 100 dense features (the paper's Fig-1 shape)
    X, y = make_dense_classification(n=16_384, d=100, seed=0)

    print("== sequential baseline ==")
    tr = GLMTrainer(X, y, objective="logistic", lam=1e-3,
                    cfg=SolverConfig(bucket=8))
    res = tr.fit(max_epochs=40, tol=1e-4, verbose=True)
    print(f"epochs={res.epochs} gap={res.final_gap:.2e} "
          f"wall={res.wall_time:.2f}s")

    print("\n== domesticated parallel (2 pods x 8 lanes, dynamic) ==")
    cfg = SolverConfig(pods=2, lanes=8, bucket=8,
                       partition="hierarchical", aggregation="adding")
    tr2 = GLMTrainer(X, y, objective="logistic", lam=1e-3, cfg=cfg)
    res2 = tr2.fit(max_epochs=60, tol=1e-4, verbose=True)
    print(f"epochs={res2.epochs} gap={res2.final_gap:.2e} "
          f"wall={res2.wall_time:.2f}s")

    print("\n== 'wild' parallel (16 lock-free lanes) ==")
    cfg3 = SolverConfig(pods=1, lanes=16, bucket=8,
                        partition="dynamic", aggregation="wild")
    tr3 = GLMTrainer(X, y, objective="logistic", lam=1e-3, cfg=cfg3)
    res3 = tr3.fit(max_epochs=40, tol=1e-4)
    print(f"epochs={res3.epochs} converged={res3.converged} "
          f"gap={res3.final_gap:.2e}  <- the paper's Fig-1 pathology")


if __name__ == "__main__":
    main()
