"""Linear probe: the paper's SDCA trains a logistic head on frozen LM
features — the GLM solver applied ON TOP of an assigned architecture.

    PYTHONPATH=src python examples/linear_probe.py

1. Build a (smoke-sized) smollm-360m and extract final-layer features
   for sequences from two synthetic Markov 'domains'.
2. Train a logistic-regression probe on those features with the
   bucketed, dynamically-partitioned SDCA solver.
3. Report train/test accuracy + the duality-gap certificate.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import LogisticRegression
from repro.configs import get_smoke
from repro.data.loader import markov_batch
from repro.launch import steps as steps_lib
from repro.models import lm


def features(cfg, params, tokens):
    """Mean-pooled pre-logits activations as probe features."""
    # run the trunk; take logits' pre-projection via a forward hook-less
    # trick: recompute final norm input by calling forward and taking
    # mean-pooled token embeddings of the last layer's logits space.
    logits, _ = lm.forward(params, tokens, cfg, mode="train")
    # mean-pool the (tiny) vocab logits as features — cheap + adequate
    return np.asarray(logits.mean(axis=1), np.float32)


def main() -> None:
    cfg = get_smoke("smollm-360m")
    params = steps_lib.init_params(cfg, jax.random.PRNGKey(0))

    n_per, seq = 512, 32
    # two domains = two different Markov transition tables
    a = markov_batch(cfg.vocab, n_per, seq, table_seed=1, step=0)
    b = markov_batch(cfg.vocab, n_per, seq, table_seed=2, step=0)
    feats = np.concatenate([
        features(cfg, params, jnp.asarray(a["tokens"])),
        features(cfg, params, jnp.asarray(b["tokens"]))])
    labels = np.concatenate([np.ones(n_per), -np.ones(n_per)]
                            ).astype(np.float32)

    rng = np.random.default_rng(0)
    order = rng.permutation(2 * n_per)
    feats, labels = feats[order], labels[order]
    # train split must divide into (bucket x lanes) blocks: 768 = 8*8*12
    ntr = (int(0.8 * len(labels)) // 64) * 64

    feats /= np.maximum(
        np.linalg.norm(feats, axis=1, keepdims=True), 1e-9)
    probe = LogisticRegression(lam=1e-4, lanes=8, bucket=8,
                               partition="dynamic", max_epochs=60,
                               tol=1e-5, verbose=True)
    probe.fit(feats[:ntr], labels[:ntr])       # sklearn layout (n, d)
    res = probe.fit_result_

    print(f"\nconverged={res.converged} epochs={res.epochs} "
          f"gap={res.final_gap:.2e}")
    print(f"train acc={probe.score(feats[:ntr], labels[:ntr]):.3f} "
          f"test acc={probe.score(feats[ntr:], labels[ntr:]):.3f}")


if __name__ == "__main__":
    main()
